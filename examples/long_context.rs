//! Long-context retrieval under compression: a passkey planted early in a
//! long document must survive winnowing of the sparse cache (LongBench
//! analogue, native-model path so every policy is comparable).
//!
//!   cargo run --release --example long_context

use swan::eval::tasks::{Task, TaskKind};
use swan::eval::Harness;
use swan::kvcache::PolicyKind;
use swan::model::{SwanModel, WeightFile};
use swan::sparse::StorageMode;
use swan::swan::projection::ProjectionVariant;

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join("weights_swan-nano-gqa.bin"))?;
    let model = SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?;
    let mut h = Harness::new(&model);

    let task = Task { kind: TaskKind::Passkey { distance: 260 }, n_cases: 8, seed: 3 };
    println!("passkey retrieval across ~260 chars of filler, 8 cases:\n");
    println!("{:<40} {:>9} {:>14}", "policy", "accuracy", "cache ratio");
    for policy in [
        PolicyKind::Dense,
        PolicyKind::Swan { k_active: 48, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 32, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 32, buffer: 64, mode: StorageMode::F8 },
        PolicyKind::Swan { k_active: 16, buffer: 64, mode: StorageMode::F8 },
        PolicyKind::Swan { k_active: 32, buffer: 0, mode: StorageMode::F16 },
        PolicyKind::Streaming { sinks: 4, window: 64 },
        PolicyKind::H2O { budget: 128, recent: 64 },
        PolicyKind::Kivi { bits: 4, residual: 64 },
    ] {
        let r = h.run_task(&task, policy);
        println!("{:<40} {:>9.3} {:>14.3}", r.policy, r.accuracy, r.compression_ratio);
    }
    println!(
        "\nNote how token-eviction baselines (streaming/H2O at tight budgets) lose\n\
         the passkey permanently, while SWAN keeps partial information for every\n\
         token (the paper's central qualitative claim)."
    );
    Ok(())
}
