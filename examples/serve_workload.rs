//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): load the trained swan-nano
//! model through the AOT/PJRT serving stack, serve a batch of concurrent
//! requests with continuous batching, and report latency, throughput and
//! KV-memory savings for SWAN vs the dense serving baseline.
//!
//!   cargo run --release --example serve_workload

use swan::config::ServeConfig;
use swan::coordinator::Engine;
use swan::eval::corpus;
use swan::sparse::StorageMode;
use swan::util::Pcg64;

fn workload(engine: &mut Engine, n: usize, max_new: usize) -> anyhow::Result<()> {
    let mut rng = Pcg64::new(7);
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let prompt = format!(
            "{}the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 200),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        engine.submit_text(&prompt, max_new);
    }
    let responses = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let decoded: usize = responses.iter().map(|r| r.stats.decode_steps).sum();
    println!(
        "  {} requests in {wall:.2}s  |  aggregate {:.1} decode tok/s",
        responses.len(),
        decoded as f64 / wall
    );
    let mut lat: Vec<f64> = responses
        .iter()
        .map(|r| (r.stats.prefill_time + r.stats.decode_time).as_secs_f64() * 1e3)
        .collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "  request latency: p50 {:.1} ms, p95 {:.1} ms",
        swan::util::stats::percentile(&lat, 50.0),
        swan::util::stats::percentile(&lat, 95.0)
    );
    let saving: f64 =
        responses.iter().map(|r| r.stats.memory_saving()).sum::<f64>() / responses.len() as f64;
    println!("  mean KV-cache saving vs dense: {:.1}%", saving * 100.0);
    let sample = &responses[0];
    println!("  sample output: {:?}", &sample.text[..sample.text.len().min(60)]);
    println!("{}", engine.metrics.snapshot());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    println!("== dense serving baseline ==");
    let mut dense = Engine::new(&dir, ServeConfig { dense_baseline: true, ..Default::default() })?;
    dense.warmup()?;
    workload(&mut dense, 8, 32)?;

    println!("\n== SWAN serving (k_active=32, 16-bit, bt=64) ==");
    let mut sw = Engine::new(
        &dir,
        ServeConfig { k_active: 32, mode: StorageMode::F16, ..Default::default() },
    )?;
    sw.warmup()?;
    workload(&mut sw, 8, 32)?;

    println!("\n== SWAN serving (k_active=16, 8-bit — aggressive) ==");
    let mut sw8 = Engine::new(
        &dir,
        ServeConfig { k_active: 16, mode: StorageMode::F8, ..Default::default() },
    )?;
    sw8.warmup()?;
    workload(&mut sw8, 8, 32)?;
    Ok(())
}
