//! Quickstart: load the trained swan-nano model, run a few SWAN-compressed
//! generations next to the dense baseline, and print memory savings.
//!
//!   cargo run --release --example quickstart

use swan::eval::tasks::{Task, TaskKind};
use swan::eval::Harness;
use swan::kvcache::PolicyKind;
use swan::model::{SwanModel, WeightFile};
use swan::sparse::StorageMode;
use swan::swan::projection::ProjectionVariant;

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join("weights_swan-nano-gqa.bin"))?;
    let model = SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?;
    println!("loaded {} ({} layers, {} q heads / {} kv heads, d_h={})",
        model.cfg.name, model.cfg.n_layers, model.cfg.n_q_heads,
        model.cfg.n_kv_heads, model.cfg.d_head);

    let mut h = Harness::new(&model);
    let tasks = [
        Task { kind: TaskKind::Arith { steps: 4 }, n_cases: 10, seed: 1 },
        Task { kind: TaskKind::Passkey { distance: 120 }, n_cases: 10, seed: 2 },
        Task { kind: TaskKind::FactRecall { distance: 100 }, n_cases: 10, seed: 3 },
        Task { kind: TaskKind::Code { clutter: 3 }, n_cases: 10, seed: 4 },
    ];
    let policies = [
        PolicyKind::Dense,
        PolicyKind::Swan { k_active: 48, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 32, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 16, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 16, buffer: 0, mode: StorageMode::F16 },
    ];
    let mut rows = Vec::new();
    for p in policies {
        for t in &tasks {
            rows.push(h.run_task(t, p));
        }
    }
    print!("{}", swan::eval::harness::format_table("quickstart: accuracy under compression", &rows));
    Ok(())
}
