//! Runtime-tunable compression (the paper's key operational claim): change
//! `k_active` on a live engine between requests and under a memory budget
//! watch the autotuner move the level.
//!
//!   cargo run --release --example runtime_tuning

use swan::config::ServeConfig;
use swan::coordinator::Engine;
use swan::sparse::StorageMode;

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    // 1. manual runtime tuning: same engine, three compression levels
    let mut engine = Engine::new(
        &dir,
        ServeConfig { k_active: 48, mode: StorageMode::F16, ..Default::default() },
    )?;
    let prompt = "fact kernel7 is 421 . the quick cache stores the hidden value . \
                  the rotated matrix maps the sparse buffer . recall kernel7 -> ";
    for k in [48usize, 32, 16] {
        engine.set_k_active(k);
        engine.submit_text(prompt, 8);
        let r = engine.run_to_completion()?.pop().unwrap();
        println!(
            "k_active={k:<3} -> {:?}  (kv saving {:.1}%, decode {:.1} tok/s)",
            r.text.trim(),
            r.stats.memory_saving() * 100.0,
            r.stats.decode_tps()
        );
    }

    // 2. autotuned under a memory budget: the tuner tightens compression
    //    as live cache bytes approach the budget
    println!("\nautotuner under a 600 KiB KV budget:");
    let mut tuned = Engine::new(
        &dir,
        ServeConfig {
            k_active: 48,
            mem_budget: 600 * 1024,
            max_batch: 4,
            ..Default::default()
        },
    )?;
    for wave in 0..3 {
        for i in 0..4 {
            tuned.submit_text(
                &format!("{prompt} and the {} token {} ", i, wave),
                24,
            );
        }
        let _ = tuned.run_to_completion()?;
        println!(
            "  wave {wave}: k_active now {} (live cache {})",
            tuned.current_k_active(),
            swan::sparse::memory::human_bytes(tuned.live_cache_bytes())
        );
    }
    Ok(())
}
