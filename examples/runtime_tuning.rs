//! Runtime-tunable compression (the paper's key operational claim): change
//! `k_active` on a live engine between requests, override it **per
//! request** through `GenParams::k_active` (requests at different
//! compression levels co-batch on one engine), and under a memory budget
//! watch the autotuner move the level.
//!
//!   cargo run --release --example runtime_tuning

use swan::api::GenParams;
use swan::config::ServeConfig;
use swan::coordinator::{Engine, Request};
use swan::sparse::StorageMode;

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    // 1. manual runtime tuning: same engine, three compression levels
    let mut engine = Engine::new(
        &dir,
        ServeConfig { k_active: 48, mode: StorageMode::F16, ..Default::default() },
    )?;
    let prompt = "fact kernel7 is 421 . the quick cache stores the hidden value . \
                  the rotated matrix maps the sparse buffer . recall kernel7 -> ";
    for k in [48usize, 32, 16] {
        engine.set_k_active(k);
        engine.submit_text(prompt, 8);
        let r = engine.run_to_completion()?.pop().unwrap();
        println!(
            "k_active={k:<3} -> {:?}  (kv saving {:.1}%, decode {:.1} tok/s)",
            r.text.trim(),
            r.stats.memory_saving() * 100.0,
            r.stats.decode_tps()
        );
    }

    // 2. per-request override: the SAME engine (left pinned at its
    //    fleet level) serves one request per level concurrently — each
    //    sequence owns its own winnowed cache, so admission charges and
    //    decodes every request at its *own* k
    println!("\nper-request k override (engine stays at k_active={}):", engine.current_k_active());
    let ids: Vec<(usize, u64)> = [48usize, 32, 16]
        .into_iter()
        .map(|k| {
            let req = Request::with_params(0, prompt, GenParams::new(8).k_active(k));
            (k, engine.submit(req))
        })
        .collect();
    let mut responses = engine.run_to_completion()?;
    responses.sort_by_key(|r| r.id);
    for ((k, id), r) in ids.iter().zip(&responses) {
        assert_eq!(*id, r.id);
        println!(
            "  k={k:<3} -> {:?}  (kv saving {:.1}%)",
            r.text.trim(),
            r.stats.memory_saving() * 100.0
        );
    }

    // 3. autotuned under a memory budget: the tuner tightens compression
    //    as live cache bytes approach the budget
    println!("\nautotuner under a 600 KiB KV budget:");
    let mut tuned = Engine::new(
        &dir,
        ServeConfig {
            k_active: 48,
            mem_budget: 600 * 1024,
            max_batch: 4,
            ..Default::default()
        },
    )?;
    for wave in 0..3 {
        for i in 0..4 {
            tuned.submit_text(
                &format!("{prompt} and the {} token {} ", i, wave),
                24,
            );
        }
        let _ = tuned.run_to_completion()?;
        println!(
            "  wave {wave}: k_active now {} (live cache {})",
            tuned.current_k_active(),
            swan::sparse::memory::human_bytes(tuned.live_cache_bytes())
        );
    }
    Ok(())
}
