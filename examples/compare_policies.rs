//! Side-by-side comparison of every cache policy at a matched memory
//! budget — SWAN vs the related-work baselines (dense / H2O eviction /
//! StreamingLLM sinks / KIVI quantization).
//!
//!   cargo run --release --example compare_policies

use swan::eval::tasks::{standard_battery};
use swan::eval::Harness;
use swan::kvcache::PolicyKind;
use swan::model::{SwanModel, WeightFile};
use swan::sparse::StorageMode;
use swan::swan::projection::ProjectionVariant;

fn main() -> anyhow::Result<()> {
    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join("weights_swan-nano-gqa.bin"))?;
    let model = SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?;
    let mut h = Harness::new(&model);

    // Policies roughly matched near ~50-60% of dense memory on ~200-token
    // histories (measured ratio is reported per row).
    let policies = [
        PolicyKind::Dense,
        PolicyKind::Swan { k_active: 32, buffer: 64, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 48, buffer: 64, mode: StorageMode::F8 },
        PolicyKind::H2O { budget: 128, recent: 64 },
        PolicyKind::Streaming { sinks: 4, window: 124 },
        PolicyKind::Kivi { bits: 8, residual: 64 },
    ];
    let tasks = standard_battery(8, 17);
    let mut rows = Vec::new();
    for p in policies {
        for t in &tasks {
            rows.push(h.run_task(t, p));
        }
    }
    print!(
        "{}",
        swan::eval::harness::format_table("policy comparison at matched memory", &rows)
    );

    // per-policy averages
    println!("\naverages:");
    for p in policies {
        let label = p.label();
        let sel: Vec<&swan::eval::EvalResult> =
            rows.iter().filter(|r| r.policy == label).collect();
        let acc = sel.iter().map(|r| r.accuracy).sum::<f64>() / sel.len() as f64;
        let ratio = sel.iter().map(|r| r.compression_ratio).sum::<f64>() / sel.len() as f64;
        println!("  {label:<36} acc {acc:.3} @ ratio {ratio:.3}");
    }
    Ok(())
}
