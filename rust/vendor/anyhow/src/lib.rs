//! Minimal offline shim of the `anyhow` crate.
//!
//! The sandbox has no crates.io access, so this in-tree crate provides the
//! subset of anyhow's API that the swan crate uses: the [`Error`] type
//! (with context chaining), the [`Result`] alias, the [`Context`]
//! extension trait for `Result` and `Option`, and the `anyhow!` / `bail!`
//! / `ensure!` macros.  Like the real crate, `Error` deliberately does
//! *not* implement `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion to coexist with the reflexive
//! `From<Error>` impl.

use std::fmt;

/// Error type: a message plus an optional chain of causes (each stored as
/// a formatted string — this shim never needs to downcast).
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        let mut chain = vec![self.msg];
        chain.extend(self.chain);
        Error { msg: ctx.to_string(), chain }
    }

    /// The context/cause messages below the top-level one, outermost
    /// first (mirrors `anyhow::Error::chain` skipping `self`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            // `{:#}` prints the whole chain on one line, like anyhow
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.chain.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::Error::msg(concat!("condition failed: ", stringify!($cond))).into(),
            );
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn from_std_error_and_context() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "missing file");
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading weights").unwrap_err();
        assert_eq!(format!("{e}"), "loading weights");
        assert_eq!(format!("{e:#}"), "loading weights: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("bucket {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "bucket 3");
        assert_eq!(Some(1u32).context("x").unwrap(), 1);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky");
        let name = "k";
        let e = anyhow!("--{name}: bad");
        assert_eq!(format!("{e}"), "--k: bad");
    }
}
