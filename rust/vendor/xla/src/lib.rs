//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libxla/PJRT, which cannot be built in this
//! sandbox.  This stub reproduces the API surface the swan crate compiles
//! against; every runtime entry point returns [`Error`] with an
//! "unavailable" message.  Because `ArtifactStore::load` already gates the
//! serving stack on `artifacts/manifest.json`, and the PJRT integration
//! tests/benches skip when artifacts are absent, the stub degrades the
//! repo to exactly the no-artifacts behavior: the rust-native model path,
//! the batched decode subsystem and all unit/property tests run fully.

use std::fmt;

/// Error raised by every stubbed PJRT call.
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error { msg: format!("PJRT unavailable in this build (stubbed xla crate): {what}") }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a literal/buffer can carry (subset + catch-all).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F16,
    F32,
    F64,
}

/// Shape of a (non-tuple) literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host-side literal value (stub: carries no data).
#[derive(Clone, Debug)]
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice (stub: shape/data dropped).
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Single-element tuple accessor.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready to compile (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with device-resident buffers.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }

    /// Execute with host literals.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle (stub: construction fails).
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu — build against the real xla crate to serve AOT graphs"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
    }

    #[test]
    fn literal_surface_compiles_and_errors() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_err());
        assert!(l.array_shape().is_err());
        assert!(l.to_vec::<f32>().is_err());
    }
}
