//! Minimal offline shim of the `log` crate facade.
//!
//! No pluggable logger registry — records go straight to stderr with a
//! level prefix.  `SWAN_LOG=off` silences everything, `SWAN_LOG=debug`
//! (or `trace`) enables the verbose levels; the default shows
//! error/warn/info, matching how the serving stack used env_logger-less
//! logging before.

/// Log levels, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Maximum level currently enabled (driven by `SWAN_LOG`, read once).
pub fn max_level() -> Level {
    static LEVEL: std::sync::OnceLock<Level> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(|| match std::env::var("SWAN_LOG").ok().as_deref() {
        Some("off") | Some("none") => Level::Error, // errors always print
        Some("trace") => Level::Trace,
        Some("debug") => Level::Debug,
        _ => Level::Info,
    })
}

/// Emit one record (used by the macros; not part of the real log API).
pub fn __emit(level: Level, args: std::fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("[{:<5}] {}", level.as_str(), args);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Trace);
    }

    #[test]
    fn macros_format() {
        // smoke: must not panic, and must accept format captures
        let x = 3;
        warn!("value {x} out of range");
        error!("{}: {}", "ctx", 7);
        info!("plain");
        debug!("dbg {x}");
        trace!("trc");
    }
}
