//! Fixture self-tests: at least one true-positive and one
//! true-negative per rule, plus the annotation grammar.  Each fixture
//! is a tiny in-memory source tree fed through the same
//! `analyze` entry point `rust/tests/lint_clean.rs` uses, so these
//! tests pin the analyzer's sensitivity *and* its precision.

use swan_lint::{analyze, Finding, Model};

fn run(files: &[(&str, &str)]) -> Vec<Finding> {
    analyze(&Model::from_sources(files), None)
}

fn run_with_readme(files: &[(&str, &str)], readme: &str) -> Vec<Finding> {
    analyze(&Model::from_sources(files), Some(readme))
}

fn rules(fs: &[Finding]) -> Vec<&str> {
    fs.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------- rule 1

#[test]
fn panic_in_supervised_scope_is_flagged() {
    let fs = run(&[(
        "shard/worker.rs",
        "pub fn go(x: Option<u32>) -> u32 { x.unwrap() }",
    )]);
    assert_eq!(rules(&fs), ["panic"], "{fs:?}");
}

#[test]
fn panic_outside_supervised_scope_is_not_flagged() {
    let fs = run(&[(
        "util/worker.rs",
        "pub fn go(x: Option<u32>) -> u32 { x.unwrap() }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn annotated_panic_is_allowed() {
    let fs = run(&[(
        "shard/worker.rs",
        "pub fn go(x: Option<u32>) -> u32 {\n\
         // lint: allow(panic, \"fixture: input is pre-validated\")\n\
         x.unwrap()\n\
         }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn direct_indexing_in_supervised_scope_is_flagged() {
    let fs = run(&[(
        "pool/table.rs",
        "pub fn head(a: &[u32]) -> u32 { a[0] }",
    )]);
    assert_eq!(rules(&fs), ["indexing"], "{fs:?}");
}

#[test]
fn range_slicing_is_not_flagged() {
    let fs = run(&[(
        "pool/table.rs",
        "pub fn mid(a: &[u32]) -> &[u32] { &a[1..3] }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

// ---------------------------------------------------------------- rule 2

#[test]
fn lock_order_cycle_is_flagged() {
    let fs = run(&[(
        "sync/pair.rs",
        "pub struct S { a: std::sync::Mutex<u32>, b: std::sync::Mutex<u32> }\n\
         impl S {\n\
         pub fn ab(&self) { let ga = lock_recover(&self.a); \
         let gb = lock_recover(&self.b); drop(gb); drop(ga); }\n\
         pub fn ba(&self) { let gb = lock_recover(&self.b); \
         let ga = lock_recover(&self.a); drop(ga); drop(gb); }\n\
         }",
    )]);
    assert_eq!(rules(&fs), ["lock_order"], "{fs:?}");
    assert!(fs[0].msg.contains("cycle"), "{fs:?}");
}

#[test]
fn consistent_lock_order_is_not_flagged() {
    let fs = run(&[(
        "sync/pair.rs",
        "pub struct S { a: std::sync::Mutex<u32>, b: std::sync::Mutex<u32> }\n\
         impl S {\n\
         pub fn ab(&self) { let ga = lock_recover(&self.a); \
         let gb = lock_recover(&self.b); drop(gb); drop(ga); }\n\
         pub fn ab2(&self) { let ga = lock_recover(&self.a); \
         let gb = lock_recover(&self.b); drop(gb); drop(ga); }\n\
         }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn reentrant_acquisition_is_a_self_deadlock() {
    let fs = run(&[(
        "sync/reent.rs",
        "pub struct S { a: std::sync::Mutex<u32> }\n\
         impl S {\n\
         pub fn twice(&self) { let g1 = lock_recover(&self.a); \
         let g2 = lock_recover(&self.a); drop(g2); drop(g1); }\n\
         }",
    )]);
    assert_eq!(rules(&fs), ["lock_order"], "{fs:?}");
    assert!(fs[0].msg.contains("self-deadlock"), "{fs:?}");
}

#[test]
fn lock_unwrap_is_flagged_anywhere() {
    let fs = run(&[(
        "net/conn.rs",
        "pub fn peek(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }",
    )]);
    assert_eq!(rules(&fs), ["lock_unwrap"], "{fs:?}");
}

#[test]
fn lock_recover_spelling_is_not_flagged() {
    let fs = run(&[(
        "net/conn.rs",
        "pub fn peek(m: &std::sync::Mutex<u32>) -> u32 { *lock_recover(m) }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn decode_path_reaching_registration_mutex_is_flagged() {
    let fs = run(&[
        (
            "obs/registry.rs",
            "pub struct Registry { series: std::sync::Mutex<u32> }\n\
             impl Registry {\n\
             pub fn register(&self) -> u32 { let g = lock_recover(&self.series); *g }\n\
             }",
        ),
        (
            "model/transformer.rs",
            "pub fn decode_step_batch(r: &Registry) { r.register(); }",
        ),
    ]);
    assert_eq!(rules(&fs), ["lock_order"], "{fs:?}");
    assert!(fs[0].msg.contains("registration mutex"), "{fs:?}");
}

// ---------------------------------------------------------------- rule 3

#[test]
fn mixed_atomic_orderings_on_one_field_are_flagged() {
    let fs = run(&[(
        "obs/counter.rs",
        "use std::sync::atomic::{AtomicUsize, Ordering};\n\
         pub struct S { head: AtomicUsize }\n\
         impl S {\n\
         pub fn put(&self) { self.head.store(1, Ordering::Release); }\n\
         pub fn get(&self) -> usize { self.head.load(Ordering::Relaxed) }\n\
         }",
    )]);
    assert_eq!(rules(&fs), ["atomic"], "{fs:?}");
    assert!(fs[0].msg.contains("mixed orderings"), "{fs:?}");
}

#[test]
fn uniform_atomic_orderings_are_not_flagged() {
    let fs = run(&[(
        "obs/counter.rs",
        "use std::sync::atomic::{AtomicUsize, Ordering};\n\
         pub struct S { head: AtomicUsize }\n\
         impl S {\n\
         pub fn put(&self) { self.head.store(1, Ordering::Relaxed); }\n\
         pub fn get(&self) -> usize { self.head.load(Ordering::Relaxed) }\n\
         }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn relaxed_store_to_declared_handoff_field_is_flagged() {
    let fs = run(&[(
        "obs/flag.rs",
        "// ordering: handoff(ready)\n\
         use std::sync::atomic::{AtomicBool, Ordering};\n\
         pub struct S { ready: AtomicBool }\n\
         impl S {\n\
         pub fn publish(&self) { self.ready.store(true, Ordering::Relaxed); }\n\
         }",
    )]);
    assert_eq!(rules(&fs), ["atomic"], "{fs:?}");
    assert!(fs[0].msg.contains("handoff"), "{fs:?}");
}

#[test]
fn release_store_to_handoff_field_is_not_flagged() {
    let fs = run(&[(
        "obs/flag.rs",
        "// ordering: handoff(ready)\n\
         use std::sync::atomic::{AtomicBool, Ordering};\n\
         pub struct S { ready: AtomicBool }\n\
         impl S {\n\
         pub fn publish(&self) { self.ready.store(true, Ordering::Release); }\n\
         }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

// ---------------------------------------------------------------- rule 4

#[test]
fn allocation_reachable_from_decode_root_is_flagged() {
    let fs = run(&[(
        "model/step.rs",
        "pub fn decode_step_batch() -> Vec<u32> { helper() }\n\
         fn helper() -> Vec<u32> { Vec::new() }",
    )]);
    assert_eq!(rules(&fs), ["hot_alloc"], "{fs:?}");
}

#[test]
fn allocation_off_the_decode_path_is_not_flagged() {
    let fs = run(&[(
        "model/step.rs",
        "pub fn setup() -> Vec<u32> { Vec::new() }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn annotated_hot_allocation_is_allowed() {
    let fs = run(&[(
        "model/step.rs",
        "pub fn decode_step_batch() -> Vec<u32> {\n\
         // lint: allow(hot_alloc, \"fixture: empty Vec::new() does not allocate\")\n\
         Vec::new()\n\
         }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}

// ---------------------------------------------------------------- rule 5

const PROTO_GEN_PING: &str = "pub fn parse_line(line: &str) -> u32 {\n\
     match line {\n\
     \"GEN\" => 1,\n\
     \"PING\" => 2,\n\
     _ => 0,\n\
     }\n\
     }";

#[test]
fn wire_verb_missing_from_client_is_flagged() {
    let fs = run(&[
        ("server/proto.rs", PROTO_GEN_PING),
        (
            "server/client.rs",
            "use std::io::Write;\n\
             pub fn send(w: &mut std::net::TcpStream) { writeln!(w, \"GEN 8 hi\").ok(); }",
        ),
    ]);
    assert_eq!(rules(&fs), ["wire"], "{fs:?}");
    assert!(fs[0].msg.contains("PING"), "{fs:?}");
}

#[test]
fn agreeing_wire_statements_are_not_flagged() {
    let fs = run(&[
        ("server/proto.rs", PROTO_GEN_PING),
        (
            "server/client.rs",
            "use std::io::Write;\n\
             pub fn send(w: &mut std::net::TcpStream) {\n\
             writeln!(w, \"GEN 8 hi\").ok();\n\
             writeln!(w, \"PING\").ok();\n\
             }",
        ),
    ]);
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn readme_drift_is_flagged_against_both_code_statements() {
    let readme = "# swan\n\n## Protocol v2 (wire)\n\n```\nGEN <max_new> <prompt> -> STREAM\n```\n";
    let fs = run_with_readme(
        &[
            ("server/proto.rs", PROTO_GEN_PING),
            (
                "server/client.rs",
                "use std::io::Write;\n\
                 pub fn send(w: &mut std::net::TcpStream) {\n\
                 writeln!(w, \"GEN 8 hi\").ok();\n\
                 writeln!(w, \"PING\").ok();\n\
                 }",
            ),
        ],
        readme,
    );
    // PING is missing from the README vs both the parser and the client
    assert_eq!(rules(&fs), ["wire", "wire"], "{fs:?}");
    assert!(fs.iter().all(|f| f.msg.contains("PING") && f.msg.contains("README")), "{fs:?}");
}

// ------------------------------------------------------- annotation grammar

#[test]
fn annotation_without_justification_is_a_finding() {
    let fs = run(&[(
        "util/x.rs",
        "// lint: allow(panic)\n\
         pub fn f() {}",
    )]);
    assert_eq!(rules(&fs), ["allow_grammar"], "{fs:?}");
}

#[test]
fn annotation_with_empty_justification_is_a_finding() {
    let fs = run(&[(
        "util/x.rs",
        "// lint: allow(panic, \"  \")\n\
         pub fn f() {}",
    )]);
    assert_eq!(rules(&fs), ["allow_grammar"], "{fs:?}");
}

#[test]
fn module_level_annotation_covers_the_whole_file() {
    let fs = run(&[(
        "shard/worker.rs",
        "// lint: allow(panic, \"fixture: whole-file waiver\")\n\
         pub fn a(x: Option<u32>) -> u32 { x.unwrap() }\n\
         pub fn b(x: Option<u32>) -> u32 { x.unwrap() }",
    )]);
    assert!(fs.is_empty(), "{fs:?}");
}
