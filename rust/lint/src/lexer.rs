//! A lightweight Rust lexer — just enough fidelity for the swan-lint
//! rules: identifiers, punctuation, string/char literals (content
//! dropped except for plain strings, whose unquoted text the wire rule
//! reads), numbers, and line comments (retained separately so the
//! annotation scanner can see `// lint: allow(...)` lines).
//!
//! Deliberate simplifications, safe for this codebase:
//! * lifetimes are recognised heuristically (after `'`, one char then a
//!   closing `'` means a char literal, anything else is a lifetime and
//!   is skipped entirely);
//! * numeric literals swallow an optional fraction and suffix but stop
//!   before `..` so range tokens survive;
//! * block comments nest (real Rust semantics) and are discarded.

/// Token kind. `Str` carries the *unquoted* content of plain `"…"` and
/// raw `r"…"` literals; byte strings and char literals carry empty
/// content (no rule reads them).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident(String),
    Punct(char),
    Str(String),
    Num,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// 1-based source line.
    pub line: u32,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.kind, TokKind::Ident(i) if i == s)
    }

    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(i) => Some(i),
            _ => None,
        }
    }

    pub fn punct(&self) -> Option<char> {
        match self.kind {
            TokKind::Punct(c) => Some(c),
            _ => None,
        }
    }

    pub fn str_content(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// One retained `//` comment (block comments are discarded — the
/// annotation grammar is line-comment only).
#[derive(Clone, Debug)]
pub struct Comment {
    /// Text after the `//`, untrimmed.
    pub text: String,
    pub line: u32,
}

pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Tokenize `src`. Never fails: unrecognised bytes become `Punct`.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let n = b.len();

    // Count newlines in b[start..end) into `line`.
    macro_rules! bump_lines {
        ($start:expr, $end:expr) => {
            line += b[$start..$end].iter().filter(|&&ch| ch == '\n').count() as u32;
        };
    }

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == '/' && i + 1 < n {
            if b[i + 1] == '/' {
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != '\n' {
                    j += 1;
                }
                comments.push(Comment { text: b[start..j].iter().collect(), line });
                i = j;
                continue;
            }
            if b[i + 1] == '*' {
                let mut depth = 1usize;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                    } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
                continue;
            }
        }
        // raw / byte strings: r"…", r#"…"#, b"…", br#"…"#, and raw
        // idents r#ident
        if c == 'b' && i + 1 < n && b[i + 1] == '"' {
            // byte string: lex like a plain string, drop content
            let (end, _) = scan_plain_str(&b, i + 2);
            bump_lines!(i, end);
            toks.push(Tok { kind: TokKind::Str(String::new()), line });
            i = end;
            continue;
        }
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            // j: index just past the 'r'
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let hash_start = j;
            while j < n && b[j] == '#' {
                j += 1;
            }
            let hashes = j - hash_start;
            if j < n && b[j] == '"' {
                let content_start = j + 1;
                let mut p = content_start;
                let mut matched = None;
                while p < n {
                    if b[p] == '"' {
                        let mut q = p + 1;
                        let mut h = 0usize;
                        while q < n && b[q] == '#' && h < hashes {
                            q += 1;
                            h += 1;
                        }
                        if h == hashes {
                            matched = Some((p, q));
                            break;
                        }
                        p = q;
                    } else {
                        p += 1;
                    }
                }
                let (content_end, end) = matched.unwrap_or((n, n));
                let content: String = b[content_start..content_end].iter().collect();
                let start_line = line;
                bump_lines!(i, end);
                toks.push(Tok {
                    kind: TokKind::Str(if c == 'b' { String::new() } else { content }),
                    line: start_line,
                });
                i = end;
                continue;
            }
            if c == 'r' && hashes > 0 {
                // raw ident r#ident
                let mut q = j;
                while q < n && (b[q].is_alphanumeric() || b[q] == '_') {
                    q += 1;
                }
                if q > j {
                    toks.push(Tok { kind: TokKind::Ident(b[j..q].iter().collect()), line });
                    i = q;
                    continue;
                }
            }
            // plain ident starting with 'r'/'b': fall through to the
            // identifier arm below
        }
        // plain string
        if c == '"' {
            let (end, _) = scan_plain_str(&b, i + 1);
            let content: String = b[i + 1..end.saturating_sub(1).max(i + 1)].iter().collect();
            let start_line = line;
            bump_lines!(i, end);
            toks.push(Tok { kind: TokKind::Str(content), line: start_line });
            i = end;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // char escape: skip to closing quote
                let mut j = i + 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok { kind: TokKind::Str(String::new()), line });
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                toks.push(Tok { kind: TokKind::Str(String::new()), line });
                i += 3;
                continue;
            }
            // lifetime: skip the ident, emit nothing
            let mut j = i + 1;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            i = j.max(i + 1);
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let mut j = i + 1;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident(b[i..j].iter().collect()), line });
            i = j;
            continue;
        }
        // number (stop before `..` so ranges survive)
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            if j < n && b[j] == '.' && !(j + 1 < n && b[j + 1] == '.') {
                j += 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct(c), line });
        i += 1;
    }
    Lexed { toks, comments }
}

/// Scan a plain (escaped) string starting *after* the opening quote;
/// returns (index one past the closing quote, newline count).
fn scan_plain_str(b: &[char], mut j: usize) -> (usize, usize) {
    let n = b.len();
    let mut nl = 0usize;
    while j < n {
        match b[j] {
            '\\' => j += 2,
            '"' => return (j + 1, nl),
            '\n' => {
                nl += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    (n, nl)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .iter()
            .filter_map(|t| t.ident().map(|s| s.to_string()))
            .collect()
    }

    #[test]
    fn basic_tokens() {
        let l = lex("fn foo(x: usize) -> u32 { x[0] + 1.5 }");
        assert!(l.toks.iter().any(|t| t.is_ident("foo")));
        assert!(l.toks.iter().any(|t| t.punct() == Some('[')));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Num));
    }

    #[test]
    fn comments_retained_with_lines() {
        let l = lex("let a = 1;\n// lint: allow(panic, \"x\")\nlet b = 2;");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].line, 2);
        assert!(l.comments[0].text.contains("lint: allow"));
    }

    #[test]
    fn block_comments_nest_and_vanish() {
        let l = lex("a /* x /* y */ z */ b");
        let ids = idents("a /* x /* y */ z */ b");
        assert_eq!(ids, vec!["a", "b"]);
        assert!(l.comments.is_empty());
    }

    #[test]
    fn strings_keep_content_rawness_handled() {
        let l = lex(r####"let s = "GEN"; let r = r#"TRACE {id}"#;"####);
        let strs: Vec<&str> = l.toks.iter().filter_map(|t| t.str_content()).collect();
        assert_eq!(strs, vec!["GEN", "TRACE {id}"]);
    }

    #[test]
    fn escaped_quotes_and_multiline_strings() {
        let l = lex("let s = \"a\\\"b\";\nlet t = \"x\ny\";\nfin");
        let last = l.toks.last().unwrap();
        assert!(last.is_ident("fin"));
        assert_eq!(last.line, 3);
    }

    #[test]
    fn lifetimes_are_skipped_char_literals_are_not() {
        let ids = idents("fn f<'a>(x: &'a str) { let c = 'q'; let esc = '\\n'; }");
        assert!(!ids.contains(&"a".to_string()));
        let l = lex("let c = 'q';");
        assert!(l.toks.iter().any(|t| matches!(t.kind, TokKind::Str(_))));
    }

    #[test]
    fn raw_idents_and_ranges() {
        let ids = idents("let r#fn = 1; for i in 0..10 {}");
        assert!(ids.contains(&"fn".to_string()));
        let l = lex("0..10");
        let dots = l.toks.iter().filter(|t| t.punct() == Some('.')).count();
        assert_eq!(dots, 2);
    }
}
