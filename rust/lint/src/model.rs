//! Source model the rules run over: per-file token streams plus the
//! structure the lexer alone does not give — function bodies, `impl`
//! contexts, `#[cfg(test)]` / `#[test]` regions, and parsed
//! `// lint: allow(...)` annotations.

use std::fs;
use std::io;
use std::path::Path;

use crate::lexer::{lex, Comment, Tok, TokKind};

/// One analyzer finding. `rule` is the annotation key that would
/// silence it (`panic`, `indexing`, `lock_order`, ...).
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A parsed `// lint: allow(<key>, "<justification>")` annotation.
#[derive(Clone, Debug)]
pub struct Allow {
    pub key: String,
    pub line: u32,
    /// The annotation sits before the file's first token, so it covers
    /// the whole file for `key`.
    pub module_level: bool,
}

/// One `fn` with a body (trait-method signatures are not recorded).
#[derive(Clone, Debug)]
pub struct FnDef {
    pub name: String,
    /// `Some(Type)` when defined inside `impl Type` / `impl Tr for Type`.
    pub impl_ty: Option<String>,
    /// Token-index range of the body *including* both braces.
    pub body: (usize, usize),
    pub line: u32,
    /// Inside a `#[cfg(test)]` region or under `#[test]`.
    pub in_test: bool,
}

pub struct File {
    /// Path relative to the analyzed root, `/`-separated.
    pub path: String,
    /// File stem (`mod.rs` keeps the stem `mod`; rules qualify by path).
    pub stem: String,
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
    /// Token-index ranges under test-only attributes.
    pub test_ranges: Vec<(usize, usize)>,
    pub fns: Vec<FnDef>,
    pub allows: Vec<Allow>,
    /// Malformed `lint:` comments: (line, problem).
    pub bad_annotations: Vec<(u32, String)>,
}

impl File {
    pub fn parse(path: &str, src: &str) -> File {
        let lexed = lex(src);
        let stem = Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(path)
            .to_string();
        let mut f = File {
            path: path.to_string(),
            stem,
            toks: lexed.toks,
            comments: lexed.comments,
            test_ranges: Vec::new(),
            fns: Vec::new(),
            allows: Vec::new(),
            bad_annotations: Vec::new(),
        };
        f.scan_annotations();
        f.scan_test_ranges();
        f.scan_fns();
        f
    }

    /// Is token index `i` inside a test-only region?
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| i >= a && i <= b)
    }

    /// Is `key` allowed at source line `line`?  A line annotation covers
    /// its own line (trailing comment) and the line below (comment
    /// above); a module-level annotation covers the whole file.
    pub fn allowed(&self, key: &str, line: u32) -> bool {
        self.allows.iter().any(|a| {
            a.key == key && (a.module_level || a.line == line || a.line + 1 == line)
        })
    }

    /// The innermost function whose body contains token index `i`.
    pub fn enclosing_fn(&self, i: usize) -> Option<&FnDef> {
        self.fns
            .iter()
            .filter(|f| i > f.body.0 && i < f.body.1)
            .min_by_key(|f| f.body.1 - f.body.0)
    }

    fn scan_annotations(&mut self) {
        let first_tok_line = self.toks.first().map(|t| t.line).unwrap_or(u32::MAX);
        for c in &self.comments {
            let text = c.text.trim();
            // `//! lint:`-style doc text never parses here: doc comments
            // keep their leading `!`/`/` in `text` only when the source
            // had `//!`/`///`, which the trim below filters out.
            let Some(rest) = text.strip_prefix("lint:") else { continue };
            let rest = rest.trim();
            let parsed = (|| -> Result<String, String> {
                let body = rest
                    .strip_prefix("allow(")
                    .ok_or_else(|| "expected `allow(<key>, \"<justification>\")`".to_string())?;
                let body = body
                    .strip_suffix(')')
                    .ok_or_else(|| "missing closing `)`".to_string())?;
                let (key, just) = body
                    .split_once(',')
                    .ok_or_else(|| "missing `, \"<justification>\"`".to_string())?;
                let key = key.trim();
                if key.is_empty() || !key.chars().all(|ch| ch.is_ascii_lowercase() || ch == '_') {
                    return Err(format!("bad key '{key}'"));
                }
                let just = just.trim();
                let inner = just
                    .strip_prefix('"')
                    .and_then(|s| s.strip_suffix('"'))
                    .ok_or_else(|| "justification must be a quoted string".to_string())?;
                if inner.trim().is_empty() {
                    return Err("empty justification — say why the pattern is sound".to_string());
                }
                Ok(key.to_string())
            })();
            match parsed {
                Ok(key) => self.allows.push(Allow {
                    key,
                    line: c.line,
                    module_level: c.line < first_tok_line,
                }),
                Err(why) => self.bad_annotations.push((c.line, why)),
            }
        }
    }

    /// Mark brace-delimited regions under attributes that mention
    /// `test` (`#[test]`, `#[cfg(test)]`, `#[cfg(all(test, ...))]`).
    fn scan_test_ranges(&mut self) {
        let t = &self.toks;
        let mut i = 0;
        while i + 1 < t.len() {
            if t[i].punct() == Some('#') && t[i + 1].punct() == Some('[') {
                let close = match match_open(t, i + 1, '[', ']') {
                    Some(c) => c,
                    None => break,
                };
                let mentions_test = t[i + 2..close].iter().any(|x| x.is_ident("test"));
                if mentions_test {
                    // the attached item: next `{` before a `;` at depth 0
                    let mut j = close + 1;
                    let mut depth = 0i32;
                    while j < t.len() {
                        match t[j].punct() {
                            Some('(') | Some('[') => depth += 1,
                            Some(')') | Some(']') => depth -= 1,
                            Some(';') if depth == 0 => break,
                            Some('{') if depth == 0 => {
                                if let Some(end) = match_open(t, j, '{', '}') {
                                    self.test_ranges.push((i, end));
                                    i = end; // skip the whole region
                                }
                                break;
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                }
                i = close + 1;
                continue;
            }
            i += 1;
        }
    }

    fn scan_fns(&mut self) {
        let t = &self.toks;
        // impl contexts: (body_open, body_close, type name)
        let mut impls: Vec<(usize, usize, String)> = Vec::new();
        let mut i = 0;
        while i < t.len() {
            if t[i].is_ident("impl") {
                if let Some((open, ty)) = impl_header(t, i) {
                    if let Some(close) = match_open(t, open, '{', '}') {
                        impls.push((open, close, ty));
                    }
                }
            }
            i += 1;
        }

        let mut fns = Vec::new();
        let mut i = 0;
        while i + 1 < t.len() {
            if t[i].is_ident("fn") {
                if let Some(name) = t[i + 1].ident() {
                    // body: first `;` or `{` at bracket-depth 0 past the name
                    let mut j = i + 2;
                    let mut depth = 0i32;
                    let mut body = None;
                    while j < t.len() {
                        match t[j].punct() {
                            Some('(') | Some('[') => depth += 1,
                            Some(')') | Some(']') => depth -= 1,
                            Some(';') if depth <= 0 => break,
                            Some('{') if depth <= 0 => {
                                body = match_open(t, j, '{', '}').map(|c| (j, c));
                                break;
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    if let Some(body) = body {
                        let impl_ty = impls
                            .iter()
                            .filter(|&&(o, c, _)| i > o && i < c)
                            .min_by_key(|&&(o, c, _)| c - o)
                            .map(|(_, _, ty)| ty.clone());
                        fns.push(FnDef {
                            name: name.to_string(),
                            impl_ty,
                            body,
                            line: t[i].line,
                            in_test: self.in_test(i),
                        });
                    }
                }
            }
            i += 1;
        }
        self.fns = fns;
    }
}

/// Given `toks[open]` == the opening delimiter, return the index of its
/// matching closer.
pub fn match_open(toks: &[Tok], open: usize, o: char, c: char) -> Option<usize> {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.punct() {
            Some(p) if p == o => depth += 1,
            Some(p) if p == c => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse an `impl` header starting at `toks[at] == "impl"`: returns the
/// index of the body's `{` and the implemented type's name (generics
/// skipped; `impl Tr for Ty` resolves to `Ty`; stops at `where`).
fn impl_header(t: &[Tok], at: usize) -> Option<(usize, String)> {
    let mut angle = 0i32;
    let mut last_ident: Option<String> = None;
    let mut in_where = false;
    let mut j = at + 1;
    while j < t.len() {
        match &t[j].kind {
            TokKind::Punct('<') => angle += 1,
            TokKind::Punct('>') => angle -= 1,
            TokKind::Punct('{') if angle <= 0 => return Some((j, last_ident?)),
            TokKind::Punct(';') if angle <= 0 => return None,
            TokKind::Ident(id) if angle <= 0 && !in_where => {
                if id == "for" {
                    last_ident = None; // names after `for` win
                } else if id == "where" {
                    in_where = true;
                } else if id != "dyn" && id != "mut" && id != "const" {
                    last_ident = Some(id.clone());
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

pub struct Model {
    pub files: Vec<File>,
}

impl Model {
    /// Build a model from in-memory sources (fixture tests).
    pub fn from_sources(sources: &[(&str, &str)]) -> Model {
        Model {
            files: sources.iter().map(|(p, s)| File::parse(p, s)).collect(),
        }
    }

    /// Build a model from every `.rs` file under `root`, recursively,
    /// in sorted order (deterministic findings).
    pub fn load(root: &Path) -> io::Result<Model> {
        let mut paths = Vec::new();
        walk(root, root, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        for rel in paths {
            let src = fs::read_to_string(root.join(&rel))?;
            files.push(File::parse(&rel, &src));
        }
        Ok(Model { files })
    }

    /// All non-test functions named `name` (for call-graph edges).
    pub fn fns_named<'a>(&'a self, name: &str) -> Vec<(&'a File, &'a FnDef)> {
        let mut out = Vec::new();
        for f in &self.files {
            for d in &f.fns {
                if d.name == name && !d.in_test {
                    out.push((f, d));
                }
            }
        }
        out
    }
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(root, &p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_bodies_and_impl_types() {
        let f = File::parse(
            "x.rs",
            "struct S; impl S { fn a(&self) { b(); } }\n\
             impl Clone for S { fn clone(&self) -> S { S } }\n\
             fn free(x: [u8; 2]) {}\n\
             trait T { fn sig(&self); }",
        );
        let names: Vec<(&str, Option<&str>)> = f
            .fns
            .iter()
            .map(|d| (d.name.as_str(), d.impl_ty.as_deref()))
            .collect();
        assert_eq!(
            names,
            vec![("a", Some("S")), ("clone", Some("S")), ("free", None)]
        );
    }

    #[test]
    fn generic_impl_header() {
        let f = File::parse(
            "x.rs",
            "impl<T: Send> Wrapper<T> { fn go(&self) {} }\n\
             impl<T> From<T> for Sink<T> where T: Sized { fn from(_: T) -> Sink<T> { todo!() } }",
        );
        assert_eq!(f.fns[0].impl_ty.as_deref(), Some("Wrapper"));
        assert_eq!(f.fns[1].impl_ty.as_deref(), Some("Sink"));
    }

    #[test]
    fn test_regions_cover_mods_and_fns() {
        let f = File::parse(
            "x.rs",
            "fn prod() { x.unwrap(); }\n\
             #[cfg(test)]\nmod tests { fn helper() {} #[test] fn t() {} }",
        );
        assert!(!f.fns[0].in_test);
        assert!(f.fns.iter().filter(|d| d.in_test).count() >= 2);
    }

    #[test]
    fn cfg_test_on_use_marks_nothing() {
        let f = File::parse("x.rs", "#[cfg(test)]\nuse std::sync::Mutex;\nfn prod() {}");
        assert!(f.test_ranges.is_empty());
        assert!(!f.fns[0].in_test);
    }

    #[test]
    fn allow_parsing_line_and_module() {
        let f = File::parse(
            "x.rs",
            "//! docs\n\
             // lint: allow(indexing, \"whole file is index-checked\")\n\
             fn a() {\n\
                 // lint: allow(panic, \"bring-up only\")\n\
                 x.unwrap();\n\
             }",
        );
        assert_eq!(f.allows.len(), 2);
        assert!(f.allows[0].module_level);
        assert!(f.allowed("indexing", 5));
        assert!(f.allowed("panic", 5)); // line above
        assert!(f.allowed("panic", 4)); // trailing
        assert!(!f.allowed("panic", 6));
        assert!(f.bad_annotations.is_empty());
    }

    #[test]
    fn malformed_allows_are_reported() {
        let f = File::parse(
            "x.rs",
            "// lint: allow(panic)\n// lint: allow(panic, \"\")\n\
             // lint: silence everything\nfn a() {}",
        );
        assert_eq!(f.bad_annotations.len(), 3);
        assert!(f.allows.is_empty());
    }

    #[test]
    fn enclosing_fn_picks_innermost() {
        let f = File::parse("x.rs", "fn outer() { fn inner() { q(); } }");
        let qi = f.toks.iter().position(|t| t.is_ident("q")).unwrap();
        assert_eq!(f.enclosing_fn(qi).unwrap().name, "inner");
    }
}
