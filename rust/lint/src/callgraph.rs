//! Name-based call graph over the model — deliberately approximate
//! (no type inference), biased so approximation errors *add* edges
//! rather than drop them, except for a blocklist of ubiquitous std
//! method names whose name-match edges would be pure noise
//! (`.len()` resolving to `TraceRing::len`, and so on).

use std::collections::HashMap;

use crate::model::Model;

/// Method names so common in std that a `.name(` call is almost never
/// a call into this crate; resolving them by bare name would wire the
/// whole graph together.  Calls spelled with an explicit
/// `Type::name(...)` path still resolve precisely.
pub const UBIQUITOUS_METHODS: &[&str] = &[
    "abs", "all", "any", "and_then", "as_bytes", "as_mut", "as_ref", "as_slice", "as_str",
    "chars", "chunks", "chunks_mut", "clear", "clone", "cloned", "cmp", "collect", "contains",
    "contains_key", "copied", "count", "dedup", "drain", "entry", "enumerate", "eq", "extend",
    "fill", "filter", "filter_map", "find", "first", "flat_map", "flatten", "flush", "fmt",
    "fold", "for_each", "get", "get_mut", "get_or_insert_with", "hash", "insert", "into_iter",
    "is_empty", "is_none", "is_ok", "is_some", "iter", "iter_mut", "join", "keys", "last",
    "len", "map", "map_err", "max", "min", "next", "parse", "partial_cmp", "pop", "position",
    "push", "push_str", "remove", "reserve", "resize", "retain", "rev", "skip", "sort",
    "sort_by", "sort_by_key", "sort_unstable", "splice", "split", "split_whitespace", "starts_with",
    "sum", "swap", "take", "to_owned", "to_string", "to_vec", "trim", "truncate", "unwrap_or",
    "unwrap_or_default", "unwrap_or_else", "values", "windows", "zip",
    // atomics and channels: `.load(`, `.store(`, `.send(`, `.recv(` are
    // pervasive std calls whose names collide with crate methods
    // (Model::load, ShardHandle::send, ...)
    "load", "store", "send", "recv", "try_recv", "recv_timeout",
    // pointer arithmetic (`ptr.add(i)` in simd/) collides with
    // Counter::add; every in-crate `snapshot` is atomics-only, and the
    // name-match edges between them fabricate lock cycles
    "add", "sub", "snapshot",
];

/// Rust keywords that can directly precede `(` in expression position.
const CALLABLE_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "loop", "return", "fn", "in", "as", "move", "unsafe",
    "let", "else", "break", "continue", "where", "impl", "dyn", "ref", "mut", "box", "await",
];

/// The poison-recovery primitives: modeled as lock *acquisitions* by
/// the lock rule, never as call edges (their bodies acquire a generic
/// parameter lock that would pollute every caller's summary).
pub const RECOVER_PRIMITIVES: &[&str] =
    &["lock_recover", "read_recover", "write_recover", "wait_recover"];

pub struct CallGraph {
    /// node id -> (file index, fn index) in the model.
    pub nodes: Vec<(usize, usize)>,
    /// node id -> callee node ids.
    pub edges: Vec<Vec<usize>>,
    index: HashMap<(usize, usize), usize>,
    by_name: HashMap<String, Vec<usize>>,
}

impl CallGraph {
    pub fn build(model: &Model) -> CallGraph {
        let mut nodes = Vec::new();
        let mut index = HashMap::new();
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        for (fi, f) in model.files.iter().enumerate() {
            for (di, d) in f.fns.iter().enumerate() {
                let id = nodes.len();
                nodes.push((fi, di));
                index.insert((fi, di), id);
                if !d.in_test {
                    by_name.entry(d.name.clone()).or_default().push(id);
                }
            }
        }
        let mut g = CallGraph { nodes, edges: Vec::new(), index, by_name };
        let mut edges = vec![Vec::new(); g.nodes.len()];
        for id in 0..g.nodes.len() {
            let (fi, di) = g.nodes[id];
            let d = &model.files[fi].fns[di];
            if d.in_test {
                continue;
            }
            let (a, b) = d.body;
            let mut out = Vec::new();
            for i in a..b {
                out.extend(g.resolve_call_from(model, fi, i, Some(id)));
            }
            out.sort_unstable();
            out.dedup();
            edges[id] = out;
        }
        g.edges = edges;
        g
    }

    pub fn node(&self, fi: usize, di: usize) -> Option<usize> {
        self.index.get(&(fi, di)).copied()
    }

    /// Node ids of every non-test fn whose name is in `names`.
    pub fn roots_named(&self, names: &[&str]) -> Vec<usize> {
        let mut out = Vec::new();
        for n in names {
            if let Some(ids) = self.by_name.get(*n) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Callee node ids when `toks[i]` of file `fi` heads a call
    /// expression; empty otherwise.
    pub fn resolve_call(&self, model: &Model, fi: usize, i: usize) -> Vec<usize> {
        self.resolve_call_from(model, fi, i, None)
    }

    /// Like [`CallGraph::resolve_call`], excluding `caller` itself from
    /// method-call candidates: `h.snapshot()` inside
    /// `Registry::snapshot` must not resolve back to the caller (the
    /// commonest false self-edge of name-based resolution).
    pub fn resolve_call_from(
        &self,
        model: &Model,
        fi: usize,
        i: usize,
        caller: Option<usize>,
    ) -> Vec<usize> {
        let f = &model.files[fi];
        let t = &f.toks;
        let Some(name) = t[i].ident() else { return Vec::new() };
        if i + 1 >= t.len() || t[i + 1].punct() != Some('(') {
            return Vec::new();
        }
        if CALLABLE_KEYWORDS.contains(&name) || RECOVER_PRIMITIVES.contains(&name) {
            return Vec::new();
        }
        let prev = i.checked_sub(1).map(|p| &t[p]);
        let prev_punct = prev.and_then(|p| p.punct());
        let prev_is_fn_kw = prev.map(|p| p.is_ident("fn")).unwrap_or(false);
        if prev_is_fn_kw {
            return Vec::new();
        }
        let candidates = |pred: &dyn Fn(&crate::model::FnDef) -> bool| -> Vec<usize> {
            self.by_name
                .get(name)
                .map(|ids| {
                    ids.iter()
                        .copied()
                        .filter(|&id| {
                            let (cfi, cdi) = self.nodes[id];
                            pred(&model.files[cfi].fns[cdi])
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        if prev_punct == Some('.') {
            // method call: blocklisted std names resolve to nothing
            if UBIQUITOUS_METHODS.contains(&name) {
                return Vec::new();
            }
            let mut out = candidates(&|d| d.impl_ty.is_some());
            if let Some(caller) = caller {
                out.retain(|&id| id != caller);
            }
            // locality preference: if any candidate lives in the same
            // file as the call site, the cross-file homonyms are noise
            // (`ring.row(..)` in paged_cache.rs means the ring's `row`,
            // not `util/stats::row`)
            if out.iter().any(|&id| self.nodes[id].0 == fi) {
                out.retain(|&id| self.nodes[id].0 == fi);
            }
            return out;
        }
        if prev_punct == Some(':') && i >= 2 && t[i - 2].punct() == Some(':') {
            // path call `Qual::name(...)`
            let qual = i.checked_sub(3).and_then(|q| t[q].ident());
            let Some(qual) = qual else { return Vec::new() };
            if qual == "Self" || qual == "self" {
                return candidates(&|d| d.impl_ty.is_some());
            }
            let typed = candidates(&|d| d.impl_ty.as_deref() == Some(qual));
            if !typed.is_empty() {
                return typed;
            }
            if qual.chars().next().is_some_and(|c| c.is_lowercase()) {
                // module path — resolve by bare name
                return candidates(&|_| true);
            }
            return Vec::new(); // external type (Vec::new, Box::new, ...)
        }
        // bare call `name(...)` — free functions only
        candidates(&|d| d.impl_ty.is_none())
    }

    /// `reachable[id]` for every node reachable from `roots` (roots
    /// included).
    pub fn reachable(&self, roots: &[usize]) -> Vec<bool> {
        let mut seen = vec![false; self.nodes.len()];
        let mut stack: Vec<usize> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if seen[id] {
                continue;
            }
            seen[id] = true;
            stack.extend(self.edges[id].iter().copied().filter(|&c| !seen[c]));
        }
        seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(src: &str) -> Model {
        Model::from_sources(&[("a.rs", src)])
    }

    #[test]
    fn free_and_method_calls_resolve() {
        let m = model(
            "fn root() { helper(); thing.work(); }\n\
             fn helper() {}\n\
             struct W; impl W { fn work(&self) { leaf(); } }\n\
             fn leaf() {}",
        );
        let g = CallGraph::build(&m);
        let roots = g.roots_named(&["root"]);
        let seen = g.reachable(&roots);
        let names: Vec<&str> = seen
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(id, _)| m.files[g.nodes[id].0].fns[g.nodes[id].1].name.as_str())
            .collect();
        assert!(names.contains(&"helper"));
        assert!(names.contains(&"work"));
        assert!(names.contains(&"leaf"));
    }

    #[test]
    fn ubiquitous_method_names_do_not_resolve() {
        let m = model(
            "fn root(v: Vec<u8>) { v.len(); }\n\
             struct R; impl R { fn len(&self) { secret(); } }\n\
             fn secret() {}",
        );
        let g = CallGraph::build(&m);
        let seen = g.reachable(&g.roots_named(&["root"]));
        let hit_secret = seen
            .iter()
            .enumerate()
            .any(|(id, &s)| s && m.files[g.nodes[id].0].fns[g.nodes[id].1].name == "secret");
        assert!(!hit_secret);
    }

    #[test]
    fn typed_path_calls_resolve_precisely() {
        let m = model(
            "struct A; impl A { fn go() { x(); } }\n\
             struct B; impl B { fn go() { y(); } }\n\
             fn x() {}\nfn y() {}\n\
             fn root() { A::go(); Vec::new(); }",
        );
        let g = CallGraph::build(&m);
        let seen = g.reachable(&g.roots_named(&["root"]));
        let names: Vec<&str> = seen
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(id, _)| m.files[g.nodes[id].0].fns[g.nodes[id].1].name.as_str())
            .collect();
        assert!(names.contains(&"x"));
        assert!(!names.contains(&"y"));
    }
}
