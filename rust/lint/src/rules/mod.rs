//! The five swan-lint rules plus the annotation-grammar check.
//!
//! Every rule returns `Vec<Finding>`; a finding's `rule` field is the
//! `lint: allow(<key>, "...")` key that silences it.  The
//! annotation-grammar check closes the loop: a `lint:` comment that
//! does not parse (wrong shape, unknown form, *empty justification*)
//! is itself a finding, so an allow can never silently rot into a
//! no-op.

pub mod atomics;
pub mod hot_alloc;
pub mod locks;
pub mod panics;
pub mod wire;

use crate::model::{Finding, Model};

/// Malformed `lint:` annotations (collected at parse time).
pub fn annotation_grammar(model: &Model) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &model.files {
        for (line, why) in &f.bad_annotations {
            out.push(Finding {
                rule: "allow_grammar",
                file: f.path.clone(),
                line: *line,
                msg: format!(
                    "malformed lint annotation ({why}); expected \
                     lint: allow(<key>, \"<justification>\")"
                ),
            });
        }
    }
    out
}
