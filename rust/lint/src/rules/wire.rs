//! Rule 5 — wire-protocol drift.
//!
//! Three independent statements of protocol v2 must agree on the verb
//! set (and `SET` subcommands):
//!
//! * the server parser (`server/proto.rs`, match arms of `parse_line`),
//! * the reference client (`server/client.rs`, first word of every
//!   `writeln!` request literal),
//! * the README's fenced protocol table (first fence after the
//!   `## Protocol v2` heading).
//!
//! Every pairwise gap is a finding: a verb the server parses that the
//! client cannot speak, a documented verb the server rejects, and so
//! on.  This is the drift class PR 7/8 kept hitting by hand (METRICS
//! and TRACE landed server-side first).

use std::collections::BTreeSet;

use crate::model::{Finding, Model};

#[derive(Debug, Default, PartialEq)]
pub struct VerbSet {
    pub verbs: BTreeSet<String>,
    pub set_subs: BTreeSet<String>,
}

fn is_verb(s: &str) -> bool {
    !s.is_empty() && s.chars().all(|c| c.is_ascii_uppercase() || c == '_')
}

/// Verbs the server parses: string match arms (`"GEN" => ...`) inside
/// `parse_line`, plus `Some("sub")` patterns for `SET`.
pub fn proto_verbs(model: &Model) -> Option<VerbSet> {
    let f = model.files.iter().find(|f| f.path.ends_with("proto.rs"))?;
    let d = f.fns.iter().find(|d| d.name == "parse_line")?;
    let mut out = VerbSet::default();
    let t = &f.toks;
    for i in d.body.0..d.body.1 {
        if let Some(s) = t[i].str_content() {
            // `"VERB" =>`
            if is_verb(s)
                && t.get(i + 1).and_then(|x| x.punct()) == Some('=')
                && t.get(i + 2).and_then(|x| x.punct()) == Some('>')
            {
                out.verbs.insert(s.to_string());
            }
            // `Some("sub")`
            if i >= 2
                && t[i - 2].is_ident("Some")
                && t[i - 1].punct() == Some('(')
                && t.get(i + 1).and_then(|x| x.punct()) == Some(')')
                && !s.is_empty()
                && s.chars().all(|c| c.is_ascii_lowercase() || c == '_')
            {
                out.set_subs.insert(s.to_string());
            }
        }
    }
    Some(out)
}

/// Verbs the reference client can speak: the first word of the first
/// string literal of each `writeln!` call (template lines like
/// `"{line}"` are skipped; the keyword-GEN path goes through
/// `encode_gen`, whose legacy twin `"GEN {max_new} {prompt}"` keeps
/// GEN visible here).
pub fn client_verbs(model: &Model) -> Option<VerbSet> {
    let f = model.files.iter().find(|f| f.path.ends_with("client.rs"))?;
    let mut out = VerbSet::default();
    let t = &f.toks;
    for i in 0..t.len() {
        if f.in_test(i) {
            continue;
        }
        if !t[i].is_ident("writeln") || t.get(i + 1).and_then(|x| x.punct()) != Some('!') {
            continue;
        }
        let Some(close) = crate::model::match_open(t, i + 2, '(', ')') else { continue };
        let Some(lit) = t[i + 2..close].iter().find_map(|x| x.str_content()) else { continue };
        let mut words = lit.split_whitespace();
        let Some(first) = words.next() else { continue };
        if !is_verb(first) {
            continue; // "{line}" template and similar
        }
        out.verbs.insert(first.to_string());
        if first == "SET" {
            if let Some(sub) = words.next() {
                if !sub.starts_with('{') {
                    out.set_subs.insert(sub.to_string());
                }
            }
        }
    }
    Some(out)
}

/// Verbs the README documents: the first fenced code block after the
/// `## Protocol v2` heading, one request form per line (`|`-separated
/// alternatives; indented lines are continuations).
pub fn readme_verbs(readme: &str) -> Option<VerbSet> {
    let mut out = VerbSet::default();
    let mut lines = readme.lines();
    lines.find(|l| {
        l.starts_with('#') && l.trim_start_matches('#').trim().starts_with("Protocol v2")
    })?;
    let mut in_fence = false;
    let mut saw_fence = false;
    for l in lines.by_ref() {
        if l.trim_start().starts_with("```") {
            if in_fence {
                break;
            }
            in_fence = true;
            saw_fence = true;
            continue;
        }
        if !in_fence {
            continue;
        }
        if l.starts_with(char::is_whitespace) {
            continue; // continuation line
        }
        let request = l.split("->").next().unwrap_or(l);
        for alt in request.split('|') {
            let mut words = alt.split_whitespace();
            let Some(first) = words.next() else { continue };
            if !is_verb(first) {
                continue;
            }
            out.verbs.insert(first.to_string());
            if first == "SET" {
                if let Some(sub) = words.next() {
                    if !sub.starts_with('<') && !sub.starts_with('{') {
                        out.set_subs.insert(sub.to_string());
                    }
                }
            }
        }
    }
    saw_fence.then_some(out)
}

pub fn check(model: &Model, readme: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    let proto = proto_verbs(model);
    let client = client_verbs(model);
    let doc = readme.and_then(readme_verbs);
    let mut sources: Vec<(&str, &VerbSet)> = Vec::new();
    if let Some(p) = proto.as_ref() {
        sources.push(("server parser (proto.rs)", p));
    }
    if let Some(c) = client.as_ref() {
        sources.push(("reference client (client.rs)", c));
    }
    if let Some(d) = doc.as_ref() {
        sources.push(("README protocol table", d));
    }
    // fewer than two statements of the protocol -> nothing to compare
    // (fixture models without these files stay silent)
    if sources.len() < 2 {
        return out;
    }
    for (ai, (aname, a)) in sources.iter().enumerate() {
        for (bname, b) in sources.iter().skip(ai + 1) {
            for v in a.verbs.difference(&b.verbs) {
                out.push(drift(format!("verb {v} is in the {aname} but missing from the {bname}")));
            }
            for v in b.verbs.difference(&a.verbs) {
                out.push(drift(format!("verb {v} is in the {bname} but missing from the {aname}")));
            }
            for s in a.set_subs.difference(&b.set_subs) {
                out.push(drift(format!(
                    "SET subcommand '{s}' is in the {aname} but missing from the {bname}"
                )));
            }
            for s in b.set_subs.difference(&a.set_subs) {
                out.push(drift(format!(
                    "SET subcommand '{s}' is in the {bname} but missing from the {aname}"
                )));
            }
        }
    }
    out
}

fn drift(msg: String) -> Finding {
    Finding { rule: "wire", file: "server/proto.rs".to_string(), line: 0, msg }
}
