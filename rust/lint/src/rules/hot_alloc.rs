//! Rule 4 — allocation in the decode hot path.
//!
//! The per-token decode loop (`decode_step_batch` →
//! `decode_step_pipeline` → the CSR attention kernels) is the latency
//! budget of the whole serving stack; SWAN's decompression-free design
//! exists so this loop touches no scratch allocations beyond the
//! pre-sized `AttentionScratch`.  This rule flags the unmistakable
//! allocator calls — `Vec::new`, `.to_vec()`, `.clone()`, `format!`,
//! `Box::new` — in any function reachable from the decode roots.
//! Amortized growth (`vec![...]`, `with_capacity`, `collect`) is NOT
//! flagged: the loop's own buffers legitimately grow once and are
//! reused.  A deliberate allocation (one-off setup inside a function
//! that also serves the hot path) carries
//! `lint: allow(hot_alloc, "...")`.

use crate::callgraph::CallGraph;
use crate::model::{Finding, Model};
use crate::rules::locks::DECODE_ROOTS;

pub fn check(model: &Model, cg: &CallGraph) -> Vec<Finding> {
    let roots = cg.roots_named(DECODE_ROOTS);
    let seen = cg.reachable(&roots);
    let mut out = Vec::new();
    for (id, &(fi, di)) in cg.nodes.iter().enumerate() {
        if !seen[id] {
            continue;
        }
        let f = &model.files[fi];
        let d = &f.fns[di];
        if d.in_test {
            continue;
        }
        let t = &f.toks;
        for i in d.body.0..d.body.1 {
            let Some(name) = t[i].ident() else { continue };
            let next = t.get(i + 1).and_then(|x| x.punct());
            let construct = match name {
                // Vec::new( / Box::new(
                "new" if next == Some('(')
                    && i >= 3
                    && t[i - 1].punct() == Some(':')
                    && t[i - 2].punct() == Some(':')
                    && t[i - 3].ident().is_some_and(|q| q == "Vec" || q == "Box") =>
                {
                    Some(format!("{}::new", t[i - 3].ident().unwrap_or_default()))
                }
                // .to_vec( / .clone(
                "to_vec" | "clone"
                    if next == Some('(') && i >= 1 && t[i - 1].punct() == Some('.') =>
                {
                    Some(format!(".{name}()"))
                }
                // format!(
                "format" if next == Some('!') => Some("format!".to_string()),
                _ => None,
            };
            if let Some(construct) = construct {
                if !f.allowed("hot_alloc", t[i].line) {
                    out.push(Finding {
                        rule: "hot_alloc",
                        file: f.path.clone(),
                        line: t[i].line,
                        msg: format!(
                            "{construct} in '{}', reachable from the decode hot path — \
                             reuse scratch or justify with lint: allow(hot_alloc, \"...\")",
                            d.name
                        ),
                    });
                }
            }
        }
    }
    out
}
