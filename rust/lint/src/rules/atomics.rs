//! Rule 3 — atomic-ordering audit.
//!
//! Two checks per (file, atomic field):
//!
//! 1. **Mixed orderings.**  All uses of one field should agree on an
//!    ordering discipline; a field touched with both `Relaxed` and
//!    `SeqCst` (say) is either over- or under-synchronized and needs a
//!    `lint: allow(atomic, "...")` explaining the split.
//! 2. **Handoff stores.**  A field documented as a cross-thread
//!    handoff — a comment anywhere in the file saying
//!    `ordering: handoff(<field>)` — must not be *stored* with
//!    `Relaxed`: a Relaxed store publishes the flag but not the data
//!    it guards.  (The swan tree today uses atomics only as
//!    monotonic counters/gauges, where Relaxed is the documented
//!    discipline, so it carries no handoff markers.)

use std::collections::BTreeMap;

use crate::model::{Finding, Model};

const ATOMIC_METHODS: &[&str] = &[
    "store", "load", "swap", "fetch_add", "fetch_sub", "fetch_or", "fetch_and", "fetch_xor",
    "fetch_max", "fetch_min", "compare_exchange", "compare_exchange_weak", "fetch_update",
];

#[derive(Clone, Debug)]
struct Use {
    ordering: String,
    method: String,
    line: u32,
}

pub fn check(model: &Model) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &model.files {
        // handoff markers: `ordering: handoff(field)` in comments
        let mut handoff: Vec<String> = Vec::new();
        for c in &f.comments {
            if let Some(rest) = c.text.trim().strip_prefix("ordering: handoff(") {
                if let Some(field) = rest.strip_suffix(')') {
                    handoff.push(field.trim().to_string());
                }
            }
        }

        let mut uses: BTreeMap<String, Vec<Use>> = BTreeMap::new();
        let t = &f.toks;
        for i in 0..t.len() {
            if f.in_test(i) {
                continue;
            }
            // ... Ordering :: <ord> ...
            if !t[i].is_ident("Ordering")
                || t.get(i + 1).and_then(|x| x.punct()) != Some(':')
                || t.get(i + 2).and_then(|x| x.punct()) != Some(':')
            {
                continue;
            }
            let Some(ord) = t.get(i + 3).and_then(|x| x.ident()) else { continue };
            // walk back for the atomic method this ordering parameterizes
            let lo = i.saturating_sub(14);
            let found = (lo..i).rev().find_map(|j| {
                t[j].ident()
                    .filter(|m| ATOMIC_METHODS.contains(m))
                    .map(|m| (j, m.to_string()))
            });
            let Some((j, method)) = found else { continue };
            // field: ident before the `.` preceding the method
            let field = (j >= 2
                && t[j - 1].punct() == Some('.')
                && t[j - 2].ident().is_some())
            .then(|| t[j - 2].ident().unwrap_or_default().to_string());
            let Some(field) = field else { continue };
            uses.entry(field).or_default().push(Use {
                ordering: ord.to_string(),
                method,
                line: t[i + 3].line,
            });
        }

        for (field, us) in &uses {
            // mixed orderings on one field
            let mut seen: Vec<&str> = Vec::new();
            for u in us {
                if !seen.contains(&u.ordering.as_str()) {
                    seen.push(&u.ordering);
                    if seen.len() == 2 && !f.allowed("atomic", u.line) {
                        out.push(Finding {
                            rule: "atomic",
                            file: f.path.clone(),
                            line: u.line,
                            msg: format!(
                                "field '{field}' is used with mixed orderings ({}) — \
                                 pick one discipline or justify with lint: allow(atomic, \"...\")",
                                {
                                    let mut all: Vec<&str> =
                                        us.iter().map(|u| u.ordering.as_str()).collect();
                                    all.sort_unstable();
                                    all.dedup();
                                    all.join(", ")
                                }
                            ),
                        });
                    }
                }
            }
            // Relaxed store to a declared handoff field
            if handoff.iter().any(|h| h == field) {
                for u in us {
                    let publishes = u.method == "store"
                        || u.method == "swap"
                        || u.method.starts_with("fetch_")
                        || u.method.starts_with("compare_exchange");
                    if publishes && u.ordering == "Relaxed" && !f.allowed("atomic", u.line) {
                        out.push(Finding {
                            rule: "atomic",
                            file: f.path.clone(),
                            line: u.line,
                            msg: format!(
                                "Relaxed {} to '{field}', which is documented as a \
                                 cross-thread handoff — use Release (or justify)",
                                u.method
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}
