//! Rule 1 — panic-path audit over the *supervised* scope.
//!
//! Inside code a shard supervisor owns (`shard/`, `pool/`, and the
//! decode worker pool `swan/batch.rs`), a panic is a recovery event:
//! the supervisor converts it into shard-death plus exact replay.
//! That makes every panic site a deliberate design decision, so each
//! one must either not exist or carry a
//! `// lint: allow(panic|indexing, "<why>")` justification.
//!
//! Flagged: `.unwrap()` / `.expect(...)`, `panic!(...)`, and direct
//! indexing `x[i]` (a hidden bounds panic).  Not flagged:
//! `unreachable!` / `assert!` (spelled invariants), `unwrap_or*`
//! (non-panicking), and range slicing `&x[a..b]` — a documented
//! limitation: slice bounds still panic, but ranges are pervasive in
//! the kernel code and their bounds are the kernels' own loop bounds.

use crate::model::{match_open, Finding, Model};

/// Is `path` (root-relative, `/`-separated) in the supervised scope?
pub fn supervised(path: &str) -> bool {
    path.starts_with("shard/") || path.starts_with("pool/") || path == "swan/batch.rs"
}

pub fn check(model: &Model) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &model.files {
        if !supervised(&f.path) {
            continue;
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if f.in_test(i) {
                continue;
            }
            // `.unwrap(` / `.expect(`
            if let Some(name) = t[i].ident() {
                if (name == "unwrap" || name == "expect")
                    && i >= 1
                    && t[i - 1].punct() == Some('.')
                    && t.get(i + 1).and_then(|x| x.punct()) == Some('(')
                    && !f.allowed("panic", t[i].line)
                {
                    out.push(Finding {
                        rule: "panic",
                        file: f.path.clone(),
                        line: t[i].line,
                        msg: format!(
                            ".{name}() in supervised scope — make the failure a recovery \
                             event or justify with lint: allow(panic, \"...\")"
                        ),
                    });
                }
                // `panic!(` — unreachable!/assert! stay legal
                if name == "panic"
                    && t.get(i + 1).and_then(|x| x.punct()) == Some('!')
                    && !f.allowed("panic", t[i].line)
                {
                    out.push(Finding {
                        rule: "panic",
                        file: f.path.clone(),
                        line: t[i].line,
                        msg: "panic! in supervised scope — justify with \
                              lint: allow(panic, \"...\")"
                            .to_string(),
                    });
                }
            }
            // direct indexing `x[i]`
            if t[i].punct() == Some('[') && i >= 1 {
                let prev = &t[i - 1];
                let indexable_recv = match prev.punct() {
                    Some(')') | Some(']') => true,
                    Some(_) => false,
                    None => prev.ident().is_some_and(|id| id != "mut"),
                };
                if indexable_recv && !is_range_index(t, i) && !f.allowed("indexing", t[i].line) {
                    out.push(Finding {
                        rule: "indexing",
                        file: f.path.clone(),
                        line: t[i].line,
                        msg: "direct indexing in supervised scope — a hidden bounds panic; \
                              use get()/get_mut() or justify with lint: allow(indexing, \"...\")"
                            .to_string(),
                    });
                }
            }
        }
    }
    out
}

/// Does the bracket pair opening at `open` contain a `..` at its own
/// depth (range slicing, excluded from the indexing rule)?
fn is_range_index(t: &[crate::lexer::Tok], open: usize) -> bool {
    let Some(close) = match_open(t, open, '[', ']') else { return false };
    let mut depth = 0i32;
    for j in open..close {
        match t[j].punct() {
            Some('[') | Some('(') => depth += 1,
            Some(']') | Some(')') => depth -= 1,
            Some('.') if depth == 1 && t.get(j + 1).and_then(|x| x.punct()) == Some('.') => {
                return true;
            }
            _ => {}
        }
    }
    false
}
