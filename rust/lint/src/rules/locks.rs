//! Rule 2 — lock-order analysis, plus the `.lock().unwrap()` sweep.
//!
//! Per function, the rule extracts mutex/rwlock acquisitions — both
//! the raw `x.lock()` / `x.read()` / `x.write()` spellings and the
//! poison-recovering `lock_recover(&x)` family — and a conservative
//! guard-liveness range (a `let`-bound guard lives to the end of its
//! enclosing block or an explicit `drop(guard)`; an unbound temporary
//! lives to the end of its statement).  Acquiring lock B while lock A
//! is live adds edge A → B; calls made while A is live add A → every
//! lock in the callee's transitive acquisition summary.  Any cycle in
//! the resulting graph (self-edges included — a re-entrant
//! `Mutex::lock` self-deadlocks) is a finding, as is any decode-hot-
//! path function that can reach the metrics *registration* mutex
//! (`registry::series` — registration is allowed at setup, never per
//! token).

use std::collections::{BTreeMap, BTreeSet};

use crate::callgraph::CallGraph;
use crate::lexer::Tok;
use crate::model::{match_open, File, Finding, Model};

/// Functions on the per-token decode path; anything they reach is
/// "hot" for the registration-mutex check (shared with the hot-alloc
/// rule's root set).
pub const DECODE_ROOTS: &[&str] = &[
    "decode_step_batch",
    "decode_step_pipeline",
    "swan_attention_scratch",
    "dense_attention_scratch",
    "attend_with",
    "scores_into_with",
    "scores_max_into_with",
    "axpy_all_with",
];

/// The registration mutex's lock id (see `obs/registry.rs`).
const REGISTRATION_LOCK: &str = "registry::series";

#[derive(Clone, Debug)]
struct Acq {
    lock: String,
    tok: usize,
    line: u32,
    /// Token index the guard is conservatively live until (exclusive).
    end: usize,
}

pub fn check(model: &Model, cg: &CallGraph) -> Vec<Finding> {
    let mut out = lock_unwrap_sweep(model);

    // per-node acquisitions and direct lock-id sets
    let mut acqs: Vec<Vec<Acq>> = Vec::with_capacity(cg.nodes.len());
    let mut direct: Vec<BTreeSet<String>> = Vec::with_capacity(cg.nodes.len());
    for &(fi, di) in &cg.nodes {
        let f = &model.files[fi];
        let d = &f.fns[di];
        let a = if d.in_test { Vec::new() } else { acquisitions(f, d.body) };
        direct.push(a.iter().map(|x| x.lock.clone()).collect());
        acqs.push(a);
    }

    // transitive acquisition summaries (fixpoint over the call graph)
    let mut summary = direct.clone();
    loop {
        let mut changed = false;
        for id in 0..cg.nodes.len() {
            for &c in &cg.edges[id] {
                if c == id {
                    continue;
                }
                let add: Vec<String> =
                    summary[c].difference(&summary[id]).cloned().collect();
                if !add.is_empty() {
                    summary[id].extend(add);
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }

    // lock-order edges with provenance: (from, to) -> (file, line)
    let mut edges: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    for (id, &(fi, _di)) in cg.nodes.iter().enumerate() {
        let f = &model.files[fi];
        for a in &acqs[id] {
            // later acquisitions while `a` is live
            for b in &acqs[id] {
                if b.tok > a.tok && b.tok < a.end && !f.allowed("lock_order", b.line) {
                    edges
                        .entry((a.lock.clone(), b.lock.clone()))
                        .or_insert((f.path.clone(), b.line));
                }
            }
            // calls made while `a` is live pull in callee summaries
            for j in a.tok + 1..a.end.min(f.toks.len()) {
                for c in cg.resolve_call_from(model, fi, j, Some(id)) {
                    for l in &summary[c] {
                        if !f.allowed("lock_order", f.toks[j].line) {
                            edges
                                .entry((a.lock.clone(), l.clone()))
                                .or_insert((f.path.clone(), f.toks[j].line));
                        }
                    }
                }
            }
        }
    }

    out.extend(cycles(&edges));
    out.extend(hot_path_registration(model, cg, &direct));
    out
}

/// `.lock().unwrap()` (and read/write + unwrap/expect) anywhere in the
/// tree: the poison-recovery helpers exist precisely so no site needs
/// this spelling.
fn lock_unwrap_sweep(model: &Model) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in &model.files {
        if f.path == "util/sync.rs" {
            continue; // the helpers' own docs/tests show the anti-pattern
        }
        let t = &f.toks;
        for i in 0..t.len() {
            if f.in_test(i) {
                continue;
            }
            let Some(m) = t[i].ident() else { continue };
            if !matches!(m, "lock" | "read" | "write") {
                continue;
            }
            let shape = i >= 1
                && t[i - 1].punct() == Some('.')
                && t.get(i + 1).and_then(|x| x.punct()) == Some('(')
                && t.get(i + 2).and_then(|x| x.punct()) == Some(')')
                && t.get(i + 3).and_then(|x| x.punct()) == Some('.')
                && t.get(i + 4)
                    .and_then(|x| x.ident())
                    .is_some_and(|n| n == "unwrap" || n == "expect");
            if shape && !f.allowed("lock_unwrap", t[i].line) {
                out.push(Finding {
                    rule: "lock_unwrap",
                    file: f.path.clone(),
                    line: t[i].line,
                    msg: format!(
                        ".{m}().unwrap() propagates poisoning into a secondary panic — \
                         use util::sync::{m}_recover"
                    ),
                });
            }
        }
    }
    out
}

/// Extract lock acquisitions (with liveness) from one fn body.
fn acquisitions(f: &File, body: (usize, usize)) -> Vec<Acq> {
    let t = &f.toks;
    let mut out = Vec::new();
    for i in body.0..body.1 {
        let Some(name) = t[i].ident() else { continue };
        let acq = if matches!(name, "lock_recover" | "read_recover" | "write_recover")
            && t.get(i + 1).and_then(|x| x.punct()) == Some('(')
        {
            // lock_recover(&self.shared.state) -> "state"
            match_open(t, i + 1, '(', ')').and_then(|close| {
                t[i + 2..close]
                    .iter()
                    .rev()
                    .find_map(|x| x.ident())
                    .map(|n| (n.to_string(), i))
            })
        } else if matches!(name, "lock" | "read" | "write")
            && i >= 2
            && t[i - 1].punct() == Some('.')
            && t.get(i + 1).and_then(|x| x.punct()) == Some('(')
            && t.get(i + 2).and_then(|x| x.punct()) == Some(')')
        {
            // self.inner.shards.read() -> "shards"
            t[i - 2].ident().map(|n| (n.to_string(), i))
        } else {
            None
        };
        let Some((lock_name, at)) = acq else { continue };
        let lock = format!("{}::{}", f.stem, lock_name);
        let end = liveness_end(t, at, body.1);
        out.push(Acq { lock, tok: at, line: t[at].line, end });
    }
    out
}

/// Conservative guard liveness: a `let`-bound guard lives to the end
/// of its enclosing block (or `drop(name)`); an unbound temporary to
/// the end of its statement.
fn liveness_end(t: &[Tok], at: usize, body_end: usize) -> usize {
    let bound = binding_name(t, at);
    let mut depth = 0i32;
    let mut j = at;
    while j < body_end {
        match t[j].punct() {
            Some('{') | Some('(') | Some('[') => depth += 1,
            Some('}') | Some(')') | Some(']') => {
                depth -= 1;
                if depth < 0 && (bound.is_none() || t[j].punct() == Some('}')) {
                    // enclosing delimiter closed: a temporary dies with
                    // its expression, a bound guard with its block
                    return j;
                }
            }
            // statement/arm boundary ends an unbound temporary
            Some(';') | Some(',') if bound.is_none() && depth <= 0 => return j,
            _ => {}
        }
        if let Some(name) = &bound {
            // drop(name) ends the guard early
            if t[j].is_ident("drop")
                && t.get(j + 1).and_then(|x| x.punct()) == Some('(')
                && t.get(j + 2).map(|x| x.is_ident(name)).unwrap_or(false)
                && t.get(j + 3).and_then(|x| x.punct()) == Some(')')
            {
                return j;
            }
        }
        j += 1;
    }
    body_end
}

/// If the statement containing `at` starts `let [mut] NAME =`, the
/// guard's binding name.
fn binding_name(t: &[Tok], at: usize) -> Option<String> {
    let lo = at.saturating_sub(12);
    for j in (lo..at).rev() {
        match t[j].punct() {
            Some(';') | Some('{') | Some('}') => return None,
            _ => {}
        }
        if t[j].is_ident("let") {
            return t[j + 1..at].iter().find_map(|x| {
                x.ident().filter(|&n| n != "mut").map(|n| n.to_string())
            });
        }
    }
    None
}

/// DFS cycle detection over the lock graph; one finding per back edge.
fn cycles(edges: &BTreeMap<(String, String), (String, u32)>) -> Vec<Finding> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (from, to) in edges.keys() {
        adj.entry(from).or_default().push(to);
    }
    let mut out = Vec::new();
    // self-edges first: unconditional deadlocks
    for ((from, to), (file, line)) in edges {
        if from == to {
            out.push(Finding {
                rule: "lock_order",
                file: file.clone(),
                line: *line,
                msg: format!("lock {from} re-acquired while already held (self-deadlock)"),
            });
        }
    }
    // cross-lock cycles
    let mut state: BTreeMap<&str, u8> = BTreeMap::new(); // 1=open, 2=done
    let mut stack: Vec<&str> = Vec::new();
    let starts: Vec<&str> = adj.keys().copied().collect();
    for start in starts {
        if state.get(start).copied().unwrap_or(0) != 0 {
            continue;
        }
        dfs(start, &adj, &mut state, &mut stack, edges, &mut out);
    }
    out
}

fn dfs<'a>(
    n: &'a str,
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    state: &mut BTreeMap<&'a str, u8>,
    stack: &mut Vec<&'a str>,
    edges: &BTreeMap<(String, String), (String, u32)>,
    out: &mut Vec<Finding>,
) {
    state.insert(n, 1);
    stack.push(n);
    for &m in adj.get(n).into_iter().flatten() {
        if m == n {
            continue; // self-edges reported separately
        }
        match state.get(m).copied().unwrap_or(0) {
            0 => dfs(m, adj, state, stack, edges, out),
            1 => {
                let pos = stack.iter().position(|&x| x == m).unwrap_or(0);
                let mut path: Vec<&str> = stack[pos..].to_vec();
                path.push(m);
                let (file, line) = edges
                    .get(&(n.to_string(), m.to_string()))
                    .cloned()
                    .unwrap_or_default();
                out.push(Finding {
                    rule: "lock_order",
                    file,
                    line,
                    msg: format!("lock-order cycle: {}", path.join(" -> ")),
                });
            }
            _ => {}
        }
    }
    stack.pop();
    state.insert(n, 2);
}

/// Decode-hot-path functions must never reach the registration mutex.
fn hot_path_registration(
    model: &Model,
    cg: &CallGraph,
    direct: &[BTreeSet<String>],
) -> Vec<Finding> {
    let roots = cg.roots_named(DECODE_ROOTS);
    let seen = cg.reachable(&roots);
    let mut out = Vec::new();
    for (id, &(fi, di)) in cg.nodes.iter().enumerate() {
        if !seen[id] || !direct[id].contains(REGISTRATION_LOCK) {
            continue;
        }
        let f = &model.files[fi];
        let d = &f.fns[di];
        if f.allowed("lock_order", d.line) {
            continue;
        }
        out.push(Finding {
            rule: "lock_order",
            file: f.path.clone(),
            line: d.line,
            msg: format!(
                "{} acquires the metrics registration mutex ({REGISTRATION_LOCK}) and is \
                 reachable from the decode hot path — register handles at setup instead",
                d.name
            ),
        });
    }
    out
}
