//! Standalone runner: `swan-lint <src-root> [readme]`.
//!
//! Prints one line per finding and exits non-zero when any exist —
//! the same contract `rust/tests/lint_clean.rs` enforces under
//! `cargo test`.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(src) = args.next().map(PathBuf::from) else {
        eprintln!("usage: swan-lint <src-root> [readme]");
        return ExitCode::from(2);
    };
    let readme = args.next().map(PathBuf::from);
    match swan_lint::analyze_tree(&src, readme.as_deref()) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                eprintln!("swan-lint: clean");
                ExitCode::SUCCESS
            } else {
                eprintln!("swan-lint: {} finding(s)", findings.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("swan-lint: {e}");
            ExitCode::from(2)
        }
    }
}
