//! swan-lint: a dependency-free static analyzer for the swan serving
//! stack, run as a tier-1 test (`rust/tests/lint_clean.rs`).
//!
//! It lexes `rust/src` with a lightweight Rust lexer, builds a
//! module/function model plus a name-based call graph, and enforces
//! five invariants the compiler cannot:
//!
//! 1. **panic-path audit** — no unjustified `.unwrap()` / `.expect()` /
//!    `panic!` / direct indexing inside the supervised shard scope;
//! 2. **lock order** — no cycles in the cross-function lock graph, no
//!    registration-mutex acquisition on the decode hot path, and no
//!    `.lock().unwrap()` now that `util::sync` recovers from poisoning;
//! 3. **atomic orderings** — fields keep one ordering discipline, and
//!    declared handoff fields are never Relaxed-stored;
//! 4. **hot-path allocation** — no `Vec::new` / `.to_vec()` /
//!    `.clone()` / `format!` / `Box::new` reachable from the decode
//!    roots;
//! 5. **wire drift** — server parser, reference client and README
//!    protocol table agree on the protocol-v2 verb set.
//!
//! Deviations are justified in-tree with
//! `// lint: allow(<key>, "<why>")`; a malformed or justification-free
//! annotation is itself a finding (`allow_grammar`).

pub mod callgraph;
pub mod lexer;
pub mod model;
pub mod rules;

use std::io;
use std::path::Path;

pub use model::{Finding, Model};

/// Run every rule over `model` (and the README text, when given for
/// the wire rule).  Findings come back deduplicated and sorted by
/// (file, line, rule).
pub fn analyze(model: &Model, readme: Option<&str>) -> Vec<Finding> {
    let cg = callgraph::CallGraph::build(model);
    let mut out = Vec::new();
    out.extend(rules::annotation_grammar(model));
    out.extend(rules::panics::check(model));
    out.extend(rules::locks::check(model, &cg));
    out.extend(rules::atomics::check(model));
    out.extend(rules::hot_alloc::check(model, &cg));
    out.extend(rules::wire::check(model, readme));
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule, a.msg.as_str())
            .cmp(&(b.file.as_str(), b.line, b.rule, b.msg.as_str()))
    });
    out.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule && a.msg == b.msg);
    out
}

/// Load every `.rs` under `src_root` (plus the README for the wire
/// rule) and analyze.
pub fn analyze_tree(src_root: &Path, readme: Option<&Path>) -> io::Result<Vec<Finding>> {
    let model = Model::load(src_root)?;
    let readme_text = match readme {
        Some(p) => Some(std::fs::read_to_string(p)?),
        None => None,
    };
    Ok(analyze(&model, readme_text.as_deref()))
}
