//! Determinism of the parallel batched decode path: for a fixed seed,
//! `SwanModel::decode_step_batch` must produce token streams identical to
//! serial `decode_step`, for every batch size and worker count.  This is
//! the executable form of the batching contract: the worker pool changes
//! *where* attention tasks run, never what they compute.

use swan::config::ModelConfig;
use swan::kvcache::PolicyKind;
use swan::model::transformer::{SequenceState, SwanModel};
use swan::sparse::StorageMode;
use swan::swan::batch::WorkerPool;
use swan::tensor::ops::argmax;

fn test_model() -> SwanModel {
    SwanModel::synthetic(
        ModelConfig {
            name: "batch-test".into(),
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        21,
    )
}

fn policy_for(i: usize) -> PolicyKind {
    // mix policies across the batch: the batched path must handle any
    // CachePolicy, not just SWAN
    if i % 3 == 2 {
        PolicyKind::Dense
    } else {
        PolicyKind::Swan { k_active: 4, buffer: 3, mode: StorageMode::F16 }
    }
}

fn prompts(batch: usize) -> Vec<Vec<u32>> {
    (0..batch)
        .map(|i| (0..(4 + 3 * i % 17)).map(|t| ((t * 11 + i * 5) % 96) as u32).collect())
        .collect()
}

/// Greedy streams via the serial per-sequence path.
fn generate_serial(model: &SwanModel, prompts: &[Vec<u32>], steps: usize) -> Vec<Vec<u32>> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut st = SequenceState::new(model, policy_for(i));
            let pf = model.prefill(p);
            st.load_prefill(&pf);
            let mut tok = argmax(&pf.logits) as u32;
            let mut out = vec![tok];
            for _ in 0..steps {
                let logits = model.decode_step(&mut st, tok);
                tok = argmax(&logits) as u32;
                out.push(tok);
            }
            out
        })
        .collect()
}

/// Greedy streams via lock-step batched decode over a pool.
fn generate_batched(
    model: &SwanModel,
    prompts: &[Vec<u32>],
    steps: usize,
    workers: usize,
) -> Vec<Vec<u32>> {
    let mut pool = WorkerPool::new(workers);
    let mut states: Vec<SequenceState> = Vec::new();
    let mut toks: Vec<u32> = Vec::new();
    let mut streams: Vec<Vec<u32>> = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let mut st = SequenceState::new(model, policy_for(i));
        let pf = model.prefill(p);
        st.load_prefill(&pf);
        let tok = argmax(&pf.logits) as u32;
        states.push(st);
        toks.push(tok);
        streams.push(vec![tok]);
    }
    for _ in 0..steps {
        let logits = model.decode_step_batch(&mut states, &toks, &mut pool);
        for ((tok, l), stream) in toks.iter_mut().zip(&logits).zip(streams.iter_mut()) {
            *tok = argmax(l) as u32;
            stream.push(*tok);
        }
    }
    streams
}

#[test]
fn batched_parallel_decode_matches_serial_streams() {
    let model = test_model();
    let steps = 24;
    for batch in [1usize, 4, 16] {
        let ps = prompts(batch);
        let serial = generate_serial(&model, &ps, steps);
        for workers in [0usize, 2, 8] {
            let batched = generate_batched(&model, &ps, steps, workers);
            assert_eq!(
                serial, batched,
                "batch={batch} workers={workers}: token streams diverged"
            );
        }
    }
}

#[test]
fn batched_decode_advances_all_positions() {
    let model = test_model();
    let ps = prompts(4);
    let mut pool = WorkerPool::new(2);
    let mut states: Vec<SequenceState> = ps
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut st = SequenceState::new(&model, policy_for(i));
            st.load_prefill(&model.prefill(p));
            st
        })
        .collect();
    let before: Vec<usize> = states.iter().map(|s| s.pos).collect();
    let toks = vec![1u32; 4];
    let logits = model.decode_step_batch(&mut states, &toks, &mut pool);
    assert_eq!(logits.len(), 4);
    assert!(logits.iter().all(|l| l.len() == model.cfg.vocab));
    assert!(logits.iter().flatten().all(|x| x.is_finite()));
    for (st, b) in states.iter().zip(&before) {
        assert_eq!(st.pos, b + 1);
    }
}

/// Parallel prefill determinism: fanning the per-layer prefill phases
/// across a pool must be *bit-identical* to the serial path — every
/// output (rotated K̂/V̂ streams, attention-mass seeds, logits) — for any
/// worker count, prompt length and GQA grouping.
#[test]
fn parallel_prefill_is_bit_identical_to_serial() {
    for nkv in [1usize, 2, 4] {
        let mut cfg = test_model().cfg;
        cfg.n_kv_heads = nkv;
        let model = SwanModel::synthetic(cfg, 21);
        for len in [1usize, 5, 23] {
            let tokens: Vec<u32> = (0..len).map(|t| ((t * 17 + nkv) % 96) as u32).collect();
            let serial = model.prefill(&tokens);
            for workers in [2usize, 8] {
                let mut pool = WorkerPool::new(workers);
                let parallel = model.prefill_with_pool(&tokens, &mut pool);
                assert_eq!(serial.len, parallel.len);
                let bits =
                    |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
                assert_eq!(
                    bits(&serial.logits),
                    bits(&parallel.logits),
                    "nkv={nkv} len={len} workers={workers}: logits diverged"
                );
                for l in 0..model.cfg.n_layers {
                    for h in 0..nkv {
                        assert_eq!(
                            bits(&serial.khat[l][h]),
                            bits(&parallel.khat[l][h]),
                            "khat l={l} h={h} nkv={nkv} len={len} workers={workers}"
                        );
                        assert_eq!(
                            bits(&serial.vhat[l][h]),
                            bits(&parallel.vhat[l][h]),
                            "vhat l={l} h={h} nkv={nkv} len={len} workers={workers}"
                        );
                        assert_eq!(
                            bits(&serial.mass[l][h]),
                            bits(&parallel.mass[l][h]),
                            "mass l={l} h={h} nkv={nkv} len={len} workers={workers}"
                        );
                    }
                }
            }
        }
    }
}

/// Prefill → decode consistency is preserved when the prefill itself ran
/// on a pool (the decode path consumes a parallel prefill unchanged).
#[test]
fn decode_after_parallel_prefill_matches_serial_prefill() {
    let model = test_model();
    let p: Vec<u32> = (0..11).map(|t| (t * 7 % 96) as u32).collect();
    let mut pool = WorkerPool::new(4);
    let pf_serial = model.prefill(&p);
    let pf_parallel = model.prefill_with_pool(&p, &mut pool);
    let mut st_a = SequenceState::new(&model, policy_for(0));
    let mut st_b = SequenceState::new(&model, policy_for(0));
    st_a.load_prefill(&pf_serial);
    st_b.load_prefill(&pf_parallel);
    let a = model.decode_step(&mut st_a, 5);
    let b = model.decode_step(&mut st_b, 5);
    let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
    let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
    assert_eq!(ab, bb);
}

#[test]
fn decode_step_is_the_batch_of_one_case() {
    let model = test_model();
    let p: Vec<u32> = (0..9).map(|t| (t * 13 % 96) as u32).collect();
    let mut st_a = SequenceState::new(&model, policy_for(0));
    let mut st_b = SequenceState::new(&model, policy_for(0));
    let pf = model.prefill(&p);
    st_a.load_prefill(&pf);
    st_b.load_prefill(&pf);
    let mut pool = WorkerPool::new(4);
    let a = model.decode_step(&mut st_a, 7);
    let b = model
        .decode_step_batch(std::slice::from_mut(&mut st_b), &[7], &mut pool)
        .pop()
        .unwrap();
    // bit-identical, not just close
    let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
    let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
    assert_eq!(ab, bb);
}
