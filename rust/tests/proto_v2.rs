//! Protocol-v2 back-compat + round-trip property suite (no artifacts;
//! runs in the default `cargo test` pass and is pinned as an explicit CI
//! step).
//!
//! Two invariants protect existing clients across the api redesign:
//! 1. **legacy identity** — every v1 line (`GEN <n> <prompt>`, `SET …`,
//!    `STATS`, `PING`, `QUIT`) parses to exactly the command it always
//!    did: a legacy `GEN` yields *default* [`GenParams`] with only
//!    `max_new` set, so its sampling, seeding and admission behaviour is
//!    bit-identical to v1;
//! 2. **round-trip** — any keyword line the reference encoder
//!    ([`encode_gen`]) emits parses back to the same `(params, prompt)`.

use swan::api::GenParams;
use swan::server::proto::{encode_gen, parse_line, Command, GEN_KEYS};
use swan::util::Pcg64;

/// Random single-line prompt over the serving alphabet (ASCII 32..127).
/// No leading space (the prompt boundary would be ambiguous); anything
/// else goes — prompts whose first word looks like a `key=value` or
/// `--` round-trip via the encoder's explicit terminator.
fn random_prompt(rng: &mut Pcg64, max_len: usize) -> String {
    let len = 1 + rng.below(max_len as u64) as usize;
    let mut s: String = (0..len)
        .map(|_| (32 + rng.below(95) as u8) as char)
        .collect();
    while s.starts_with(' ') {
        s.remove(0);
        s.push('x');
    }
    s
}

fn random_params(rng: &mut Pcg64) -> GenParams {
    let mut p = GenParams::new(1 + rng.below(512) as usize);
    if rng.below(2) == 0 {
        // one-decimal temperatures/top-p print exactly and round-trip
        p = p.temperature(rng.below(30) as f32 / 10.0);
    }
    if rng.below(2) == 0 {
        p = p.top_p(rng.below(10) as f32 / 10.0);
    }
    if rng.below(2) == 0 {
        p = p.repetition_penalty(1.0 + rng.below(20) as f32 / 10.0);
    }
    if rng.below(2) == 0 {
        p = p.seed(rng.next_u64() >> 1);
    }
    if rng.below(2) == 0 {
        p = p.stop(rng.below(96) as u32);
    }
    if rng.below(2) == 0 {
        p = p.k_active(1 + rng.below(128) as usize);
    }
    if rng.below(2) == 0 {
        p = p.stream(true);
    }
    p
}

#[test]
fn every_legacy_gen_line_parses_identically() {
    let mut rng = Pcg64::new(0x9e_02);
    for _ in 0..500 {
        let max_new = 1 + rng.below(999) as usize;
        let prompt = random_prompt(&mut rng, 60);
        let line = format!("GEN {max_new} {prompt}");
        let got = parse_line(&line).unwrap();
        // v1 parsing contract: max_new + the raw prompt, nothing else —
        // params must be pure defaults so behaviour is unchanged
        assert_eq!(
            got,
            Command::Gen { params: GenParams::new(max_new), prompt: prompt.clone() },
            "line {line:?}"
        );
        let Command::Gen { params, .. } = got else { unreachable!() };
        assert_eq!(params.temperature, 0.0);
        assert_eq!(params.top_p, 1.0);
        assert_eq!(params.repetition_penalty, 1.0);
        assert_eq!(params.seed, None);
        assert_eq!(params.stop, None);
        assert_eq!(params.k_active, None);
        assert!(!params.stream);
    }
}

#[test]
fn legacy_admin_lines_parse_identically() {
    assert_eq!(parse_line("SET k_active 16").unwrap(), Command::SetKActive(16));
    assert_eq!(parse_line("SET balance mem-aware").unwrap(), Command::SetBalance("mem-aware".into()));
    assert_eq!(parse_line("STATS").unwrap(), Command::Stats);
    assert_eq!(parse_line("PING").unwrap(), Command::Ping);
    assert_eq!(parse_line("QUIT").unwrap(), Command::Quit);
    // malformed lines still produce the same structured codes
    assert_eq!(parse_line("").unwrap_err().code(), "empty");
    assert_eq!(parse_line("NOPE").unwrap_err().code(), "unknown-command");
    assert_eq!(parse_line("GEN").unwrap_err().code(), "bad-args");
    assert_eq!(parse_line("SET foo 3").unwrap_err().code(), "bad-args");
}

#[test]
fn keyword_lines_survive_encode_then_parse() {
    let mut rng = Pcg64::new(0x9e_03);
    for i in 0..500 {
        let params = random_params(&mut rng);
        // every 4th prompt is adversarial: starts with a recognized
        // key=value or the terminator itself — the encoder must emit
        // an explicit `--` so these round-trip too
        let prompt = match i % 4 {
            0 => format!("k=2 {}", random_prompt(&mut rng, 30)),
            1 if i % 8 == 1 => format!("-- {}", random_prompt(&mut rng, 30)),
            _ => random_prompt(&mut rng, 40),
        };
        let line = encode_gen(&params, &prompt);
        match parse_line(&line) {
            Ok(Command::Gen { params: got_p, prompt: got_prompt }) => {
                assert_eq!(got_p, params, "iter {i}: line {line:?}");
                assert_eq!(got_prompt, prompt, "iter {i}: line {line:?}");
            }
            other => panic!("iter {i}: line {line:?} parsed to {other:?}"),
        }
    }
}

#[test]
fn issue_spelling_parses() {
    // the exact spelling the protocol doc advertises
    let got = parse_line("GEN max_new=64 temp=0.8 top_p=0.9 k=8 stream=1 the quick cache").unwrap();
    assert_eq!(
        got,
        Command::Gen {
            params: GenParams::new(64).temperature(0.8).top_p(0.9).k_active(8).stream(true),
            prompt: "the quick cache".into()
        }
    );
    assert_eq!(parse_line("CANCEL 12").unwrap(), Command::Cancel(12));
}

#[test]
fn prompts_led_by_keyword_lookalikes_stay_prompts() {
    let mut rng = Pcg64::new(0x9e_04);
    for _ in 0..200 {
        // "<unknown>=<junk>" must start the prompt, never error
        let prompt = format!("zz{}=what is this", rng.below(10));
        let line = format!("GEN max_new=4 {prompt}");
        assert_eq!(
            parse_line(&line).unwrap(),
            Command::Gen { params: GenParams::new(4), prompt: prompt.clone() },
            "{line}"
        );
    }
    // every recognized key with a garbage value is an error, not prompt
    for key in GEN_KEYS {
        let line = format!("GEN {key}=@@garbage@@ hi");
        assert_eq!(parse_line(&line).unwrap_err().code(), "bad-args", "{line}");
    }
}
