//! Paged KV block pool, end to end on a synthetic model (no artifacts).
//!
//! The contract under test: `--pool` serving is **bit-identical** to the
//! per-sequence contiguous path for every topology (stages x workers x
//! block size), block accounting is Eq. 1-exact (the analytic
//! `seq_blocks` rate equals the physical lease count, and the paged
//! `storage_bytes` equals the closed-form per-row sum), and a
//! budget-bounded pool preempts block-granularly — requeued sequences
//! resume by replay and still produce the same tokens.

use std::sync::Arc;

use swan::api::GenParams;
use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::engine::sample;
use swan::coordinator::Request;
use swan::kvcache::{CachePolicy, PolicyKind};
use swan::model::transformer::{SequenceState, SwanModel};
use swan::pool::{pool_blocks_for_budget, seq_blocks, BlockAllocator, BlockPool, PagedSwanCache};
use swan::shard::pipeline::launch_group;
use swan::shard::{RoundRobin, Router};
use swan::sparse::StorageMode;
use swan::swan::{HybridCache, SwanParams};
use swan::util::Pcg64;

/// Mirror of the engine's per-sequence decode RNG seed (see
/// `tests/pipeline.rs`) — the wire contract both paths derive from.
const SWAN_SEED: u64 = 0x53_57_41_4e;

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "pool-test".into(),
            d_model: 32,
            n_layers: 4,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: StorageMode::F16,
        max_batch: 8,
        ..Default::default()
    }
}

/// The request mix: greedy, temperature-sampled, and mixed per-request k
/// (different k => different per-row nnz => different block fill).
fn requests() -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..4)
        .map(|i| Request::from_text(i + 1, &format!("the pooled vector {i} maps the "), 10))
        .collect();
    reqs.push(Request::with_params(
        5,
        "the hot cache winnows ",
        GenParams::new(10).temperature(0.8),
    ));
    reqs.push(Request::with_params(6, "mixed low ", GenParams::new(10).k_active(2)));
    reqs.push(Request::with_params(7, "mixed high ", GenParams::new(10).k_active(6)));
    reqs
}

/// Serve `reqs` through one pipeline group with the given topology and
/// pool settings; returns `(streams by id, preempted, completed)`.
fn run_pool_fleet(
    stages: usize,
    decode_workers: usize,
    block_tokens: usize,
    mem_budget: usize,
    reqs: &[Request],
) -> (Vec<(u64, Vec<u32>)>, u64, u64) {
    let model = test_model();
    let cfg = ServeConfig {
        pipeline: stages,
        decode_workers,
        pool: true,
        block_tokens,
        mem_budget,
        ..serve_cfg()
    };
    let handle = launch_group(0, model, &cfg).unwrap();
    let router = Router::from_handles(vec![handle], Box::new(RoundRobin::default()));
    let pending: Vec<_> =
        reqs.iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    let mut out: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, h)| {
            let resp = h.wait().expect("generation ok");
            assert_eq!(resp.id, id);
            (id, resp.tokens)
        })
        .collect();
    out.sort_by_key(|(id, _)| *id);
    let (mut preempted, mut completed) = (0u64, 0u64);
    for s in router.shards() {
        preempted += s.metrics.requests_preempted.get();
        completed += s.metrics.requests_completed.get();
    }
    (out, preempted, completed)
}

/// Direct native reference (the engine's sampling/seeding contract),
/// each request at its own d_head-clamped compression level.
fn single_shard_reference(reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = serve_cfg();
    reqs.iter()
        .map(|req| {
            let k = req
                .params
                .k_active
                .map(|k| k.clamp(1, model.cfg.d_head))
                .unwrap_or(cfg.k_active);
            let kind = PolicyKind::Swan { k_active: k, buffer: cfg.buffer, mode: cfg.mode };
            let tokens: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
            let pf = model.prefill(tokens);
            let mut st = SequenceState::new(&model, kind);
            st.load_prefill(&pf);
            let base = req.params.seed.unwrap_or(req.id);
            let mut tok = sample(&pf.logits, &req.params, &[], &mut Pcg64::new(base));
            let mut rng = Pcg64::new(base ^ SWAN_SEED);
            let mut produced = vec![tok];
            while produced.len() < req.params.max_new {
                let logits = model.decode_step(&mut st, tok);
                tok = sample(&logits, &req.params, &produced, &mut rng);
                produced.push(tok);
            }
            (req.id, produced)
        })
        .collect()
}

/// The tentpole acceptance sweep: pool-backed decode is bit-identical to
/// the per-sequence reference for every (stages, workers, block size)
/// combination, including temperature sampling and mixed per-request k.
#[test]
fn pool_decode_is_bit_identical_across_topologies() {
    let reqs = requests();
    let want = single_shard_reference(&reqs);
    for stages in [1usize, 2] {
        for workers in [0usize, 3] {
            for bt in [1usize, 5, 16] {
                let (got, preempted, _) = run_pool_fleet(stages, workers, bt, 0, &reqs);
                assert_eq!(
                    got, want,
                    "pool fleet diverged: stages={stages} workers={workers} block_tokens={bt}"
                );
                assert_eq!(preempted, 0, "an unbounded pool must never preempt");
            }
        }
    }
}

/// A tight block budget forces preemption mid-decode; the preempted
/// sequence resumes by replay and the final streams still match the
/// unbounded reference, with `requests_preempted` counting the event.
#[test]
fn bounded_pool_preempts_and_resumes_bit_exactly() {
    let reqs = vec![
        Request::from_text(1, "the long one ", 12),
        Request::from_text(2, "the bystander ", 12),
    ];
    let want = single_shard_reference(&reqs);
    // block_tokens=1 for fine granularity: each stream set (2 streams x
    // 4 layers x 2 kv heads = 16 tables) leases one block per retained
    // row.  700 blocks admit both sequences early but run out before
    // either finishes, so the coordinator must preempt the youngest.
    let budget = 700 * swan::pool::block_bytes(1, 8, StorageMode::F16, 4);
    assert_eq!(pool_blocks_for_budget(budget, 1, 8, StorageMode::F16, 4), 700);
    let (got, preempted, completed) = run_pool_fleet(1, 0, 1, budget, &reqs);
    assert_eq!(got, want, "preemption/replay changed the decoded streams");
    assert!(preempted >= 1, "the tight budget must preempt at least once");
    assert_eq!(completed, 2, "every request still completes");
}

/// Preemption under a worker pool and a 2-stage pipeline stays bit-exact
/// (the carry/replay path crosses stage channels).
#[test]
fn bounded_pool_preemption_is_bit_exact_with_stages_and_workers() {
    let reqs = vec![
        Request::from_text(1, "the long one ", 12),
        Request::from_text(2, "the bystander ", 12),
        Request::from_text(3, "the third seat ", 12),
    ];
    let want = single_shard_reference(&reqs);
    let budget = 900 * swan::pool::block_bytes(1, 8, StorageMode::F16, 4);
    for (stages, workers) in [(2usize, 0usize), (1, 3)] {
        let (got, preempted, completed) = run_pool_fleet(stages, workers, 1, budget, &reqs);
        assert_eq!(got, want, "stages={stages} workers={workers} diverged under preemption");
        assert!(preempted >= 1, "stages={stages} workers={workers}: no preemption observed");
        assert_eq!(completed, 3);
    }
}

/// STATS surfaces the pool: per-stage `blocks=` gauges drain to zero once
/// every sequence retires (Retire is FIFO-ordered before the stats
/// request in each stage channel), and the fleet aggregate renders the
/// pool line.
#[test]
fn stats_show_pool_blocks_and_drain_to_zero() {
    let model = test_model();
    let cfg = ServeConfig {
        pipeline: 2,
        pool: true,
        block_tokens: 4,
        ..serve_cfg()
    };
    let handle = launch_group(0, model, &cfg).unwrap();
    let router = Router::from_handles(vec![handle], Box::new(RoundRobin::default()));
    for r in requests() {
        router.submit(r).unwrap().wait().unwrap();
    }
    let stats = router.stats();
    // every stage line carries a drained blocks gauge: the Retire hop is
    // FIFO-ordered before the stats request in each stage channel, so a
    // completed fleet deterministically shows zero leased blocks per
    // stage (the coordinator-side gauges are published asynchronously —
    // only their presence is asserted)
    assert_eq!(stats.matches(" blocks=0").count(), 2, "{stats}");
    assert!(stats.contains("/unbounded bt=4 frag="), "{stats}");
    assert!(stats.contains("fleet pool: blocks leased="), "{stats}");
    assert!(stats.contains("target=unbounded"), "{stats}");
}

/// The analytic admission rate (`seq_blocks`) equals the physical lease
/// count: one full stream set (n_layers x n_kv_heads paged caches, each
/// holding k+v sparse and k+v ring tables) on one pool, token by token.
#[test]
fn seq_blocks_predicts_physical_leases() {
    let (d_h, nl, nkv) = (8usize, 3usize, 2usize);
    for bt in [1usize, 2, 4] {
        for buffer in [0usize, 1, 3, 7] {
            let pool = Arc::new(BlockPool::new(usize::MAX));
            let params = SwanParams::new(4, buffer, StorageMode::F16);
            let mut caches: Vec<PagedSwanCache> = (0..nl * nkv)
                .map(|_| PagedSwanCache::new(d_h, params, bt, pool.clone()))
                .collect();
            let mut rng = Pcg64::new(21);
            for t in 1..=17 {
                let k = rng.normal_vec(d_h);
                let v = rng.normal_vec(d_h);
                for c in &mut caches {
                    c.append(&k, &v);
                }
                assert_eq!(
                    pool.leased(),
                    seq_blocks(t, buffer, bt, nl, nkv),
                    "bt={bt} buffer={buffer} token {t}"
                );
            }
            drop(caches);
            assert_eq!(pool.leased(), 0, "bt={bt} buffer={buffer}: blocks leaked");
            pool.check_invariants().unwrap();
        }
    }
}

/// Eq. 1 exactness: the paged cache's accounted bytes equal both the
/// contiguous cache's total and the closed-form per-row sum
/// `sum_r vector_bytes(nnz_r)` (+ the f16 ring convention), across
/// storage modes and block sizes that straddle row boundaries.
#[test]
fn block_accounting_matches_eq1_closed_form() {
    let d_h = 16usize;
    for mode in [StorageMode::F16, StorageMode::F8] {
        for bt in [1usize, 3, 8] {
            let pool = Arc::new(BlockPool::new(usize::MAX));
            let params = SwanParams::new(6, 2, mode);
            let mut paged = PagedSwanCache::new(d_h, params, bt, pool.clone());
            let mut flat = HybridCache::new(d_h, params);
            let mut rng = Pcg64::new(33);
            for _ in 0..23 {
                let k = rng.normal_vec(d_h);
                let v = rng.normal_vec(d_h);
                paged.append(&k, &v);
                flat.append(&k, &v);
            }
            assert_eq!(paged.storage_bytes(), flat.storage_bytes(), "mode={mode:?} bt={bt}");
            let inner = paged.inner();
            let mut want = 2 * inner.buffer_len() * d_h * 2; // live ring rows, k+v, f16
            for r in 0..inner.sparse_len() {
                want += mode.vector_bytes(inner.k_sparse.nnz(r));
                want += mode.vector_bytes(inner.v_sparse.nnz(r));
            }
            assert_eq!(
                paged.storage_bytes(),
                want,
                "mode={mode:?} bt={bt}: Eq. 1 closed form diverged"
            );
        }
    }
}

/// Pool/allocator invariants under adversarial churn: interleaved
/// lease/give_back keeps `leased()` exact, recycles ids, and never
/// corrupts the free list; the refcounted allocator enforces its
/// retain/release discipline.
#[test]
fn pool_and_allocator_survive_churn() {
    let pool = BlockPool::new(64);
    let mut held = Vec::new();
    let mut rng = Pcg64::new(7);
    for step in 0..500 {
        if held.is_empty() || rng.next_u64() % 3 != 0 {
            held.push(pool.lease());
        } else {
            let i = (rng.next_u64() as usize) % held.len();
            pool.give_back(held.swap_remove(i));
        }
        assert_eq!(pool.leased(), held.len(), "step {step}");
        pool.check_invariants().unwrap();
    }
    // ids are recycled: drain, then re-lease and watch an old id return
    let seen: Vec<u32> = held.iter().map(|b| b.id).collect();
    for b in held.drain(..) {
        pool.give_back(b);
    }
    assert_eq!(pool.leased(), 0);
    let again = pool.lease();
    assert!(seen.contains(&again.id), "freed ids must be recycled");
    pool.give_back(again);
    pool.check_invariants().unwrap();

    // the refcounted allocator: retain keeps a block alive across one
    // release; the second release frees it for reuse
    let mut alloc = BlockAllocator::new(8);
    let b = alloc.alloc().unwrap();
    alloc.retain(b);
    assert!(!alloc.release(b), "retained block must stay live");
    assert_eq!(alloc.refcount(b), 1);
    assert!(alloc.release(b), "final release must free the block");
    assert_eq!(alloc.refcount(b), 0);
    assert_eq!(alloc.live(), 0);
    assert_eq!(alloc.capacity(), 8);
    alloc.check_invariants().unwrap();
}
