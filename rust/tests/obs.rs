//! `swan::obs` end to end: exposition validity, exact fleet merge,
//! lifecycle tracing, and the lock-freedom contract of the decode path.
//!
//! The integration half drives a real pipeline group on a synthetic
//! model with a tight block budget (the `tests/pool.rs` topology), so a
//! request is genuinely preempted and resumed — then asserts the
//! retained `TRACE` timeline is complete and ordered, and that the
//! `METRICS` exposition and `STATS` text agree because they read the
//! same registry handles.

use std::collections::HashMap;
use std::sync::Arc;

use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::{Metrics, Request};
use swan::model::transformer::SwanModel;
use swan::obs::{render, render_one, HistSnapshot, Histogram, Registry, Source, Trace, TraceKind};
use swan::shard::pipeline::launch_group;
use swan::shard::{RoundRobin, Router};
use swan::sparse::StorageMode;

// ---------------------------------------------------------------------------
// exposition format

/// Split a sample line into its series key (`name{labels}`) and value.
fn split_sample(line: &str) -> (&str, f64) {
    let (key, val) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value in {line:?}"));
    let v = if val == "+Inf" {
        f64::INFINITY
    } else {
        val.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value in {line:?}"))
    };
    (key, v)
}

/// Series key -> (family name, label block without braces).
fn split_key(key: &str) -> (String, String) {
    match key.split_once('{') {
        Some((name, rest)) => {
            let labels = rest.strip_suffix('}').unwrap_or_else(|| panic!("unbalanced {key:?}"));
            (name.to_string(), labels.to_string())
        }
        None => (key.to_string(), String::new()),
    }
}

/// Validate an exposition end to end: every line is a `# TYPE` comment
/// or a parseable sample; every histogram family's `_bucket` series is
/// cumulative and monotone in `le`, ends at `+Inf`, and `+Inf` equals
/// the family `_count`.
fn check_exposition(text: &str) {
    let mut kinds: HashMap<String, String> = HashMap::new();
    // (family, labels-without-le) -> cumulative bucket counts in order
    let mut buckets: HashMap<(String, String), Vec<(f64, u64)>> = HashMap::new();
    let mut counts: HashMap<(String, String), u64> = HashMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let (name, kind) = (it.next().unwrap(), it.next().unwrap());
            assert!(it.next().is_none(), "trailing tokens in {line:?}");
            assert!(
                matches!(kind, "counter" | "gauge" | "histogram"),
                "unknown kind in {line:?}"
            );
            assert!(
                kinds.insert(name.to_string(), kind.to_string()).is_none(),
                "duplicate # TYPE for {name}"
            );
            continue;
        }
        assert!(!line.starts_with('#'), "unexpected comment {line:?}");
        let (key, value) = split_sample(line);
        let (name, labels) = split_key(key);
        if let Some(fam) = name.strip_suffix("_bucket") {
            let mut le = None;
            let rest: Vec<&str> = labels
                .split(',')
                .filter(|part| match part.strip_prefix("le=\"") {
                    Some(v) => {
                        let v = v.strip_suffix('"').expect("closing quote on le");
                        le = Some(if v == "+Inf" {
                            f64::INFINITY
                        } else {
                            v.parse::<f64>().expect("numeric le")
                        });
                        false
                    }
                    None => true,
                })
                .collect();
            let le = le.unwrap_or_else(|| panic!("bucket line without le: {line:?}"));
            buckets
                .entry((fam.to_string(), rest.join(",")))
                .or_default()
                .push((le, value as u64));
        } else if let Some(fam) = name.strip_suffix("_count") {
            counts.insert((fam.to_string(), labels), value as u64);
        }
    }
    assert!(!kinds.is_empty(), "no # TYPE lines in exposition");
    for ((fam, labels), series) in &buckets {
        assert_eq!(
            kinds.get(fam).map(String::as_str),
            Some("histogram"),
            "{fam} has buckets but is not typed histogram"
        );
        for pair in series.windows(2) {
            assert!(pair[0].0 < pair[1].0, "{fam}: le bounds not increasing");
            assert!(pair[0].1 <= pair[1].1, "{fam}: cumulative counts decreased");
        }
        let (last_le, last_cum) = *series.last().unwrap();
        assert!(last_le.is_infinite(), "{fam}: bucket series must end at +Inf");
        let count = counts
            .get(&(fam.clone(), labels.clone()))
            .unwrap_or_else(|| panic!("{fam}: missing _count for labels {labels:?}"));
        assert_eq!(*count, last_cum, "{fam}: +Inf bucket != _count");
    }
}

/// Golden exposition over a hand-populated registry: exact lines for
/// each metric class, then the structural validity sweep.
#[test]
fn exposition_golden_and_valid() {
    let r = Registry::new();
    r.counter("swan_requests_total", &[("outcome", "completed")]).add(7);
    r.gauge("swan_k_active", &[]).set(8);
    let h = r.histogram("swan_ttft_seconds", &[]);
    h.record_ns(1_000); // -> bucket le = 1024 ns
    h.record_ns(2_000_000); // -> bucket le = 2^21 ns
    let text = render(&[Source::shard(0, &r)]);
    assert!(text.contains("# TYPE swan_requests_total counter\n"), "{text}");
    assert!(text.contains("swan_requests_total{outcome=\"completed\"} 7\n"), "{text}");
    assert!(text.contains("swan_k_active{shard=\"0\"} 8\n"), "{text}");
    assert!(text.contains("swan_ttft_seconds_bucket{le=\"0.000001024\"} 1\n"), "{text}");
    assert!(text.contains("swan_ttft_seconds_bucket{le=\"+Inf\"} 2\n"), "{text}");
    assert!(text.contains("swan_ttft_seconds_sum 0.002001\n"), "{text}");
    assert!(text.contains("swan_ttft_seconds_count 2\n"), "{text}");
    check_exposition(&text);
    // identity labels only decorate gauges: the counter key is unlabeled
    // by shard so fleet sources sum into one series
    assert!(!text.contains("swan_requests_total{outcome=\"completed\",shard"), "{text}");
}

// ---------------------------------------------------------------------------
// merge exactness

#[test]
fn snapshot_merge_is_associative_and_exact() {
    let (a, b, c, one) = (Histogram::new(), Histogram::new(), Histogram::new(), Histogram::new());
    for v in 1..200u64 {
        let target = match v % 3 {
            0 => &a,
            1 => &b,
            _ => &c,
        };
        target.record_ns(v * v * 31);
        one.record_ns(v * v * 31);
    }
    let (sa, sb, sc) = (a.snapshot(), b.snapshot(), c.snapshot());
    let mut left: HistSnapshot = sa.clone();
    left.merge(&sb);
    left.merge(&sc);
    let mut bc = sb.clone();
    bc.merge(&sc);
    let mut right = sa.clone();
    right.merge(&bc);
    assert_eq!(left, right, "merge must be associative");
    assert_eq!(left, one.snapshot(), "merged shards must equal one recording stream");
    assert_eq!(left.count(), 199);
    // quantiles of the merge are quantiles of the union
    assert!((left.quantile_ns(0.5) - one.snapshot().quantile_ns(0.5)).abs() < 1e-9);
}

// ---------------------------------------------------------------------------
// tracing

#[test]
fn trace_lifecycle_is_ordered() {
    let mut t = Trace::new();
    t.begin(42);
    t.record(TraceKind::Admit);
    t.record(TraceKind::PrefillDone);
    t.record(TraceKind::FirstToken);
    for _ in 0..3 {
        t.record(TraceKind::Decode);
    }
    t.record(TraceKind::Preempt);
    t.record(TraceKind::Resume);
    t.record(TraceKind::Decode);
    t.record(TraceKind::Retire);
    let at = |k: TraceKind| t.last_ns(k).unwrap_or_else(|| panic!("missing {:?}", k));
    assert!(at(TraceKind::Submit) <= at(TraceKind::Admit));
    assert!(at(TraceKind::Admit) <= at(TraceKind::PrefillDone));
    assert!(at(TraceKind::PrefillDone) <= at(TraceKind::FirstToken));
    assert!(at(TraceKind::FirstToken) <= at(TraceKind::Preempt));
    assert!(at(TraceKind::Preempt) <= at(TraceKind::Resume));
    assert!(at(TraceKind::Resume) <= at(TraceKind::Retire));
    let j = t.jsonl();
    let lines: Vec<&str> = j.lines().collect();
    assert_eq!(lines.len(), t.events().len());
    assert!(lines[0].contains("\"event\":\"submit\""), "{j}");
    assert!(lines.last().unwrap().contains("\"event\":\"retire\""), "{j}");
    assert!(lines.iter().all(|l| l.contains("\"id\":42")), "{j}");
}

// ---------------------------------------------------------------------------
// concurrency and lock-freedom

/// N threads x M samples with zero coordination: the lock-free recording
/// path must not lose a single sample (relaxed atomics still guarantee
/// every fetch_add lands).
#[test]
fn concurrent_recording_loses_no_samples() {
    const THREADS: usize = 8;
    const SAMPLES: u64 = 10_000;
    let r = Arc::new(Registry::new());
    let h = r.histogram("swan_itl_seconds", &[]);
    let c = r.counter("swan_tokens_total", &[("phase", "decode")]);
    let handles: Vec<_> = (0..THREADS)
        .map(|i| {
            let (h, c) = (h.clone(), c.clone());
            std::thread::spawn(move || {
                for s in 0..SAMPLES {
                    h.record_ns((i as u64 + 1) * 1000 + s);
                    c.inc();
                }
            })
        })
        .collect();
    for t in handles {
        t.join().unwrap();
    }
    let want = THREADS as u64 * SAMPLES;
    assert_eq!(h.snapshot().count(), want, "histogram lost samples");
    assert_eq!(c.get(), want, "counter lost increments");
}

/// The acceptance contract: recording through the handles the decode
/// path holds must never touch the registry Mutex. We prove it by
/// recording *while this thread holds that Mutex* — a recording call
/// that secretly locked it would self-deadlock (std Mutex is not
/// reentrant), so mere completion is the assertion. The handles are the
/// real per-token ones from `coordinator::Metrics`.
#[test]
fn decode_path_recording_is_registry_lock_free() {
    let m = Metrics::default();
    m.registry.with_registration_locked(|| {
        m.itl_seconds.record_ns(1_000);
        m.ttft_seconds.record_ns(2_000);
        m.queue_wait_seconds.record(std::time::Duration::from_micros(5));
        m.decode_tokens.inc();
        m.k_active.set(16);
    });
    assert_eq!(m.itl_seconds.snapshot().count(), 1);
    assert_eq!(m.decode_tokens.get(), 1);
    // same property for a bare registry histogram handle
    let r = Registry::new();
    let h = r.histogram("swan_stage_bubble_seconds", &[]);
    r.with_registration_locked(|| h.record_ns(7));
    assert_eq!(h.snapshot().count(), 1);
}

// ---------------------------------------------------------------------------
// integration: a real preempted-and-resumed request

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "obs-test".into(),
            d_model: 32,
            n_layers: 4,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn first_idx(lines: &[&str], ev: &str) -> usize {
    let needle = format!("\"event\":\"{ev}\"");
    lines
        .iter()
        .position(|l| l.contains(&needle))
        .unwrap_or_else(|| panic!("missing event {ev} in trace:\n{}", lines.join("\n")))
}

/// Drive the `tests/pool.rs` preemption topology (block_tokens=1, a
/// 700-block budget, two 12-token requests) and assert the observability
/// surfaces: the preempted request's retained `TRACE` timeline is a
/// complete ordered lifecycle, `METRICS` is a valid exposition carrying
/// the preemption/SLO series, and `STATS` agrees with it because both
/// read the same registry.
#[test]
fn preempted_request_yields_full_trace_and_metrics() {
    let reqs = vec![
        Request::from_text(1, "the long one ", 12),
        Request::from_text(2, "the bystander ", 12),
    ];
    let budget = 700 * swan::pool::block_bytes(1, 8, StorageMode::F16, 4);
    let cfg = ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: StorageMode::F16,
        max_batch: 8,
        pipeline: 1,
        pool: true,
        block_tokens: 1,
        mem_budget: budget,
        ..Default::default()
    };
    let handle = launch_group(0, test_model(), &cfg).unwrap();
    let router = Router::from_handles(vec![handle], Box::new(RoundRobin::default()));
    let pending: Vec<_> = reqs.iter().map(|r| router.submit(r.clone()).unwrap()).collect();
    for h in pending {
        h.wait().expect("generation ok");
    }
    let preempted: u64 = router.shards().iter().map(|s| s.metrics.requests_preempted.get()).sum();
    assert!(preempted >= 1, "the tight budget must preempt at least once");

    // --- TRACE: some request was preempted; its retained timeline must
    // hold the full ordered lifecycle including the preempt/resume pair.
    let traced = [1u64, 2]
        .into_iter()
        .filter_map(|id| router.trace_jsonl(id))
        .find(|j| j.contains("\"event\":\"preempt\""))
        .expect("a preempted request's trace is retained");
    let lines: Vec<&str> = traced.lines().collect();
    assert_eq!(first_idx(&lines, "submit"), 0, "timeline starts at submit");
    let admit = first_idx(&lines, "admit");
    let prefill = first_idx(&lines, "prefill_done");
    let first_token = first_idx(&lines, "first_token");
    let preempt = first_idx(&lines, "preempt");
    let resume = first_idx(&lines, "resume");
    let retire = first_idx(&lines, "retire");
    assert!(admit < prefill && prefill < first_token, "admission ordering broken");
    assert!(first_token < preempt && preempt < resume, "preemption ordering broken");
    assert!(resume < retire, "resume must precede retire");
    assert_eq!(retire, lines.len() - 1, "retire terminates the timeline");
    // both lifecycles are retained; unknown ids are a clean miss
    assert!(router.trace_jsonl(1).is_some() && router.trace_jsonl(2).is_some());
    assert!(router.trace_jsonl(999).is_none());

    // --- METRICS: valid exposition carrying the serving series.
    let text = router.metrics_text();
    check_exposition(&text);
    for needle in [
        "# TYPE swan_ttft_seconds histogram\n",
        "swan_requests_total{outcome=\"completed\"} 2\n",
        "swan_ttft_seconds_count 2\n",
        "swan_k_active{shard=\"0\"} 4\n",
        "swan_pool_blocks_leased{shard=\"0\",stage=\"0\"}",
        "# TYPE swan_preempt_wait_seconds histogram\n",
        "# TYPE swan_stage_bubble_seconds histogram\n",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    let preempt_line = text
        .lines()
        .find(|l| l.starts_with("swan_preemptions_total"))
        .expect("preemption counter exported");
    assert_eq!(split_sample(preempt_line).1 as u64, preempted, "exposition disagrees");
    let itl_count = text
        .lines()
        .find(|l| l.starts_with("swan_itl_seconds_count"))
        .map(split_sample)
        .expect("ITL histogram exported")
        .1;
    assert!(itl_count >= 1.0, "decode commits must record inter-token gaps");
    let lease_count = text
        .lines()
        .find(|l| l.starts_with("swan_pool_lease_seconds_count"))
        .map(split_sample)
        .expect("pool lease histogram exported")
        .1;
    assert!(lease_count >= 1.0, "pool leases must be timed");

    // --- STATS reads the same handles, so the two surfaces agree.
    let stats = router.stats();
    assert!(stats.contains("completed=2"), "{stats}");
    assert!(stats.contains(&format!("preempted={preempted}")), "{stats}");
    assert!(stats.contains("ttft"), "STATS must surface the SLO rows: {stats}");

    // single-registry sanity: the exposition really is the shard
    // registry rendered (no hidden second bookkeeping surface)
    let direct = render_one(&router.shards()[0].metrics.registry);
    assert!(direct.contains("swan_requests_total{outcome=\"completed\"} 2\n"), "{direct}");
}
