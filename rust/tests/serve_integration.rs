//! Serving-stack integration: engine over the real AOT artifacts, plus a
//! live TCP round-trip.  Skipped cleanly when artifacts are absent.

use std::io::{BufRead, BufReader, Write};

use swan::api::GenParams;
use swan::config::ServeConfig;
use swan::coordinator::Engine;
use swan::sparse::StorageMode;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = swan::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn engine_serves_single_request() {
    let dir = require_artifacts!();
    let mut engine = Engine::new(&dir, ServeConfig::default()).unwrap();
    engine.submit_text("the quick cache stores the ", 12);
    let rs = engine.run_to_completion().unwrap();
    assert_eq!(rs.len(), 1);
    let r = &rs[0];
    assert_eq!(r.stats.decode_steps + 1, r.tokens.len().max(r.stats.decode_steps + 1));
    assert!(r.tokens.len() <= 12);
    assert!(r.text.is_ascii());
    assert!(r.stats.prefill_time.as_nanos() > 0);
}

#[test]
fn engine_batches_multiple_requests() {
    let dir = require_artifacts!();
    let mut engine = Engine::new(&dir, ServeConfig { max_batch: 4, ..Default::default() }).unwrap();
    for i in 0..5 {
        engine.submit_text(&format!("the sparse vector {i} maps the "), 8);
    }
    let rs = engine.run_to_completion().unwrap();
    assert_eq!(rs.len(), 5);
    let ids: std::collections::HashSet<u64> = rs.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), 5, "every request answered exactly once");
}

#[test]
fn swan_saves_memory_vs_dense_serving() {
    let dir = require_artifacts!();
    let prompt = format!(
        "{} the value ",
        swan::eval::corpus::mixed_text(&mut swan::util::Pcg64::new(4), 220)
    );
    let run = |cfg: ServeConfig| {
        let mut engine = Engine::new(&dir, cfg).unwrap();
        engine.submit_text(&prompt, 16);
        engine.run_to_completion().unwrap().pop().unwrap()
    };
    let dense = run(ServeConfig { dense_baseline: true, ..Default::default() });
    let sw = run(ServeConfig { k_active: 16, mode: StorageMode::F8, ..Default::default() });
    assert!(dense.stats.memory_saving().abs() < 1e-6);
    assert!(
        sw.stats.memory_saving() > 0.3,
        "swan saving {:.3} too small",
        sw.stats.memory_saving()
    );
}

#[test]
fn swan_output_tracks_dense_output() {
    // greedy generations should agree for at least the first tokens at
    // mild compression
    let dir = require_artifacts!();
    let prompt = "fact kernel9 is 300 . recall kernel9 -> ";
    let run = |cfg: ServeConfig| {
        let mut engine = Engine::new(&dir, cfg).unwrap();
        engine.submit_text(prompt, 6);
        engine.run_to_completion().unwrap().pop().unwrap().text
    };
    let dense = run(ServeConfig { dense_baseline: true, ..Default::default() });
    let sw = run(ServeConfig { k_active: 48, ..Default::default() });
    assert_eq!(
        dense.chars().take(3).collect::<String>(),
        sw.chars().take(3).collect::<String>(),
        "dense '{dense}' vs swan '{sw}'"
    );
}

#[test]
fn runtime_k_change_applies() {
    let dir = require_artifacts!();
    let mut engine =
        Engine::new(&dir, ServeConfig { k_active: 48, ..Default::default() }).unwrap();
    assert_eq!(engine.current_k_active(), 48);
    engine.set_k_active(16);
    assert_eq!(engine.current_k_active(), 16);
    engine.submit_text("the rotated kernel splits the ", 4);
    let r = engine.run_to_completion().unwrap().pop().unwrap();
    assert!(r.text.is_ascii());
}

#[test]
fn multi_shard_tcp_concurrent_clients_and_fleet_retune() {
    let dir = require_artifacts!();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let cfg = ServeConfig {
        bind: "127.0.0.1:0".into(),
        shards: 2,
        balance: "least-queued".into(),
        ..Default::default()
    };
    std::thread::spawn(move || {
        let _ = swan::server::tcp::serve_with_ready(&dir, cfg, move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(240)).expect("server start");

    // concurrent clients: every generation completes, correctly bounded
    let clients: Vec<_> = (0..4)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = swan::server::client::Client::connect(&addr.to_string()).unwrap();
                let (text, stats) =
                    c.generate(&format!("the sparse vector {i} maps the "), 8).unwrap();
                assert!(text.is_ascii());
                assert!(stats.tokens <= 8, "tokens {} > cap", stats.tokens);
                c.quit();
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    let mut c = swan::server::client::Client::connect(&addr.to_string()).unwrap();

    // live fleet-wide retune: STATS must report the new level on *every*
    // shard, with no engine restarted
    c.set_k_active(16).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains("fleet: shards=2"), "{stats}");
    for shard in 0..2 {
        assert!(stats.contains(&format!("shard {shard}: k_active=16")), "{stats}");
    }
    // the placement policy is also swappable live
    c.set_balance("mem-aware").unwrap();
    c.quit();

    // malformed lines answer a structured ERR and keep the connection
    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    writeln!(stream, "SET").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR bad-args"), "{line}");
    line.clear();
    writeln!(stream, "PING").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "PONG", "connection should survive a bad line");
    writeln!(stream, "QUIT").unwrap();
}

/// The acceptance topology over real artifacts: a `--shards 4
/// --pipeline 2` fleet (2 groups x 2 stages, `Router::launch_pipeline`'s
/// native-model leg) serves the wire protocol, retunes every stage live,
/// and its greedy output tracks a plain single-shard (PJRT) run.  The
/// bit-identity guarantee is native-vs-native (see `tests/pipeline.rs`);
/// across the PJRT/native backend boundary outputs agree to float
/// tolerance, checked here on the leading characters.
#[test]
fn pipeline_fleet_serves_retunes_and_tracks_single_shard() {
    let dir = require_artifacts!();
    let prompt = "fact kernel9 is 300 . recall kernel9 -> ";

    // single-shard (PJRT engine) reference
    let single = {
        let mut engine =
            Engine::new(&dir, ServeConfig { k_active: 48, ..Default::default() }).unwrap();
        engine.submit_text(prompt, 6);
        engine.run_to_completion().unwrap().pop().unwrap().text
    };

    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let cfg = ServeConfig {
        bind: "127.0.0.1:0".into(),
        shards: 4,
        pipeline: 2,
        k_active: 48,
        ..Default::default()
    };
    let sdir = dir.clone();
    std::thread::spawn(move || {
        let _ = swan::server::tcp::serve_with_ready(&sdir, cfg, move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(240)).expect("server start");

    let mut c = swan::server::client::Client::connect(&addr.to_string()).unwrap();
    let (text, stats) = c.generate(prompt, 6).unwrap();
    assert!(text.is_ascii());
    assert!(stats.tokens <= 6);
    assert_eq!(
        single.chars().take(3).collect::<String>(),
        text.chars().take(3).collect::<String>(),
        "single-shard '{single}' vs pipeline '{text}'"
    );

    // live retune reaches every stage of both groups; STATS shows it
    c.set_k_active(16).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.contains("fleet: shards=2"), "{stats}");
    for group in 0..2 {
        assert!(
            stats.contains(&format!("shard {group}: pipeline stages=2 k_active=16")),
            "{stats}"
        );
    }
    assert_eq!(stats.matches("stage 0: layers").count(), 2, "{stats}");
    assert_eq!(stats.matches("stage 1: layers").count(), 2, "{stats}");
    c.quit();
}

/// Protocol v2 over real artifacts: keyword `GEN` (per-request k,
/// sampling params), the surfaced `max_new` clamp, `TOK` streaming,
/// `CANCEL` from a second connection, and disconnect-cancel leaving the
/// server healthy.
#[test]
fn protocol_v2_streaming_cancel_and_per_request_k() {
    let dir = require_artifacts!();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let cfg = ServeConfig { bind: "127.0.0.1:0".into(), ..Default::default() };
    std::thread::spawn(move || {
        let _ = swan::server::tcp::serve_with_ready(&dir, cfg, move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(240)).expect("server start");
    let mut c = swan::server::client::Client::connect(&addr.to_string()).unwrap();

    // keyword GEN: per-request compression override + typed sampling
    let g = c
        .generate_with(
            "the quick cache stores the ",
            &GenParams::new(8).k_active(16).temperature(0.7).seed(9),
        )
        .unwrap();
    assert!(g.id > 0);
    assert!(g.text.is_ascii());
    assert!(g.stats.tokens <= 8);
    assert_eq!(g.clamped_to, None);
    assert!(!g.stats.cancelled);

    // two requests with different k on the same fleet both answer
    let lo = c.generate_with("fact kernel9 is 300 . recall kernel9 -> ", &GenParams::new(6).k_active(16)).unwrap();
    let hi = c.generate_with("fact kernel9 is 300 . recall kernel9 -> ", &GenParams::new(6).k_active(48)).unwrap();
    assert!(lo.text.is_ascii() && hi.text.is_ascii());

    // streaming: TOK lines reassemble the final text
    let mut streamed = String::new();
    let g = c
        .generate_stream("stream the value ", &GenParams::new(8).stream(true), |_, t| {
            streamed.push_str(t)
        })
        .unwrap();
    assert_eq!(streamed, g.text, "TOK lines must reassemble the OK text");

    // oversized max_new is clamped AND surfaced (reply + stats)
    let g = c.generate_with("clamped ", &GenParams::new(5000).stop(0)).unwrap();
    assert_eq!(g.clamped_to, Some(ServeConfig::default().max_new_hard_cap()));
    assert_eq!(g.stats.requested, Some(5000));

    // CANCEL from a second connection retires a mid-decode stream
    let mut s1 = std::net::TcpStream::connect(addr).unwrap();
    let mut r1 = BufReader::new(s1.try_clone().unwrap());
    writeln!(s1, "GEN max_new=512 stream=1 the long running prompt ").unwrap();
    let mut line = String::new();
    r1.read_line(&mut line).unwrap();
    assert!(line.starts_with("TOK "), "{line}");
    let id: u64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    c.cancel(id).unwrap();
    let ok_line = loop {
        line.clear();
        r1.read_line(&mut line).unwrap();
        if line.starts_with("OK ") {
            break line.clone();
        }
        assert!(line.starts_with("TOK "), "{line}");
    };
    assert!(ok_line.starts_with(&format!("OK {id}")), "{ok_line}");
    line.clear();
    r1.read_line(&mut line).unwrap();
    assert!(line.starts_with("STAT "), "{line}");
    assert!(line.contains("cancelled=1"), "cancel must be surfaced: {line}");
    writeln!(s1, "QUIT").unwrap();

    // disconnect mid-GEN: drop the socket without reading the reply;
    // the reader loop observes EOF and cancels the abandoned sequence,
    // and the server keeps serving
    {
        let mut s2 = std::net::TcpStream::connect(addr).unwrap();
        writeln!(s2, "GEN max_new=512 stream=1 abandoned request ").unwrap();
        // read one TOK so the request is provably decoding, then vanish
        let mut r2 = BufReader::new(s2.try_clone().unwrap());
        let mut l = String::new();
        r2.read_line(&mut l).unwrap();
        assert!(l.starts_with("TOK "), "{l}");
    }
    c.ping().unwrap();
    let (text, _) = c.generate("still serving after the disconnect ", 4).unwrap();
    assert!(text.is_ascii());
    c.quit();
}

#[test]
fn tcp_round_trip() {
    let dir = require_artifacts!();
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let cfg = ServeConfig { bind: "127.0.0.1:0".into(), ..Default::default() };
    std::thread::spawn(move || {
        let _ = swan::server::tcp::serve_with_ready(&dir, cfg, move |a| {
            let _ = addr_tx.send(a);
        });
    });
    let addr = addr_rx.recv_timeout(std::time::Duration::from_secs(120)).expect("server start");

    let mut stream = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    writeln!(stream, "PING").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "PONG");

    line.clear();
    writeln!(stream, "SET k_active 32").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "OK");

    line.clear();
    writeln!(stream, "GEN 8 the quick cache stores the ").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("STAT "), "{line}");

    line.clear();
    writeln!(stream, "STATS").unwrap();
    let mut saw_dot = false;
    for _ in 0..32 {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if line.trim() == "." {
            saw_dot = true;
            break;
        }
    }
    assert!(saw_dot, "STATS terminator missing");

    writeln!(stream, "QUIT").unwrap();
}
