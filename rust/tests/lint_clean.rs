//! Tier-1 gate: `swan-lint` must report zero findings on the tree.
//!
//! Every rule (panic-path audit, lock-order analysis, atomic-ordering
//! audit, hot-path allocation audit, wire-protocol drift) runs against
//! `rust/src` plus the README protocol table.  A finding here means
//! either new code broke an invariant or it needs a justified
//! `// lint: allow(<rule>, "<why>")` annotation — see README
//! §Static analysis.

use std::path::Path;

#[test]
fn swan_lint_reports_zero_findings() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src = manifest.join("src");
    let readme = manifest.join("../README.md");
    let findings = swan_lint::analyze_tree(&src, Some(&readme)).expect("lint walk failed");
    assert!(
        findings.is_empty(),
        "swan-lint found {} issue(s):\n{}",
        findings.len(),
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
