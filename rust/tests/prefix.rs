//! Cross-request prefix caching (`swan::prefix`), end to end on a
//! synthetic model (no artifacts).
//!
//! The contract under test: winnowed state is a pure function of tokens
//! x compression config, so a prefix-hit admission — attach the cached
//! blocks copy-on-write, prefill only the uncached suffix — is
//! **bit-identical** to a cold admission of the same request under the
//! same prefix-mode group.  On top of that: COW forks never corrupt
//! their sharers, refcounts stay exact under insert/hit/evict churn,
//! memory pressure sheds cold tree entries *before* preempting running
//! sequences, and the router's affinity placement sends repeat prompts
//! back to the shard that cached them.

use std::sync::Arc;
use std::time::Duration;

use swan::api::GenParams;
use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::Request;
use swan::kvcache::CachePolicy;
use swan::model::transformer::SwanModel;
use swan::pool::{block_bytes, pool_blocks_for_budget, seq_blocks, BlockPool, PagedSwanCache};
use swan::prefix::{insert_depth, EntryStream};
use swan::shard::balance::policy_from_name;
use swan::shard::pipeline::launch_group;
use swan::shard::{RoundRobin, Router};
use swan::sparse::StorageMode;
use swan::swan::SwanParams;
use swan::util::Pcg64;

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "prefix-test".into(),
            d_model: 32,
            n_layers: 4,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: StorageMode::F16,
        max_batch: 8,
        ..Default::default()
    }
}

/// Launch one prefix-enabled pipeline group behind a router.
fn launch_prefix_fleet(cfg: &ServeConfig) -> Router {
    let handle = launch_group(0, test_model(), cfg).unwrap();
    Router::from_handles(vec![handle], Box::new(RoundRobin::default()))
}

/// Sum a prefix counter across the fleet.
fn fleet_counter(router: &Router, pick: impl Fn(&swan::coordinator::Metrics) -> u64) -> u64 {
    router.shards().iter().map(|s| pick(&s.metrics)).sum()
}

/// The tentpole acceptance property: a warm (prefix-hit) generation is
/// bit-identical to the cold (prefix-miss) generation of the same
/// request, across block sizes and pipeline depths.  Seeds are pinned —
/// the decode RNG otherwise derives from the request id, and the two
/// submissions carry different ids on purpose (a repeat request is a
/// *new* request).
#[test]
fn prefix_hit_decode_is_bit_identical_to_cold() {
    let prompt = "the shared instruction preamble winnows the cache ";
    for stages in [1usize, 2] {
        for bt in [1usize, 5, 16] {
            let cfg = ServeConfig {
                pipeline: stages,
                prefix: true,
                block_tokens: bt,
                ..serve_cfg()
            };
            let router = launch_prefix_fleet(&cfg);
            let params = GenParams::new(10).seed(7);
            let cold = router
                .submit(Request::with_params(1, prompt, params.clone()))
                .unwrap()
                .wait()
                .unwrap();
            let warm = router
                .submit(Request::with_params(2, prompt, params))
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(
                cold.tokens, warm.tokens,
                "prefix hit diverged from cold run: stages={stages} block_tokens={bt}"
            );
            let prompt_len = swan::coordinator::request::encode_text(prompt).len();
            assert!(prompt_len > 16, "prompt must span > 1 block at every bt");
            assert_eq!(fleet_counter(&router, |m| m.prefix_hits.get()), 1);
            assert_eq!(fleet_counter(&router, |m| m.prefix_misses.get()), 1);
            assert_eq!(
                fleet_counter(&router, |m| m.prefix_tokens_saved.get()),
                insert_depth(prompt_len, bt) as u64,
                "tokens_saved must equal the matched full-block depth: bt={bt}"
            );
            let stats = router.stats();
            assert!(stats.contains("prefix: entries="), "{stats}");
            assert!(stats.contains("hit_rate=50.0%"), "{stats}");
        }
    }
}

/// The reuse key covers the whole compression config: f8 storage and
/// per-request `k` overrides hit only entries built under the *same*
/// config, and a mismatched `k` is a miss (never a wrong reuse), while
/// matched pairs stay bit-identical — including under temperature
/// sampling and decode workers.
#[test]
fn prefix_hit_is_bit_identical_across_modes_and_per_request_k() {
    let prompt = "mixed configuration prompts share a winnowed preamble ";
    let cases: [(StorageMode, GenParams); 3] = [
        (StorageMode::F16, GenParams::new(10).temperature(0.8).seed(11)),
        (StorageMode::F8, GenParams::new(10).seed(12)),
        (StorageMode::F16, GenParams::new(10).k_active(2).seed(13)),
    ];
    for (mode, params) in cases {
        let cfg = ServeConfig {
            pipeline: 2,
            decode_workers: 2,
            prefix: true,
            block_tokens: 5,
            mode,
            ..serve_cfg()
        };
        let router = launch_prefix_fleet(&cfg);
        let cold = router
            .submit(Request::with_params(1, prompt, params.clone()))
            .unwrap()
            .wait()
            .unwrap();
        let warm = router
            .submit(Request::with_params(2, prompt, params.clone()))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(cold.tokens, warm.tokens, "mode={mode:?} params={params:?}");
        assert_eq!(fleet_counter(&router, |m| m.prefix_hits.get()), 1, "mode={mode:?}");
        if params.k_active.is_some() {
            // same prompt at a different compression level: the entry
            // key differs, so this must miss (and insert its own entry)
            let other_k = router
                .submit(Request::with_params(3, prompt, GenParams::new(10).k_active(6).seed(13)))
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(other_k.tokens.len(), 10);
            assert_eq!(fleet_counter(&router, |m| m.prefix_hits.get()), 1);
            assert_eq!(fleet_counter(&router, |m| m.prefix_misses.get()), 2);
        }
    }
}

/// COW fork-before-mutate: two concurrent generations share one cached
/// prefix and extend it divergently; both streams match what a fresh
/// (cold) group produces for the same requests, and the shared-block
/// gauge confirms physical sharing actually happened.
#[test]
fn cow_forked_sequences_stay_bit_identical_under_concurrent_sharing() {
    let common = "the common system preamble attached to every request ";
    let req_a = || {
        Request::with_params(2, &format!("{common}alpha branch"), GenParams::new(10).seed(3))
    };
    let req_b = || {
        Request::with_params(3, &format!("{common}beta fork path"), GenParams::new(10).seed(4))
    };
    let cfg = ServeConfig {
        pipeline: 2,
        decode_workers: 2,
        prefix: true,
        block_tokens: 4,
        ..serve_cfg()
    };
    // cold references: each request alone in its own fresh group (the
    // first admission under prefix mode is the cold path)
    let want_a = launch_prefix_fleet(&cfg).submit(req_a()).unwrap().wait().unwrap().tokens;
    let want_b = launch_prefix_fleet(&cfg).submit(req_b()).unwrap().wait().unwrap().tokens;

    // warm fleet: retire the common prefix once, then fork it twice
    // concurrently
    let router = launch_prefix_fleet(&cfg);
    router
        .submit(Request::with_params(1, common, GenParams::new(4).seed(2)))
        .unwrap()
        .wait()
        .unwrap();
    let ha = router.submit(req_a()).unwrap();
    let hb = router.submit(req_b()).unwrap();
    let got_a = ha.wait().unwrap().tokens;
    let got_b = hb.wait().unwrap().tokens;
    assert_eq!(got_a, want_a, "fork A diverged while sharing the prefix");
    assert_eq!(got_b, want_b, "fork B diverged while sharing the prefix");
    assert!(
        fleet_counter(&router, |m| m.prefix_hits.get()) >= 2,
        "both forks must hit the cached prefix"
    );
    assert!(
        fleet_counter(&router, |m| m.prefix_blocks_shared.get()) > 0,
        "forks long enough to span full blocks must share physical blocks"
    );
}

/// Refcount exactness under churn: 500 insert/hit/evict cycles across
/// interleaved entry lifetimes, with two sharers extending every entry
/// concurrently (COW forks), leak zero blocks and never trip a pool
/// invariant.  Periodically the two forks append identical rows and
/// must read back identical sparse state — a mutation leaking through
/// a shared block would diverge them.
#[test]
fn prefix_store_refcounts_stay_exact_after_churn() {
    let d_h = 8usize;
    let pool = Arc::new(BlockPool::new(usize::MAX));
    let params = SwanParams::new(4, 3, StorageMode::F16);
    let mut rng = Pcg64::new(11);
    let mut entries: Vec<EntryStream> = Vec::new();
    for cycle in 0..500usize {
        let bt = [1usize, 2, 4][cycle % 3];
        let depth = 5 + (cycle % 9);
        let mut donor = PagedSwanCache::new(d_h, params, bt, pool.clone());
        let mut rings = (Vec::new(), Vec::new());
        for t in 1..=depth + 3 {
            let k = rng.normal_vec(d_h);
            let v = rng.normal_vec(d_h);
            donor.append(&k, &v);
            if t == depth {
                // the pipeline captures the ring when the cache holds
                // exactly the prefix (later winnowing destroys it)
                rings = donor.ring_snapshot();
            }
        }
        let entry = donor.share_prefix(depth, rings, pool.clone());
        let mut sharers: Vec<PagedSwanCache> = (0..2)
            .map(|_| {
                let mut c = PagedSwanCache::new(d_h, params, bt, pool.clone());
                c.attach_prefix(&entry, depth);
                c
            })
            .collect();
        let ext: Vec<(Vec<f32>, Vec<f32>)> =
            (0..3).map(|_| (rng.normal_vec(d_h), rng.normal_vec(d_h))).collect();
        for s in &mut sharers {
            for (k, v) in &ext {
                s.append(k, v);
            }
        }
        if cycle % 50 == 0 {
            let (a, b) = (sharers[0].inner(), sharers[1].inner());
            assert_eq!(a.sparse_len(), b.sparse_len(), "cycle {cycle}");
            for r in 0..a.sparse_len() {
                assert_eq!(a.k_sparse.row(r), b.k_sparse.row(r), "cycle {cycle} row {r}");
                assert_eq!(a.v_sparse.row(r), b.v_sparse.row(r), "cycle {cycle} row {r}");
            }
        }
        drop(donor);
        drop(sharers);
        entries.push(entry);
        if entries.len() > 4 {
            // evict the coldest of the interleaved lifetimes
            entries.remove(0);
        }
        pool.check_invariants().unwrap();
    }
    entries.clear();
    assert_eq!(pool.leased(), 0, "churn leaked blocks");
    pool.check_invariants().unwrap();
}

/// Under block-budget pressure the coordinator sheds cold tree entries
/// *before* preempting running sequences: a tight budget whose headroom
/// is consumed by a retired prefix admits new work by evicting the
/// entry, never by preemption.
#[test]
fn prefix_entries_shed_before_preemption_under_pressure() {
    let budget_blocks = 800usize;
    let budget = budget_blocks * block_bytes(1, 8, StorageMode::F16, 4);
    assert_eq!(pool_blocks_for_budget(budget, 1, 8, StorageMode::F16, 4), budget_blocks);
    let cfg = ServeConfig {
        prefix: true,
        block_tokens: 1,
        mem_budget: budget,
        ..serve_cfg()
    };
    let router = launch_prefix_fleet(&cfg);

    // retire a long prompt: its full-block prefix stays in the tree,
    // pinned at the analytic rate — most of the budget
    let long = "the very long shared preamble that fills ";
    let p = swan::coordinator::request::encode_text(long).len();
    let charge = seq_blocks(insert_depth(p, 1), 3, 1, 4, 2);
    assert!(charge > budget_blocks / 2, "prefix charge too small to pressure the pool");
    assert!(seq_blocks(p + 1, 3, 1, 4, 2) <= budget_blocks, "warmup itself must fit");
    router
        .submit(Request::with_params(1, long, GenParams::new(2).seed(1)))
        .unwrap()
        .wait()
        .unwrap();

    // two fresh decodes need more than the remaining headroom
    let h2 = router.submit(Request::with_params(2, "ab c", GenParams::new(12).seed(2))).unwrap();
    let h3 = router.submit(Request::with_params(3, "xy z", GenParams::new(12).seed(3))).unwrap();
    assert_eq!(h2.wait().unwrap().tokens.len(), 12);
    assert_eq!(h3.wait().unwrap().tokens.len(), 12);

    assert!(
        fleet_counter(&router, |m| m.prefix_evictions.get()) >= 1,
        "pressure must evict the cold tree entry"
    );
    assert_eq!(
        fleet_counter(&router, |m| m.requests_preempted.get()),
        0,
        "shedding the tree must spare the running sequences"
    );
    assert_eq!(fleet_counter(&router, |m| m.requests_completed.get()), 3);
}

/// `SET prefix off` flushes the tree: stage pools drain to zero leased
/// blocks, the STATS tree line disappears, and a re-enabled tree starts
/// empty (a repeat of a previously cached prompt misses again).
#[test]
fn set_prefix_off_flushes_entries_and_drains_blocks() {
    let cfg = ServeConfig {
        pipeline: 2,
        prefix: true,
        block_tokens: 4,
        ..serve_cfg()
    };
    let router = launch_prefix_fleet(&cfg);
    let prompt = "a prompt cached once and then flushed away ";
    router
        .submit(Request::with_params(1, prompt, GenParams::new(6).seed(5)))
        .unwrap()
        .wait()
        .unwrap();
    assert!(router.stats().contains("prefix: entries=1"));

    let acks = router.set_prefix(false).unwrap();
    assert_eq!(acks, vec![(0, true)]);
    let stats = router.stats();
    assert!(!stats.contains("prefix: entries="), "{stats}");
    // Retire and PrefixEvict are FIFO-ordered before the stats request
    // in each stage channel: with the tree flushed and every sequence
    // retired, both stages deterministically report zero leased blocks
    assert_eq!(stats.matches(" blocks=0").count(), 2, "{stats}");

    let acks = router.set_prefix(true).unwrap();
    assert_eq!(acks, vec![(0, true)]);
    router
        .submit(Request::with_params(2, prompt, GenParams::new(6).seed(5)))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(
        fleet_counter(&router, |m| m.prefix_hits.get()),
        0,
        "a flushed tree must not serve stale entries"
    );
    assert_eq!(fleet_counter(&router, |m| m.prefix_misses.get()), 2);
}

/// Mixed-affinity placement: two prompts warmed on two different shards
/// (round-robin), then repeats submitted under `mem-aware` — affinity
/// must route each repeat back to the shard holding its prefix, so both
/// repeats hit (the no-affinity tie-break would send both to one shard
/// and one of them would miss).
#[test]
fn router_routes_repeats_to_their_cached_shard() {
    let cfg = ServeConfig {
        shards: 2,
        pipeline: 1,
        balance: "round-robin".into(),
        prefix: true,
        block_tokens: 4,
        ..serve_cfg()
    };
    let router = Router::launch_pipeline_from_model(test_model(), &cfg, Vec::new()).unwrap();
    let p = "alpha team prompt preamble with enough length to cache ";
    let q = "omega crew prompt preamble with enough length to cache ";
    let first_p = router
        .submit(Request::with_params(1, p, GenParams::new(6).seed(5)))
        .unwrap()
        .wait()
        .unwrap();
    let first_q = router
        .submit(Request::with_params(2, q, GenParams::new(6).seed(6)))
        .unwrap()
        .wait()
        .unwrap();
    for s in router.shards() {
        assert_eq!(s.metrics.prefix_misses.get(), 1, "warmups must land on distinct shards");
    }

    // wait for both groups to publish their fingerprint sets (published
    // when a group goes idle), then score placement on them
    for _ in 0..500 {
        let published = router
            .shards()
            .iter()
            .all(|s| !s.status.prefix_fps.lock().unwrap().is_empty());
        if published {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    router.set_policy(policy_from_name("mem-aware").unwrap());

    let warm_p = router
        .submit(Request::with_params(3, p, GenParams::new(6).seed(5)))
        .unwrap()
        .wait()
        .unwrap();
    let warm_q = router
        .submit(Request::with_params(4, q, GenParams::new(6).seed(6)))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(warm_p.tokens, first_p.tokens);
    assert_eq!(warm_q.tokens, first_q.tokens);
    let per_shard: Vec<u64> =
        router.shards().iter().map(|s| s.metrics.prefix_hits.get()).collect();
    assert_eq!(
        per_shard,
        vec![1, 1],
        "affinity must route each repeat to the shard caching its prefix"
    );
}
