//! Property-based tests on coordinator and cache invariants (in-repo
//! harness; proptest is unavailable offline).

use swan::coordinator::sequence::{CacheShape, SeqCache};
use swan::simd::Kernels;
use swan::sparse::topk::{topk_indices, topk_indices_select};
use swan::sparse::{SparseStore, SparseVec, StorageMode};
use swan::swan::attention::{dense_attention, swan_attention};
use swan::swan::hybrid_cache::{HybridCache, SwanParams};
use swan::swan::projection::ProjectionSet;
use swan::tensor::ops::matvec;
use swan::testing::prop::{check, gen_vec};
use swan::util::Pcg64;

/// Relative-ish tolerance for cross-kernel comparisons (different
/// accumulation trees, same math).
fn kernel_close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

/// topk select variant == sort variant on arbitrary inputs.
#[test]
fn prop_topk_variants_agree() {
    check("topk-agree", 300, |r| {
        let v = gen_vec(r, 96);
        let k = r.below(v.len() as u64 + 1) as usize;
        (v, k)
    }, |(v, k)| {
        let a = topk_indices(v, *k);
        let b = topk_indices_select(v, *k);
        if a == b { Ok(()) } else { Err(format!("{a:?} != {b:?}")) }
    });
}

/// SWAN sparse-dense dot == dot of reconstruction (decompression-free
/// computation is exact w.r.t. the stored representation).
#[test]
fn prop_sparse_dot_matches_reconstruction() {
    check("sparse-dot", 200, |r| {
        let d = 4 + r.below(96) as usize;
        let k = 1 + r.below(d as u64) as usize;
        let x = r.normal_vec(d);
        let q = r.normal_vec(d);
        (x, (q, k))
    }, |(x, (q, k))| {
        let sv = SparseVec::prune(x, *k, StorageMode::F32);
        let direct = sv.dot_dense(q);
        let recon = swan::tensor::ops::dot(&sv.reconstruct(), q);
        if (direct - recon).abs() < 1e-4 {
            Ok(())
        } else {
            Err(format!("{direct} vs {recon}"))
        }
    });
}

/// HybridCache invariant: token conservation — every appended token is in
/// the buffer or the sparse store, in order; memory accounting matches the
/// closed-form Eq. 1 sum.
#[test]
fn prop_hybrid_cache_conserves_tokens() {
    check("cache-conserve", 150, |r| {
        let n = r.below(60) as usize;
        let buffer = r.below(16) as usize;
        let k = 1 + r.below(16) as usize;
        (n, (buffer, k))
    }, |(n, (buffer, k))| {
        let d = 16;
        let mut c = HybridCache::new(d, SwanParams::new(*k, *buffer, StorageMode::F16));
        let mut r2 = Pcg64::new(7);
        for _ in 0..*n {
            c.append(&r2.normal_vec(d), &r2.normal_vec(d));
        }
        if c.len() != *n {
            return Err(format!("len {} != {n}", c.len()));
        }
        let expect_sparse = n.saturating_sub(*buffer);
        if c.sparse_len() != expect_sparse {
            return Err(format!("sparse {} != {expect_sparse}", c.sparse_len()));
        }
        let kk = (*k).min(d);
        let expect_bytes =
            expect_sparse * 2 * (3 * kk + 2) + (n - expect_sparse) * 2 * d * 2;
        if c.storage_bytes() != expect_bytes {
            return Err(format!("bytes {} != {expect_bytes}", c.storage_bytes()));
        }
        Ok(())
    });
}

/// The hybrid attention is a convex combination: with all values equal to
/// c, the output is exactly c regardless of pruning (value vectors of
/// constant c prune to k entries, so this holds only at full retention —
/// use k = d).
#[test]
fn prop_attention_convexity_full_k() {
    check("attn-convex", 100, |r| {
        let n = 1 + r.below(30) as usize;
        let buffer = r.below(8) as usize;
        (n, buffer)
    }, |(n, buffer)| {
        let d = 8;
        let mut c = HybridCache::new(d, SwanParams::new(d, *buffer, StorageMode::F32));
        let mut r2 = Pcg64::new(11);
        for _ in 0..*n {
            c.append(&r2.normal_vec(d), &vec![2.5; d]);
        }
        let q = r2.normal_vec(d);
        let mut out = vec![0.0; d];
        swan_attention(&q, &c, &r2.normal_vec(d), &vec![2.5; d], &mut out);
        for &o in &out {
            if (o - 2.5).abs() > 1e-4 {
                return Err(format!("{o}"));
            }
        }
        Ok(())
    });
}

/// SeqCache (PJRT layout) and HybridCache (native layout) agree on
/// bookkeeping counters under identical append streams.
#[test]
fn prop_seqcache_matches_hybridcache_counters() {
    check("seq-vs-hybrid", 100, |r| {
        let n = r.below(50) as usize;
        let k = 1 + r.below(8) as usize;
        (n, k)
    }, |(n, k)| {
        let shape = CacheShape { n_layers: 2, n_kv: 1, d_head: 8, buf_cap: 4 };
        let mut seq = SeqCache::new(shape, 64, *k, StorageMode::F16);
        let mut hyb = HybridCache::new(8, SwanParams::new(*k, 4, StorageMode::F16));
        let mut r2 = Pcg64::new(3);
        for _ in 0..*n {
            let kv = r2.normal_vec(2 * 8);
            let vv = r2.normal_vec(2 * 8);
            seq.append(&kv, &vv);
            hyb.append(&kv[..8].to_vec(), &vv[..8].to_vec());
        }
        if seq.buf_len != hyb.buffer_len() {
            return Err(format!("buf {} != {}", seq.buf_len, hyb.buffer_len()));
        }
        if seq.sparse_len != hyb.sparse_len() {
            return Err(format!("sparse {} != {}", seq.sparse_len, hyb.sparse_len()));
        }
        // per-(layer,head) byte accounting must agree too (seq counts 2
        // layers x 1 head; hybrid counts 1)
        if seq.storage_bytes() != 2 * hyb.storage_bytes() {
            return Err(format!("{} != 2*{}", seq.storage_bytes(), hyb.storage_bytes()));
        }
        Ok(())
    });
}

/// SparseStore structural invariants survive arbitrary interleavings of
/// per-row `k` (including 0 and > d) and storage modes, and the Eq. 1
/// byte accounting matches the per-row closed form exactly.
#[test]
fn prop_store_invariants_under_mixed_pushes() {
    let modes = [StorageMode::F32, StorageMode::F16, StorageMode::F8];
    check("store-mixed", 150, |r| {
        let rows = r.below(20) as usize;
        (0..rows)
            .map(|_| (r.below(20) as usize, r.below(3) as usize))
            .collect::<Vec<(usize, usize)>>()
    }, |pushes| {
        let d = 16usize;
        let mut rng = Pcg64::new(13);
        let mut store = SparseStore::new();
        let mut expect_bytes = 0usize;
        for (i, &(k, m)) in pushes.iter().enumerate() {
            let mode = modes[m % 3];
            store.push_pruned(&rng.normal_vec(d), k, mode);
            store.check_invariants()?;
            let kk = k.min(d);
            if store.nnz(i) != kk {
                return Err(format!("row {i}: nnz {} != {kk}", store.nnz(i)));
            }
            expect_bytes += mode.vector_bytes(kk);
        }
        if store.len() != pushes.len() {
            return Err(format!("len {} != {}", store.len(), pushes.len()));
        }
        if store.storage_bytes() != expect_bytes {
            return Err(format!("bytes {} != {expect_bytes}", store.storage_bytes()));
        }
        Ok(())
    });
}

/// The batched CSR walks (`scores_into` / `axpy_all`) agree with a naive
/// per-row implementation over `row()`.
#[test]
fn prop_store_walks_match_naive() {
    check("store-walks", 150, |r| {
        let n = r.below(24) as usize;
        let k = 1 + r.below(16) as usize;
        (n, k)
    }, |(n, k)| {
        let d = 32usize;
        let mut rng = Pcg64::new(17);
        let mut store = SparseStore::new();
        for _ in 0..*n {
            store.push_pruned(&rng.normal_vec(d), *k, StorageMode::F16);
        }
        let q = rng.normal_vec(d);
        let mut scores = Vec::new();
        store.scores_into(&q, 0.5, &mut scores);
        if scores.len() != *n {
            return Err(format!("scores len {} != {n}", scores.len()));
        }
        for r in 0..store.len() {
            let (vals, idx) = store.row(r);
            let naive: f32 =
                vals.iter().zip(idx).map(|(v, &i)| v * q[i as usize]).sum::<f32>() * 0.5;
            if (scores[r] - naive).abs() > 1e-4 {
                return Err(format!("row {r}: {} vs {naive}", scores[r]));
            }
        }
        let w: Vec<f32> = (0..*n).map(|i| 0.2 - 0.01 * i as f32).collect();
        let mut out = vec![0.0f32; d];
        store.axpy_all(&w, &mut out);
        let mut naive = vec![0.0f32; d];
        for r in 0..store.len() {
            let (vals, idx) = store.row(r);
            for (v, &i) in vals.iter().zip(idx) {
                naive[i as usize] += w[r] * v;
            }
        }
        for (a, b) in out.iter().zip(&naive) {
            if (a - b).abs() > 1e-4 {
                return Err(format!("axpy {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Lossless-retention invariant: at `k_active = d_h` (f32 storage) the
/// decompression-free kernel reproduces dense attention for any sequence
/// length and buffer split.  Shrinks on both.
#[test]
fn prop_swan_attention_exact_at_full_k() {
    check("attn-exact-full-k", 150, |r| {
        let n = 1 + r.below(30) as usize;
        let buffer = r.below(8) as usize;
        (n, buffer)
    }, |(n, buffer)| {
        let d = 16usize;
        let mut rng = Pcg64::new(23);
        let mut cache = HybridCache::new(d, SwanParams::new(d, *buffer, StorageMode::F32));
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        for _ in 0..*n {
            let kv = rng.normal_vec(d);
            let vv = rng.normal_vec(d);
            cache.append(&kv, &vv);
            kflat.extend_from_slice(&kv);
            vflat.extend_from_slice(&vv);
        }
        let q = rng.normal_vec(d);
        let kc = rng.normal_vec(d);
        let vc = rng.normal_vec(d);
        let mut got = vec![0.0; d];
        swan_attention(&q, &cache, &kc, &vc, &mut got);
        let mut want = vec![0.0; d];
        dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut want);
        for (a, b) in got.iter().zip(&want) {
            if (a - b).abs() > 1e-4 {
                return Err(format!("{a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Bounded error under pruning: both outputs are convex combinations of
/// value rows (winnowed rows may zero dims), so every output dim must lie
/// in the per-dim hull `[min(0, values), max(0, values)]` and the
/// swan-dense gap cannot exceed the hull width.  Shrinks on sequence
/// length and `k_active`.
#[test]
fn prop_swan_attention_error_bounded_under_pruning() {
    check("attn-bounded-pruned", 150, |r| {
        let n = 1 + r.below(24) as usize;
        let k = 1 + r.below(16) as usize;
        (n, k)
    }, |(n, k)| {
        let d = 16usize;
        let eps = 1e-3f32;
        let mut rng = Pcg64::new(29);
        let mut cache = HybridCache::new(d, SwanParams::new(*k, 2, StorageMode::F32));
        let mut vrows: Vec<Vec<f32>> = Vec::new();
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        for _ in 0..*n {
            let kv = rng.normal_vec(d);
            let vv = rng.normal_vec(d);
            cache.append(&kv, &vv);
            kflat.extend_from_slice(&kv);
            vflat.extend_from_slice(&vv);
            vrows.push(vv);
        }
        let q = rng.normal_vec(d);
        let kc = rng.normal_vec(d);
        let vc = rng.normal_vec(d);
        let mut got = vec![0.0; d];
        swan_attention(&q, &cache, &kc, &vc, &mut got);
        let mut want = vec![0.0; d];
        dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut want);
        for i in 0..d {
            let mut lo = 0.0f32.min(vc[i]);
            let mut hi = 0.0f32.max(vc[i]);
            for vr in &vrows {
                lo = lo.min(vr[i]);
                hi = hi.max(vr[i]);
            }
            if got[i] < lo - eps || got[i] > hi + eps {
                return Err(format!("dim {i}: {} outside hull [{lo}, {hi}]", got[i]));
            }
            if (got[i] - want[i]).abs() > (hi - lo) + 2.0 * eps {
                return Err(format!(
                    "dim {i}: gap {} exceeds hull width {}",
                    (got[i] - want[i]).abs(),
                    hi - lo
                ));
            }
        }
        Ok(())
    });
}

/// Rotation-lossless invariant (rust mirror of
/// `python/tests/test_rotation_lossless.py`): with orthogonal P_QK/P_VO
/// and full retention, attending in the rotated space and un-rotating the
/// output reproduces unrotated dense attention.
#[test]
fn prop_rotation_lossless_at_full_retention() {
    check("rotation-lossless", 60, |r| {
        let n = 1 + r.below(16) as usize;
        let seed = r.below(1000) as usize;
        (n, seed)
    }, |(n, seed)| {
        let d = 16usize;
        let ps = ProjectionSet::random(1, 1, d, *seed as u64 + 1);
        let mut rng = Pcg64::new(31);
        let mut cache = HybridCache::new(d, SwanParams::new(d, 3, StorageMode::F32));
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        let mut krot = vec![0.0f32; d];
        let mut vrot = vec![0.0f32; d];
        for _ in 0..*n {
            let kv = rng.normal_vec(d);
            let vv = rng.normal_vec(d);
            ps.rotate_qk(0, 0, &kv, &mut krot);
            ps.rotate_vo(0, 0, &vv, &mut vrot);
            cache.append(&krot, &vrot);
            kflat.extend_from_slice(&kv);
            vflat.extend_from_slice(&vv);
        }
        let q = rng.normal_vec(d);
        let kc = rng.normal_vec(d);
        let vc = rng.normal_vec(d);
        let mut qrot = vec![0.0f32; d];
        let mut kcrot = vec![0.0f32; d];
        let mut vcrot = vec![0.0f32; d];
        ps.rotate_qk(0, 0, &q, &mut qrot);
        ps.rotate_qk(0, 0, &kc, &mut kcrot);
        ps.rotate_vo(0, 0, &vc, &mut vcrot);

        let mut out_rot = vec![0.0; d];
        swan_attention(&qrot, &cache, &kcrot, &vcrot, &mut out_rot);
        // un-rotate: out = out_rot @ P_vo^T  (P orthonormal)
        let mut got = vec![0.0; d];
        matvec(&ps.p_vo[0][0], &out_rot, d, d, &mut got);

        let mut want = vec![0.0; d];
        dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut want);
        for (a, b) in got.iter().zip(&want) {
            if (a - b).abs() > 1e-2 {
                return Err(format!("{a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Kernel-dispatch parity, dense primitives: every available path
/// (scalar, and AVX2 where the host supports it) agrees with the scalar
/// reference on dot / vecmat / rmsnorm / axpy to tight tolerance, and on
/// softmax / max bit-exactly, across odd lengths that exercise every
/// remainder-handling branch.
#[test]
fn prop_kernel_dispatch_parity_dense() {
    let kinds = Kernels::available();
    check("kernel-parity-dense", 120, |r| {
        let n = 1 + r.below(150) as usize;
        let m = 1 + r.below(20) as usize;
        (n, m)
    }, |(n, m)| {
        let sc = Kernels::scalar();
        let mut rng = Pcg64::new(37);
        let a = rng.normal_vec(*n);
        let b = rng.normal_vec(*n);
        let w = rng.normal_vec(*n);
        let x = rng.normal_vec(*m);
        let mat = rng.normal_vec(*m * *n);
        for ks in &kinds {
            if !kernel_close(ks.dot(&a, &b), sc.dot(&a, &b), 1e-4) {
                return Err(format!("dot n={n} {}", ks.label()));
            }
            if ks.max_fold(&a) != sc.max_fold(&a) {
                return Err(format!("max n={n} {}", ks.label()));
            }
            let mut s1 = a.clone();
            let mut s2 = a.clone();
            ks.softmax_inplace(&mut s1);
            sc.softmax_inplace(&mut s2);
            if s1 != s2 {
                return Err(format!("softmax not bit-exact n={n} {}", ks.label()));
            }
            let mut o1 = vec![0.0; *n];
            let mut o2 = vec![0.0; *n];
            ks.rmsnorm(&a, &w, 1e-5, &mut o1);
            sc.rmsnorm(&a, &w, 1e-5, &mut o2);
            for (p, q) in o1.iter().zip(&o2) {
                if !kernel_close(*p, *q, 1e-4) {
                    return Err(format!("rmsnorm n={n} {}", ks.label()));
                }
            }
            let mut y1 = b.clone();
            let mut y2 = b.clone();
            ks.axpy(0.37, &a, &mut y1);
            sc.axpy(0.37, &a, &mut y2);
            for (p, q) in y1.iter().zip(&y2) {
                if !kernel_close(*p, *q, 1e-4) {
                    return Err(format!("axpy n={n} {}", ks.label()));
                }
            }
            let mut v1 = vec![0.0; *n];
            let mut v2 = vec![0.0; *n];
            ks.vecmat(&x, &mat, *m, *n, &mut v1);
            sc.vecmat(&x, &mat, *m, *n, &mut v2);
            for (p, q) in v1.iter().zip(&v2) {
                if !kernel_close(*p, *q, 1e-3) {
                    return Err(format!("vecmat m={m} n={n} {}", ks.label()));
                }
            }
        }
        Ok(())
    });
}

/// Kernel-dispatch parity, CSR walks: scalar and AVX2 agree on
/// scores/axpy over stores with mixed per-row k (odd lengths included),
/// both unpadded and lane-padded; the fused scores+max equals a post-hoc
/// fold exactly; padding never changes results beyond kernel tolerance.
#[test]
fn prop_kernel_dispatch_parity_csr() {
    let kinds = Kernels::available();
    check("kernel-parity-csr", 100, |r| {
        let rows = r.below(24) as usize;
        let d = 8 + r.below(120) as usize;
        let ks: Vec<usize> = (0..rows).map(|_| 1 + r.below(d as u64) as usize).collect();
        (d, ks)
    }, |(d, row_ks)| {
        let sc = Kernels::scalar();
        let mut rng = Pcg64::new(43);
        let mut plain = SparseStore::new();
        let mut padded = SparseStore::with_lanes(8);
        for &k in row_ks.iter() {
            let x = rng.normal_vec(*d);
            plain.push_pruned(&x, k, StorageMode::F16);
            padded.push_pruned(&x, k, StorageMode::F16);
        }
        padded.check_invariants()?;
        if padded.storage_bytes() != plain.storage_bytes() {
            return Err("padding changed Eq.1 bytes".into());
        }
        let q = rng.normal_vec(*d);
        let w: Vec<f32> = (0..plain.len()).map(|i| 0.25 - 0.01 * i as f32).collect();

        let mut ref_scores = Vec::new();
        let ref_max = plain.scores_max_into_with(sc, &q, 0.5, &mut ref_scores);
        let fold = ref_scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if ref_max != fold {
            return Err(format!("fused max {ref_max} != fold {fold}"));
        }
        let mut ref_out = vec![0.0f32; *d];
        plain.axpy_all_with(sc, &w, &mut ref_out);

        for ks in &kinds {
            for store in [&plain, &padded] {
                let mut scores = Vec::new();
                let m = store.scores_max_into_with(*ks, &q, 0.5, &mut scores);
                if scores.len() != plain.len() {
                    return Err(format!("{}: scores len {}", ks.label(), scores.len()));
                }
                let fold = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                if m != fold {
                    return Err(format!("{}: fused max {m} != fold {fold}", ks.label()));
                }
                for (r, (a, b)) in scores.iter().zip(&ref_scores).enumerate() {
                    if !kernel_close(*a, *b, 1e-4) {
                        return Err(format!(
                            "{} lane={}: score row {r}: {a} vs {b}",
                            ks.label(),
                            store.lanes()
                        ));
                    }
                }
                let mut out = vec![0.0f32; *d];
                store.axpy_all_with(*ks, &w, &mut out);
                for (i, (a, b)) in out.iter().zip(&ref_out).enumerate() {
                    if !kernel_close(*a, *b, 1e-4) {
                        return Err(format!(
                            "{} lane={}: axpy dim {i}: {a} vs {b}",
                            ks.label(),
                            store.lanes()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Ring-buffer wraparound parity: the head-index ring must behave
/// bit-identically to a naive front-drained `Vec<Vec<f32>>` model on
/// append/evict/attend, across buffer sizes (including 0, 1 and a prime
/// 17 that never divides the append count) and runtime-mixed `k_active`.
#[test]
fn prop_ring_buffer_matches_naive_model() {
    for &buffer in &[0usize, 1, 4, 17] {
        check("ring-naive-parity", 60, |r| {
            let n = 1 + r.below(60) as usize;
            let k0 = 1 + r.below(16) as usize;
            let k1 = 1 + r.below(16) as usize;
            (n, (k0, k1))
        }, |(n, (k0, k1))| {
            let d = 16usize;
            let mut rng = Pcg64::new(71 + buffer as u64);
            let mut c = HybridCache::new(d, SwanParams::new(*k0, buffer, StorageMode::F16));
            // naive model: buffered rows in a Vec, evictions winnowed into
            // a lane-1 store through the same push_pruned entry point
            let mut nk: Vec<Vec<f32>> = Vec::new();
            let mut nv: Vec<Vec<f32>> = Vec::new();
            let mut sk = SparseStore::new();
            let mut sv = SparseStore::new();
            for t in 0..*n {
                // retune mid-stream: old evictions keep k0, new use k1
                let k_now = if t < n / 2 { *k0 } else { *k1 };
                if t == n / 2 {
                    c.set_k_active(*k1, *k1);
                }
                let kr = rng.normal_vec(d);
                let vr = rng.normal_vec(d);
                c.append(&kr, &vr);
                nk.push(kr);
                nv.push(vr);
                if nk.len() > buffer {
                    let ko = nk.remove(0);
                    let vo = nv.remove(0);
                    sk.push_pruned(&ko, k_now, StorageMode::F16);
                    sv.push_pruned(&vo, k_now, StorageMode::F16);
                }
            }
            // structural parity
            if c.buffer_len() != nk.len() {
                return Err(format!("buf {} != {}", c.buffer_len(), nk.len()));
            }
            if c.sparse_len() != sk.len() {
                return Err(format!("sparse {} != {}", c.sparse_len(), sk.len()));
            }
            // buffer content parity, oldest first across the wrap point
            let (kb0, kb1) = c.k_buffer();
            let ring: Vec<f32> = kb0.iter().chain(kb1.iter()).copied().collect();
            let naive: Vec<f32> = nk.iter().flat_map(|r| r.iter().copied()).collect();
            if ring != naive {
                return Err(format!("ring contents diverged (bt={buffer} n={n})"));
            }
            // sparse content parity (same rows winnowed at the same k)
            for i in 0..sk.len() {
                if c.k_sparse.reconstruct(i, d) != sk.reconstruct(i, d)
                    || c.v_sparse.reconstruct(i, d) != sv.reconstruct(i, d)
                {
                    return Err(format!("sparse row {i} diverged"));
                }
            }
            // attend parity: swan attention vs dense attention over the
            // naive reconstruction (exact because both read identical data)
            let q = rng.normal_vec(d);
            let kc = rng.normal_vec(d);
            let vc = rng.normal_vec(d);
            let mut got = vec![0.0; d];
            swan_attention(&q, &c, &kc, &vc, &mut got);
            let mut kflat = Vec::new();
            let mut vflat = Vec::new();
            for i in 0..sk.len() {
                kflat.extend_from_slice(&sk.reconstruct(i, d));
                vflat.extend_from_slice(&sv.reconstruct(i, d));
            }
            kflat.extend_from_slice(&naive);
            for row in &nv {
                vflat.extend_from_slice(row);
            }
            let mut want = vec![0.0; d];
            dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut want);
            for (a, b) in got.iter().zip(&want) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("attend: {a} vs {b} (bt={buffer})"));
                }
            }
            Ok(())
        });
    }
}

/// Hybrid attention equals dense attention over the reconstructed cache
/// (the sparse representation is the ONLY approximation).
#[test]
fn prop_attention_equals_dense_over_reconstruction() {
    check("attn-recon", 100, |r| {
        let n = 1 + r.below(24) as usize;
        let k = 1 + r.below(16) as usize;
        (n, k)
    }, |(n, k)| {
        let d = 16;
        let mut c = HybridCache::new(d, SwanParams::new(*k, 3, StorageMode::F32));
        let mut r2 = Pcg64::new(5);
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        for _ in 0..*n {
            let kv = r2.normal_vec(d);
            let vv = r2.normal_vec(d);
            c.append(&kv, &vv);
            kflat.push(kv);
            vflat.push(vv);
        }
        // build the reconstructed dense cache in the same order
        let mut krec = Vec::new();
        let mut vrec = Vec::new();
        for i in 0..c.k_sparse.len() {
            krec.extend_from_slice(&c.k_sparse.reconstruct(i, d));
        }
        for i in 0..c.v_sparse.len() {
            vrec.extend_from_slice(&c.v_sparse.reconstruct(i, d));
        }
        let (kb0, kb1) = c.k_buffer();
        krec.extend_from_slice(kb0);
        krec.extend_from_slice(kb1);
        let (vb0, vb1) = c.v_buffer();
        vrec.extend_from_slice(vb0);
        vrec.extend_from_slice(vb1);

        let q = r2.normal_vec(d);
        let kc = r2.normal_vec(d);
        let vc = r2.normal_vec(d);
        let mut a = vec![0.0; d];
        swan_attention(&q, &c, &kc, &vc, &mut a);
        let mut b = vec![0.0; d];
        dense_attention(&q, &krec, &vrec, &kc, &vc, d, &mut b);
        for (x, y) in a.iter().zip(&b) {
            if (x - y).abs() > 1e-4 {
                return Err(format!("{x} vs {y}"));
            }
        }
        Ok(())
    });
}
