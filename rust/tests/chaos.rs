//! Chaos harness for the elastic, fault-tolerant fleet (no artifacts —
//! everything runs on a synthetic model over the real shard/pipeline
//! machinery).
//!
//! The contract under test: a supervised fleet survives shard deaths
//! (coordinator kills, stage poison, prefill poison), drains, and live
//! rescales **without changing a single output token**.  SWAN decode is
//! deterministic — the fixed offline rotation plus the seeded sampling
//! contract make `{prompt, emitted_tokens, params, seed}` a complete
//! resume point — so a recovered request re-prefills on a healthy shard,
//! replays its committed tokens as forced decode steps, and continues
//! bit-identically to an uninterrupted run.  Every scenario here asserts
//! that bit-identity against a direct single-shard reference, plus the
//! observability needles (`swan_shard_deaths`, `swan_requests_recovered`,
//! `swan_replay_tokens`, and the `die`→`recover` arc in `TRACE <id>`).
//!
//! The `#[ignore]` soak at the bottom drives a 4-shard fleet through 200
//! seeded kill/drain/scale events (the nightly CI job runs it with
//! `--ignored`): zero lost requests, zero wrong tokens, no hangs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use swan::api::{Event, GenParams};
use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::engine::sample;
use swan::coordinator::Request;
use swan::kvcache::PolicyKind;
use swan::model::transformer::{SequenceState, SwanModel};
use swan::shard::pipeline::MAX_PREEMPTIONS;
use swan::shard::{FaultPlan, Router, ShardCmd, ShardLostError, ShardState};
use swan::sparse::StorageMode;
use swan::util::Pcg64;

/// Mirror of the engine's per-sequence decode RNG seed (see
/// `tests/pipeline.rs`) — the wire contract both paths derive from.
const SWAN_SEED: u64 = 0x53_57_41_4e;

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "chaos-test".into(),
            d_model: 32,
            n_layers: 4,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: StorageMode::F16,
        max_batch: 8,
        ..Default::default()
    }
}

/// A supervised pipeline fleet of `shards / pipeline` groups over the
/// synthetic model; `plans[g]` injects a deterministic fault into group
/// `g` (missing entries run fault-free).
fn chaos_fleet(cfg: &ServeConfig, plans: Vec<Option<Arc<FaultPlan>>>) -> Router {
    Router::launch_pipeline_from_model(test_model(), cfg, plans).unwrap()
}

/// The request mix: mostly greedy, one temperature-sampled stream (which
/// exercises the recovered-RNG-state contract).
fn requests() -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..5)
        .map(|i| Request::from_text(i + 1, &format!("the sparse vector {i} maps the "), 10))
        .collect();
    reqs.push(Request::with_params(
        6,
        "the hot cache winnows ",
        GenParams::new(10).temperature(0.8),
    ));
    reqs
}

/// Direct native reference (the engine's sampling/seeding contract),
/// each request at its own d_head-clamped compression level — what an
/// undisturbed `--shards 1` fleet produces.
fn reference(reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = serve_cfg();
    reqs.iter()
        .map(|req| {
            let k = req
                .params
                .k_active
                .map(|k| k.clamp(1, model.cfg.d_head))
                .unwrap_or(cfg.k_active);
            let kind = PolicyKind::Swan { k_active: k, buffer: cfg.buffer, mode: cfg.mode };
            let tokens: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
            let pf = model.prefill(tokens);
            let mut st = SequenceState::new(&model, kind);
            st.load_prefill(&pf);
            let base = req.params.seed.unwrap_or(req.id);
            let mut tok = sample(&pf.logits, &req.params, &[], &mut Pcg64::new(base));
            let mut rng = Pcg64::new(base ^ SWAN_SEED);
            let mut produced = vec![tok];
            while produced.len() < req.params.max_new {
                let logits = model.decode_step(&mut st, tok);
                tok = sample(&logits, &req.params, &produced, &mut rng);
                produced.push(tok);
            }
            (req.id, produced)
        })
        .collect()
}

/// Submit every request, wait for every response, return `(id, tokens)`
/// sorted by id (panics on any lost or failed generation).
fn run_to_completion(router: &Router, reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let pending: Vec<_> =
        reqs.iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    let mut out: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, h)| {
            let resp = h.wait().expect("generation must survive the fault");
            assert_eq!(resp.id, id);
            (id, resp.tokens)
        })
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

/// Sum of every exposition sample named exactly `name` (counters merge
/// into one unlabeled line; shard-labeled gauges sum across shards).
fn metric_sum(text: &str, name: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let rest = l.strip_prefix(name)?;
            if !(rest.starts_with(' ') || rest.starts_with('{')) {
                return None;
            }
            l.rsplit(' ').next()?.parse::<f64>().ok()
        })
        .sum()
}

/// Poll `pred` until it holds or `timeout` elapses; returns the final
/// verdict (supervisor actions — removal, relaunch — are asynchronous).
fn poll_until(timeout: Duration, mut pred: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if pred() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    pred()
}

// ---------------------------------------------------------------------
// shard death: coordinator kill, stage poison, prefill poison
// ---------------------------------------------------------------------

/// A coordinator kill mid-decode hands every in-flight and queued
/// request back; recovery on the surviving shard is bit-identical, the
/// fleet shrinks, and the metrics/trace needles record the arc.
#[test]
fn kill_mid_decode_recovers_bit_identically() {
    let reqs = requests();
    let want = reference(&reqs);
    let cfg = ServeConfig { shards: 2, ..serve_cfg() };
    // group 0 dies at its third iteration — after admission, mid-decode
    let router = chaos_fleet(&cfg, vec![Some(FaultPlan::kill_at(3)), None]);
    assert_eq!(router.n_shards(), 2);

    let got = run_to_completion(&router, &reqs);
    assert_eq!(got, want, "recovery after a coordinator kill changed the decoded streams");

    // recovery happens-after removal, so by now the fleet has shrunk
    assert_eq!(router.n_shards(), 1, "the dead shard must be removed");
    let metrics = router.metrics_text();
    assert_eq!(metric_sum(&metrics, "swan_shard_deaths"), 1.0, "{metrics}");
    assert!(metric_sum(&metrics, "swan_requests_recovered") >= 1.0, "{metrics}");
    assert!(
        metric_sum(&metrics, "swan_replay_tokens") >= 1.0,
        "a mid-decode kill must force replayed tokens: {metrics}"
    );

    // at least one request carries the die → recover → retire arc
    let arc = (1..=6u64)
        .filter_map(|id| router.trace_jsonl(id))
        .find(|j| j.contains("\"event\":\"die\"") && j.contains("\"event\":\"recover\""))
        .expect("a recovered request must trace its die→recover arc");
    let die = arc.find("\"event\":\"die\"").unwrap();
    let rec = arc.find("\"event\":\"recover\"").unwrap();
    assert!(die < rec, "die must precede recover: {arc}");
    assert!(arc.contains("\"event\":\"retire\""), "{arc}");
    // STATS surfaces the lifecycle tally
    assert!(router.stats().contains("shard_deaths=1"), "{}", router.stats());
}

/// A streaming request whose shard dies mid-stream resumes with no gap
/// and no duplicate: token indexes stay strictly sequential across the
/// death, and the stream equals the undisturbed reference.
#[test]
fn kill_mid_stream_resumes_with_no_gap_or_duplicate() {
    let req = Request::with_params(
        1,
        "the hot cache winnows ",
        GenParams::new(12).temperature(0.8).stream(true),
    );
    let want = reference(std::slice::from_ref(&req));
    let cfg = ServeConfig { shards: 2, ..serve_cfg() };
    // round-robin places request 1 on group 0, which dies 4 iterations
    // in — several tokens are already on the wire by then
    let router = chaos_fleet(&cfg, vec![Some(FaultPlan::kill_at(4)), None]);

    let handle = router.submit(req).unwrap();
    let mut seen: Vec<(usize, u32)> = Vec::new();
    let resp = loop {
        match handle.recv().unwrap() {
            Event::Token { id, index, token, .. } => {
                assert_eq!(id, 1);
                seen.push((index, token));
            }
            Event::Done(r) => break r,
            Event::Error { message, .. } => panic!("stream died unrecovered: {message}"),
        }
    };

    let indexes: Vec<usize> = seen.iter().map(|(i, _)| *i).collect();
    assert_eq!(
        indexes,
        (0..12).collect::<Vec<_>>(),
        "token indexes must stay gapless and duplicate-free across the shard death"
    );
    let streamed: Vec<u32> = seen.iter().map(|(_, t)| *t).collect();
    assert_eq!(streamed, resp.tokens, "streamed tokens must equal the terminal response");
    assert_eq!(vec![(1u64, resp.tokens.clone())], want, "recovered stream diverged");
    assert!(resp.stats.recoveries >= 1, "the stream must have migrated shards");
    let metrics = router.metrics_text();
    assert!(metric_sum(&metrics, "swan_replay_tokens") >= 1.0, "{metrics}");
}

/// A stage panic mid-forward (2 groups x 2 stages) kills the whole
/// group; its requests recover bit-identically on the healthy group.
#[test]
fn stage_poison_mid_decode_recovers_bit_identically() {
    let reqs = requests();
    let want = reference(&reqs);
    let cfg = ServeConfig { shards: 4, pipeline: 2, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![Some(FaultPlan::poison_stage_after(1, 5)), None]);
    assert_eq!(router.n_shards(), 2, "4 stage slots = 2 groups");

    let got = run_to_completion(&router, &reqs);
    assert_eq!(got, want, "recovery after a stage panic changed the decoded streams");
    let metrics = router.metrics_text();
    assert_eq!(metric_sum(&metrics, "swan_shard_deaths"), 1.0, "{metrics}");
    assert!(metric_sum(&metrics, "swan_requests_recovered") >= 1.0, "{metrics}");
}

/// A stage panic inside the admission hop (prefill poison) — the
/// death lands mid-prefill, before the victim committed any token; the
/// request still recovers exactly (fresh re-enqueue, full re-prefill).
#[test]
fn prefill_poison_recovers_bit_identically() {
    let reqs = requests();
    let want = reference(&reqs);
    let cfg = ServeConfig { shards: 4, pipeline: 2, ..serve_cfg() };
    let plan = Arc::new(FaultPlan {
        poison_prefill: Some((0, 2)), // stage 0's second prefill panics
        ..Default::default()
    });
    let router = chaos_fleet(&cfg, vec![Some(plan), None]);

    let got = run_to_completion(&router, &reqs);
    assert_eq!(got, want, "recovery after a prefill panic changed the decoded streams");
    let metrics = router.metrics_text();
    assert_eq!(metric_sum(&metrics, "swan_shard_deaths"), 1.0, "{metrics}");
}

/// When the LAST shard dies, recovery is impossible: waiters get the
/// structured `shard_lost` error (never a hang), submit refuses with
/// [`ShardLostError`], and `SET shards` revives the fleet live.
#[test]
fn losing_the_last_shard_is_a_structured_error_and_scale_up_revives() {
    let cfg = ServeConfig { shards: 1, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![Some(FaultPlan::kill_at(2))]);

    let req = Request::from_text(1, "the sparse vector 0 maps the ", 10);
    let err = router
        .submit(req.clone())
        .unwrap()
        .wait()
        .expect_err("no healthy shard remains; the waiter must fail, not hang")
        .to_string();
    assert!(err.contains("shard_lost"), "unstructured failure: {err}");
    assert!(poll_until(Duration::from_secs(5), || router.n_shards() == 0));

    // with the fleet empty, submission fails structurally too
    let err = router.submit(req.clone()).unwrap_err();
    let lost = err.downcast_ref::<ShardLostError>().expect("typed placement failure");
    assert_eq!(lost.attempts, 0, "no shard was available to even try");

    // elastic revival: scale-up relaunches a live shard and serving resumes
    assert_eq!(router.set_shards(1).unwrap(), 1);
    assert_eq!(router.n_shards(), 1);
    let got = run_to_completion(&router, std::slice::from_ref(&req));
    assert_eq!(got, reference(std::slice::from_ref(&req)));
}

// ---------------------------------------------------------------------
// drain + elastic membership
// ---------------------------------------------------------------------

/// `drain` stops placement but lets in-flight and queued work finish
/// locally: every output stays bit-identical, the shard retires, and
/// draining the last healthy shard (or an unknown id) is refused.
#[test]
fn drain_lets_in_flight_finish_and_retires_the_shard() {
    let reqs = requests();
    let want = reference(&reqs);
    let cfg = ServeConfig { shards: 2, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![]);

    let pending: Vec<_> =
        reqs.iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    router.drain(0).unwrap();
    let mut got: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, h)| (id, h.wait().expect("drain must not lose work").tokens))
        .collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "draining a busy shard changed the decoded streams");

    assert!(
        poll_until(Duration::from_secs(10), || router.n_shards() == 1),
        "the drained shard must retire"
    );
    let err = router.drain(1).unwrap_err().to_string();
    assert!(err.contains("last healthy shard"), "{err}");
    assert!(router.drain(42).unwrap_err().to_string().contains("unknown shard"));

    // the survivor keeps serving
    let extra = Request::from_text(7, "the sparse vector 9 maps the ", 10);
    let got = run_to_completion(&router, std::slice::from_ref(&extra));
    assert_eq!(got, reference(std::slice::from_ref(&extra)));
}

/// With a zero drain timeout the stragglers migrate instead of
/// finishing locally — through the exact-recovery path, so the outputs
/// still match the reference token for token.
#[test]
fn drain_timeout_migrates_stragglers_bit_identically() {
    // the streaming request goes first so round-robin lands it on shard
    // 0 — the one being drained — together with half the greedy wave
    let mut reqs = vec![Request::with_params(
        1,
        "the hot cache winnows ",
        GenParams::new(10).temperature(0.8).stream(true),
    )];
    reqs.extend((0..15u64).map(|i| {
        Request::from_text(i + 2, &format!("the sparse vector {i} maps the "), 10)
    }));
    let want = reference(&reqs);
    let cfg = ServeConfig { shards: 2, drain_timeout_ms: 0, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![]);

    let pending: Vec<_> =
        reqs.iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    router.drain(0).unwrap();
    let mut got: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, h)| (id, h.wait().expect("migration must not lose work").tokens))
        .collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "drain-timeout migration changed the decoded streams");

    // half the fleet's requests sat on shard 0 and the timeout was
    // already expired when DRAIN landed, so they went through recovery
    let metrics = router.metrics_text();
    assert!(metric_sum(&metrics, "swan_requests_recovered") >= 1.0, "{metrics}");
    assert!(poll_until(Duration::from_secs(10), || router.n_shards() == 1));
}

/// `SET shards <n>` scales a live fleet up (new supervised shards join
/// placement) and back down (drain-based retirement) without disturbing
/// in-flight work.
#[test]
fn set_shards_scales_the_fleet_up_and_down_live() {
    let cfg = ServeConfig { shards: 1, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![]);
    let reqs = requests();
    let want = reference(&reqs);

    // submit a first wave, grow mid-flight, submit a second wave
    let pending: Vec<_> =
        reqs[..3].iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    assert_eq!(router.set_shards(3).unwrap(), 3);
    assert_eq!(router.n_shards(), 3);
    let snaps = router.snapshots();
    assert!(snaps.iter().all(|s| s.state == ShardState::Healthy), "{snaps:?}");
    let mut ids: Vec<usize> = snaps.iter().map(|s| s.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2], "new shards get fresh ids");

    let pending: Vec<_> = pending
        .into_iter()
        .chain(reqs[3..].iter().map(|r| (r.id, router.submit(r.clone()).unwrap())))
        .collect();
    let mut got: Vec<(u64, Vec<u32>)> =
        pending.into_iter().map(|(id, h)| (id, h.wait().unwrap().tokens)).collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "scaling mid-flight changed the decoded streams");

    // shrink back to one shard; the retirees drain clean and retire
    assert_eq!(router.set_shards(1).unwrap(), 1);
    assert!(
        poll_until(Duration::from_secs(10), || router.n_shards() == 1),
        "scale-down must retire the drained shards"
    );
    let extra = Request::from_text(7, "the sparse vector 9 maps the ", 10);
    let got = run_to_completion(&router, std::slice::from_ref(&extra));
    assert_eq!(got, reference(std::slice::from_ref(&extra)));
}

// ---------------------------------------------------------------------
// preemption-age fairness (regression for the MAX_PREEMPTIONS cap)
// ---------------------------------------------------------------------

/// Under a tight paged-pool budget the coordinator preempts — but no
/// request may be evicted more than `MAX_PREEMPTIONS` times while
/// uncapped co-runners exist (the age cap keeps eviction rotating
/// instead of hammering the youngest sequence), and the preempted
/// streams still finish bit-identically.
#[test]
fn preemption_cap_bounds_per_request_evictions() {
    let mut reqs: Vec<Request> = (0..4)
        .map(|i| Request::from_text(i + 1, &format!("the pooled vector {i} maps the "), 10))
        .collect();
    reqs.push(Request::with_params(
        5,
        "the hot cache winnows ",
        GenParams::new(10).temperature(0.8),
    ));
    reqs.push(Request::with_params(6, "mixed low ", GenParams::new(10).k_active(2)));
    reqs.push(Request::with_params(7, "mixed high ", GenParams::new(10).k_active(6)));
    let want = reference(&reqs);

    // the budget that forces preemption in tests/pool.rs, on the
    // supervised launch path (pool + supervision compose)
    let budget = 700 * swan::pool::block_bytes(1, 8, StorageMode::F16, 4);
    let cfg = ServeConfig {
        shards: 1,
        pool: true,
        block_tokens: 1,
        mem_budget: budget,
        ..serve_cfg()
    };
    let router = chaos_fleet(&cfg, vec![]);

    let pending: Vec<_> =
        reqs.iter().map(|r| (r.id, router.submit(r.clone()).unwrap())).collect();
    let resps: Vec<_> = pending
        .into_iter()
        .map(|(id, h)| {
            let resp = h.wait().expect("generation ok");
            assert_eq!(resp.id, id);
            resp
        })
        .collect();
    let mut got: Vec<(u64, Vec<u32>)> =
        resps.iter().map(|r| (r.id, r.tokens.clone())).collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "preemption/replay changed the decoded streams");

    let max_preemptions = resps.iter().map(|r| r.stats.preemptions).max().unwrap();
    assert!(max_preemptions >= 1, "the tight budget must preempt at least once");
    assert!(
        max_preemptions <= MAX_PREEMPTIONS,
        "a request was evicted {max_preemptions} times — the fairness cap \
         ({MAX_PREEMPTIONS}) regressed"
    );
}

// ---------------------------------------------------------------------
// live TCP round-trip: SET shards / DRAIN against a running fleet
// ---------------------------------------------------------------------

/// `SET shards <n>` and `DRAIN <id>` round-trip on a live TCP fleet
/// while a generation streams: the stream migrates (zero drain timeout)
/// without dropping or duplicating a token, lifecycle verbs answer OK,
/// and draining the last healthy shard is refused on the wire.
#[test]
fn fleet_lifecycle_round_trips_over_tcp_without_disturbing_streams() {
    let params = GenParams::new(96).temperature(0.9).seed(11); // seeded => id-independent
    let reference_text = {
        let router = chaos_fleet(&ServeConfig { shards: 1, ..serve_cfg() }, vec![]);
        let h = router
            .submit(Request::with_params(0, "the hot cache winnows ", params.clone()))
            .unwrap();
        h.wait().unwrap().text
    };

    let cfg = ServeConfig {
        shards: 2,
        drain_timeout_ms: 0,
        max_new_tokens: 128,
        bind: "127.0.0.1:0".into(),
        ..serve_cfg()
    };
    let router = Arc::new(chaos_fleet(&cfg, vec![]));
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    {
        let (router, cfg) = (router.clone(), cfg.clone());
        std::thread::spawn(move || {
            swan::server::tcp::serve_router(router, &cfg, move |a| {
                let _ = addr_tx.send(a);
            })
        });
    }
    let addr = addr_rx.recv_timeout(Duration::from_secs(10)).unwrap().to_string();

    // stream on one connection; drive lifecycle verbs from another as
    // soon as the first token proves the stream is in flight
    let (first_tok_tx, first_tok_rx) = std::sync::mpsc::channel();
    let stream = {
        let (addr, params) = (addr.clone(), params.clone());
        std::thread::spawn(move || {
            let mut c = swan::server::client::Client::connect(&addr).unwrap();
            let mut tokens = Vec::new();
            let gen = c
                .generate_stream("the hot cache winnows ", &params.stream(true), |_, text| {
                    tokens.push(text.to_string());
                    let _ = first_tok_tx.send(());
                })
                .unwrap();
            c.quit();
            (gen, tokens)
        })
    };
    first_tok_rx.recv_timeout(Duration::from_secs(10)).unwrap();

    let mut ctl = swan::server::client::Client::connect(&addr).unwrap();
    ctl.ping().unwrap();
    ctl.set_shards(3).unwrap(); // grow while the stream runs
    ctl.drain(0).unwrap(); // retire the shard serving the stream
    let (gen, tokens) = stream.join().unwrap();
    assert_eq!(tokens.len(), 96, "dropped or duplicated tokens across the drain");
    assert_eq!(gen.stats.tokens, 96);
    assert_eq!(gen.text, reference_text, "the migrated stream diverged");
    assert_eq!(tokens.concat(), reference_text, "streamed text != terminal text");

    // shrink to the last healthy shard; draining it is refused
    ctl.drain(1).unwrap();
    let err = ctl.drain(2).expect_err("the last healthy shard must not drain");
    assert!(err.to_string().contains("last healthy shard"), "{err}");

    // the survivor still serves, and STATS shows the fleet view
    let (text, _) = ctl.generate("the sparse vector 1 maps the ", 8).unwrap();
    assert!(!text.is_empty());
    assert!(ctl.stats().unwrap().contains("fleet: shards="));
    ctl.quit();
}

// ---------------------------------------------------------------------
// nightly soak: randomized kill/drain/scale churn, zero lost requests
// ---------------------------------------------------------------------

/// 200 seeded chaos events (coordinator kills, drains, rescales)
/// against a 4-shard fleet with requests flowing throughout.  Greedy
/// decoding is id-independent, so every response is checked against its
/// prompt's solo reference: zero lost requests, zero wrong tokens.
/// Run explicitly (`cargo test --test chaos -- --ignored`); the nightly
/// CI soak job does.
#[test]
#[ignore = "soak: ~200 randomized fault events; run with --ignored (nightly CI)"]
fn soak_randomized_kill_drain_scale_loses_nothing() {
    const EVENTS: usize = 200;
    let prompts = [
        "the sparse vector 0 maps the ",
        "the hot cache winnows ",
        "the pooled vector 2 maps the ",
        "mixed low ",
    ];
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| reference(&[Request::from_text(1, p, 8)])[0].1.clone())
        .collect();

    let cfg = ServeConfig { shards: 4, drain_timeout_ms: 50, ..serve_cfg() };
    let router = chaos_fleet(&cfg, vec![]);
    let mut rng = Pcg64::new(0xC4A0_55_u64);
    let mut pending = Vec::with_capacity(EVENTS);

    for i in 0..EVENTS {
        let prompt_ix = i % prompts.len();
        let req = Request::from_text(1000 + i as u64, prompts[prompt_ix], 8);
        pending.push((prompt_ix, router.submit(req).unwrap()));

        // pick a victim only while a healthy peer remains, so recovery
        // always has somewhere to land (zero-lost is the invariant)
        let healthy: Vec<usize> = router
            .snapshots()
            .iter()
            .filter(|s| s.state == ShardState::Healthy)
            .map(|s| s.id)
            .collect();
        match rng.below(3) {
            0 if healthy.len() >= 2 => {
                let victim = healthy[rng.below(healthy.len() as u64) as usize];
                if let Some(shard) =
                    router.shards().into_iter().find(|s| s.id == victim)
                {
                    let _ = shard.send(ShardCmd::Crash);
                }
                // serialize deaths: wait for the supervisor to remove it
                assert!(
                    poll_until(Duration::from_secs(10), || {
                        !router.shards().iter().any(|s| s.id == victim)
                    }),
                    "event {i}: shard {victim} was never reaped"
                );
            }
            1 if healthy.len() >= 2 => {
                let victim = healthy[rng.below(healthy.len() as u64) as usize];
                router.drain(victim).unwrap();
            }
            2 => {
                let n = 1 + rng.below(4) as usize;
                router.set_shards(n).unwrap();
            }
            _ => {}
        }
        if rng.below(4) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    // make sure capacity exists for the tail, then collect everything
    router.set_shards(2).unwrap();
    for (prompt_ix, handle) in pending {
        let resp = handle.wait().expect("soak lost a request");
        assert_eq!(
            resp.tokens, want[prompt_ix],
            "request {} decoded wrong tokens after fleet churn",
            resp.id
        );
    }
    let metrics = router.metrics_text();
    assert!(metric_sum(&metrics, "swan_shard_deaths") >= 1.0, "{metrics}");

    // the churned fleet still serves
    let extra = Request::from_text(9999, prompts[0], 8);
    let got = run_to_completion(&router, std::slice::from_ref(&extra));
    assert_eq!(got[0].1, want[0]);
}
