//! The forced `--kernels` override, exercised end-to-end through the
//! *global* dispatch every layer (ops, stores, attention) routes through.
//!
//! This lives in its own integration binary on purpose: flipping the
//! process-wide kernel selection mid-run would race with concurrently
//! running tests that compare two globally-dispatched computations (see
//! the note in `swan::simd`'s lib tests).  Here the flip tests are the
//! only tests in the process, and they serialize themselves through one
//! `#[test]` fn.
//!
//! `--kernels scalar|avx2` on the CLI and `SWAN_KERNELS` both feed the
//! same `init_from_name`/`detect` entry points exercised below.

use swan::simd::{self, KernelKind, Kernels};
use swan::sparse::StorageMode;
use swan::swan::attention::swan_attention;
use swan::swan::hybrid_cache::{HybridCache, SwanParams};
use swan::util::Pcg64;

/// One attention output computed under the *current global* selection.
fn attend_under_active(lanes: usize) -> Vec<f32> {
    let d = 16;
    let mut cache =
        HybridCache::new(d, SwanParams::new(8, 2, StorageMode::F16).with_lanes(lanes));
    let mut rng = Pcg64::new(3);
    for _ in 0..12 {
        cache.append(&rng.normal_vec(d), &rng.normal_vec(d));
    }
    let q = rng.normal_vec(d);
    let kc = rng.normal_vec(d);
    let vc = rng.normal_vec(d);
    let mut out = vec![0.0; d];
    swan_attention(&q, &cache, &kc, &vc, &mut out);
    out
}

#[test]
fn forced_override_routes_global_dispatch() {
    // every path this host can run, forced by name through the same
    // entry point the CLI flag uses
    for ks in Kernels::available() {
        // params built BEFORE the pin must still resolve row padding to
        // the post-pin selection (lane resolution is deferred to
        // HybridCache::new, not captured at SwanParams::new)
        let pre_pin_params = SwanParams::new(8, 2, StorageMode::F16);
        let pinned = simd::init_from_name(ks.label()).unwrap();
        assert_eq!(pinned, ks);
        assert_eq!(simd::active(), ks, "global did not follow --kernels {}", ks.label());
        let cache = HybridCache::new(16, pre_pin_params);
        assert_eq!(
            cache.k_sparse.lanes(),
            ks.lanes(),
            "pre-pin SwanParams captured stale lanes under --kernels {}",
            ks.label()
        );
        let out = attend_under_active(ks.lanes());
        assert!(out.iter().all(|x| x.is_finite()), "kernels {}", ks.label());
    }

    // the two paths agree on the same workload to tight tolerance
    let a = {
        simd::set_active(Kernels::scalar());
        attend_under_active(1)
    };
    let b = {
        simd::set_active(simd::Kernels::detect());
        attend_under_active(simd::active().lanes())
    };
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() <= 1e-4 * (1.0 + y.abs()), "{x} vs {y}");
    }

    // `auto` resolves to something runnable; garbage is rejected loudly
    let auto = simd::init_from_name("auto").unwrap();
    assert!(Kernels::available().contains(&auto));
    assert!(simd::init_from_name("no-such-kernel").is_err());
    match Kernels::avx2() {
        Some(k) => assert_eq!(simd::init_from_name("avx2").unwrap(), k),
        None => assert!(simd::init_from_name("avx2").is_err()),
    }

    // scalar is always forceable, and its kind is what it claims
    let sc = simd::init_from_name("scalar").unwrap();
    assert_eq!(sc.kind(), KernelKind::Scalar);
    assert_eq!(simd::active().lanes(), 1);

    // leave the process on the detected default
    simd::set_active(Kernels::detect());
}
