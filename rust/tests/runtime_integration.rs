//! PJRT runtime integration: load AOT artifacts, execute graphs, compare
//! against the python goldens.  Skipped (cleanly) when `make artifacts`
//! has not been run.

use swan::coordinator::request::decode_tokens;
use swan::model::weights::WeightFile;
use swan::runtime::engine::{HostTensor, LoadedModel};
use swan::runtime::ArtifactStore;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = swan::artifacts_dir();
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => {
                eprintln!("skipping: run `make artifacts` first");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_is_complete() {
    let dir = require_artifacts!();
    let store = ArtifactStore::load(&dir).unwrap();
    for name in ["swan-nano-gqa", "swan-nano-mha"] {
        let m = store.model(name).unwrap();
        assert!(!m.decode_buckets().is_empty());
        assert!(!m.prefill_buckets().is_empty());
        assert!(m.weights.exists());
        assert!(m.golden.exists());
        for g in m.graphs.values() {
            assert!(g.file.exists(), "{:?}", g.file);
        }
    }
}

#[test]
fn smoke_graph_executes() {
    let dir = require_artifacts!();
    // model.hlo.txt: single-head swan attention, d=8, ls=4, k=2, b=3
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(
        dir.join("model.hlo.txt").to_str().unwrap(),
    )
    .unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();

    let d = 8usize;
    let q = vec![1.0f32; d];
    let kvals = vec![0.0f32; 4 * 2];
    let kidx = vec![0i32; 4 * 2];
    let smask = vec![0.0f32; 4]; // sparse all masked
    let bmask = vec![1.0f32, 0.0, 0.0];
    let kbuf = vec![0.0f32; 3 * d];
    let mut vbuf = vec![0.0f32; 3 * d];
    vbuf[..d].iter_mut().enumerate().for_each(|(i, v)| *v = i as f32);

    let lit = |v: &Vec<f32>, dims: &[i64]| xla::Literal::vec1(v).reshape(dims).unwrap();
    let liti = |v: &Vec<i32>, dims: &[i64]| xla::Literal::vec1(v).reshape(dims).unwrap();
    let args = vec![
        lit(&q, &[8]),
        lit(&kvals, &[4, 2]),
        liti(&kidx, &[4, 2]),
        lit(&kvals, &[4, 2]),
        liti(&kidx, &[4, 2]),
        lit(&kbuf, &[3, 8]),
        lit(&vbuf, &[3, 8]),
        lit(&smask, &[4]),
        lit(&bmask, &[3]),
    ];
    let out = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let vals = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
    // single live slot (buffer row 0) -> output == vbuf row 0
    for (i, v) in vals.iter().enumerate() {
        assert!((v - i as f32).abs() < 1e-5, "{vals:?}");
    }
}

#[test]
fn prefill_matches_python_golden() {
    let dir = require_artifacts!();
    let lm = LoadedModel::open(&dir, "swan-nano-gqa").unwrap();
    let arts = lm.store.model("swan-nano-gqa").unwrap();
    let golden = WeightFile::load(&arts.golden).unwrap();

    let prompt = golden.get("prompt_tokens").unwrap().as_i32().unwrap().to_vec();
    let t = prompt.len();
    let cap = 64usize;
    let mut tokens = vec![0i32; cap];
    tokens[..t].copy_from_slice(&prompt);
    let mut tmask = vec![0.0f32; cap];
    tmask[..t].iter_mut().for_each(|x| *x = 1.0);

    let outs = lm
        .execute(
            "prefill_t64",
            &[HostTensor::i32(tokens, vec![cap]), HostTensor::f32(tmask, vec![cap])],
        )
        .unwrap();
    let logits = outs[0].as_f32().unwrap();
    let want = golden.f32("prefill_logits").unwrap();
    let mut max_diff = 0.0f32;
    for (a, b) in logits.iter().zip(want) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 2e-2, "prefill logits deviate: {max_diff}");

    // khat must match on the live prefix
    let khat = outs[1].as_f32().unwrap();
    let gk = golden.f32("prefill_khat").unwrap();
    let cfg = &arts.config;
    // graph layout [L, nkv, cap, dh], golden [L, nkv, t, dh]
    let dh = cfg.d_head;
    let mut kdiff = 0.0f32;
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            for ti in 0..t {
                let src = ((l * cfg.n_kv_heads + h) * cap + ti) * dh;
                let dst = ((l * cfg.n_kv_heads + h) * t + ti) * dh;
                for j in 0..dh {
                    kdiff = kdiff.max((khat[src + j] - gk[dst + j]).abs());
                }
            }
        }
    }
    assert!(kdiff < 1e-2, "prefill khat deviates: {kdiff}");
}

#[test]
fn swan_decode_matches_python_golden() {
    let dir = require_artifacts!();
    let lm = LoadedModel::open(&dir, "swan-nano-gqa").unwrap();
    let arts = lm.store.model("swan-nano-gqa").unwrap();
    let golden = WeightFile::load(&arts.golden).unwrap();
    let cfg = arts.config.clone();

    // golden swan decode used buf=16, k=32, ls=64 over a 48-token prefill;
    // replay it through the compiled decode_l128_k32 graph (pad 64 -> 128).
    let meta = golden.get("swan_decode_cfg").unwrap().as_i32().unwrap();
    let (buf_n, k_active, ls_g, t) =
        (meta[0] as usize, meta[1] as usize, meta[2] as usize, meta[3] as usize);
    assert_eq!((buf_n, k_active, ls_g, t), (16, 32, 64, 48));

    let khat = golden.f32("prefill_khat").unwrap();
    let vhat = golden.f32("prefill_vhat").unwrap();
    let (nl, nkv, dh) = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head);
    let n_sp = t - buf_n;
    let l_cap = 128usize;
    let buf_cap = 64usize;

    let sp_n = nl * nkv * l_cap * k_active;
    let mut kvals = vec![0.0f32; sp_n];
    let mut kidx = vec![0i32; sp_n];
    let mut vvals = vec![0.0f32; sp_n];
    let mut vidx = vec![0i32; sp_n];
    let mut kbuf = vec![0.0f32; nl * nkv * buf_cap * dh];
    let mut vbuf = vec![0.0f32; nl * nkv * buf_cap * dh];
    for l in 0..nl {
        for h in 0..nkv {
            for ti in 0..n_sp {
                let row = &khat[((l * nkv + h) * t + ti) * dh..][..dh];
                let vrow = &vhat[((l * nkv + h) * t + ti) * dh..][..dh];
                let ki = swan::sparse::topk::topk_indices(row, k_active);
                let vi = swan::sparse::topk::topk_indices(vrow, k_active);
                let off = ((l * nkv + h) * l_cap + ti) * k_active;
                for j in 0..k_active {
                    kvals[off + j] = row[ki[j] as usize];
                    kidx[off + j] = ki[j] as i32;
                    vvals[off + j] = vrow[vi[j] as usize];
                    vidx[off + j] = vi[j] as i32;
                }
            }
            for (slot, ti) in (n_sp..t).enumerate() {
                let src = ((l * nkv + h) * t + ti) * dh;
                let dst = ((l * nkv + h) * buf_cap + slot) * dh;
                kbuf[dst..dst + dh].copy_from_slice(&khat[src..src + dh]);
                vbuf[dst..dst + dh].copy_from_slice(&vhat[src..src + dh]);
            }
        }
    }
    let mut smask = vec![0.0f32; l_cap];
    smask[..n_sp].iter_mut().for_each(|x| *x = 1.0);
    let mut bmask = vec![0.0f32; buf_cap];
    bmask[..buf_n].iter_mut().for_each(|x| *x = 1.0);

    let next_tok = golden.get("swan_decode_token").unwrap().as_i32().unwrap()[0];
    let sp_shape = vec![nl, nkv, l_cap, k_active];
    let outs = lm
        .execute(
            "decode_l128_k32",
            &[
                HostTensor::scalar_i32(next_tok),
                HostTensor::scalar_i32(t as i32),
                HostTensor::f32(kvals, sp_shape.clone()),
                HostTensor::i32(kidx, sp_shape.clone()),
                HostTensor::f32(vvals, sp_shape.clone()),
                HostTensor::i32(vidx, sp_shape),
                HostTensor::f32(kbuf, vec![nl, nkv, buf_cap, dh]),
                HostTensor::f32(vbuf, vec![nl, nkv, buf_cap, dh]),
                HostTensor::f32(smask, vec![l_cap]),
                HostTensor::f32(bmask, vec![buf_cap]),
            ],
        )
        .unwrap();
    let logits = outs[0].as_f32().unwrap();
    let want = golden.f32("swan_decode_logits").unwrap();
    let mut max_diff = 0.0f32;
    for (a, b) in logits.iter().zip(want) {
        max_diff = max_diff.max((a - b).abs());
    }
    assert!(max_diff < 2e-2, "swan decode logits deviate: {max_diff}");
}

#[test]
fn golden_prompt_is_corpus_text() {
    let dir = require_artifacts!();
    let store = ArtifactStore::load(&dir).unwrap();
    let golden = WeightFile::load(&store.model("swan-nano-gqa").unwrap().golden).unwrap();
    let toks: Vec<u32> =
        golden.get("prompt_tokens").unwrap().as_i32().unwrap().iter().map(|&t| t as u32).collect();
    let text = decode_tokens(&toks);
    assert!(text.is_ascii());
    assert!(text.contains(' '));
}
