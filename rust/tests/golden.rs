//! Golden verification of the rust-native model path against the python
//! model: same weights, same prompt, logits must agree.  This is what
//! makes the rust-side experiment harness a valid stand-in for the JAX
//! model in the quality experiments.

use swan::kvcache::PolicyKind;
use swan::model::transformer::SequenceState;
use swan::model::{SwanModel, WeightFile};
use swan::sparse::StorageMode;
use swan::swan::projection::ProjectionVariant;

fn load(name: &str) -> Option<(SwanModel, WeightFile)> {
    let dir = swan::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let wf = WeightFile::load(&dir.join(format!("weights_{name}.bin"))).unwrap();
    let golden = WeightFile::load(&dir.join(format!("golden_{name}.bin"))).unwrap();
    let model = SwanModel::load(&wf, ProjectionVariant::Calibrated, 0).unwrap();
    Some((model, golden))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn native_prefill_matches_python_gqa() {
    let Some((model, golden)) = load("swan-nano-gqa") else { return };
    let prompt: Vec<u32> =
        golden.get("prompt_tokens").unwrap().as_i32().unwrap().iter().map(|&t| t as u32).collect();
    let pf = model.prefill(&prompt);
    let want = golden.f32("prefill_logits").unwrap();
    let diff = max_abs_diff(&pf.logits, want);
    assert!(diff < 3e-2, "native prefill logits deviate: {diff}");

    // khat history must match too (layout [L, nkv, T, dh])
    let gk = golden.f32("prefill_khat").unwrap();
    let cfg = &model.cfg;
    let t = prompt.len();
    let mut kdiff = 0.0f32;
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_kv_heads {
            let ours = &pf.khat[l][h];
            let base = (l * cfg.n_kv_heads + h) * t * cfg.d_head;
            kdiff = kdiff.max(max_abs_diff(ours, &gk[base..base + t * cfg.d_head]));
        }
    }
    assert!(kdiff < 2e-2, "native khat deviates: {kdiff}");
}

#[test]
fn native_prefill_matches_python_mha() {
    let Some((model, golden)) = load("swan-nano-mha") else { return };
    let prompt: Vec<u32> =
        golden.get("prompt_tokens").unwrap().as_i32().unwrap().iter().map(|&t| t as u32).collect();
    let pf = model.prefill(&prompt);
    let diff = max_abs_diff(&pf.logits, golden.f32("prefill_logits").unwrap());
    assert!(diff < 3e-2, "native MHA prefill deviates: {diff}");
}

#[test]
fn native_dense_decode_matches_python() {
    let Some((model, golden)) = load("swan-nano-gqa") else { return };
    let prompt: Vec<u32> =
        golden.get("prompt_tokens").unwrap().as_i32().unwrap().iter().map(|&t| t as u32).collect();
    let next = golden.get("swan_decode_token").unwrap().as_i32().unwrap()[0] as u32;

    let pf = model.prefill(&prompt);
    let mut st = SequenceState::new(&model, PolicyKind::Dense);
    st.load_prefill(&pf);
    let logits = model.decode_step(&mut st, next);
    let diff = max_abs_diff(&logits, golden.f32("dense_decode_logits").unwrap());
    assert!(diff < 5e-2, "native dense decode deviates: {diff}");
}

#[test]
fn native_swan_decode_matches_python() {
    let Some((model, golden)) = load("swan-nano-gqa") else { return };
    let prompt: Vec<u32> =
        golden.get("prompt_tokens").unwrap().as_i32().unwrap().iter().map(|&t| t as u32).collect();
    let meta = golden.get("swan_decode_cfg").unwrap().as_i32().unwrap();
    let (buf_n, k_active) = (meta[0] as usize, meta[1] as usize);
    let next = golden.get("swan_decode_token").unwrap().as_i32().unwrap()[0] as u32;

    let pf = model.prefill(&prompt);
    let mut st = SequenceState::new(
        &model,
        PolicyKind::Swan { k_active, buffer: buf_n, mode: StorageMode::F32 },
    );
    st.load_prefill(&pf);
    let logits = model.decode_step(&mut st, next);
    let diff = max_abs_diff(&logits, golden.f32("swan_decode_logits").unwrap());
    assert!(diff < 5e-2, "native swan decode deviates: {diff}");
}

#[test]
fn trained_model_continues_corpus_plausibly() {
    // end-to-end sanity: greedy continuation of corpus-like text stays in
    // the printable alphabet and is deterministic
    let Some((model, _)) = load("swan-nano-gqa") else { return };
    let prompt = swan::coordinator::request::encode_text("the sparse cache stores the ");
    let pf = model.prefill(&prompt);
    let mut st = SequenceState::new(&model, PolicyKind::Dense);
    st.load_prefill(&pf);
    let next = swan::tensor::ops::argmax(&pf.logits) as u32;
    let toks = swan::model::generate::greedy(&model, &mut st, next, 24);
    let text = swan::coordinator::request::decode_tokens(&toks);
    assert!(text.is_ascii(), "{text}");
}
