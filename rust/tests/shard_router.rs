//! Shard-router semantics over stub shards — placement per balance
//! policy, the optimistic queue bump, and the `SET k_active`
//! broadcast+gather — all without model artifacts (the stubs script the
//! shard side of the command channel).

use std::sync::atomic::Ordering;
use std::sync::mpsc;

use swan::coordinator::Request;
use swan::shard::balance::{LeastQueued, MemAware, RoundRobin};
use swan::shard::{policy_from_name, Router, ShardCmd, ShardHandle};

fn stub_fleet(n: usize) -> (Vec<ShardHandle>, Vec<mpsc::Receiver<ShardCmd>>) {
    (0..n).map(ShardHandle::stub).unzip()
}

fn gen_count(rx: &mpsc::Receiver<ShardCmd>) -> usize {
    let mut n = 0;
    while let Ok(cmd) = rx.try_recv() {
        if matches!(cmd, ShardCmd::Gen { .. }) {
            n += 1;
        }
    }
    n
}

#[test]
fn round_robin_routes_a_mix_cyclically() {
    let (shards, rxs) = stub_fleet(3);
    // skew the load heavily — round-robin must ignore it
    shards[0].status.queued.store(50, Ordering::Relaxed);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    for i in 0..6 {
        router.submit(Request::from_text(0, &format!("req {i}"), 4)).unwrap();
    }
    assert_eq!(rxs.iter().map(gen_count).collect::<Vec<_>>(), vec![2, 2, 2]);
}

#[test]
fn least_queued_balances_and_reacts_to_scripted_load() {
    let (shards, rxs) = stub_fleet(2);
    let router = Router::from_handles(shards, Box::new(LeastQueued));
    // idle fleet: the optimistic bump alternates placements 0,1,0,1
    for i in 0..4 {
        router.submit(Request::from_text(0, &format!("req {i}"), 4)).unwrap();
    }
    assert_eq!(rxs.iter().map(gen_count).collect::<Vec<_>>(), vec![2, 2]);
    // now script shard 0 as saturated: everything goes to shard 1
    router.shards()[0].status.active.store(8, Ordering::Relaxed);
    for i in 0..3 {
        router.submit(Request::from_text(0, &format!("more {i}"), 4)).unwrap();
    }
    assert_eq!(rxs.iter().map(gen_count).collect::<Vec<_>>(), vec![0, 3]);
}

#[test]
fn mem_aware_follows_projected_kv_bytes() {
    let (shards, rxs) = stub_fleet(3);
    shards[0].status.projected_bytes.store(1 << 20, Ordering::Relaxed);
    shards[1].status.projected_bytes.store(1 << 10, Ordering::Relaxed);
    shards[2].status.projected_bytes.store(1 << 30, Ordering::Relaxed);
    let router = Router::from_handles(shards, Box::new(MemAware));
    for i in 0..3 {
        router.submit(Request::from_text(0, &format!("req {i}"), 4)).unwrap();
    }
    // projected bytes are scripted (stubs never republish), so the
    // lightest shard keeps winning regardless of the queue bumps
    assert_eq!(rxs.iter().map(gen_count).collect::<Vec<_>>(), vec![0, 3, 0]);
}

#[test]
fn submit_bumps_the_placed_shards_queue() {
    let (shards, _rxs) = stub_fleet(2);
    let router = Router::from_handles(shards, Box::new(LeastQueued));
    router.submit(Request::from_text(0, "hello", 4)).unwrap();
    assert_eq!(router.shards()[0].snapshot().queued, 1);
    assert_eq!(router.shards()[1].snapshot().queued, 0);
}

#[test]
fn submit_assigns_fleet_unique_ids() {
    let (shards, rxs) = stub_fleet(2);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    for _ in 0..4 {
        router.submit(Request::from_text(0, "hello", 4)).unwrap();
    }
    let mut ids = Vec::new();
    for rx in &rxs {
        while let Ok(ShardCmd::Gen { req, .. }) = rx.try_recv() {
            ids.push(req.id);
        }
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3, 4]);
}

#[test]
fn set_k_active_broadcast_reaches_every_shard() {
    let (shards, rxs) = stub_fleet(3);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    // script the shard side: each shard acks the retune with the k it
    // applied (a real engine snaps to its nearest compiled bucket)
    let responders: Vec<_> = rxs
        .into_iter()
        .map(|rx| {
            std::thread::spawn(move || match rx.recv().unwrap() {
                ShardCmd::SetK { k, ack } => {
                    ack.send(k).unwrap();
                    k
                }
                _ => panic!("expected SetK"),
            })
        })
        .collect();
    let applied = router.set_k_active(24).unwrap();
    assert_eq!(applied, vec![(0, 24), (1, 24), (2, 24)]);
    for r in responders {
        assert_eq!(r.join().unwrap(), 24);
    }
}

#[test]
fn cancel_broadcast_reaches_every_shard() {
    let (shards, rxs) = stub_fleet(3);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    router.cancel(42).unwrap();
    for rx in &rxs {
        match rx.try_recv().unwrap() {
            ShardCmd::Cancel { id } => assert_eq!(id, 42),
            _ => panic!("expected Cancel on every shard"),
        }
    }
}

#[test]
fn submit_returns_a_handle_wired_to_the_request() {
    let (shards, rxs) = stub_fleet(1);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    let handle = router.submit(Request::from_text(0, "hello", 4)).unwrap();
    assert_eq!(handle.id(), 1, "fleet ids start at 1");
    // the shard sees the same id, and the handle's cancel token IS the
    // request's token (flipping one flips the other)
    match rxs[0].try_recv().unwrap() {
        ShardCmd::Gen { req, .. } => {
            assert_eq!(req.id, handle.id());
            assert!(!req.cancel.is_cancelled());
            handle.cancel();
            assert!(req.cancel.is_cancelled(), "handle.cancel() must reach the request");
        }
        _ => panic!("expected Gen"),
    }
}

#[test]
fn live_policy_swap_changes_placement() {
    let (shards, rxs) = stub_fleet(2);
    shards[1].status.projected_bytes.store(0, Ordering::Relaxed);
    shards[0].status.projected_bytes.store(1 << 20, Ordering::Relaxed);
    let router = Router::from_handles(shards, Box::new(RoundRobin::default()));
    router.submit(Request::from_text(0, "a", 4)).unwrap(); // rr -> shard 0
    router.set_policy(policy_from_name("mem-aware").unwrap());
    router.submit(Request::from_text(0, "b", 4)).unwrap(); // mem -> shard 1
    assert_eq!(gen_count(&rxs[0]), 1);
    assert_eq!(gen_count(&rxs[1]), 1);
}
