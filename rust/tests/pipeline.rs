//! Pipeline layer-sharding + api v2, end to end on a synthetic model (no
//! artifacts): an S-stage pipeline group must decode **bit-identically**
//! to a single-shard run on the same seed, the `--shards 4 --pipeline 2`
//! topology (2 groups x 2 stages) must match too, and a live fleet-wide
//! `SET k_active` must reach every stage of every group.
//!
//! The api-v2 acceptance coverage also lives here (it needs no
//! artifacts): a request with a per-request `k_active` override decodes
//! bit-identically to the same request under a fleet-wide retune, two
//! concurrent requests with different k on one shard each match their
//! solo references, top-p / repetition-penalty streams are identical
//! across worker counts, and `cancel()` retires a mid-decode sequence
//! within an iteration without disturbing co-batched sequences.

use std::sync::Arc;

use swan::api::{Event, GenParams};
use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::engine::sample;
use swan::coordinator::Request;
use swan::kvcache::PolicyKind;
use swan::model::transformer::{SequenceState, SwanModel};
use swan::shard::pipeline::launch_group;
use swan::shard::{Router, RoundRobin};
use swan::util::Pcg64;

/// Mirror of the engine's per-sequence decode RNG seed
/// (`coordinator::engine::x5wan_seed`, the "SWAN" constant) — the wire
/// contract both serving paths derive their sampling streams from.
const SWAN_SEED: u64 = 0x53_57_41_4e;

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "pipe-test".into(),
            d_model: 32,
            n_layers: 4, // divisible into 1, 2 and 4 stages
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: swan::sparse::StorageMode::F16,
        max_batch: 8,
        ..Default::default()
    }
}

fn one_group_router(cfg: &ServeConfig) -> Router {
    let handle = launch_group(0, test_model(), cfg).unwrap();
    Router::from_handles(vec![handle], Box::new(RoundRobin::default()))
}

/// The request mix: mostly greedy, one temperature-sampled stream (which
/// exercises the shared per-request RNG contract).
fn requests() -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..5)
        .map(|i| Request::from_text(i + 1, &format!("the sparse vector {i} maps the "), 10))
        .collect();
    reqs.push(Request::with_params(
        6,
        "the hot cache winnows ",
        GenParams::new(10).temperature(0.8),
    ));
    reqs
}

/// Serve `reqs` through `n_groups` pipeline groups of `stages` stages
/// each behind a round-robin router; returns token streams by request id.
fn run_fleet_with(
    stages: usize,
    n_groups: usize,
    decode_workers: usize,
    reqs: &[Request],
) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = ServeConfig { pipeline: stages, decode_workers, ..serve_cfg() };
    let handles: Vec<_> = (0..n_groups)
        .map(|id| launch_group(id, model.clone(), &cfg).unwrap())
        .collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| (r.id, router.submit(r.clone()).unwrap()))
        .collect();
    let mut out: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, handle)| {
            let resp = handle.wait().expect("generation ok");
            assert_eq!(resp.id, id);
            (id, resp.tokens)
        })
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

fn run_fleet(stages: usize, n_groups: usize, reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    run_fleet_with(stages, n_groups, 0, reqs)
}

/// The single-shard reference, computed directly on the native model with
/// the engine's sampling/seeding contract — what `--shards 1` produces.
/// Each request runs at its *own* compression level (`params.k_active`
/// d_head-clamped, exactly as the group coordinator admits it).
fn single_shard_reference(reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = serve_cfg();
    reqs.iter()
        .map(|req| {
            let k = req
                .params
                .k_active
                .map(|k| k.clamp(1, model.cfg.d_head))
                .unwrap_or(cfg.k_active);
            let kind = PolicyKind::Swan { k_active: k, buffer: cfg.buffer, mode: cfg.mode };
            let tokens: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
            let pf = model.prefill(tokens);
            let mut st = SequenceState::new(&model, kind);
            st.load_prefill(&pf);
            let base = req.params.seed.unwrap_or(req.id);
            let mut tok = sample(&pf.logits, &req.params, &[], &mut Pcg64::new(base));
            let mut rng = Pcg64::new(base ^ SWAN_SEED);
            let mut produced = vec![tok];
            while produced.len() < req.params.max_new {
                let logits = model.decode_step(&mut st, tok);
                tok = sample(&logits, &req.params, &produced, &mut rng);
                produced.push(tok);
            }
            (req.id, produced)
        })
        .collect()
}

#[test]
fn pipeline_stages_decode_bit_identically_to_single_shard() {
    let reqs = requests();
    let want = single_shard_reference(&reqs);
    for stages in [1usize, 2, 4] {
        let got = run_fleet(stages, 1, &reqs);
        assert_eq!(got, want, "{stages}-stage pipeline diverged from the single-shard run");
    }
}

/// The acceptance topology: `--shards 4 --pipeline 2` = 2 groups x 2
/// stages, decoding bit-identically to `--shards 1` on the same seed.
#[test]
fn two_groups_of_two_stages_match_single_shard() {
    let reqs = requests();
    let want = single_shard_reference(&reqs);
    let got = run_fleet(2, 2, &reqs);
    assert_eq!(got, want, "2x2 pipeline fleet diverged from the single-shard run");
}

/// Live fleet retune: `SET k_active` broadcasts through every group to
/// every stage, acks gather, and STATS shows the new level on all stages.
#[test]
fn set_k_active_reaches_every_stage_of_every_group() {
    let model = test_model();
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let handles: Vec<_> =
        (0..2).map(|id| launch_group(id, model.clone(), &cfg).unwrap()).collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));

    let applied = router.set_k_active(6).unwrap();
    assert_eq!(applied, vec![(0, 6), (1, 6)], "every group must ack the retune");
    // an over-range retune snaps to d_head on every stage (native path
    // has no compiled buckets; the clamp is the snap)
    let applied = router.set_k_active(500).unwrap();
    assert_eq!(applied, vec![(0, 8), (1, 8)]);
}

/// STATS renders per-stage queue depth and the retuned compression level
/// on every stage (the bubble-visibility requirement).
#[test]
fn fleet_stats_show_per_stage_depth_and_retuned_k() {
    let model = test_model();
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let handles: Vec<_> =
        (0..2).map(|id| launch_group(id, model.clone(), &cfg).unwrap()).collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));
    router.set_k_active(6).unwrap();

    let stats = router.stats();
    assert!(stats.contains("fleet: shards=2"), "{stats}");
    for group in 0..2 {
        assert!(
            stats.contains(&format!("shard {group}: pipeline stages=2 k_active=6")),
            "group {group} header missing or stale k: {stats}"
        );
    }
    // two stage lines per group, each carrying the retuned k and a queue
    // depth (the pipeline-bubble indicator) and its layer range
    assert_eq!(stats.matches("stage 0: layers 0..2 k_active=6 queued=").count(), 2, "{stats}");
    assert_eq!(stats.matches("stage 1: layers 2..4 k_active=6 queued=").count(), 2, "{stats}");

    // the fleet still serves after the retune
    let handle = router.submit(Request::from_text(9, "retuned ", 4)).unwrap();
    let resp = handle.wait().unwrap();
    assert_eq!(resp.tokens.len(), 4);
}

/// Uneven layer counts still pipeline correctly (3 stages over 4 layers:
/// ranges 0..2, 2..3, 3..4) and stay bit-identical to one stage.
#[test]
fn uneven_stage_split_is_still_bit_identical() {
    let reqs: Vec<Request> = vec![Request::from_text(1, "uneven split ", 8)];
    let want = single_shard_reference(&reqs);
    let got = run_fleet(3, 1, &reqs);
    assert_eq!(got, want);
}

// ----------------------------------------------------------------------
// api v2: per-request compression, cancellation, streaming, samplers
// ----------------------------------------------------------------------

/// Acceptance: a request with `k=<n>` decodes bit-identically to the
/// same seed/prompt under a fleet-wide `SET k_active <n>`.
#[test]
fn per_request_k_override_matches_fleet_retune() {
    for k in [2usize, 6] {
        let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
        // fleet-wide retune, then a plain request
        let fleet_router = one_group_router(&cfg);
        fleet_router.set_k_active(k).unwrap();
        let fleet = fleet_router
            .submit(Request::from_text(3, "override parity ", 10))
            .unwrap()
            .wait()
            .unwrap();
        // fresh fleet left at the default level; the request carries k=
        let over_router = one_group_router(&cfg);
        let over = over_router
            .submit(Request::with_params(3, "override parity ", GenParams::new(10).k_active(k)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(over.tokens, fleet.tokens, "k={k} override diverged from fleet retune");
    }
}

/// Acceptance: two concurrent requests with different k on ONE shard
/// co-batch and each still matches its single-request reference.
#[test]
fn mixed_k_requests_on_one_shard_match_their_solo_references() {
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let reqs = vec![
        Request::with_params(1, "mixed low ", GenParams::new(10).k_active(2)),
        Request::with_params(2, "mixed high ", GenParams::new(10).k_active(6)),
    ];
    // solo runs: one request per fresh fleet
    let mut want = Vec::new();
    for r in &reqs {
        let router = one_group_router(&cfg);
        let resp = router.submit(r.clone()).unwrap().wait().unwrap();
        want.push((resp.id, resp.tokens));
    }
    // both concurrently on ONE group
    let router = one_group_router(&cfg);
    let handles: Vec<_> = reqs.iter().map(|r| router.submit(r.clone()).unwrap()).collect();
    let mut got: Vec<(u64, Vec<u32>)> = handles
        .into_iter()
        .map(|h| {
            let r = h.wait().unwrap();
            (r.id, r.tokens)
        })
        .collect();
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got, want, "co-batched mixed-k runs diverged from solo runs");
    // and the direct native reference agrees per-request
    assert_eq!(got, single_shard_reference(&reqs));
}

/// Acceptance: `cancel()` retires a mid-decode sequence within an
/// iteration; the co-batched sequence decodes exactly as if alone.
#[test]
fn cancel_retires_mid_decode_without_disturbing_batchmates() {
    // a huge max_new default keeps A's budget unreachable, so the test
    // can never lose the race between its cancel and A's natural finish
    let cfg = ServeConfig { pipeline: 2, max_new_tokens: 100_000, ..serve_cfg() };
    let router = one_group_router(&cfg);
    // A: effectively unbounded + streaming, so the test observes
    // progress before cancelling (if cancellation ever breaks, this
    // fails on the token-count assert rather than flaking)
    let a = router
        .submit(Request::with_params(1, "the long one ", GenParams::new(100_000).stream(true)))
        .unwrap();
    let b = router.submit(Request::from_text(2, "the bystander ", 12)).unwrap();
    let mut seen = 0;
    while seen < 2 {
        match a.recv().unwrap() {
            Event::Token { .. } => seen += 1,
            Event::Done(_) => panic!("A finished before it could be cancelled"),
            Event::Error { message, .. } => panic!("{message}"),
        }
    }
    a.cancel();
    let a_resp = a.wait().unwrap();
    assert!(a_resp.stats.cancelled, "cancel flag must be surfaced in stats");
    assert!(a_resp.tokens.len() >= 2, "partial output is preserved");
    assert!(a_resp.tokens.len() < 100_000, "cancel must beat the budget");
    // the bystander is bit-identical to decoding alone
    let b_resp = b.wait().unwrap();
    assert!(!b_resp.stats.cancelled);
    let want = single_shard_reference(&[Request::from_text(2, "the bystander ", 12)]);
    assert_eq!(vec![(b_resp.id, b_resp.tokens)], want, "co-batched sequence was disturbed");
    // the mid-decode cancel is counted (and the bystander is not)
    let cancelled: u64 = router
        .shards()
        .iter()
        .map(|s| s.metrics.requests_cancelled.get())
        .sum();
    assert_eq!(cancelled, 1, "mid-decode cancel must increment requests_cancelled");
}

/// A cancel that lands while the request is still queued answers the
/// waiter immediately with an empty cancelled response (and the id-hop
/// through `Router::cancel` / `ShardCmd::Cancel` finds the queue).
#[test]
fn queued_cancel_answers_with_empty_cancelled_response() {
    // A's budget is unreachable (see the mid-decode cancel test), so B
    // provably stays queued until its cancel is processed
    let cfg =
        ServeConfig { pipeline: 1, max_batch: 1, max_new_tokens: 100_000, ..serve_cfg() };
    let router = one_group_router(&cfg);
    let a = router
        .submit(Request::with_params(1, "hold the slot ", GenParams::new(100_000).stream(true)))
        .unwrap();
    // A holds the only batch slot once its first token streams back
    loop {
        match a.recv().unwrap() {
            Event::Token { .. } => break,
            Event::Done(_) => panic!("A finished prematurely"),
            Event::Error { message, .. } => panic!("{message}"),
        }
    }
    let b = router.submit(Request::from_text(2, "stuck in queue ", 8)).unwrap();
    router.cancel(2).unwrap();
    let b_resp = b.wait().unwrap();
    assert!(b_resp.stats.cancelled);
    assert!(b_resp.tokens.is_empty(), "queued cancel produces no tokens");
    a.cancel();
    assert!(a.wait().unwrap().stats.cancelled);
    // both paths count: B through the queued purge, A mid-decode
    let cancelled: u64 = router
        .shards()
        .iter()
        .map(|s| s.metrics.requests_cancelled.get())
        .sum();
    assert_eq!(cancelled, 2, "queued purge and mid-decode cancels must both count");
}

/// Top-p and repetition-penalty run inside the parallel execute phase;
/// their streams must be bit-identical for any stage worker count (and
/// equal to the direct native reference).
#[test]
fn topp_and_rep_penalty_streams_match_across_worker_counts() {
    let reqs: Vec<Request> = (0..4u64)
        .map(|i| {
            Request::with_params(
                i + 1,
                &format!("sampled stream {i} "),
                GenParams::new(12)
                    .temperature(0.9)
                    .top_p(0.8)
                    .repetition_penalty(1.2)
                    .seed(100 + i),
            )
        })
        .collect();
    let want = single_shard_reference(&reqs);
    for workers in [0usize, 3] {
        for stages in [1usize, 2] {
            let got = run_fleet_with(stages, 1, workers, &reqs);
            assert_eq!(got, want, "stages={stages} workers={workers} diverged");
        }
    }
}

/// `stream=1` delivers every token as an in-order event whose
/// concatenation is exactly the final response.
#[test]
fn streamed_tokens_reassemble_the_final_response() {
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let router = one_group_router(&cfg);
    let handle = router
        .submit(Request::with_params(
            5,
            "stream me ",
            GenParams::new(9).temperature(0.7).seed(3).stream(true),
        ))
        .unwrap();
    let mut toks: Vec<u32> = Vec::new();
    let resp = loop {
        match handle.recv().unwrap() {
            Event::Token { id, index, token, text } => {
                assert_eq!(id, 5);
                assert_eq!(index, toks.len(), "token events must arrive in order");
                assert_eq!(text.len(), 1, "char-level tokenizer streams one char per token");
                toks.push(token);
            }
            Event::Done(r) => break r,
            Event::Error { message, .. } => panic!("{message}"),
        }
    };
    assert_eq!(toks, resp.tokens, "streamed tokens must reassemble the response");
    assert_eq!(resp.tokens.len(), 9);
}

/// The `max_new` hard cap is enforced on the pipeline path and surfaced
/// in stats; requests under the cap are untouched.
#[test]
fn max_new_clamp_is_enforced_and_surfaced() {
    let model = test_model();
    let cfg = ServeConfig { pipeline: 1, max_new_tokens: 4, ..serve_cfg() };
    let h = launch_group(0, model.clone(), &cfg).unwrap();
    let router = Router::from_handles(vec![h], Box::new(RoundRobin::default()));
    let resp = router.submit(Request::from_text(1, "clamp me ", 100)).unwrap().wait().unwrap();
    assert_eq!(resp.tokens.len(), 32, "hard cap = 8 x max_new_tokens");
    assert_eq!(resp.stats.clamped_from, Some(100));
    let h = launch_group(1, model, &cfg).unwrap();
    let router = Router::from_handles(vec![h], Box::new(RoundRobin::default()));
    let resp = router.submit(Request::from_text(1, "clamp me ", 8)).unwrap().wait().unwrap();
    assert_eq!(resp.tokens.len(), 8);
    assert_eq!(resp.stats.clamped_from, None);
}
