//! Pipeline layer-sharding, end to end on a synthetic model (no
//! artifacts): an S-stage pipeline group must decode **bit-identically**
//! to a single-shard run on the same seed, the `--shards 4 --pipeline 2`
//! topology (2 groups x 2 stages) must match too, and a live fleet-wide
//! `SET k_active` must reach every stage of every group.

use std::sync::Arc;

use swan::config::{ModelConfig, ServeConfig};
use swan::coordinator::engine::sample;
use swan::coordinator::Request;
use swan::kvcache::PolicyKind;
use swan::model::transformer::{SequenceState, SwanModel};
use swan::shard::pipeline::launch_group;
use swan::shard::{Router, RoundRobin};
use swan::sparse::StorageMode;
use swan::util::Pcg64;

/// Mirror of the engine's per-sequence decode RNG seed
/// (`coordinator::engine::x5wan_seed`, the "SWAN" constant) — the wire
/// contract both serving paths derive their sampling streams from.
const SWAN_SEED: u64 = 0x53_57_41_4e;

fn test_model() -> Arc<SwanModel> {
    Arc::new(SwanModel::synthetic(
        ModelConfig {
            name: "pipe-test".into(),
            d_model: 32,
            n_layers: 4, // divisible into 1, 2 and 4 stages
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            vocab: 96,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        },
        33,
    ))
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        k_active: 4,
        buffer: 3,
        mode: StorageMode::F16,
        max_batch: 8,
        ..Default::default()
    }
}

/// The request mix: mostly greedy, one temperature-sampled stream (which
/// exercises the shared per-request RNG contract).
fn requests() -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..5)
        .map(|i| Request::from_text(i + 1, &format!("the sparse vector {i} maps the "), 10))
        .collect();
    reqs.push(Request {
        temperature: 0.8,
        ..Request::from_text(6, "the hot cache winnows ", 10)
    });
    reqs
}

/// Serve `reqs` through `n_groups` pipeline groups of `stages` stages
/// each behind a round-robin router; returns token streams by request id.
fn run_fleet(stages: usize, n_groups: usize, reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = ServeConfig { pipeline: stages, ..serve_cfg() };
    let handles: Vec<_> = (0..n_groups)
        .map(|id| launch_group(id, model.clone(), &cfg).unwrap())
        .collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));
    let pending: Vec<_> = reqs
        .iter()
        .map(|r| (r.id, router.submit(r.clone()).unwrap()))
        .collect();
    let mut out: Vec<(u64, Vec<u32>)> = pending
        .into_iter()
        .map(|(id, rx)| {
            let resp = rx.recv().expect("group alive").expect("generation ok");
            assert_eq!(resp.id, id);
            (id, resp.tokens)
        })
        .collect();
    out.sort_by_key(|(id, _)| *id);
    out
}

/// The single-shard reference, computed directly on the native model with
/// the engine's sampling/seeding contract — what `--shards 1` produces.
fn single_shard_reference(reqs: &[Request]) -> Vec<(u64, Vec<u32>)> {
    let model = test_model();
    let cfg = serve_cfg();
    let kind = PolicyKind::Swan {
        k_active: cfg.k_active,
        buffer: cfg.buffer,
        mode: cfg.mode,
    };
    reqs.iter()
        .map(|req| {
            let tokens: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
            let pf = model.prefill(tokens);
            let mut st = SequenceState::new(&model, kind);
            st.load_prefill(&pf);
            let mut tok = sample(&pf.logits, req.temperature, &mut Pcg64::new(req.id));
            let mut rng = Pcg64::new(req.id ^ SWAN_SEED);
            let mut produced = vec![tok];
            while produced.len() < req.max_new_tokens {
                let logits = model.decode_step(&mut st, tok);
                tok = sample(&logits, req.temperature, &mut rng);
                produced.push(tok);
            }
            (req.id, produced)
        })
        .collect()
}

#[test]
fn pipeline_stages_decode_bit_identically_to_single_shard() {
    let reqs = requests();
    let want = single_shard_reference(&reqs);
    for stages in [1usize, 2, 4] {
        let got = run_fleet(stages, 1, &reqs);
        assert_eq!(got, want, "{stages}-stage pipeline diverged from the single-shard run");
    }
}

/// The acceptance topology: `--shards 4 --pipeline 2` = 2 groups x 2
/// stages, decoding bit-identically to `--shards 1` on the same seed.
#[test]
fn two_groups_of_two_stages_match_single_shard() {
    let reqs = requests();
    let want = single_shard_reference(&reqs);
    let got = run_fleet(2, 2, &reqs);
    assert_eq!(got, want, "2x2 pipeline fleet diverged from the single-shard run");
}

/// Live fleet retune: `SET k_active` broadcasts through every group to
/// every stage, acks gather, and STATS shows the new level on all stages.
#[test]
fn set_k_active_reaches_every_stage_of_every_group() {
    let model = test_model();
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let handles: Vec<_> =
        (0..2).map(|id| launch_group(id, model.clone(), &cfg).unwrap()).collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));

    let applied = router.set_k_active(6).unwrap();
    assert_eq!(applied, vec![(0, 6), (1, 6)], "every group must ack the retune");
    // an over-range retune snaps to d_head on every stage (native path
    // has no compiled buckets; the clamp is the snap)
    let applied = router.set_k_active(500).unwrap();
    assert_eq!(applied, vec![(0, 8), (1, 8)]);
}

/// STATS renders per-stage queue depth and the retuned compression level
/// on every stage (the bubble-visibility requirement).
#[test]
fn fleet_stats_show_per_stage_depth_and_retuned_k() {
    let model = test_model();
    let cfg = ServeConfig { pipeline: 2, ..serve_cfg() };
    let handles: Vec<_> =
        (0..2).map(|id| launch_group(id, model.clone(), &cfg).unwrap()).collect();
    let router = Router::from_handles(handles, Box::new(RoundRobin::default()));
    router.set_k_active(6).unwrap();

    let stats = router.stats();
    assert!(stats.contains("fleet: shards=2"), "{stats}");
    for group in 0..2 {
        assert!(
            stats.contains(&format!("shard {group}: pipeline stages=2 k_active=6")),
            "group {group} header missing or stale k: {stats}"
        );
    }
    // two stage lines per group, each carrying the retuned k and a queue
    // depth (the pipeline-bubble indicator) and its layer range
    assert_eq!(stats.matches("stage 0: layers 0..2 k_active=6 queued=").count(), 2, "{stats}");
    assert_eq!(stats.matches("stage 1: layers 2..4 k_active=6 queued=").count(), 2, "{stats}");

    // the fleet still serves after the retune
    let rx = router.submit(Request::from_text(9, "retuned ", 4)).unwrap();
    let resp = rx.recv().unwrap().unwrap();
    assert_eq!(resp.tokens.len(), 4);
}

/// Uneven layer counts still pipeline correctly (3 stages over 4 layers:
/// ranges 0..2, 2..3, 3..4) and stay bit-identical to one stage.
#[test]
fn uneven_stage_split_is_still_bit_identical() {
    let reqs: Vec<Request> = vec![Request::from_text(1, "uneven split ", 8)];
    let want = single_shard_reference(&reqs);
    let got = run_fleet(3, 1, &reqs);
    assert_eq!(got, want);
}
