//! swan::prefix — cross-request KV reuse over the block pool.
//!
//! Real traffic is dominated by shared prefixes (system prompts,
//! few-shot headers, multi-turn history).  SWAN's rotation is a fixed
//! offline matrix, so the winnowed, lane-padded state computed for a
//! prompt prefix is a *pure function of tokens × compression config* —
//! reusable verbatim across requests at the same `(k, mode, lanes,
//! buffer, block_tokens)`, no recompute, no decompression.  This module
//! holds the pieces shared between the serving coordinator and the
//! pipeline stages:
//!
//! * [`PrefixTree`] — the coordinator-side index: a hash tree over
//!   prompt token-blocks (`block_tokens` granularity).  Entries are
//!   keyed by the rolling token-block hash chain mixed with the
//!   compression-config hash ([`entry_key`]), verified against the
//!   exact stored token prefix on every match (hash collisions can
//!   never cause wrong reuse), and aged by a logical LRU clock so the
//!   sweeper sheds cold entries under pool pressure *before* any
//!   running sequence is preempted.
//! * [`EntryStream`] / [`StageEntry`] — the stage-side payload: per
//!   (layer, kv-head) stream, the full winnowed blocks pinned via pool
//!   refcounts ([`crate::pool::BlockPool::share`] — the copy-on-write
//!   hook), plus owned copies of the partial tail rows and the dense
//!   recency ring captured at exactly the entry's depth.  Full blocks
//!   are shared zero-copy and never mutated; tails and rings
//!   re-materialize into fresh leases on attach (the mandatory fork).
//! * [`PrefixPrefill`] / [`PendingInsert`] — the stage-protocol
//!   sidecar: what to attach, where the suffix starts, and what to
//!   capture for insertion when the sequence retires.
//!
//! Reuse contract: under prefix serving every prompt runs through the
//! same cache-consistent per-token prefill, so a warm hit (attach L
//! tokens, run P−L) produces bit-identical state and output to a cold
//! miss (attach 0, run P) — locked down by `tests/prefix.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use crate::pool::{BlockBuf, BlockPool};
use crate::sparse::StorageMode;
use crate::swan::hybrid_cache::SwanParams;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_u64(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash of the compression config a cached prefix is only valid under.
/// Any knob that changes winnowed bytes participates: per-request k
/// (keys and values), ring capacity, value precision, lane padding, and
/// the block granularity itself.
pub fn cfg_key(params: &SwanParams, block_tokens: usize) -> u64 {
    let mode_tag: u64 = match params.mode {
        StorageMode::F16 => 1,
        StorageMode::F8 => 2,
        StorageMode::F32 => 3,
    };
    let mut h = FNV_OFFSET;
    for x in [
        params.k_active_keys as u64,
        params.k_active_vals as u64,
        params.buffer as u64,
        mode_tag,
        params.resolved_lanes() as u64,
        block_tokens.max(1) as u64,
    ] {
        h = fnv_u64(h, x);
    }
    h
}

/// Rolling hash chain over token blocks: one value per *complete*
/// block, where the i-th value covers `tokens[..(i + 1) * bt]`.  A
/// chain value at depth d therefore commits to the entire prefix up to
/// d, which is what makes a flat hash map behave like a radix tree.
pub fn chain_hashes(tokens: &[u32], bt: usize) -> Vec<u64> {
    let bt = bt.max(1);
    let mut out = Vec::with_capacity(tokens.len() / bt);
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens.iter().enumerate() {
        h = fnv_u64(h, t as u64);
        if (i + 1) % bt == 0 {
            out.push(h);
        }
    }
    out
}

/// Tree key of one (prefix, config) pair: the chain hash mixed with the
/// config hash through an avalanche so nearby chains spread across the
/// compact fingerprint sets shards publish for affinity routing.
pub fn entry_key(chain: u64, cfg: u64) -> u64 {
    let mut x = chain ^ cfg.rotate_left(32);
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

/// Deepest depth a prompt of `prompt_len` tokens can match or insert:
/// the largest block multiple that still leaves at least one suffix
/// token to run (prefill must produce the first-token logits, so a
/// fully cached prompt is capped one token short).
pub fn insert_depth(prompt_len: usize, bt: usize) -> usize {
    let bt = bt.max(1);
    if prompt_len <= 1 {
        return 0;
    }
    ((prompt_len - 1) / bt) * bt
}

/// Pool blocks a sequence stops holding uniquely when it attaches a
/// prefix at `depth`: the full shared sparse blocks across the whole
/// model (k and v streams of every (layer, kv-head)).  Ring blocks and
/// the forked tail stay owned and are charged to the sequence.
pub fn shared_full_blocks(
    depth: usize,
    buffer: usize,
    block_tokens: usize,
    n_layers: usize,
    n_kv_heads: usize,
) -> usize {
    let bt = block_tokens.max(1);
    2 * n_layers * n_kv_heads * (depth.saturating_sub(buffer) / bt)
}

/// The candidate entry keys of a prompt under one config, shallowest
/// block first — computed once per request so the router can score
/// every shard's fingerprint set without rehashing the prompt per
/// shard ([`affinity_from_keys`]).
pub fn affinity_keys(tokens: &[u32], bt: usize, cfg: u64) -> Vec<u64> {
    let bt = bt.max(1);
    let m = insert_depth(tokens.len(), bt);
    if m == 0 {
        return Vec::new();
    }
    chain_hashes(&tokens[..m], bt).into_iter().map(|ch| entry_key(ch, cfg)).collect()
}

/// Deepest key of [`affinity_keys`] present in a shard's published
/// fingerprint set, as a token depth (`0` — no overlap).
pub fn affinity_from_keys(keys: &[u64], bt: usize, fps: &[u64]) -> usize {
    if fps.is_empty() {
        return 0;
    }
    let bt = bt.max(1);
    for (bi, k) in keys.iter().enumerate().rev() {
        if fps.contains(k) {
            return (bi + 1) * bt;
        }
    }
    0
}

/// Longest prefix of `tokens` whose entry key appears in a shard's
/// published fingerprint set — the cache-affinity signal MemAware
/// placement routes on.  A fingerprint hit is only a heuristic (the
/// shard may have evicted since publishing); placement falls back to
/// load, never correctness.
pub fn affinity_depth(tokens: &[u32], bt: usize, cfg: u64, fps: &[u64]) -> usize {
    affinity_from_keys(&affinity_keys(tokens, bt, cfg), bt, fps)
}

/// One cached prefix in the coordinator-side tree.
pub struct PrefixEntry {
    /// Tree key (chain hash at `depth` mixed with the config hash) —
    /// also the id the stage-side stores file their payloads under.
    pub key: u64,
    /// Cached token count (a multiple of `block_tokens`).
    pub depth: usize,
    /// The exact tokens — every match verifies against these, so a
    /// hash collision degrades to a miss, never to wrong reuse.
    pub tokens: Vec<u32>,
    /// Analytic block charge held against the pool budget.
    pub charge_blocks: usize,
    /// Logical LRU clock value at last match/insert/refresh.
    pub last_used: u64,
    pub hits: u64,
}

/// The coordinator-side prefix index for one pipeline group.  Flat map,
/// radix-tree semantics: because each chain hash commits to its whole
/// prefix, "longest cached prefix" is a walk over the prompt's O(P/bt)
/// chain values, deepest first.
pub struct PrefixTree {
    entries: HashMap<u64, PrefixEntry>,
    clock: u64,
    bt: usize,
}

impl PrefixTree {
    pub fn new(block_tokens: usize) -> PrefixTree {
        PrefixTree { entries: HashMap::new(), clock: 0, bt: block_tokens.max(1) }
    }

    pub fn block_tokens(&self) -> usize {
        self.bt
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Sum of analytic block charges across all entries.
    pub fn total_charge(&self) -> usize {
        self.entries.values().map(|e| e.charge_blocks).sum()
    }

    /// See [`insert_depth`].
    pub fn insert_depth(&self, prompt_len: usize) -> usize {
        insert_depth(prompt_len, self.bt)
    }

    /// Longest cached, token-verified prefix of `tokens` under config
    /// `cfg`; bumps the winner's LRU clock and hit count.
    pub fn match_longest(&mut self, tokens: &[u32], cfg: u64) -> Option<(u64, usize)> {
        let (key, depth) = self.peek_longest(tokens, cfg)?;
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_used = self.clock;
            e.hits += 1;
        }
        Some((key, depth))
    }

    /// [`PrefixTree::match_longest`] without the LRU side effects —
    /// admission projections peek without committing.
    pub fn peek_longest(&self, tokens: &[u32], cfg: u64) -> Option<(u64, usize)> {
        let m = self.insert_depth(tokens.len());
        if m == 0 {
            return None;
        }
        let chains = chain_hashes(&tokens[..m], self.bt);
        for (bi, &ch) in chains.iter().enumerate().rev() {
            let depth = (bi + 1) * self.bt;
            let key = entry_key(ch, cfg);
            if let Some(e) = self.entries.get(&key) {
                if e.depth == depth && e.tokens == tokens[..depth] {
                    return Some((key, depth));
                }
            }
        }
        None
    }

    /// Insert a prefix under a precomputed `key` (the chain hash at
    /// `tokens.len()` mixed with the config hash).  Returns `true` when
    /// a NEW entry was created — the caller then commits the stage-side
    /// payload.  An existing entry with the same tokens just refreshes
    /// its clock; a colliding entry with different tokens is left alone
    /// (the insert degrades to a no-op).
    pub fn insert(&mut self, key: u64, tokens: &[u32], charge_blocks: usize) -> bool {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            if e.tokens == tokens {
                e.last_used = self.clock;
            }
            return false;
        }
        self.entries.insert(
            key,
            PrefixEntry {
                key,
                depth: tokens.len(),
                tokens: tokens.to_vec(),
                charge_blocks,
                last_used: self.clock,
                hits: 0,
            },
        );
        true
    }

    pub fn contains(&self, key: u64) -> bool {
        self.entries.contains_key(&key)
    }

    /// Least-recently-used entry key, skipping `excluded` (entries
    /// currently attached by running sequences — evicting those frees
    /// nothing until the sequences retire, so the sweeper prefers cold
    /// ones).
    pub fn lru_key_excluding(&self, excluded: &[u64]) -> Option<u64> {
        self.entries
            .values()
            .filter(|e| !excluded.contains(&e.key))
            .min_by_key(|e| (e.last_used, e.key))
            .map(|e| e.key)
    }

    pub fn remove(&mut self, key: u64) -> Option<PrefixEntry> {
        self.entries.remove(&key)
    }

    /// Drop everything, returning the evicted keys (broadcast to the
    /// stages so their stores release the pinned blocks).
    pub fn flush(&mut self) -> Vec<u64> {
        let keys: Vec<u64> = self.entries.keys().copied().collect();
        self.entries.clear();
        keys
    }

    /// Compact fingerprint set for affinity routing: up to `cap` entry
    /// keys, most recently used first.
    pub fn fingerprints(&self, cap: usize) -> Vec<u64> {
        let mut v: Vec<(u64, u64)> = self.entries.values().map(|e| (e.last_used, e.key)).collect();
        v.sort_unstable_by(|a, b| b.0.cmp(&a.0));
        v.truncate(cap);
        v.into_iter().map(|(_, k)| k).collect()
    }

    /// Total hits across live entries (STATS rendering).
    pub fn total_hits(&self) -> u64 {
        self.entries.values().map(|e| e.hits).sum()
    }
}

/// Per-Prefill sidecar of the stage protocol: how to run this prompt
/// under prefix serving.  `None` at the protocol level means legacy
/// exact prefill (prefix serving off).
#[derive(Clone, Debug)]
pub struct PrefixPrefill {
    /// Prefix-store entry to attach before the suffix runs (`None` —
    /// miss: the whole prompt is the suffix).
    pub attach: Option<u64>,
    /// Tokens already cached (the attach depth); the carried hidden
    /// rows cover positions `start_pos..prompt_len`.
    pub start_pos: usize,
    /// `(entry_key, depth)` to capture mid-prefill and commit at retire
    /// (`None` — the tree already holds this prompt's insertable
    /// prefix).
    pub insert: Option<(u64, usize)>,
}

/// A stage's parked capture for one running sequence: committed into
/// the stage store when the coordinator retires the sequence with an
/// insert marker, dropped on preemption or cancellation.
pub struct PendingInsert {
    pub key: u64,
    pub depth: usize,
    /// Ring snapshots captured at exactly `depth` tokens, one `(k, v)`
    /// pair per cache in the sequence's cache order.
    pub rings: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Owned copy of the first `rows` CSR rows of a partially filled block
/// — the prefix entry's share of a block the donor sequence kept
/// appending into.  Attaching copies these into a fresh lease, so the
/// bytes a warm cache ends up with are bit-identical to a cold run's.
pub struct TailRows {
    pub vals: Vec<f32>,
    pub idx: Vec<u16>,
    /// Padded row boundaries, `rows + 1` entries starting at 0.
    pub offsets: Vec<u32>,
    pub nnz: Vec<u32>,
    /// Eq. 1 bytes of the copied rows.
    pub bytes: usize,
}

impl TailRows {
    pub fn row_count(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }
}

/// One (layer, kv-head) stream of a cached prefix: full winnowed blocks
/// shared zero-copy (each `Arc` clone holds one pool reference), plus
/// owned copies of the partial sparse tails and the dense ring rows.
/// Dropping the stream releases its pool references — blocks free only
/// when the last holder (entry or attached sequence) lets go.
pub struct EntryStream {
    pub pool: Arc<BlockPool>,
    pub full_k: Vec<Arc<BlockBuf>>,
    pub full_v: Vec<Arc<BlockBuf>>,
    pub tail_k: Option<TailRows>,
    pub tail_v: Option<TailRows>,
    /// Ring rows at the entry's depth, oldest first, flattened.
    pub ring_k: Vec<f32>,
    pub ring_v: Vec<f32>,
}

impl EntryStream {
    /// Shared (pool-resident) blocks this stream pins.
    pub fn shared_blocks(&self) -> usize {
        self.full_k.len() + self.full_v.len()
    }
}

impl Drop for EntryStream {
    fn drop(&mut self) {
        for a in self.full_k.drain(..) {
            self.pool.release_shared(a);
        }
        for a in self.full_v.drain(..) {
            self.pool.release_shared(a);
        }
    }
}

/// One stage's share of a prefix entry: the streams for its layer
/// range, in the stage's cache order (`layer-in-range * n_kv + head`).
pub struct StageEntry {
    pub depth: usize,
    pub streams: Vec<EntryStream>,
}

/// The per-stage store, keyed by entry key.
pub type StagePrefixStore = HashMap<u64, StageEntry>;

#[cfg(test)]
mod tests {
    use super::*;

    fn params(k: usize, buffer: usize) -> SwanParams {
        SwanParams::new(k, buffer, StorageMode::F16).with_lanes(1)
    }

    #[test]
    fn chain_hashes_commit_to_whole_prefix() {
        let a = chain_hashes(&[1, 2, 3, 4, 5, 6], 2);
        assert_eq!(a.len(), 3);
        let b = chain_hashes(&[1, 2, 3, 4], 2);
        assert_eq!(&a[..2], &b[..]);
        // a different early token changes every later chain value
        let c = chain_hashes(&[9, 2, 3, 4, 5, 6], 2);
        assert!(a.iter().zip(&c).all(|(x, y)| x != y));
        // partial blocks contribute nothing
        assert_eq!(chain_hashes(&[1, 2, 3], 2).len(), 1);
        assert_eq!(chain_hashes(&[1], 2).len(), 0);
    }

    #[test]
    fn cfg_key_separates_compression_configs() {
        let base = cfg_key(&params(8, 4), 16);
        assert_ne!(base, cfg_key(&params(9, 4), 16), "k must participate");
        assert_ne!(base, cfg_key(&params(8, 5), 16), "buffer must participate");
        assert_ne!(base, cfg_key(&params(8, 4), 8), "block_tokens must participate");
        let mut p8 = params(8, 4);
        p8.mode = StorageMode::F8;
        assert_ne!(base, cfg_key(&p8, 16), "mode must participate");
        assert_eq!(base, cfg_key(&params(8, 4), 16), "deterministic");
    }

    #[test]
    fn insert_depth_leaves_one_suffix_token() {
        assert_eq!(insert_depth(0, 4), 0);
        assert_eq!(insert_depth(1, 4), 0);
        assert_eq!(insert_depth(4, 4), 0); // 4 tokens: depth 4 would leave no suffix
        assert_eq!(insert_depth(5, 4), 4);
        assert_eq!(insert_depth(9, 4), 8);
        assert_eq!(insert_depth(8, 4), 4);
        assert_eq!(insert_depth(3, 1), 2);
    }

    #[test]
    fn tree_matches_longest_and_verifies_tokens() {
        let cfg = cfg_key(&params(8, 2), 2);
        let mut t = PrefixTree::new(2);
        let tokens: Vec<u32> = (0..10).collect();
        let chains = chain_hashes(&tokens, 2);
        // insert depth-4 and depth-8 entries of the same chain
        assert!(t.insert(entry_key(chains[1], cfg), &tokens[..4], 10));
        assert!(t.insert(entry_key(chains[3], cfg), &tokens[..8], 20));
        assert_eq!(t.len(), 2);
        assert_eq!(t.total_charge(), 30);
        // a 9-token prompt caps matching at depth 8
        assert_eq!(t.match_longest(&tokens[..9], cfg).map(|(_, d)| d), Some(8));
        // an 8-token prompt caps at depth 6 -> chain has no entry at 6, falls to 4
        assert_eq!(t.match_longest(&tokens[..8], cfg).map(|(_, d)| d), Some(4));
        // a diverging prompt with the same length misses
        let other: Vec<u32> = (100..110).collect();
        assert_eq!(t.match_longest(&other, cfg), None);
        // a different config misses even on identical tokens
        let cfg2 = cfg_key(&params(4, 2), 2);
        assert_eq!(t.match_longest(&tokens[..9], cfg2), None);
        // re-insert of the same prefix refreshes, not duplicates
        assert!(!t.insert(entry_key(chains[3], cfg), &tokens[..8], 20));
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn lru_order_and_exclusion() {
        let cfg = 7;
        let mut t = PrefixTree::new(1);
        let ka = entry_key(chain_hashes(&[1], 1)[0], cfg);
        let kb = entry_key(chain_hashes(&[2], 1)[0], cfg);
        let kc = entry_key(chain_hashes(&[3], 1)[0], cfg);
        assert!(t.insert(ka, &[1], 1));
        assert!(t.insert(kb, &[2], 1));
        assert!(t.insert(kc, &[3], 1));
        // a is oldest; touch it via a match and b becomes LRU
        assert!(t.match_longest(&[1, 99], cfg).is_some());
        assert_eq!(t.lru_key_excluding(&[]), Some(kb));
        assert_eq!(t.lru_key_excluding(&[kb]), Some(kc));
        assert_eq!(t.lru_key_excluding(&[kb, kc]), Some(ka));
        assert_eq!(t.lru_key_excluding(&[ka, kb, kc]), None);
        let e = t.remove(kb).unwrap();
        assert_eq!(e.depth, 1);
        assert_eq!(t.len(), 2);
        let mut flushed = t.flush();
        flushed.sort_unstable();
        let mut want = vec![ka, kc];
        want.sort_unstable();
        assert_eq!(flushed, want);
        assert!(t.is_empty());
    }

    #[test]
    fn fingerprints_prefer_recent_and_drive_affinity() {
        let cfg = 11;
        let mut t = PrefixTree::new(2);
        let tokens: Vec<u32> = (0..6).collect();
        let chains = chain_hashes(&tokens, 2);
        let k4 = entry_key(chains[1], cfg);
        t.insert(entry_key(chains[0], cfg), &tokens[..2], 1);
        t.insert(k4, &tokens[..4], 1);
        let fps = t.fingerprints(1);
        assert_eq!(fps, vec![k4], "cap keeps the most recently used");
        // affinity: a 6-token prompt matches depth 4 via the fingerprint
        assert_eq!(affinity_depth(&tokens, 2, cfg, &t.fingerprints(8)), 4);
        assert_eq!(affinity_depth(&tokens, 2, cfg, &fps), 4);
        // wrong config or foreign tokens -> no affinity
        assert_eq!(affinity_depth(&tokens, 2, 12, &fps), 0);
        assert_eq!(affinity_depth(&[9, 9, 9, 9, 9, 9], 2, cfg, &fps), 0);
        assert_eq!(affinity_depth(&tokens, 2, cfg, &[]), 0);
    }

    #[test]
    fn shared_full_block_rate() {
        // depth 17, buffer 3 -> 14 sparse rows -> 3 full blocks of 4 per
        // stream; 2 layers x 2 kv heads x (k+v) = 8 streams
        assert_eq!(shared_full_blocks(17, 3, 4, 2, 2), 8 * 3);
        // all-ring depth shares nothing
        assert_eq!(shared_full_blocks(3, 4, 4, 2, 2), 0);
    }
}
