//! Fleet lifecycle: shard supervision, dead-shard recovery, drain
//! migration, and chaos-test fault injection.
//!
//! SWAN's decode is fully deterministic (fixed offline rotation, one RNG
//! draw per non-greedy sampled token), so a request interrupted by a
//! shard death is recoverable *bit-exactly*: re-prefill the retained
//! prompt on a healthy shard and replay the already-emitted tokens as
//! forced decode steps — the same mechanism pool-budget preemption uses
//! within a shard, generalized across shards.  This module holds the
//! types that travel that path:
//!
//! * [`RecoveredReq`] — everything needed to resume a request elsewhere:
//!   the request itself (prompt, params, cancel token, trace), the
//!   emitted tokens, the RNG stream at its exact position, accumulated
//!   stats, and the event sink the client is still reading;
//! * [`FleetEvent`] — what a dying or draining shard reports to the
//!   router's supervisor thread ([`FleetEvent::ShardDead`] /
//!   [`FleetEvent::ShardDrained`]), carrying every in-flight and queued
//!   request back for re-placement;
//! * [`ShardHooks`] — the supervision wiring a launched shard carries: a
//!   fleet-event sender (absent on unsupervised test fleets, which keep
//!   the old fail-the-sinks behavior) and an optional [`FaultPlan`];
//! * [`FaultPlan`] — deterministic chaos: kill the coordinator at
//!   iteration N, poison a stage after its Nth forward/prefill, drop a
//!   stage channel, or trigger an external kill (`kill_now`, for soak
//!   tests).  Each one-shot trigger fires exactly once;
//! * [`ShardLostError`] — the structured terminal error
//!   (`ERR shard_lost` on the wire) when placement/recovery is
//!   impossible: no healthy shard exists or every submit attempt failed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use crate::api::Event;
use crate::coordinator::request::{Request, RequestStats};
use crate::util::Pcg64;

/// Recovery payload for one request pulled off a dead or draining shard.
///
/// `produced` empty means the request never prefilled (it was still
/// queued): recovery is a plain re-submission.  Non-empty, the receiving
/// shard re-prefills and replays `produced[1..]` as forced decode steps
/// (no RNG draw, no re-emission), then resumes sampling with `rng` —
/// which sits at exactly the stream position an uninterrupted run would
/// have — so the continued output is bit-identical.
pub struct RecoveredReq {
    pub req: Request,
    /// Tokens already committed (and, for streaming requests, already
    /// delivered to the client), first token included.
    pub produced: Vec<u32>,
    /// The request's decode RNG stream at its exact position (one draw
    /// consumed per non-greedy committed token).
    pub rng: Pcg64,
    /// Stats accumulated so far; the recovering shard adds its own
    /// queue/prefill/decode time on top.
    pub stats: RequestStats,
    /// Compression level the sequence was admitted at (0 = let the
    /// receiving shard derive it from the request params).
    pub k_active: usize,
    /// The client's event channel, carried across so the same stream
    /// resumes — token indexes continue without a gap or duplicate.
    pub sink: Option<mpsc::Sender<Event>>,
}

impl RecoveredReq {
    /// A queued (never-prefilled) request: recovery is a fresh re-run.
    pub fn fresh(req: Request, sink: Option<mpsc::Sender<Event>>) -> RecoveredReq {
        RecoveredReq {
            req,
            produced: Vec::new(),
            rng: Pcg64::new(0),
            stats: RequestStats::default(),
            k_active: 0,
            sink,
        }
    }
}

/// What a shard reports to the router's supervisor thread.
pub enum FleetEvent {
    /// The shard's coordinator died (panic, stage failure, injected
    /// fault).  `recovered` holds every in-flight and queued request,
    /// extracted for re-placement on healthy shards.
    ShardDead { id: usize, reason: String, recovered: Vec<RecoveredReq> },
    /// A drain finished: in-flight work completed locally, or —
    /// after the drain timeout — was extracted into `migrated` for the
    /// recovery path.  The supervisor retires the shard's handle.
    ShardDrained { id: usize, migrated: Vec<RecoveredReq> },
}

/// Supervision wiring a launched shard/group carries.
#[derive(Clone, Default)]
pub struct ShardHooks {
    /// Where death/drain events go.  `None` = unsupervised (stub and
    /// test fleets): a dying coordinator fails its sinks instead of
    /// handing work back, exactly the pre-fleet behavior.
    pub fleet: Option<mpsc::Sender<FleetEvent>>,
    /// Deterministic fault injection (chaos tests only).
    pub plan: Option<Arc<FaultPlan>>,
}

impl ShardHooks {
    /// Hooks that report to `fleet` with no fault injection.
    pub fn supervised(fleet: mpsc::Sender<FleetEvent>) -> ShardHooks {
        ShardHooks { fleet: Some(fleet), plan: None }
    }
}

/// Deterministic fault-injection plan for one shard (chaos harness).
///
/// Every trigger fires exactly once; a `FaultPlan::default()` never
/// fires.  Counters are compared against per-thread event counts, so a
/// scripted plan plus a fixed request set reproduces the same death at
/// the same point on every run.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Kill the group/shard coordinator at the start of iteration N
    /// (0 = before the first admission — "mid-prefill" from the
    /// client's point of view).
    pub kill_coordinator_at: Option<u64>,
    /// Panic stage `stage` when it has seen `n` Forward commands.
    pub poison_stage: Option<(usize, u64)>,
    /// Panic stage `stage` when it receives its `n`-th Prefill command
    /// (counted from 1) — a death inside the admission hop.
    pub poison_prefill: Option<(usize, u64)>,
    /// Stage `stage` exits (drops its channels) after `n` Forwards —
    /// the disconnect flavor of stage death.
    pub drop_stage_at: Option<(usize, u64)>,
    /// Externally-triggered coordinator kill (soak tests flip this at
    /// arbitrary times); consumed by the next iteration-boundary check.
    pub kill_now: AtomicBool,
    /// One-shot latch for `kill_coordinator_at`.
    fired: AtomicBool,
}

impl FaultPlan {
    /// Kill the coordinator at iteration `n`.
    pub fn kill_at(n: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { kill_coordinator_at: Some(n), ..Default::default() })
    }

    /// Panic stage `stage` after `n` Forward hops.
    pub fn poison_stage_after(stage: usize, n: u64) -> Arc<FaultPlan> {
        Arc::new(FaultPlan { poison_stage: Some((stage, n)), ..Default::default() })
    }

    /// Should the coordinator die at iteration `iter`?  One-shot: the
    /// scheduled kill and the external `kill_now` latch each fire once.
    pub fn coordinator_dies(&self, iter: u64) -> bool {
        if self.kill_now.swap(false, Ordering::Relaxed) {
            return true;
        }
        if self.kill_coordinator_at == Some(iter) && !self.fired.swap(true, Ordering::Relaxed) {
            return true;
        }
        false
    }
}

/// Per-stage view of a [`FaultPlan`], holding the local event counters
/// the stage thread advances (forwards seen, prefills seen).
#[derive(Default)]
pub struct StageFaults {
    pub plan: Option<Arc<FaultPlan>>,
    forwards: AtomicU64,
    prefills: AtomicU64,
}

impl StageFaults {
    pub fn new(plan: Option<Arc<FaultPlan>>) -> StageFaults {
        StageFaults { plan, forwards: AtomicU64::new(0), prefills: AtomicU64::new(0) }
    }

    /// Called per Forward command; panics (poison) or returns `true`
    /// (drop the stage) when the plan says so.
    pub fn on_forward(&self, stage: usize) -> bool {
        let n = self.forwards.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(plan) = &self.plan {
            if plan.poison_stage == Some((stage, n)) {
                // lint: allow(panic, "deliberate chaos-test fault injection: this panic IS the fault the recovery contract is tested against")
                panic!("chaos: injected stage {stage} poison at forward {n}");
            }
            if plan.drop_stage_at == Some((stage, n)) {
                return true;
            }
        }
        false
    }

    /// Called per Prefill command; panics when the plan poisons it.
    pub fn on_prefill(&self, stage: usize) {
        let n = self.prefills.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(plan) = &self.plan {
            if plan.poison_prefill == Some((stage, n)) {
                // lint: allow(panic, "deliberate chaos-test fault injection: this panic IS the fault the recovery contract is tested against")
                panic!("chaos: injected stage {stage} poison at prefill {n}");
            }
        }
    }
}

/// Terminal placement failure: every healthy shard was tried (or none
/// exists) and the request cannot be served.  Rendered on the wire as
/// `ERR shard_lost <detail>`; [`crate::shard::Router::submit`] returns
/// it only after its bounded retry is exhausted, and the supervisor
/// emits it (as an [`Event::Error`] with a `shard_lost:` prefix) when a
/// recovered request has no healthy shard left to land on.
#[derive(Debug)]
pub struct ShardLostError {
    pub attempts: usize,
    pub detail: &'static str,
}

impl std::fmt::Display for ShardLostError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} after {} placement attempt{}",
            self.detail,
            self.attempts,
            if self.attempts == 1 { "" } else { "s" }
        )
    }
}

impl std::error::Error for ShardLostError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_never_fires() {
        let p = FaultPlan::default();
        for i in 0..32 {
            assert!(!p.coordinator_dies(i));
        }
        let sf = StageFaults::new(None);
        for _ in 0..8 {
            assert!(!sf.on_forward(0));
            sf.on_prefill(0);
        }
    }

    #[test]
    fn scheduled_kill_fires_exactly_once() {
        let p = FaultPlan::kill_at(3);
        assert!(!p.coordinator_dies(0));
        assert!(!p.coordinator_dies(2));
        assert!(p.coordinator_dies(3));
        // relaunched coordinators re-see the same iteration numbers;
        // the latch keeps the plan from killing them again
        assert!(!p.coordinator_dies(3));
        assert!(!p.coordinator_dies(4));
    }

    #[test]
    fn kill_now_is_a_one_shot_latch() {
        let p = FaultPlan::default();
        p.kill_now.store(true, Ordering::Relaxed);
        assert!(p.coordinator_dies(7));
        assert!(!p.coordinator_dies(8));
    }

    #[test]
    fn stage_drop_triggers_on_the_nth_forward() {
        let plan = Arc::new(FaultPlan { drop_stage_at: Some((1, 2)), ..Default::default() });
        let sf = StageFaults::new(Some(plan));
        assert!(!sf.on_forward(1));
        assert!(sf.on_forward(1), "second forward on stage 1 drops");
        // other stages never trigger
        let plan = Arc::new(FaultPlan { drop_stage_at: Some((1, 1)), ..Default::default() });
        let sf = StageFaults::new(Some(plan));
        assert!(!sf.on_forward(0));
    }

    #[test]
    fn stage_poison_panics() {
        let plan = Arc::new(FaultPlan { poison_stage: Some((0, 1)), ..Default::default() });
        let sf = StageFaults::new(Some(plan));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| sf.on_forward(0)));
        assert!(err.is_err(), "poisoned forward must panic");
    }

    #[test]
    fn shard_lost_error_renders_and_downcasts() {
        let e = ShardLostError { attempts: 3, detail: "no healthy shard" };
        assert_eq!(e.to_string(), "no healthy shard after 3 placement attempts");
        let any: anyhow::Error = e.into();
        assert!(any.downcast_ref::<ShardLostError>().is_some());
    }

    #[test]
    fn fresh_recovery_payload_is_a_resubmission() {
        let r = RecoveredReq::fresh(Request::from_text(9, "hi", 4), None);
        assert!(r.produced.is_empty());
        assert_eq!(r.req.id, 9);
        assert!(r.sink.is_none());
    }
}
