//! Placement policies: which shard gets the next request.
//!
//! Policies are deliberately cheap and deterministic — they look only at
//! [`ShardSnapshot`]s (no locks into the shards, no RPCs), so placement
//! adds nothing measurable to the request path and a scripted snapshot
//! sequence fully determines the routing (see `tests/shard_router.rs`).

use crate::shard::ShardSnapshot;

/// A pluggable shard-placement policy.
///
/// `pick` receives one snapshot per shard (never empty, indexed by
/// position) and returns the index of the shard to place the next request
/// on.  Policies may keep state (`&mut self`) — e.g. the round-robin
/// cursor — which the router guards with its own lock.
///
/// Lifecycle is not a policy concern: the router filters the snapshot
/// list to `Healthy` members *before* calling `pick` (draining and dead
/// shards are never candidates), so policies stay state-oblivious and
/// the scripted-snapshot determinism above survives fleet churn.
pub trait BalancePolicy: Send {
    /// Stable policy name (the `--balance` / `SET balance` spelling).
    fn name(&self) -> &'static str;

    /// Choose a shard index in `0..shards.len()` for the next request.
    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize;
}

/// Cycle through the shards in order, ignoring load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl BalancePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        let i = self.next % shards.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Place on the shard with the fewest queued + active sequences
/// (ties break toward the lowest shard id).
#[derive(Debug, Default)]
pub struct LeastQueued;

impl BalancePolicy for LeastQueued {
    fn name(&self) -> &'static str {
        "least-queued"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.load(), s.id))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Place on the shard with the smallest projected KV footprint — the
/// figure each shard's scheduler derives from `Scheduler::projected_bytes`
/// over its live set and queue.  Sequence *count* ties break by load,
/// then id, so an all-idle fleet degrades to round-robin-by-id rather
/// than piling onto shard 0.
#[derive(Debug, Default)]
pub struct MemAware;

impl BalancePolicy for MemAware {
    fn name(&self) -> &'static str {
        "mem-aware"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.projected_bytes, s.load(), s.id))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// The `--balance` spellings, for usage strings and error messages.
pub const POLICY_NAMES: &[&str] = &["round-robin", "least-queued", "mem-aware"];

/// Build a policy from its wire/CLI name.
pub fn policy_from_name(name: &str) -> anyhow::Result<Box<dyn BalancePolicy>> {
    match name {
        "round-robin" | "rr" => Ok(Box::new(RoundRobin::default())),
        "least-queued" | "lq" => Ok(Box::new(LeastQueued)),
        "mem-aware" | "mem" => Ok(Box::new(MemAware)),
        other => anyhow::bail!(
            "unknown balance policy '{other}' (expected one of {})",
            POLICY_NAMES.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(id: usize, queued: usize, active: usize, projected: usize) -> ShardSnapshot {
        ShardSnapshot { id, queued, active, projected_bytes: projected, ..Default::default() }
    }

    #[test]
    fn round_robin_cycles() {
        let shards = vec![snap(0, 9, 9, 9), snap(1, 0, 0, 0), snap(2, 5, 5, 5)];
        let mut p = RoundRobin::default();
        let picks: Vec<usize> = (0..7).map(|_| p.pick(&shards)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_queued_picks_min_load_lowest_id_on_tie() {
        let mut p = LeastQueued;
        assert_eq!(p.pick(&[snap(0, 3, 1, 0), snap(1, 0, 2, 0), snap(2, 1, 3, 0)]), 1);
        // tie on load -> lowest id
        assert_eq!(p.pick(&[snap(0, 1, 1, 0), snap(1, 2, 0, 0), snap(2, 0, 2, 0)]), 0);
    }

    #[test]
    fn mem_aware_follows_projected_bytes() {
        let mut p = MemAware;
        assert_eq!(p.pick(&[snap(0, 0, 0, 900), snap(1, 9, 9, 100), snap(2, 0, 0, 500)]), 1);
        // byte tie -> fewer sequences wins
        assert_eq!(p.pick(&[snap(0, 2, 2, 100), snap(1, 0, 1, 100)]), 1);
    }

    #[test]
    fn names_resolve_and_unknown_errors() {
        for name in POLICY_NAMES {
            assert_eq!(policy_from_name(name).unwrap().name(), *name);
        }
        assert_eq!(policy_from_name("rr").unwrap().name(), "round-robin");
        assert!(policy_from_name("hash-ring").is_err());
    }
}
