//! Placement policies: which shard gets the next request.
//!
//! Policies are deliberately cheap and deterministic — they look only at
//! [`ShardSnapshot`]s (no locks into the shards, no RPCs), so placement
//! adds nothing measurable to the request path and a scripted snapshot
//! sequence fully determines the routing (see `tests/shard_router.rs`).

use crate::shard::ShardSnapshot;

/// A pluggable shard-placement policy.
///
/// `pick` receives one snapshot per shard (never empty, indexed by
/// position) and returns the index of the shard to place the next request
/// on.  Policies may keep state (`&mut self`) — e.g. the round-robin
/// cursor — which the router guards with its own lock.
///
/// Lifecycle is not a policy concern: the router filters the snapshot
/// list to `Healthy` members *before* calling `pick` (draining and dead
/// shards are never candidates), so policies stay state-oblivious and
/// the scripted-snapshot determinism above survives fleet churn.
pub trait BalancePolicy: Send {
    /// Stable policy name (the `--balance` / `SET balance` spelling).
    fn name(&self) -> &'static str;

    /// Choose a shard index in `0..shards.len()` for the next request.
    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize;
}

/// Cycle through the shards in order, ignoring load.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl BalancePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        let i = self.next % shards.len();
        self.next = self.next.wrapping_add(1);
        i
    }
}

/// Place on the shard with the fewest queued + active sequences
/// (ties break toward the lowest shard id).
#[derive(Debug, Default)]
pub struct LeastQueued;

impl BalancePolicy for LeastQueued {
    fn name(&self) -> &'static str {
        "least-queued"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.load(), s.id))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Memory-aware placement, now cache-affinity first:
///
/// 1. largest `affinity` (cached-prefix overlap in tokens, filled per
///    request by the router from the shards' published prefix
///    fingerprints — landing on the shard that already holds the
///    prompt's prefix turns its prefill into a block attach);
/// 2. then most free KV space: *block-granular* where the shard
///    publishes a block budget (`total_blocks > 0` — fewest used
///    granules, which with a fleet-uniform budget is "most free
///    blocks"), projected bytes where it accounts bytes only;
/// 3. then fewest sequences, then lowest id, so an all-idle fleet
///    degrades to round-robin-by-id rather than piling onto shard 0.
#[derive(Debug, Default)]
pub struct MemAware;

impl BalancePolicy for MemAware {
    fn name(&self) -> &'static str {
        "mem-aware"
    }

    fn pick(&mut self, shards: &[ShardSnapshot]) -> usize {
        shards
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| {
                let space = if s.total_blocks > 0 {
                    s.total_blocks.saturating_sub(s.free_blocks)
                } else {
                    s.projected_bytes
                };
                (std::cmp::Reverse(s.affinity), space, s.load(), s.id)
            })
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// The `--balance` spellings, for usage strings and error messages.
pub const POLICY_NAMES: &[&str] = &["round-robin", "least-queued", "mem-aware"];

/// Build a policy from its wire/CLI name.
pub fn policy_from_name(name: &str) -> anyhow::Result<Box<dyn BalancePolicy>> {
    match name {
        "round-robin" | "rr" => Ok(Box::new(RoundRobin::default())),
        "least-queued" | "lq" => Ok(Box::new(LeastQueued)),
        "mem-aware" | "mem" => Ok(Box::new(MemAware)),
        other => anyhow::bail!(
            "unknown balance policy '{other}' (expected one of {})",
            POLICY_NAMES.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(id: usize, queued: usize, active: usize, projected: usize) -> ShardSnapshot {
        ShardSnapshot { id, queued, active, projected_bytes: projected, ..Default::default() }
    }

    #[test]
    fn round_robin_cycles() {
        let shards = vec![snap(0, 9, 9, 9), snap(1, 0, 0, 0), snap(2, 5, 5, 5)];
        let mut p = RoundRobin::default();
        let picks: Vec<usize> = (0..7).map(|_| p.pick(&shards)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_queued_picks_min_load_lowest_id_on_tie() {
        let mut p = LeastQueued;
        assert_eq!(p.pick(&[snap(0, 3, 1, 0), snap(1, 0, 2, 0), snap(2, 1, 3, 0)]), 1);
        // tie on load -> lowest id
        assert_eq!(p.pick(&[snap(0, 1, 1, 0), snap(1, 2, 0, 0), snap(2, 0, 2, 0)]), 0);
    }

    #[test]
    fn mem_aware_follows_projected_bytes() {
        let mut p = MemAware;
        assert_eq!(p.pick(&[snap(0, 0, 0, 900), snap(1, 9, 9, 100), snap(2, 0, 0, 500)]), 1);
        // byte tie -> fewer sequences wins
        assert_eq!(p.pick(&[snap(0, 2, 2, 100), snap(1, 0, 1, 100)]), 1);
    }

    #[test]
    fn mem_aware_prefers_affinity_then_free_blocks() {
        let mut p = MemAware;
        let mut a = snap(0, 0, 0, 100);
        a.total_blocks = 64;
        a.free_blocks = 10;
        let mut b = snap(1, 5, 5, 900);
        b.total_blocks = 64;
        b.free_blocks = 2;
        b.affinity = 32;
        // cached-prefix overlap dominates load and free space
        assert_eq!(p.pick(&[a, b]), 1);
        // without affinity, block-granular free space decides
        b.affinity = 0;
        assert_eq!(p.pick(&[a, b]), 0);
        // a byte-only shard (no block budget) still compares by bytes
        let c = snap(2, 0, 0, 50);
        assert_eq!(p.pick(&[snap(0, 0, 0, 900), c]), 1);
    }

    #[test]
    fn names_resolve_and_unknown_errors() {
        for name in POLICY_NAMES {
            assert_eq!(policy_from_name(name).unwrap().name(), *name);
        }
        assert_eq!(policy_from_name("rr").unwrap().name(), "round-robin");
        assert!(policy_from_name("hash-ring").is_err());
    }
}
