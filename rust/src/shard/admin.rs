//! Fleet administration: the aggregated STATS view.
//!
//! `STATS` over the wire is a fleet operation: the router broadcasts a
//! stats request to every shard (so the shards render their blocks
//! concurrently), gathers the replies, and appends totals aggregated
//! straight from the shards' shared [`Metrics`] — the aggregate never
//! blocks on a shard thread, so a wedged shard degrades to a "timed out"
//! line instead of hanging the whole view.  `METRICS` and `TRACE <id>`
//! are fleet operations the same way: the exposition merges every
//! shard's registry ([`fleet_metrics`]), and trace lookup broadcasts
//! because the router does not track placement ([`fleet_trace`]).

use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::obs::export::{render, Source};
use crate::obs::registry::Registry;
use crate::shard::shard::{ShardCmd, ShardHandle};
use crate::sparse::memory::human_bytes;

/// How long the gather waits on any one shard's stats block.
const STATS_GATHER_TIMEOUT: Duration = Duration::from_secs(30);

/// How long the gather waits on any one shard's trace lookup.
const TRACE_GATHER_TIMEOUT: Duration = Duration::from_secs(5);

/// Render the fleet view: header, per-shard blocks, aggregate totals.
/// (Handles arrive as `Arc`s: membership is elastic, so the router hands
/// out point-in-time clones of the shard list, not slice borrows.)
pub fn fleet_stats(shards: &[Arc<ShardHandle>], policy: &str) -> String {
    let mut out = format!("fleet: shards={} balance={policy}\n", shards.len());
    // broadcast first, then gather — shards render in parallel
    let mut pending = Vec::with_capacity(shards.len());
    for s in shards {
        if s.status.state() != crate::shard::ShardState::Healthy {
            out.push_str(&format!("shard {}: {}\n", s.id, s.status.state().name()));
        }
        let (tx, rx) = mpsc::channel();
        match s.send(ShardCmd::Stats { reply: tx }) {
            Ok(()) => pending.push((s.id, rx)),
            Err(_) => out.push_str(&format!("shard {}: unreachable\n", s.id)),
        }
    }
    for (id, rx) in pending {
        match rx.recv_timeout(STATS_GATHER_TIMEOUT) {
            Ok(block) => out.push_str(&block),
            Err(_) => out.push_str(&format!("shard {id}: stats timed out\n")),
        }
    }
    out.push_str(&aggregate_totals(shards.iter().map(|s| s.metrics.as_ref())));
    out
}

/// Sum every shard's counters into the fleet totals block.
pub fn aggregate_totals<'a>(metrics: impl Iterator<Item = &'a Metrics>) -> String {
    let (mut submitted, mut completed, mut rejected) = (0u64, 0u64, 0u64);
    let (mut cancelled, mut preempted) = (0u64, 0u64);
    let (mut prefill, mut decode) = (0u64, 0u64);
    let (mut cache, mut dense) = (0u64, 0u64);
    let (mut pool_total, mut pool_leased) = (0u64, 0u64);
    let mut pool_unbounded = false;
    for m in metrics {
        submitted += m.requests_submitted.get();
        completed += m.requests_completed.get();
        rejected += m.requests_rejected.get();
        cancelled += m.requests_cancelled.get();
        preempted += m.requests_preempted.get();
        prefill += m.prefill_tokens.get();
        decode += m.decode_tokens.get();
        cache += m.cache_bytes.get();
        dense += m.dense_equiv_bytes.get();
        let pt = m.pool_blocks_total.get();
        if pt == u64::MAX {
            pool_unbounded = true;
        } else {
            pool_total += pt;
        }
        pool_leased += m.pool_blocks_leased.get();
    }
    let saving = if dense > 0 { 100.0 * (1.0 - cache as f64 / dense as f64) } else { 0.0 };
    let mut out = format!(
        "fleet requests: submitted={submitted} completed={completed} rejected={rejected} \
         cancelled={cancelled} preempted={preempted}\n\
         fleet tokens: prefill={prefill} decode={decode}\n\
         fleet kv-cache: {} live (dense-equiv {}, saving {saving:.1}%)\n",
        human_bytes(cache as usize),
        human_bytes(dense as usize),
    );
    if pool_total > 0 || pool_unbounded {
        let target =
            if pool_unbounded { "unbounded".to_string() } else { pool_total.to_string() };
        out.push_str(&format!("fleet pool: blocks leased={pool_leased} target={target}\n"));
    }
    out
}

/// The fleet `METRICS` exposition: the server's own registry
/// (connection counters, no identity label) plus every shard's registry
/// as a `shard="i"`-labelled source, merged per the
/// [`crate::obs::export`] rules.
pub fn fleet_metrics(shards: &[Arc<ShardHandle>], server: &Registry) -> String {
    let mut sources = vec![Source::new(server)];
    for s in shards {
        sources.push(Source::shard(s.id as u64, &s.metrics.registry));
    }
    render(&sources)
}

/// `TRACE <id>` fleet-wide: the router does not track placement, so the
/// lookup broadcasts and the first shard that knows the id answers.
/// `None` when no shard retains it (never submitted, or evicted from
/// the retired-trace ring).
pub fn fleet_trace(shards: &[Arc<ShardHandle>], id: u64) -> Option<String> {
    let mut pending = Vec::with_capacity(shards.len());
    for s in shards {
        let (tx, rx) = mpsc::channel();
        if s.send(ShardCmd::Trace { id, reply: tx }).is_ok() {
            pending.push(rx);
        }
    }
    pending.into_iter().find_map(|rx| rx.recv_timeout(TRACE_GATHER_TIMEOUT).ok().flatten())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_sums_across_shards() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.requests_submitted.add(3);
        b.requests_submitted.add(4);
        a.decode_tokens.add(10);
        b.decode_tokens.add(30);
        a.cache_bytes.set(256);
        b.cache_bytes.set(256);
        a.dense_equiv_bytes.set(1024);
        b.dense_equiv_bytes.set(1024);
        let s = aggregate_totals([&a, &b].into_iter());
        assert!(s.contains("submitted=7"), "{s}");
        assert!(s.contains("decode=40"), "{s}");
        assert!(s.contains("saving 75.0%"), "{s}");
    }

    #[test]
    fn fleet_metrics_merges_server_and_shard_sources() {
        let (h0, _rx0) = ShardHandle::stub(0);
        let (h1, _rx1) = ShardHandle::stub(1);
        h0.metrics.requests_completed.add(2);
        h1.metrics.requests_completed.add(5);
        h0.metrics.k_active.set(16);
        h1.metrics.k_active.set(8);
        let server = Registry::new();
        server.counter("swan_connections_total", &[]).add(3);
        let shards = vec![Arc::new(h0), Arc::new(h1)];
        let text = fleet_metrics(&shards, &server);
        assert!(text.contains("swan_requests_total{outcome=\"completed\"} 7\n"), "{text}");
        assert!(text.contains("swan_k_active{shard=\"0\"} 16\n"), "{text}");
        assert!(text.contains("swan_k_active{shard=\"1\"} 8\n"), "{text}");
        assert!(text.contains("swan_connections_total 3\n"), "{text}");
    }

    #[test]
    fn fleet_trace_takes_first_owning_shard() {
        let (h0, rx0) = ShardHandle::stub(0);
        let (h1, rx1) = ShardHandle::stub(1);
        let responders: Vec<_> = [(rx0, None), (rx1, Some("{\"id\":7}\n".to_string()))]
            .into_iter()
            .map(|(rx, answer)| {
                std::thread::spawn(move || {
                    if let Ok(ShardCmd::Trace { id, reply }) = rx.recv() {
                        assert_eq!(id, 7);
                        let _ = reply.send(answer);
                    }
                })
            })
            .collect();
        let shards = vec![Arc::new(h0), Arc::new(h1)];
        assert_eq!(fleet_trace(&shards, 7).as_deref(), Some("{\"id\":7}\n"));
        for r in responders {
            r.join().unwrap();
        }
    }

    #[test]
    fn fleet_stats_gathers_stub_blocks() {
        let (h0, rx0) = ShardHandle::stub(0);
        let (h1, rx1) = ShardHandle::stub(1);
        // script the shard side: answer one stats request each
        let responders: Vec<_> = [rx0, rx1]
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                std::thread::spawn(move || {
                    if let Ok(ShardCmd::Stats { reply }) = rx.recv() {
                        let _ = reply.send(format!("shard {i}: k_active=32\n"));
                    }
                })
            })
            .collect();
        let shards = vec![Arc::new(h0), Arc::new(h1)];
        let s = fleet_stats(&shards, "round-robin");
        for r in responders {
            r.join().unwrap();
        }
        assert!(s.contains("fleet: shards=2 balance=round-robin"), "{s}");
        assert!(s.contains("shard 0: k_active=32"), "{s}");
        assert!(s.contains("shard 1: k_active=32"), "{s}");
        assert!(s.contains("fleet requests:"), "{s}");
    }
}
