//! Fleet administration: the aggregated STATS view.
//!
//! `STATS` over the wire is a fleet operation: the router broadcasts a
//! stats request to every shard (so the shards render their blocks
//! concurrently), gathers the replies, and appends totals aggregated
//! straight from the shards' shared [`Metrics`] — the aggregate never
//! blocks on a shard thread, so a wedged shard degrades to a "timed out"
//! line instead of hanging the whole view.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::shard::shard::{ShardCmd, ShardHandle};
use crate::sparse::memory::human_bytes;

/// How long the gather waits on any one shard's stats block.
const STATS_GATHER_TIMEOUT: Duration = Duration::from_secs(30);

/// Render the fleet view: header, per-shard blocks, aggregate totals.
pub fn fleet_stats(shards: &[ShardHandle], policy: &str) -> String {
    let mut out = format!("fleet: shards={} balance={policy}\n", shards.len());
    // broadcast first, then gather — shards render in parallel
    let mut pending = Vec::with_capacity(shards.len());
    for s in shards {
        let (tx, rx) = mpsc::channel();
        match s.send(ShardCmd::Stats { reply: tx }) {
            Ok(()) => pending.push((s.id, rx)),
            Err(_) => out.push_str(&format!("shard {}: unreachable\n", s.id)),
        }
    }
    for (id, rx) in pending {
        match rx.recv_timeout(STATS_GATHER_TIMEOUT) {
            Ok(block) => out.push_str(&block),
            Err(_) => out.push_str(&format!("shard {id}: stats timed out\n")),
        }
    }
    out.push_str(&aggregate_totals(shards.iter().map(|s| s.metrics.as_ref())));
    out
}

/// Sum every shard's counters into the fleet totals block.
pub fn aggregate_totals<'a>(metrics: impl Iterator<Item = &'a Metrics>) -> String {
    let (mut submitted, mut completed, mut rejected) = (0u64, 0u64, 0u64);
    let (mut cancelled, mut preempted) = (0u64, 0u64);
    let (mut prefill, mut decode) = (0u64, 0u64);
    let (mut cache, mut dense) = (0usize, 0usize);
    let (mut pool_total, mut pool_leased) = (0usize, 0usize);
    let mut pool_unbounded = false;
    for m in metrics {
        submitted += m.requests_submitted.load(Ordering::Relaxed);
        completed += m.requests_completed.load(Ordering::Relaxed);
        rejected += m.requests_rejected.load(Ordering::Relaxed);
        cancelled += m.requests_cancelled.load(Ordering::Relaxed);
        preempted += m.requests_preempted.load(Ordering::Relaxed);
        prefill += m.prefill_tokens.load(Ordering::Relaxed);
        decode += m.decode_tokens.load(Ordering::Relaxed);
        cache += m.cache_bytes.load(Ordering::Relaxed);
        dense += m.dense_equiv_bytes.load(Ordering::Relaxed);
        let pt = m.pool_blocks_total.load(Ordering::Relaxed);
        if pt == usize::MAX {
            pool_unbounded = true;
        } else {
            pool_total += pt;
        }
        pool_leased += m.pool_blocks_leased.load(Ordering::Relaxed);
    }
    let saving = if dense > 0 { 100.0 * (1.0 - cache as f64 / dense as f64) } else { 0.0 };
    let mut out = format!(
        "fleet requests: submitted={submitted} completed={completed} rejected={rejected} \
         cancelled={cancelled} preempted={preempted}\n\
         fleet tokens: prefill={prefill} decode={decode}\n\
         fleet kv-cache: {} live (dense-equiv {}, saving {saving:.1}%)\n",
        human_bytes(cache),
        human_bytes(dense),
    );
    if pool_total > 0 || pool_unbounded {
        let target =
            if pool_unbounded { "unbounded".to_string() } else { pool_total.to_string() };
        out.push_str(&format!("fleet pool: blocks leased={pool_leased} target={target}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregate_sums_across_shards() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.requests_submitted.store(3, Ordering::Relaxed);
        b.requests_submitted.store(4, Ordering::Relaxed);
        a.decode_tokens.store(10, Ordering::Relaxed);
        b.decode_tokens.store(30, Ordering::Relaxed);
        a.cache_bytes.store(256, Ordering::Relaxed);
        b.cache_bytes.store(256, Ordering::Relaxed);
        a.dense_equiv_bytes.store(1024, Ordering::Relaxed);
        b.dense_equiv_bytes.store(1024, Ordering::Relaxed);
        let s = aggregate_totals([&a, &b].into_iter());
        assert!(s.contains("submitted=7"), "{s}");
        assert!(s.contains("decode=40"), "{s}");
        assert!(s.contains("saving 75.0%"), "{s}");
    }

    #[test]
    fn fleet_stats_gathers_stub_blocks() {
        let (h0, rx0) = ShardHandle::stub(0);
        let (h1, rx1) = ShardHandle::stub(1);
        // script the shard side: answer one stats request each
        let responders: Vec<_> = [rx0, rx1]
            .into_iter()
            .enumerate()
            .map(|(i, rx)| {
                std::thread::spawn(move || {
                    if let Ok(ShardCmd::Stats { reply }) = rx.recv() {
                        let _ = reply.send(format!("shard {i}: k_active=32\n"));
                    }
                })
            })
            .collect();
        let shards = vec![h0, h1];
        let s = fleet_stats(&shards, "round-robin");
        for r in responders {
            r.join().unwrap();
        }
        assert!(s.contains("fleet: shards=2 balance=round-robin"), "{s}");
        assert!(s.contains("shard 0: k_active=32"), "{s}");
        assert!(s.contains("shard 1: k_active=32"), "{s}");
        assert!(s.contains("fleet requests:"), "{s}");
    }
}
