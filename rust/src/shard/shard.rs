//! One shard: an engine on its own thread behind a command channel.
//!
//! The shard thread owns the [`Engine`] (and with it a scheduler, a
//! decode worker pool and a slice of the fleet's KV budget).  It drains
//! commands between engine iterations — non-blocking while there is work,
//! blocking when idle — exactly like the single-engine TCP loop this
//! subsystem replaces, and additionally publishes a lock-free
//! [`ShardStatus`] after every iteration so the router can place requests
//! without a round trip into the shard.
//!
//! Since api v2 the `Gen` reply channel carries [`crate::api::Event`]s
//! (token stream + terminal `Done`/`Error`) and the engine owns the
//! id→sink map, so the shard loop no longer tracks waiters; `Cancel`
//! is the by-id hop of the cancellation path (the router broadcasts it,
//! each engine flips the matching request's token).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::Event;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Request;
use crate::shard::supervisor::{FleetEvent, RecoveredReq, ShardHooks};
use crate::shard::{ShardSnapshot, ShardState};
use crate::util::sync::lock_recover;

/// Commands a shard thread accepts.
pub enum ShardCmd {
    /// Place one generation; `reply` receives its [`Event`] stream —
    /// per-token events when the request streams, then one terminal
    /// `Done` (or `Error`).
    Gen { req: Request, reply: mpsc::Sender<Event> },
    /// Cancel a request by id (queued or decoding); unknown ids no-op,
    /// so the router can broadcast without tracking placement.
    Cancel { id: u64 },
    /// Retune compression; the applied (bucket-snapped) `k` is acked.
    SetK { k: usize, ack: mpsc::Sender<usize> },
    /// Toggle cross-request prefix caching (`SET prefix on|off`); the ack
    /// reports whether this shard applied the change.  Engine shards ack
    /// `false` — the prefix tree lives in the pipeline-group coordinator
    /// ([`crate::shard::pipeline`]), which is where shared KV blocks exist.
    SetPrefix { on: bool, ack: mpsc::Sender<bool> },
    /// Render this shard's stats block.
    Stats { reply: mpsc::Sender<String> },
    /// Dump one request's lifecycle trace as JSONL (`TRACE <id>` wire
    /// verb): retired traces come from the shard's bounded ring, live
    /// ones from the active/queued sets.  `None` when the id is unknown
    /// here — the router tries every shard and takes the first hit.
    Trace { id: u64, reply: mpsc::Sender<Option<String>> },
    /// Resume a request recovered from a dead or draining shard:
    /// re-prefill, replay its emitted tokens as forced decode steps,
    /// then continue its RNG stream — output stays bit-identical to an
    /// uninterrupted run (boxed: the payload dwarfs the other variants).
    Recover(Box<RecoveredReq>),
    /// Stop placing on this shard, let in-flight work finish (or hand
    /// it back for migration once `timeout` passes), then retire.
    Drain { timeout: Duration },
    /// Retarget this shard's KV memory budget (live `SET shards <n>`
    /// rebalance: the fleet total re-split over the new member count).
    SetMemBudget(usize),
    /// Chaos-test fault injection: die exactly as an unexpected panic
    /// would — hand all work back to the supervisor (or abandon it when
    /// unsupervised).  Processed at an iteration boundary, so the
    /// extracted state is consistent and the death is deterministic.
    Crash,
    /// Stop the shard thread (in-flight sequences are abandoned).
    Shutdown,
}

/// Lock-free load view a shard publishes for the router's placement
/// policies.  See [`ShardSnapshot`] for the staleness contract.
#[derive(Debug, Default)]
pub struct ShardStatus {
    pub queued: AtomicUsize,
    pub active: AtomicUsize,
    pub live_bytes: AtomicUsize,
    pub projected_bytes: AtomicUsize,
    pub k_active: AtomicUsize,
    /// Block-granular budget view (allocation granules free / total)
    /// when the shard runs block-accounted admission; both zero under
    /// byte-only accounting, which tells `MemAware` to fall back to
    /// projected bytes for this shard.
    pub free_blocks: AtomicUsize,
    pub total_blocks: AtomicUsize,
    /// Token-block hash-chain fingerprints of the shard's cached
    /// prefixes (capped sample, see `pipeline::PREFIX_FP_CAP`); the
    /// router's affinity placement intersects a request's own chain
    /// against these without a round trip into the shard.
    pub prefix_fps: Mutex<Vec<u64>>,
    /// Lifecycle state ([`ShardState`] as its `repr(u8)` value); the
    /// router reads it to filter placement to healthy shards.
    pub state: AtomicU8,
}

impl ShardStatus {
    pub fn state(&self) -> ShardState {
        ShardState::from_u8(self.state.load(Ordering::Relaxed))
    }

    pub fn set_state(&self, s: ShardState) {
        self.state.store(s as u8, Ordering::Relaxed);
    }

    pub fn snapshot(&self, id: usize) -> ShardSnapshot {
        ShardSnapshot {
            id,
            queued: self.queued.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            live_bytes: self.live_bytes.load(Ordering::Relaxed),
            projected_bytes: self.projected_bytes.load(Ordering::Relaxed),
            k_active: self.k_active.load(Ordering::Relaxed),
            free_blocks: self.free_blocks.load(Ordering::Relaxed),
            total_blocks: self.total_blocks.load(Ordering::Relaxed),
            affinity: 0,
            state: self.state(),
        }
    }

    fn publish(&self, engine: &Engine) {
        self.queued.store(engine.queue_len(), Ordering::Relaxed);
        self.active.store(engine.active_len(), Ordering::Relaxed);
        self.live_bytes.store(engine.live_cache_bytes(), Ordering::Relaxed);
        self.projected_bytes.store(engine.projected_load_bytes(), Ordering::Relaxed);
        self.k_active.store(engine.current_k_active(), Ordering::Relaxed);
        let (total, free) = engine.block_budget();
        self.total_blocks.store(total, Ordering::Relaxed);
        self.free_blocks.store(free, Ordering::Relaxed);
    }
}

/// Handle the router holds for one shard: the command channel, the shared
/// status, and the shard's metrics (for fleet aggregation).
pub struct ShardHandle {
    pub id: usize,
    tx: Mutex<mpsc::Sender<ShardCmd>>,
    pub status: Arc<ShardStatus>,
    pub metrics: Arc<Metrics>,
    join: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Move `engine` onto a dedicated shard thread and return its handle.
    pub fn spawn(id: usize, engine: Engine) -> ShardHandle {
        ShardHandle::spawn_with(id, engine, ShardHooks::default())
    }

    /// [`ShardHandle::spawn`] with supervision wiring: the shard loop
    /// catches coordinator panics and hands every in-flight and queued
    /// request back through `hooks.fleet` instead of abandoning them,
    /// and honours the fault-injection plan (chaos tests).
    pub fn spawn_with(id: usize, engine: Engine, hooks: ShardHooks) -> ShardHandle {
        let status = Arc::new(ShardStatus::default());
        status.k_active.store(engine.current_k_active(), Ordering::Relaxed);
        let metrics = engine.metrics.clone();
        let (tx, rx) = mpsc::channel();
        let thread_status = status.clone();
        let join = std::thread::Builder::new()
            .name(format!("swan-shard-{id}"))
            .spawn(move || shard_loop(id, engine, rx, &thread_status, hooks))
            // lint: allow(panic, "shard bring-up, before the handle joins the fleet: a host that cannot spawn threads cannot add a shard, and no request has been placed yet")
            .expect("spawning shard thread");
        ShardHandle { id, tx: Mutex::new(tx), status, metrics, join: Some(join) }
    }

    /// Assemble a handle from an externally-built command loop — the
    /// pipeline-group coordinator ([`crate::shard::pipeline`]) presents
    /// itself to the router through exactly the [`ShardCmd`] interface an
    /// engine shard does, so placement policies, the `SET k_active`
    /// broadcast and fleet STATS work unchanged over mixed fleets.
    pub(crate) fn from_parts(
        id: usize,
        tx: mpsc::Sender<ShardCmd>,
        status: Arc<ShardStatus>,
        metrics: Arc<Metrics>,
        join: Option<JoinHandle<()>>,
    ) -> ShardHandle {
        ShardHandle { id, tx: Mutex::new(tx), status, metrics, join }
    }

    /// A handle with no engine behind it: commands sent through it arrive
    /// on the returned receiver.  For router/policy tests and tooling that
    /// script shard behaviour without model artifacts.
    pub fn stub(id: usize) -> (ShardHandle, mpsc::Receiver<ShardCmd>) {
        let (tx, rx) = mpsc::channel();
        let handle = ShardHandle {
            id,
            tx: Mutex::new(tx),
            status: Arc::new(ShardStatus::default()),
            metrics: Arc::new(Metrics::default()),
            join: None,
        };
        (handle, rx)
    }

    /// Send a command to the shard thread.
    ///
    /// A poisoned sender lock (some thread panicked while holding it) is
    /// recovered rather than propagated: the `Sender` inside is plain
    /// data that cannot be left in a torn state, so poisoning here must
    /// not cascade one shard's panic into every later caller.  (This was
    /// the original one-off recovery site; `util::sync` generalizes it
    /// fleet-wide.)
    pub fn send(&self, cmd: ShardCmd) -> anyhow::Result<()> {
        self.try_send(cmd)
            .map_err(|_| anyhow::anyhow!("shard {} is gone", self.id))
    }

    /// Like [`ShardHandle::send`], but hands the command back on failure
    /// so the caller can retry it on another shard without cloning the
    /// payload (the router's bounded-retry submit path).
    pub fn try_send(&self, cmd: ShardCmd) -> Result<(), ShardCmd> {
        lock_recover(&self.tx).send(cmd).map_err(|mpsc::SendError(c)| c)
    }

    pub fn snapshot(&self) -> ShardSnapshot {
        self.status.snapshot(self.id)
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        // same poison recovery as `send`: shutdown must reach the shard
        // thread even after some sender panicked holding the lock
        let _ = lock_recover(&self.tx).send(ShardCmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Render one shard's stats block (header line + indented engine metrics).
fn shard_stats(id: usize, engine: &Engine) -> String {
    use crate::sparse::memory::human_bytes;
    let mut out = format!(
        "shard {id}: k_active={} queued={} active={} kv={} projected={}\n",
        engine.current_k_active(),
        engine.queue_len(),
        engine.active_len(),
        human_bytes(engine.live_cache_bytes()),
        human_bytes(engine.projected_load_bytes()),
    );
    for line in engine.metrics.snapshot().lines() {
        out.push_str("  ");
        out.push_str(line);
        out.push('\n');
    }
    out
}

/// Render a panic payload as a one-line reason string.
pub(crate) fn panic_reason(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

/// Terminal supervised death: mark the shard `Dead`, extract every
/// in-flight and queued request, and hand them to the supervisor for
/// re-placement.  Called only when a fleet hook exists.
fn die(
    id: usize,
    reason: String,
    engine: &mut Engine,
    status: &ShardStatus,
    fleet: &mpsc::Sender<FleetEvent>,
) {
    status.set_state(ShardState::Dead);
    let recovered = engine.take_work();
    log::error!("shard {id} died ({reason}); handing {} request(s) to supervisor", recovered.len());
    status.publish(engine);
    let _ = fleet.send(FleetEvent::ShardDead { id, reason, recovered });
}

/// The shard thread: drain commands, step the engine, route completions,
/// publish status.  With a fleet hook the engine step runs supervised —
/// a panic or step error becomes a shard death that hands all work back
/// instead of a hung or silently degraded shard.
fn shard_loop(
    id: usize,
    mut engine: Engine,
    rx: mpsc::Receiver<ShardCmd>,
    status: &ShardStatus,
    hooks: ShardHooks,
) {
    let mut iter: u64 = 0;
    let mut drain_deadline: Option<Instant> = None;
    loop {
        // scripted fault injection (chaos tests): die at an iteration
        // boundary, exactly like an unexpected panic would
        if let Some(plan) = hooks.plan.as_deref() {
            if plan.coordinator_dies(iter) {
                if let Some(fleet) = &hooks.fleet {
                    die(id, "chaos: injected coordinator kill".into(), &mut engine, status, fleet);
                }
                return;
            }
        }
        iter += 1;
        // drain commands (non-blocking when busy or draining, blocking
        // when idle — a draining shard must keep observing its deadline)
        loop {
            let cmd = if engine.has_work() {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            } else if drain_deadline.is_some() {
                // idle + draining: fall through to the completion check
                break;
            } else {
                status.publish(&engine);
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            };
            match cmd {
                ShardCmd::Gen { req, reply } => {
                    // the engine owns the id→sink map and answers the
                    // channel itself (tokens, Done, Error) — no waiter
                    // bookkeeping on the shard thread
                    engine.submit_with_sink(req, reply);
                    status.publish(&engine);
                }
                ShardCmd::Cancel { id: rid } => {
                    engine.cancel(rid);
                }
                ShardCmd::SetK { k, ack } => {
                    engine.set_k_active(k);
                    let applied = engine.current_k_active();
                    status.k_active.store(applied, Ordering::Relaxed);
                    let _ = ack.send(applied);
                }
                ShardCmd::SetPrefix { on: _, ack } => {
                    // no prefix tree here: shared KV blocks exist only in
                    // pipeline groups, so an engine shard reports "not
                    // applied" and the router surfaces the partial toggle
                    let _ = ack.send(false);
                }
                ShardCmd::Stats { reply } => {
                    let _ = reply.send(shard_stats(id, &engine));
                }
                ShardCmd::Trace { id: rid, reply } => {
                    let _ = reply.send(engine.trace_jsonl(rid));
                }
                ShardCmd::Recover(rec) => {
                    engine.recover(*rec);
                    status.publish(&engine);
                }
                ShardCmd::Drain { timeout } => {
                    status.set_state(ShardState::Draining);
                    drain_deadline = Some(Instant::now() + timeout);
                }
                ShardCmd::SetMemBudget(bytes) => {
                    engine.set_mem_budget(bytes);
                }
                ShardCmd::Crash => {
                    if let Some(fleet) = &hooks.fleet {
                        die(id, "chaos: crash command".into(), &mut engine, status, fleet);
                    }
                    return;
                }
                ShardCmd::Shutdown => return,
            }
        }
        // drain lifecycle: retire once idle, or migrate on timeout
        if let Some(deadline) = drain_deadline {
            if !engine.has_work() {
                status.set_state(ShardState::Dead);
                status.publish(&engine);
                if let Some(fleet) = &hooks.fleet {
                    let _ = fleet.send(FleetEvent::ShardDrained { id, migrated: Vec::new() });
                }
                return;
            }
            if Instant::now() >= deadline {
                status.set_state(ShardState::Dead);
                let migrated = engine.take_work();
                status.publish(&engine);
                if let Some(fleet) = &hooks.fleet {
                    let _ = fleet.send(FleetEvent::ShardDrained { id, migrated });
                }
                return;
            }
        }
        // supervised engine step: panics and step errors become a shard
        // death (work handed back) instead of a dead-but-listed fleet
        // member; without a fleet hook, preserve the historical behavior
        match catch_unwind(AssertUnwindSafe(|| engine.step())) {
            Ok(Ok(())) => {}
            Ok(Err(e)) => match &hooks.fleet {
                Some(fleet) => {
                    die(id, format!("engine step failed: {e:#}"), &mut engine, status, fleet);
                    return;
                }
                None => log::error!("shard {id}: engine step failed: {e:#}"),
            },
            Err(payload) => match &hooks.fleet {
                Some(fleet) => {
                    die(id, panic_reason(payload.as_ref()), &mut engine, status, fleet);
                    return;
                }
                None => std::panic::resume_unwind(payload),
            },
        }
        // sink-attached requests were answered inside the engine; these
        // drains only catch sink-less submissions (none on this path,
        // kept so nothing can accumulate unbounded)
        while engine.pop_finished().is_some() {}
        while engine.pop_rejected().is_some() {}
        status.publish(&engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_handle_delivers_commands() {
        let (handle, rx) = ShardHandle::stub(3);
        let (ack_tx, ack_rx) = mpsc::channel();
        handle.send(ShardCmd::SetK { k: 16, ack: ack_tx }).unwrap();
        match rx.recv().unwrap() {
            ShardCmd::SetK { k, ack } => {
                assert_eq!(k, 16);
                ack.send(k).unwrap();
            }
            _ => panic!("expected SetK"),
        }
        assert_eq!(ack_rx.recv().unwrap(), 16);
    }

    #[test]
    fn poisoned_sender_lock_recovers() {
        let (handle, rx) = ShardHandle::stub(7);
        let h = &handle;
        // poison the sender mutex: panic while holding the guard
        std::thread::scope(|s| {
            let _ = s
                .spawn(move || {
                    let _guard = h.tx.lock().unwrap();
                    panic!("poison the shard sender lock");
                })
                .join();
        });
        assert!(handle.tx.lock().is_err(), "lock must actually be poisoned");
        // sends recover the lock instead of cascading the panic
        handle.send(ShardCmd::Cancel { id: 1 }).expect("send after poison");
        match rx.recv().unwrap() {
            ShardCmd::Cancel { id } => assert_eq!(id, 1),
            _ => panic!("expected Cancel"),
        }
        // once the shard is really gone, the error is structured — not a panic
        drop(rx);
        let err = handle.send(ShardCmd::Cancel { id: 2 }).unwrap_err();
        assert!(err.to_string().contains("shard 7 is gone"));
        // try_send hands the command back for retry elsewhere
        match handle.try_send(ShardCmd::Cancel { id: 3 }) {
            Err(ShardCmd::Cancel { id }) => assert_eq!(id, 3),
            _ => panic!("expected the command back"),
        }
        // Drop (sends Shutdown) must also survive the poisoned lock
        drop(handle);
    }

    #[test]
    fn snapshot_carries_lifecycle_state() {
        let (handle, _rx) = ShardHandle::stub(2);
        assert_eq!(handle.snapshot().state, ShardState::Healthy);
        handle.status.set_state(ShardState::Draining);
        assert_eq!(handle.status.state(), ShardState::Draining);
        assert_eq!(handle.snapshot().state, ShardState::Draining);
        assert_eq!(ShardState::from_u8(2), ShardState::Dead);
        assert_eq!(ShardState::from_u8(9), ShardState::Healthy, "unknown maps to healthy");
        assert_eq!(ShardState::Dead.name(), "dead");
    }

    #[test]
    fn status_snapshot_reflects_stores() {
        let (handle, _rx) = ShardHandle::stub(1);
        handle.status.queued.store(4, Ordering::Relaxed);
        handle.status.projected_bytes.store(1024, Ordering::Relaxed);
        let s = handle.snapshot();
        assert_eq!(s.id, 1);
        assert_eq!(s.queued, 4);
        assert_eq!(s.projected_bytes, 1024);
        assert_eq!(s.load(), 4);
    }
}
