//! One shard: an engine on its own thread behind a command channel.
//!
//! The shard thread owns the [`Engine`] (and with it a scheduler, a
//! decode worker pool and a slice of the fleet's KV budget).  It drains
//! commands between engine iterations — non-blocking while there is work,
//! blocking when idle — exactly like the single-engine TCP loop this
//! subsystem replaces, and additionally publishes a lock-free
//! [`ShardStatus`] after every iteration so the router can place requests
//! without a round trip into the shard.
//!
//! Since api v2 the `Gen` reply channel carries [`crate::api::Event`]s
//! (token stream + terminal `Done`/`Error`) and the engine owns the
//! id→sink map, so the shard loop no longer tracks waiters; `Cancel`
//! is the by-id hop of the cancellation path (the router broadcasts it,
//! each engine flips the matching request's token).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::Event;
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::Request;
use crate::shard::ShardSnapshot;

/// Commands a shard thread accepts.
pub enum ShardCmd {
    /// Place one generation; `reply` receives its [`Event`] stream —
    /// per-token events when the request streams, then one terminal
    /// `Done` (or `Error`).
    Gen { req: Request, reply: mpsc::Sender<Event> },
    /// Cancel a request by id (queued or decoding); unknown ids no-op,
    /// so the router can broadcast without tracking placement.
    Cancel { id: u64 },
    /// Retune compression; the applied (bucket-snapped) `k` is acked.
    SetK { k: usize, ack: mpsc::Sender<usize> },
    /// Render this shard's stats block.
    Stats { reply: mpsc::Sender<String> },
    /// Dump one request's lifecycle trace as JSONL (`TRACE <id>` wire
    /// verb): retired traces come from the shard's bounded ring, live
    /// ones from the active/queued sets.  `None` when the id is unknown
    /// here — the router tries every shard and takes the first hit.
    Trace { id: u64, reply: mpsc::Sender<Option<String>> },
    /// Stop the shard thread (in-flight sequences are abandoned).
    Shutdown,
}

/// Lock-free load view a shard publishes for the router's placement
/// policies.  See [`ShardSnapshot`] for the staleness contract.
#[derive(Debug, Default)]
pub struct ShardStatus {
    pub queued: AtomicUsize,
    pub active: AtomicUsize,
    pub live_bytes: AtomicUsize,
    pub projected_bytes: AtomicUsize,
    pub k_active: AtomicUsize,
}

impl ShardStatus {
    pub fn snapshot(&self, id: usize) -> ShardSnapshot {
        ShardSnapshot {
            id,
            queued: self.queued.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            live_bytes: self.live_bytes.load(Ordering::Relaxed),
            projected_bytes: self.projected_bytes.load(Ordering::Relaxed),
            k_active: self.k_active.load(Ordering::Relaxed),
        }
    }

    fn publish(&self, engine: &Engine) {
        self.queued.store(engine.queue_len(), Ordering::Relaxed);
        self.active.store(engine.active_len(), Ordering::Relaxed);
        self.live_bytes.store(engine.live_cache_bytes(), Ordering::Relaxed);
        self.projected_bytes.store(engine.projected_load_bytes(), Ordering::Relaxed);
        self.k_active.store(engine.current_k_active(), Ordering::Relaxed);
    }
}

/// Handle the router holds for one shard: the command channel, the shared
/// status, and the shard's metrics (for fleet aggregation).
pub struct ShardHandle {
    pub id: usize,
    tx: Mutex<mpsc::Sender<ShardCmd>>,
    pub status: Arc<ShardStatus>,
    pub metrics: Arc<Metrics>,
    join: Option<JoinHandle<()>>,
}

impl ShardHandle {
    /// Move `engine` onto a dedicated shard thread and return its handle.
    pub fn spawn(id: usize, engine: Engine) -> ShardHandle {
        let status = Arc::new(ShardStatus::default());
        status.k_active.store(engine.current_k_active(), Ordering::Relaxed);
        let metrics = engine.metrics.clone();
        let (tx, rx) = mpsc::channel();
        let thread_status = status.clone();
        let join = std::thread::Builder::new()
            .name(format!("swan-shard-{id}"))
            .spawn(move || shard_loop(id, engine, rx, &thread_status))
            .expect("spawning shard thread");
        ShardHandle { id, tx: Mutex::new(tx), status, metrics, join: Some(join) }
    }

    /// Assemble a handle from an externally-built command loop — the
    /// pipeline-group coordinator ([`crate::shard::pipeline`]) presents
    /// itself to the router through exactly the [`ShardCmd`] interface an
    /// engine shard does, so placement policies, the `SET k_active`
    /// broadcast and fleet STATS work unchanged over mixed fleets.
    pub(crate) fn from_parts(
        id: usize,
        tx: mpsc::Sender<ShardCmd>,
        status: Arc<ShardStatus>,
        metrics: Arc<Metrics>,
        join: Option<JoinHandle<()>>,
    ) -> ShardHandle {
        ShardHandle { id, tx: Mutex::new(tx), status, metrics, join }
    }

    /// A handle with no engine behind it: commands sent through it arrive
    /// on the returned receiver.  For router/policy tests and tooling that
    /// script shard behaviour without model artifacts.
    pub fn stub(id: usize) -> (ShardHandle, mpsc::Receiver<ShardCmd>) {
        let (tx, rx) = mpsc::channel();
        let handle = ShardHandle {
            id,
            tx: Mutex::new(tx),
            status: Arc::new(ShardStatus::default()),
            metrics: Arc::new(Metrics::default()),
            join: None,
        };
        (handle, rx)
    }

    /// Send a command to the shard thread.
    pub fn send(&self, cmd: ShardCmd) -> anyhow::Result<()> {
        self.tx
            .lock()
            .unwrap()
            .send(cmd)
            .map_err(|_| anyhow::anyhow!("shard {} is gone", self.id))
    }

    pub fn snapshot(&self) -> ShardSnapshot {
        self.status.snapshot(self.id)
    }
}

impl Drop for ShardHandle {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(ShardCmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Render one shard's stats block (header line + indented engine metrics).
fn shard_stats(id: usize, engine: &Engine) -> String {
    use crate::sparse::memory::human_bytes;
    let mut out = format!(
        "shard {id}: k_active={} queued={} active={} kv={} projected={}\n",
        engine.current_k_active(),
        engine.queue_len(),
        engine.active_len(),
        human_bytes(engine.live_cache_bytes()),
        human_bytes(engine.projected_load_bytes()),
    );
    for line in engine.metrics.snapshot().lines() {
        out.push_str("  ");
        out.push_str(line);
        out.push('\n');
    }
    out
}

/// The shard thread: drain commands, step the engine, route completions,
/// publish status.
fn shard_loop(
    id: usize,
    mut engine: Engine,
    rx: mpsc::Receiver<ShardCmd>,
    status: &ShardStatus,
) {
    loop {
        // drain commands (non-blocking when busy, blocking when idle)
        loop {
            let cmd = if engine.has_work() {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            } else {
                status.publish(&engine);
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            };
            match cmd {
                ShardCmd::Gen { req, reply } => {
                    // the engine owns the id→sink map and answers the
                    // channel itself (tokens, Done, Error) — no waiter
                    // bookkeeping on the shard thread
                    engine.submit_with_sink(req, reply);
                    status.publish(&engine);
                }
                ShardCmd::Cancel { id: rid } => {
                    engine.cancel(rid);
                }
                ShardCmd::SetK { k, ack } => {
                    engine.set_k_active(k);
                    let applied = engine.current_k_active();
                    status.k_active.store(applied, Ordering::Relaxed);
                    let _ = ack.send(applied);
                }
                ShardCmd::Stats { reply } => {
                    let _ = reply.send(shard_stats(id, &engine));
                }
                ShardCmd::Trace { id: rid, reply } => {
                    let _ = reply.send(engine.trace_jsonl(rid));
                }
                ShardCmd::Shutdown => return,
            }
        }
        if let Err(e) = engine.step() {
            log::error!("shard {id}: engine step failed: {e:#}");
        }
        // sink-attached requests were answered inside the engine; these
        // drains only catch sink-less submissions (none on this path,
        // kept so nothing can accumulate unbounded)
        while engine.pop_finished().is_some() {}
        while engine.pop_rejected().is_some() {}
        status.publish(&engine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_handle_delivers_commands() {
        let (handle, rx) = ShardHandle::stub(3);
        let (ack_tx, ack_rx) = mpsc::channel();
        handle.send(ShardCmd::SetK { k: 16, ack: ack_tx }).unwrap();
        match rx.recv().unwrap() {
            ShardCmd::SetK { k, ack } => {
                assert_eq!(k, 16);
                ack.send(k).unwrap();
            }
            _ => panic!("expected SetK"),
        }
        assert_eq!(ack_rx.recv().unwrap(), 16);
    }

    #[test]
    fn status_snapshot_reflects_stores() {
        let (handle, _rx) = ShardHandle::stub(1);
        handle.status.queued.store(4, Ordering::Relaxed);
        handle.status.projected_bytes.store(1024, Ordering::Relaxed);
        let s = handle.snapshot();
        assert_eq!(s.id, 1);
        assert_eq!(s.queued, 4);
        assert_eq!(s.projected_bytes, 1024);
        assert_eq!(s.load(), 4);
    }
}
