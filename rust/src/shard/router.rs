//! The front-end router: places `GEN` on one shard, fans admin commands
//! out to all of them.
//!
//! The router is the only object connection threads touch.  It is shared
//! as `Arc<Router>`; interior mutability is confined to the policy lock
//! (placement state such as the round-robin cursor) and each handle's
//! sender lock, so concurrent connections place and submit without
//! serializing on the shards themselves.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use anyhow::Context;

use crate::api::GenHandle;
use crate::config::ServeConfig;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::Request;
use crate::obs::registry::Registry;
use crate::shard::admin;
use crate::shard::balance::{policy_from_name, BalancePolicy};
use crate::shard::shard::{ShardCmd, ShardHandle};
use crate::shard::ShardSnapshot;

pub struct Router {
    shards: Vec<ShardHandle>,
    policy: Mutex<Box<dyn BalancePolicy>>,
    /// Fleet-global request ids (per-shard engines would otherwise hand
    /// out colliding ids on the wire).
    next_id: AtomicU64,
    /// Server-level obs series (per-connection counters, protocol
    /// errors) — rendered into the `METRICS` exposition alongside every
    /// shard's registry, with no shard identity label.
    server_registry: Arc<Registry>,
}

impl Router {
    /// Launch the fleet and front it with the configured balance policy.
    ///
    /// * `cfg.pipeline == 1` (default): `cfg.shards` PJRT engines, each on
    ///   its own thread with its own scheduler, worker pool and a
    ///   `mem_budget / shards` KV slice.  Bring-up (artifact load + graph
    ///   warmup) runs concurrently, so fleet startup costs ~one engine
    ///   launch, not N.
    /// * `cfg.pipeline > 1`: layer-sharded mode — the shard slots form
    ///   `shards / pipeline` pipeline groups of `pipeline` stages each
    ///   over one shared rust-native model; every group registers as one
    ///   placeable shard, so balance policies, `SET k_active` broadcast
    ///   and fleet STATS are mode-agnostic.
    pub fn launch(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Router> {
        anyhow::ensure!(cfg.shards >= 1, "shards must be >= 1, got {}", cfg.shards);
        if cfg.pipeline > 1 {
            return Router::launch_pipeline(artifacts_dir, cfg);
        }
        let policy = policy_from_name(&cfg.balance)?;
        let per_shard_budget =
            if cfg.mem_budget == 0 { 0 } else { (cfg.mem_budget / cfg.shards).max(1) };
        let launchers: Vec<_> = (0..cfg.shards)
            .map(|id| {
                let shard_cfg = ServeConfig { mem_budget: per_shard_budget, ..cfg.clone() };
                let dir = artifacts_dir.to_path_buf();
                std::thread::Builder::new()
                    .name(format!("swan-shard-launch-{id}"))
                    .spawn(move || -> anyhow::Result<Engine> {
                        let engine = Engine::new(&dir, shard_cfg)?;
                        engine.warmup()?;
                        Ok(engine)
                    })
                    .expect("spawning shard launch thread")
            })
            .collect();
        let mut shards = Vec::with_capacity(cfg.shards);
        for (id, launcher) in launchers.into_iter().enumerate() {
            let engine = launcher
                .join()
                .map_err(|_| anyhow::anyhow!("shard {id} launch thread panicked"))?
                .with_context(|| format!("launching shard {id}"))?;
            shards.push(ShardHandle::spawn(id, engine));
        }
        Ok(Router {
            shards,
            policy: Mutex::new(policy),
            next_id: AtomicU64::new(1),
            server_registry: Arc::new(Registry::new()),
        })
    }

    /// Pipeline-sharded launch: `shards / pipeline` groups of `pipeline`
    /// stages each, over one shared rust-native model (the AOT graphs are
    /// whole-model artifacts, so layer-range execution runs on the native
    /// path; see `swan::shard::pipeline`).  The fleet KV budget splits
    /// evenly across groups; within a group each stage's share follows
    /// its layer count by construction.
    fn launch_pipeline(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Router> {
        anyhow::ensure!(
            cfg.shards % cfg.pipeline == 0,
            "shards ({}) must be a multiple of pipeline ({}) so stages form whole groups",
            cfg.shards,
            cfg.pipeline
        );
        // same kernel-pin contract as Engine::new: an explicit choice pins
        // the process-wide path before any stage builds caches (lane
        // padding) or dispatches; "auto" leaves an embedder's pin alone
        if !matches!(cfg.kernels.as_str(), "auto" | "") {
            crate::simd::init_from_name(&cfg.kernels)?;
        }
        let policy = policy_from_name(&cfg.balance)?;
        let n_groups = cfg.shards / cfg.pipeline;
        let wf = crate::model::WeightFile::load(
            &artifacts_dir.join(format!("weights_{}.bin", cfg.model)),
        )
        .with_context(|| format!("native weights for {} (run `make artifacts`)", cfg.model))?;
        let model = std::sync::Arc::new(crate::model::SwanModel::load(
            &wf,
            crate::swan::projection::ProjectionVariant::Calibrated,
            0,
        )?);
        let per_group_budget =
            if cfg.mem_budget == 0 { 0 } else { (cfg.mem_budget / n_groups).max(1) };
        let group_cfg = ServeConfig { mem_budget: per_group_budget, ..cfg.clone() };
        let mut shards = Vec::with_capacity(n_groups);
        for id in 0..n_groups {
            shards.push(crate::shard::pipeline::launch_group(id, model.clone(), &group_cfg)?);
        }
        Ok(Router {
            shards,
            policy: Mutex::new(policy),
            next_id: AtomicU64::new(1),
            server_registry: Arc::new(Registry::new()),
        })
    }

    /// Assemble a router from pre-built handles (tests, embedders).
    pub fn from_handles(shards: Vec<ShardHandle>, policy: Box<dyn BalancePolicy>) -> Router {
        assert!(!shards.is_empty(), "router needs at least one shard");
        Router {
            shards,
            policy: Mutex::new(policy),
            next_id: AtomicU64::new(1),
            server_registry: Arc::new(Registry::new()),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[ShardHandle] {
        &self.shards
    }

    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards.iter().map(|s| s.snapshot()).collect()
    }

    /// Swap the placement policy live (`SET balance <name>`).
    pub fn set_policy(&self, policy: Box<dyn BalancePolicy>) {
        *self.policy.lock().unwrap() = policy;
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.lock().unwrap().name()
    }

    /// Pick the shard the next request should land on (placement only).
    pub fn place(&self) -> usize {
        let snaps = self.snapshots();
        let pick = self.policy.lock().unwrap().pick(&snaps);
        // a misbehaving policy must not take the fleet down
        pick.min(self.shards.len() - 1)
    }

    /// Place and submit one request; the returned [`GenHandle`] carries
    /// the event channel (per-token events for streaming requests, then
    /// the terminal `Done`/`Error`) and the cancellation token.
    pub fn submit(&self, mut req: Request) -> anyhow::Result<GenHandle> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let id = req.id;
        let cancel = req.cancel.clone();
        let idx = self.place();
        let (tx, handle) = GenHandle::channel(id, cancel);
        let shard = &self.shards[idx];
        // optimistic bump so back-to-back placements see this request
        // before the shard thread next publishes authoritative counts
        shard.status.queued.fetch_add(1, Ordering::Relaxed);
        shard.send(ShardCmd::Gen { req, reply: tx })?;
        Ok(handle)
    }

    /// Cancel a request by id, fleet-wide: the router does not track
    /// placement, so the hop is broadcast — unknown ids no-op on every
    /// shard that doesn't own the sequence.  (Callers holding the
    /// request's [`GenHandle`] can cancel without the round trip; this
    /// path serves the wire `CANCEL <id>` and cross-connection cancels.)
    pub fn cancel(&self, id: u64) -> anyhow::Result<()> {
        for s in &self.shards {
            s.send(ShardCmd::Cancel { id })?;
        }
        Ok(())
    }

    /// Fleet-wide live compression retune: broadcast `SET k_active` to
    /// every shard, then gather the acks.  Returns `(shard id, applied
    /// k)` per shard — "applied" because each engine snaps to its nearest
    /// compiled bucket.  No engine restarts; newly admitted sequences on
    /// every shard use the new level.
    pub fn set_k_active(&self, k: usize) -> anyhow::Result<Vec<(usize, usize)>> {
        let mut pending = Vec::with_capacity(self.shards.len());
        for s in &self.shards {
            let (ack_tx, ack_rx) = mpsc::channel();
            s.send(ShardCmd::SetK { k, ack: ack_tx })?;
            pending.push((s.id, ack_rx));
        }
        let mut applied = Vec::with_capacity(pending.len());
        for (id, rx) in pending {
            let got = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("shard {id} dropped its SET k_active ack"))?;
            applied.push((id, got));
        }
        Ok(applied)
    }

    /// The fleet STATS view: per-shard blocks + aggregate totals.
    pub fn stats(&self) -> String {
        admin::fleet_stats(&self.shards, self.policy_name())
    }

    /// The registry server-level series (connection counters) register
    /// in; the TCP front-end holds a clone per listener.
    pub fn server_registry(&self) -> Arc<Registry> {
        self.server_registry.clone()
    }

    /// The fleet `METRICS` exposition (Prometheus text format 0.0.4).
    pub fn metrics_text(&self) -> String {
        admin::fleet_metrics(&self.shards, &self.server_registry)
    }

    /// `TRACE <id>`: the first shard retaining the request's lifecycle
    /// trace answers with its JSONL timeline.
    pub fn trace_jsonl(&self, id: u64) -> Option<String> {
        admin::fleet_trace(&self.shards, id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::balance::RoundRobin;

    #[test]
    fn place_clamps_rogue_policy() {
        struct Rogue;
        impl BalancePolicy for Rogue {
            fn name(&self) -> &'static str {
                "rogue"
            }
            fn pick(&mut self, _s: &[ShardSnapshot]) -> usize {
                usize::MAX
            }
        }
        let (h, _rx) = ShardHandle::stub(0);
        let router = Router::from_handles(vec![h], Box::new(Rogue));
        assert_eq!(router.place(), 0);
    }

    #[test]
    fn policy_swap_is_visible() {
        let (h, _rx) = ShardHandle::stub(0);
        let router = Router::from_handles(vec![h], Box::new(RoundRobin::default()));
        assert_eq!(router.policy_name(), "round-robin");
        router.set_policy(policy_from_name("mem-aware").unwrap());
        assert_eq!(router.policy_name(), "mem-aware");
    }
}
