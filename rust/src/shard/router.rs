//! The front-end router: places `GEN` on one shard, fans admin commands
//! out to all of them, and supervises the fleet's lifecycle.
//!
//! The router is the only object connection threads touch.  It is shared
//! as `Arc<Router>`; interior mutability is confined to the membership
//! lock (the shard list is elastic since `SET shards <n>`), the policy
//! lock (placement state such as the round-robin cursor) and each
//! handle's sender lock, so concurrent connections place and submit
//! without serializing on the shards themselves.
//!
//! Fleet lifecycle (see [`crate::shard::supervisor`]):
//!
//! * every launched shard/group runs **supervised**: its coordinator
//!   catches panics, stage deaths and step errors, extracts all
//!   in-flight and queued work, and reports a [`FleetEvent`] instead of
//!   leaving a hung or silently-degraded member;
//! * the router's **supervisor thread** consumes those events: it
//!   retires the dead handle, bumps `swan_shard_deaths`, and re-places
//!   every recovered request on a healthy shard via
//!   [`ShardCmd::Recover`] — the receiving shard re-prefills and
//!   replays the emitted tokens, so recovered output is bit-identical
//!   to an uninterrupted run (SWAN decode is deterministic);
//! * **placement filters to healthy shards** before any
//!   [`BalancePolicy`] sees a snapshot, so policies stay
//!   state-oblivious; `submit` retries with jittered backoff across
//!   healthy members and fails with a structured [`ShardLostError`]
//!   only when none exists;
//! * `SET shards <n>` / `DRAIN <id>` drive **elastic membership**:
//!   scale-up launches supervised members live (and rebalances the KV
//!   budget), scale-down and drains stop placement, let in-flight work
//!   finish, and migrate stragglers through the recovery path after the
//!   drain timeout.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock, Weak};
use std::time::Duration;

use anyhow::Context;

use crate::api::{Event, GenHandle};
use crate::config::ServeConfig;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::Request;
use crate::model::transformer::SwanModel;
use crate::obs::registry::{Counter, Registry};
use crate::shard::admin;
use crate::shard::balance::{policy_from_name, BalancePolicy};
use crate::shard::shard::{ShardCmd, ShardHandle};
use crate::shard::supervisor::{FaultPlan, FleetEvent, RecoveredReq, ShardHooks, ShardLostError};
use crate::shard::{ShardSnapshot, ShardState};
use crate::util::sync::{lock_recover, read_recover, write_recover};
use crate::util::Pcg64;

/// Bounded placement retry: how many distinct healthy shards `submit`
/// (and the supervisor's recovery re-placement) tries before giving up
/// with a [`ShardLostError`].
const SUBMIT_ATTEMPTS: usize = 3;

/// How a fleet launches one more member (live scale-up).  Holds the
/// *fleet-level* config; the per-shard KV budget slice is computed at
/// launch time from the membership target.
enum Launcher {
    /// PJRT engine shards (`--pipeline 1`).
    Engine { artifacts: std::path::PathBuf, cfg: ServeConfig },
    /// Pipeline groups over one shared native model: `SET shards <n>`
    /// counts placeable *groups* (each of `cfg.pipeline` stages).
    Pipeline { model: Arc<SwanModel>, cfg: ServeConfig },
}

impl Launcher {
    fn launch(
        &self,
        id: usize,
        mem_budget: usize,
        hooks: ShardHooks,
    ) -> anyhow::Result<ShardHandle> {
        match self {
            Launcher::Engine { artifacts, cfg } => {
                let shard_cfg = ServeConfig { mem_budget, ..cfg.clone() };
                let engine = Engine::new(artifacts, shard_cfg)
                    .with_context(|| format!("launching shard {id}"))?;
                engine.warmup()?;
                Ok(ShardHandle::spawn_with(id, engine, hooks))
            }
            Launcher::Pipeline { model, cfg } => {
                let group_cfg = ServeConfig { mem_budget, ..cfg.clone() };
                crate::shard::pipeline::launch_group_with(id, model.clone(), &group_cfg, hooks)
            }
        }
    }
}

/// What the router needs to score a request's prefix cache-affinity
/// against each shard's published fingerprints: the fleet compression
/// defaults that parameterize [`crate::prefix::cfg_key`].  Present on
/// pipeline fleets (the mode that can hold a prefix tree); a request
/// whose per-request `k` snaps differently inside the group simply
/// scores zero affinity and placement falls back to load — the
/// fingerprint check is a heuristic, never a correctness input.
struct PrefixRoute {
    block_tokens: usize,
    buffer: usize,
    mode: crate::sparse::StorageMode,
    k_default: usize,
}

struct RouterInner {
    /// Elastic membership; handles leave when the supervisor retires a
    /// dead/drained shard and join on live scale-up.
    shards: RwLock<Vec<Arc<ShardHandle>>>,
    policy: Mutex<Box<dyn BalancePolicy>>,
    /// Fleet-global request ids (per-shard engines would otherwise hand
    /// out colliding ids on the wire).
    next_id: AtomicU64,
    /// Monotonic shard ids — never reused, so METRICS shard labels and
    /// TRACE output stay unambiguous across deaths and scale events.
    next_shard_id: AtomicUsize,
    /// Server-level obs series (per-connection counters, protocol
    /// errors, shard deaths) — rendered into the `METRICS` exposition
    /// alongside every shard's registry, with no shard identity label.
    server_registry: Arc<Registry>,
    /// `swan_shard_deaths`: fleet-level (server registry — shard
    /// registries die with their shard, and counters there would be
    /// summed and then lost on retirement).
    shard_deaths: Arc<Counter>,
    /// Where supervised shards report death/drain; kept here so live
    /// scale-up can wire new members into the same supervisor.
    fleet_tx: mpsc::Sender<FleetEvent>,
    /// `None` for fleets assembled from pre-built handles — they can
    /// drain/shrink but not scale up.
    launcher: Option<Launcher>,
    /// The fleet-level KV budget (`0` = unbounded), re-split across the
    /// healthy membership on every scale event.
    fleet_budget: usize,
    /// How long a draining shard waits for in-flight work before
    /// migrating it through the recovery path.
    drain_timeout: Duration,
    /// Affinity-scoring inputs for prefix-cache routing; `None` on
    /// fleets that can never hold a prefix tree (engine shards,
    /// pre-built handles), which keeps their placement path unchanged.
    prefix_route: Option<PrefixRoute>,
}

impl RouterInner {
    /// Pick a healthy shard for placement, or `None` when the fleet has
    /// no healthy member.  Policies only ever see healthy snapshots, so
    /// they stay lifecycle-oblivious (see `balance`).
    ///
    /// `aff_keys` carries the request's candidate prefix entry keys
    /// (precomputed once per request by `submit`); when present, each
    /// healthy snapshot's `affinity` is filled from the shard's
    /// published fingerprints before the policy runs, so MemAware can
    /// land the request where its prompt prefix is already cached.
    fn place_healthy(&self, aff_keys: Option<&[u64]>) -> Option<Arc<ShardHandle>> {
        let shards = read_recover(&self.shards);
        let healthy: Vec<&Arc<ShardHandle>> =
            shards.iter().filter(|s| s.status.state() == ShardState::Healthy).collect();
        if healthy.is_empty() {
            return None;
        }
        let mut snaps: Vec<ShardSnapshot> = healthy.iter().map(|s| s.snapshot()).collect();
        if let (Some(keys), Some(pr)) = (aff_keys, &self.prefix_route) {
            if !keys.is_empty() {
                for (snap, h) in snaps.iter_mut().zip(&healthy) {
                    let fps = lock_recover(&h.status.prefix_fps);
                    snap.affinity =
                        crate::prefix::affinity_from_keys(keys, pr.block_tokens, &fps);
                }
            }
        }
        let pick = lock_recover(&self.policy).pick(&snaps);
        // lint: allow(indexing, "clamped to len-1 after the non-empty check above; a rogue policy pick cannot go out of bounds")
        Some(healthy[pick.min(healthy.len() - 1)].clone())
    }

    /// Retire a handle from the membership (its thread has already
    /// exited).  The drop — which joins the thread — runs after the
    /// write lock is released.
    fn remove_shard(&self, id: usize) {
        let removed = {
            let mut shards = write_recover(&self.shards);
            shards.iter().position(|s| s.id == id).map(|pos| shards.remove(pos))
        };
        drop(removed);
    }

    /// Re-place one recovered request on a healthy shard.  A shard that
    /// rejects the hop (its channel closed between snapshot and send) is
    /// marked dead and the next healthy one is tried; with no healthy
    /// shard left the request fails terminally with a `shard_lost`
    /// error on its own event stream.
    fn recover_one(&self, rec: RecoveredReq) {
        let mut rec = rec;
        for _ in 0..SUBMIT_ATTEMPTS {
            // no affinity scoring on recovery: a resumed sequence
            // rebuilds its cache by full per-token re-prefill (never an
            // attach), so landing near a cached prefix buys nothing
            let Some(shard) = self.place_healthy(None) else { break };
            shard.status.queued.fetch_add(1, Ordering::Relaxed);
            match shard.try_send(ShardCmd::Recover(Box::new(rec))) {
                Ok(()) => return,
                Err(cmd) => {
                    shard.status.queued.fetch_sub(1, Ordering::Relaxed);
                    shard.status.set_state(ShardState::Dead);
                    match cmd {
                        ShardCmd::Recover(back) => rec = *back,
                        // try_send hands back exactly what it was given
                        _ => unreachable!("try_send returned a different command"),
                    }
                }
            }
        }
        log::error!("fleet: request {} lost — no healthy shard to recover onto", rec.req.id);
        if let Some(tx) = rec.sink {
            let _ = tx.send(Event::Error {
                id: rec.req.id,
                message: format!(
                    "shard_lost: no healthy shard to recover request {}",
                    rec.req.id
                ),
            });
        }
    }
}

/// The supervisor thread: consumes [`FleetEvent`]s from every supervised
/// shard, retires dead handles, and re-places recovered work.  Holds
/// only a `Weak` to the router while blocked, so dropping the router
/// tears the whole fleet down cleanly (shards drop their event senders
/// and the receive loop ends).
fn supervisor_loop(inner: Weak<RouterInner>, rx: mpsc::Receiver<FleetEvent>) {
    while let Ok(ev) = rx.recv() {
        let Some(inner) = inner.upgrade() else { return };
        match ev {
            FleetEvent::ShardDead { id, reason, recovered } => {
                inner.shard_deaths.inc();
                log::warn!(
                    "fleet: shard {id} died ({reason}); recovering {} request(s)",
                    recovered.len()
                );
                inner.remove_shard(id);
                for rec in recovered {
                    inner.recover_one(rec);
                }
            }
            FleetEvent::ShardDrained { id, migrated } => {
                log::info!("fleet: shard {id} drained ({} migrated)", migrated.len());
                inner.remove_shard(id);
                for rec in migrated {
                    inner.recover_one(rec);
                }
            }
        }
    }
}

pub struct Router {
    inner: Arc<RouterInner>,
}

impl Router {
    /// Launch the fleet and front it with the configured balance policy.
    ///
    /// * `cfg.pipeline == 1` (default): `cfg.shards` PJRT engines, each on
    ///   its own thread with its own scheduler, worker pool and a
    ///   `mem_budget / shards` KV slice.  Bring-up (artifact load + graph
    ///   warmup) runs concurrently, so fleet startup costs ~one engine
    ///   launch, not N.
    /// * `cfg.pipeline > 1`: layer-sharded mode — the shard slots form
    ///   `shards / pipeline` pipeline groups of `pipeline` stages each
    ///   over one shared rust-native model; every group registers as one
    ///   placeable shard, so balance policies, `SET k_active` broadcast
    ///   and fleet STATS are mode-agnostic.
    ///
    /// Every member launches supervised: deaths recover, `DRAIN <id>`
    /// and `SET shards <n>` work live.
    pub fn launch(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Router> {
        anyhow::ensure!(cfg.shards >= 1, "shards must be >= 1, got {}", cfg.shards);
        if cfg.pipeline > 1 {
            return Router::launch_pipeline(artifacts_dir, cfg);
        }
        let policy = policy_from_name(&cfg.balance)?;
        let (fleet_tx, fleet_rx) = mpsc::channel();
        let per_shard_budget =
            if cfg.mem_budget == 0 { 0 } else { (cfg.mem_budget / cfg.shards).max(1) };
        let launchers: Vec<_> = (0..cfg.shards)
            .map(|id| {
                let shard_cfg = ServeConfig { mem_budget: per_shard_budget, ..cfg.clone() };
                let dir = artifacts_dir.to_path_buf();
                std::thread::Builder::new()
                    .name(format!("swan-shard-launch-{id}"))
                    .spawn(move || -> anyhow::Result<Engine> {
                        let engine = Engine::new(&dir, shard_cfg)?;
                        engine.warmup()?;
                        Ok(engine)
                    })
                    // lint: allow(panic, "fleet bring-up, before any request is admitted: a host that cannot spawn threads cannot launch the fleet")
                    .expect("spawning shard launch thread")
            })
            .collect();
        let mut shards = Vec::with_capacity(cfg.shards);
        for (id, launcher) in launchers.into_iter().enumerate() {
            let engine = launcher
                .join()
                .map_err(|_| anyhow::anyhow!("shard {id} launch thread panicked"))?
                .with_context(|| format!("launching shard {id}"))?;
            let hooks = ShardHooks::supervised(fleet_tx.clone());
            shards.push(Arc::new(ShardHandle::spawn_with(id, engine, hooks)));
        }
        let launcher =
            Launcher::Engine { artifacts: artifacts_dir.to_path_buf(), cfg: cfg.clone() };
        Ok(Router::assemble(shards, policy, Some(launcher), fleet_tx, fleet_rx, &cfg, None))
    }

    /// Pipeline-sharded launch: `shards / pipeline` groups of `pipeline`
    /// stages each, over one shared rust-native model (the AOT graphs are
    /// whole-model artifacts, so layer-range execution runs on the native
    /// path; see `swan::shard::pipeline`).  The fleet KV budget splits
    /// evenly across groups; within a group each stage's share follows
    /// its layer count by construction.
    fn launch_pipeline(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Router> {
        anyhow::ensure!(
            cfg.shards % cfg.pipeline == 0,
            "shards ({}) must be a multiple of pipeline ({}) so stages form whole groups",
            cfg.shards,
            cfg.pipeline
        );
        // same kernel-pin contract as Engine::new: an explicit choice pins
        // the process-wide path before any stage builds caches (lane
        // padding) or dispatches; "auto" leaves an embedder's pin alone
        if !matches!(cfg.kernels.as_str(), "auto" | "") {
            crate::simd::init_from_name(&cfg.kernels)?;
        }
        let wf = crate::model::WeightFile::load(
            &artifacts_dir.join(format!("weights_{}.bin", cfg.model)),
        )
        .with_context(|| format!("native weights for {} (run `make artifacts`)", cfg.model))?;
        let model = std::sync::Arc::new(crate::model::SwanModel::load(
            &wf,
            crate::swan::projection::ProjectionVariant::Calibrated,
            0,
        )?);
        Router::launch_pipeline_from_model(model, &cfg, Vec::new())
    }

    /// Launch a supervised pipeline fleet over an already-built model —
    /// the chaos/test entry point (synthetic models need no artifacts).
    /// `plans[g]` optionally injects a deterministic [`FaultPlan`] into
    /// group `g`; missing entries run fault-free.  `SET shards <n>` on
    /// the returned router launches further (plan-free) groups live.
    pub fn launch_pipeline_from_model(
        model: Arc<SwanModel>,
        cfg: &ServeConfig,
        plans: Vec<Option<Arc<FaultPlan>>>,
    ) -> anyhow::Result<Router> {
        anyhow::ensure!(cfg.shards >= 1, "shards must be >= 1, got {}", cfg.shards);
        let pipeline = cfg.pipeline.max(1);
        anyhow::ensure!(
            cfg.shards % pipeline == 0,
            "shards ({}) must be a multiple of pipeline ({}) so stages form whole groups",
            cfg.shards,
            pipeline
        );
        let policy = policy_from_name(&cfg.balance)?;
        let (fleet_tx, fleet_rx) = mpsc::channel();
        let n_groups = cfg.shards / pipeline;
        let per_group_budget =
            if cfg.mem_budget == 0 { 0 } else { (cfg.mem_budget / n_groups).max(1) };
        let group_cfg = ServeConfig { mem_budget: per_group_budget, ..cfg.clone() };
        let mut shards = Vec::with_capacity(n_groups);
        for id in 0..n_groups {
            let hooks = ShardHooks {
                fleet: Some(fleet_tx.clone()),
                plan: plans.get(id).cloned().flatten(),
            };
            shards.push(Arc::new(crate::shard::pipeline::launch_group_with(
                id,
                model.clone(),
                &group_cfg,
                hooks,
            )?));
        }
        let launcher = Launcher::Pipeline { model, cfg: cfg.clone() };
        // pipeline fleets can hold prefix trees (launched with
        // `--prefix-cache` or toggled live), so affinity scoring is
        // always wired; it costs nothing while fingerprint sets are empty
        let prefix_route = Some(PrefixRoute {
            block_tokens: cfg.block_tokens,
            buffer: cfg.buffer,
            mode: cfg.mode,
            k_default: cfg.k_active,
        });
        Ok(Router::assemble(shards, policy, Some(launcher), fleet_tx, fleet_rx, cfg, prefix_route))
    }

    /// Assemble a router from pre-built handles (tests, embedders).
    /// Handles spawned without supervision hooks keep the pre-fleet
    /// failure behavior (a dying shard fails its own waiters); the
    /// fleet can drain/shrink but not scale up.
    pub fn from_handles(shards: Vec<ShardHandle>, policy: Box<dyn BalancePolicy>) -> Router {
        assert!(!shards.is_empty(), "router needs at least one shard");
        let (fleet_tx, fleet_rx) = mpsc::channel();
        let shards: Vec<Arc<ShardHandle>> = shards.into_iter().map(Arc::new).collect();
        Router::assemble(shards, policy, None, fleet_tx, fleet_rx, &ServeConfig::default(), None)
    }

    fn assemble(
        shards: Vec<Arc<ShardHandle>>,
        policy: Box<dyn BalancePolicy>,
        launcher: Option<Launcher>,
        fleet_tx: mpsc::Sender<FleetEvent>,
        fleet_rx: mpsc::Receiver<FleetEvent>,
        cfg: &ServeConfig,
        prefix_route: Option<PrefixRoute>,
    ) -> Router {
        let server_registry = Arc::new(Registry::new());
        let shard_deaths = server_registry.counter("swan_shard_deaths", &[]);
        let next_shard_id = shards.iter().map(|s| s.id + 1).max().unwrap_or(0);
        let inner = Arc::new(RouterInner {
            shards: RwLock::new(shards),
            policy: Mutex::new(policy),
            next_id: AtomicU64::new(1),
            next_shard_id: AtomicUsize::new(next_shard_id),
            server_registry,
            shard_deaths,
            fleet_tx,
            launcher,
            fleet_budget: cfg.mem_budget,
            drain_timeout: Duration::from_millis(cfg.drain_timeout_ms),
            prefix_route,
        });
        let weak = Arc::downgrade(&inner);
        std::thread::Builder::new()
            .name("swan-fleet-supervisor".to_string())
            .spawn(move || supervisor_loop(weak, fleet_rx))
            // lint: allow(panic, "router construction, before the fleet serves: without a supervisor thread no recovery contract can hold, so failing loudly here is the safe outcome")
            .expect("spawning fleet supervisor thread");
        Router { inner }
    }

    pub fn n_shards(&self) -> usize {
        read_recover(&self.inner.shards).len()
    }

    /// A point-in-time clone of the membership (handles are `Arc`s; the
    /// list itself is elastic, so no slice borrow can be handed out).
    pub fn shards(&self) -> Vec<Arc<ShardHandle>> {
        read_recover(&self.inner.shards).clone()
    }

    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        read_recover(&self.inner.shards).iter().map(|s| s.snapshot()).collect()
    }

    /// Swap the placement policy live (`SET balance <name>`).
    pub fn set_policy(&self, policy: Box<dyn BalancePolicy>) {
        *lock_recover(&self.inner.policy) = policy;
    }

    pub fn policy_name(&self) -> &'static str {
        lock_recover(&self.inner.policy).name()
    }

    /// Pick the shard the next request should land on (placement only;
    /// kept for tooling/tests — `submit` itself filters to healthy
    /// members and retries).
    pub fn place(&self) -> usize {
        let snaps = self.snapshots();
        let pick = lock_recover(&self.inner.policy).pick(&snaps);
        // a misbehaving policy must not take the fleet down
        pick.min(snaps.len().saturating_sub(1))
    }

    /// Place and submit one request; the returned [`GenHandle`] carries
    /// the event channel (per-token events for streaming requests, then
    /// the terminal `Done`/`Error`) and the cancellation token.
    ///
    /// Placement is edge-resilient: only healthy shards are candidates,
    /// a shard whose channel closed mid-submit is marked dead and the
    /// hop retries on the next healthy member (jittered backoff), and
    /// the terminal failure is a structured [`ShardLostError`] — never
    /// a hang, never a silent drop.
    pub fn submit(&self, mut req: Request) -> anyhow::Result<GenHandle> {
        if req.id == 0 {
            req.id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let id = req.id;
        let cancel = req.cancel.clone();
        // candidate prefix entry keys, hashed once per request — each
        // placement attempt scores them against every healthy shard's
        // published fingerprints (cache-affinity routing)
        let aff_keys: Option<Vec<u64>> = self.inner.prefix_route.as_ref().map(|pr| {
            let params = crate::swan::hybrid_cache::SwanParams::new(
                req.params.k_active.unwrap_or(pr.k_default),
                pr.buffer,
                pr.mode,
            );
            crate::prefix::affinity_keys(
                &req.prompt,
                pr.block_tokens,
                crate::prefix::cfg_key(&params, pr.block_tokens),
            )
        });
        let (tx, handle) = GenHandle::channel(id, cancel);
        let mut cmd = ShardCmd::Gen { req, reply: tx };
        // deterministic per-request jitter (no global RNG state)
        let mut jitter = Pcg64::new(id ^ 0x524f_5554_4552);
        let mut attempts = 0;
        while attempts < SUBMIT_ATTEMPTS {
            let Some(shard) = self.inner.place_healthy(aff_keys.as_deref()) else { break };
            attempts += 1;
            // optimistic bump so back-to-back placements see this request
            // before the shard thread next publishes authoritative counts
            shard.status.queued.fetch_add(1, Ordering::Relaxed);
            match shard.try_send(cmd) {
                Ok(()) => return Ok(handle),
                Err(back) => {
                    // closed channel = the coordinator is gone; mark it so
                    // placement skips it (the supervisor retires it when
                    // its death event lands)
                    shard.status.queued.fetch_sub(1, Ordering::Relaxed);
                    shard.status.set_state(ShardState::Dead);
                    cmd = back;
                    if attempts < SUBMIT_ATTEMPTS {
                        let ns = 200_000 + jitter.below(1_800_000);
                        std::thread::sleep(Duration::from_nanos(ns));
                    }
                }
            }
        }
        Err(ShardLostError { attempts, detail: "no healthy shard" }.into())
    }

    /// Cancel a request by id, fleet-wide: the router does not track
    /// placement, so the hop is broadcast — unknown ids no-op on every
    /// shard that doesn't own the sequence.  Unreachable (dying) shards
    /// are skipped: their in-flight work re-lands on a healthy shard
    /// with the cancel token intact, so the cancel still takes effect.
    pub fn cancel(&self, id: u64) -> anyhow::Result<()> {
        for s in read_recover(&self.inner.shards).iter() {
            let _ = s.send(ShardCmd::Cancel { id });
        }
        Ok(())
    }

    /// Fleet-wide live compression retune: broadcast `SET k_active` to
    /// every shard, then gather the acks.  Returns `(shard id, applied
    /// k)` per responsive shard — "applied" because each engine snaps to
    /// its nearest compiled bucket.  Dying shards drop out of the gather
    /// instead of failing it (their successors launch at the fleet cfg).
    pub fn set_k_active(&self, k: usize) -> anyhow::Result<Vec<(usize, usize)>> {
        let shards = self.shards();
        let mut pending = Vec::with_capacity(shards.len());
        for s in &shards {
            let (ack_tx, ack_rx) = mpsc::channel();
            if s.send(ShardCmd::SetK { k, ack: ack_tx }).is_ok() {
                pending.push((s.id, ack_rx));
            }
        }
        anyhow::ensure!(!pending.is_empty(), "no shard accepted the retune");
        let mut applied = Vec::with_capacity(pending.len());
        for (id, rx) in pending {
            if let Ok(got) = rx.recv() {
                applied.push((id, got));
            }
        }
        Ok(applied)
    }

    /// Fleet-wide prefix-caching toggle: broadcast `SET prefix on|off`
    /// to every shard, then gather the acks.  Returns `(shard id,
    /// applied)` per responsive shard — engine shards and groups that
    /// cannot host a tree (dense baseline, pool off) report `false`, so
    /// the wire reply shows exactly where the toggle took effect.
    /// Turning the cache off flushes every group's tree and releases
    /// the pinned blocks.
    pub fn set_prefix(&self, on: bool) -> anyhow::Result<Vec<(usize, bool)>> {
        let shards = self.shards();
        let mut pending = Vec::with_capacity(shards.len());
        for s in &shards {
            let (ack_tx, ack_rx) = mpsc::channel();
            if s.send(ShardCmd::SetPrefix { on, ack: ack_tx }).is_ok() {
                pending.push((s.id, ack_rx));
            }
        }
        anyhow::ensure!(!pending.is_empty(), "no shard accepted the prefix toggle");
        let mut applied = Vec::with_capacity(pending.len());
        for (id, rx) in pending {
            if let Ok(got) = rx.recv() {
                applied.push((id, got));
            }
        }
        Ok(applied)
    }

    /// `DRAIN <id>`: stop placing on the shard immediately, let its
    /// in-flight and queued work finish (or migrate, after the drain
    /// timeout), then retire it.  Draining the last healthy shard is
    /// refused — the fleet must always be able to serve.
    pub fn drain(&self, id: usize) -> anyhow::Result<()> {
        let shards = read_recover(&self.inner.shards);
        let healthy = shards.iter().filter(|s| s.status.state() == ShardState::Healthy).count();
        let shard = shards
            .iter()
            .find(|s| s.id == id)
            .ok_or_else(|| anyhow::anyhow!("unknown shard {id}"))?;
        if shard.status.state() == ShardState::Healthy && healthy <= 1 {
            anyhow::bail!("cannot drain the last healthy shard");
        }
        // flip the published state before the command lands, so the next
        // placement already skips this shard
        shard.status.set_state(ShardState::Draining);
        shard.send(ShardCmd::Drain { timeout: self.inner.drain_timeout })
    }

    /// `SET shards <n>`: elastic membership.  Scale-up launches new
    /// supervised members live (placeable as soon as each is up);
    /// scale-down drains the youngest healthy members (their in-flight
    /// work finishes or migrates — nothing is dropped).  Either way the
    /// fleet KV budget is re-split over the target membership.  Returns
    /// the target count.
    pub fn set_shards(&self, n: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(n >= 1, "shards must be >= 1, got {n}");
        let inner = &self.inner;
        let per_shard =
            if inner.fleet_budget == 0 { 0 } else { (inner.fleet_budget / n).max(1) };
        let healthy: Vec<usize> = {
            let shards = read_recover(&inner.shards);
            shards
                .iter()
                .filter(|s| s.status.state() == ShardState::Healthy)
                .map(|s| s.id)
                .collect()
        };
        if healthy.len() < n {
            let Some(launcher) = inner.launcher.as_ref() else {
                anyhow::bail!(
                    "this fleet was assembled from pre-built handles and cannot scale up"
                );
            };
            for _ in healthy.len()..n {
                let id = inner.next_shard_id.fetch_add(1, Ordering::Relaxed);
                let hooks = ShardHooks::supervised(inner.fleet_tx.clone());
                let handle = launcher.launch(id, per_shard, hooks)?;
                write_recover(&inner.shards).push(Arc::new(handle));
            }
        } else {
            // drain the youngest healthy members down to the target
            for id in healthy.iter().rev().take(healthy.len() - n) {
                self.drain(*id)?;
            }
        }
        if inner.fleet_budget > 0 {
            // rebalance the surviving members' KV slices to total/n
            for s in read_recover(&inner.shards).iter() {
                if s.status.state() == ShardState::Healthy {
                    let _ = s.send(ShardCmd::SetMemBudget(per_shard));
                }
            }
        }
        Ok(n)
    }

    /// The fleet STATS view: per-shard blocks + aggregate totals.
    pub fn stats(&self) -> String {
        let mut out = admin::fleet_stats(&self.shards(), self.policy_name());
        let deaths = self.inner.shard_deaths.get();
        if deaths > 0 {
            out.push_str(&format!("fleet lifecycle: shard_deaths={deaths}\n"));
        }
        out
    }

    /// The registry server-level series (connection counters) register
    /// in; the TCP front-end holds a clone per listener.
    pub fn server_registry(&self) -> Arc<Registry> {
        self.inner.server_registry.clone()
    }

    /// The fleet `METRICS` exposition (Prometheus text format 0.0.4).
    pub fn metrics_text(&self) -> String {
        admin::fleet_metrics(&self.shards(), &self.inner.server_registry)
    }

    /// `TRACE <id>`: the first shard retaining the request's lifecycle
    /// trace answers with its JSONL timeline.
    pub fn trace_jsonl(&self, id: u64) -> Option<String> {
        admin::fleet_trace(&self.shards(), id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::balance::RoundRobin;

    #[test]
    fn place_clamps_rogue_policy() {
        struct Rogue;
        impl BalancePolicy for Rogue {
            fn name(&self) -> &'static str {
                "rogue"
            }
            fn pick(&mut self, _s: &[ShardSnapshot]) -> usize {
                usize::MAX
            }
        }
        let (h, _rx) = ShardHandle::stub(0);
        let router = Router::from_handles(vec![h], Box::new(Rogue));
        assert_eq!(router.place(), 0);
    }

    #[test]
    fn policy_swap_is_visible() {
        let (h, _rx) = ShardHandle::stub(0);
        let router = Router::from_handles(vec![h], Box::new(RoundRobin::default()));
        assert_eq!(router.policy_name(), "round-robin");
        router.set_policy(policy_from_name("mem-aware").unwrap());
        assert_eq!(router.policy_name(), "mem-aware");
    }

    #[test]
    fn submit_skips_unhealthy_shards() {
        let (h0, _rx0) = ShardHandle::stub(0);
        let (h1, rx1) = ShardHandle::stub(1);
        h0.status.set_state(ShardState::Draining);
        let router = Router::from_handles(vec![h0, h1], Box::new(RoundRobin::default()));
        for _ in 0..3 {
            let _ = router.submit(Request::from_text(0, "hi", 4)).unwrap();
        }
        // every placement must have landed on the sole healthy shard
        let mut landed = 0;
        while let Ok(cmd) = rx1.try_recv() {
            assert!(matches!(cmd, ShardCmd::Gen { .. }));
            landed += 1;
        }
        assert_eq!(landed, 3);
    }

    #[test]
    fn submit_with_no_healthy_shard_is_a_structured_error() {
        let (h, _rx) = ShardHandle::stub(0);
        h.status.set_state(ShardState::Dead);
        let router = Router::from_handles(vec![h], Box::new(RoundRobin::default()));
        let err = router.submit(Request::from_text(0, "hi", 4)).unwrap_err();
        let lost = err.downcast_ref::<ShardLostError>().expect("ShardLostError");
        assert_eq!(lost.attempts, 0);
        assert!(err.to_string().contains("no healthy shard"));
    }

    #[test]
    fn submit_retries_onto_a_live_shard_when_one_dies_mid_submit() {
        let (h0, rx0) = ShardHandle::stub(0);
        let (h1, rx1) = ShardHandle::stub(1);
        drop(rx0); // shard 0's coordinator is gone, but still marked healthy
        let router = Router::from_handles(vec![h0, h1], Box::new(RoundRobin::default()));
        let _ = router.submit(Request::from_text(0, "hi", 4)).unwrap();
        let _ = router.submit(Request::from_text(0, "hi", 4)).unwrap();
        let mut landed = 0;
        while let Ok(cmd) = rx1.try_recv() {
            assert!(matches!(cmd, ShardCmd::Gen { .. }));
            landed += 1;
        }
        assert_eq!(landed, 2, "both submits must land on the live shard");
        // the dead shard is now marked so placement never retries it
        assert_eq!(router.snapshots().iter().find(|s| s.id == 0).unwrap().state, ShardState::Dead);
    }

    #[test]
    fn drain_refuses_the_last_healthy_shard() {
        let (h0, _rx0) = ShardHandle::stub(0);
        let (h1, _rx1) = ShardHandle::stub(1);
        let router = Router::from_handles(vec![h0, h1], Box::new(RoundRobin::default()));
        router.drain(1).unwrap();
        let err = router.drain(0).unwrap_err();
        assert!(err.to_string().contains("last healthy shard"), "{err}");
        assert!(router.drain(99).unwrap_err().to_string().contains("unknown shard"));
    }

    #[test]
    fn from_handles_fleet_cannot_scale_up_but_can_shrink() {
        let (h0, _rx0) = ShardHandle::stub(0);
        let (h1, _rx1) = ShardHandle::stub(1);
        let router = Router::from_handles(vec![h0, h1], Box::new(RoundRobin::default()));
        let err = router.set_shards(4).unwrap_err();
        assert!(err.to_string().contains("cannot scale up"), "{err}");
        router.set_shards(1).unwrap();
        // the youngest healthy shard is draining; membership shrinks once
        // its (stub, unsupervised) thread would report drained
        let snap = router.snapshots();
        assert_eq!(snap.iter().filter(|s| s.state == ShardState::Healthy).count(), 1);
        assert_eq!(snap.iter().find(|s| s.id == 1).unwrap().state, ShardState::Draining);
    }
}
