//! Pipeline layer-sharding: one *group* of stage threads serves a model
//! too big for any single engine's memory budget by giving each stage a
//! contiguous layer range and flowing every sequence through the chain.
//!
//! Topology (one group):
//!
//! ```text
//!   coordinator ──Prefill/Forward──▶ stage 0 ──▶ stage 1 ──▶ … ──▶ stage S-1
//!        ▲                         (embed +      (middle        (final norm
//!        └────────── GroupEvent ◀── layers a..b)  layers)        + logits)
//! ```
//!
//! * **Stages** own `layers[a..b]` of an `Arc<SwanModel>` plus the
//!   per-sequence [`SequenceState`] caches for exactly those layers —
//!   the fleet KV budget a group receives is therefore split across its
//!   stages *by layer count*, automatically.  Stage 0 embeds sampled
//!   tokens; the last stage runs final-norm + lm-head.
//! * **Activation handoff** is the [`StageCmd::Forward`] hop: one message
//!   per decode iteration carrying the whole ready set's hidden rows, so
//!   a stage processes its full batch before handing off (no per-sequence
//!   ping-pong).
//! * **The coordinator** presents the standard [`ShardCmd`] interface, so
//!   the router places sequences onto pipeline *groups* exactly like it
//!   places them onto engine shards, `SET k_active` broadcasts reach
//!   every stage, and fleet STATS renders per-stage queue depth (the
//!   bubble indicator) alongside the usual engine metrics.
//!
//! Determinism: every stage runs [`SwanModel::decode_step_pipeline`] /
//! [`SwanModel::prefill_layers`] — the exact functions a single engine
//! composes over the full range — and sampling shares the engine's
//! per-request RNG streams, so an S-stage group decodes bit-identically
//! to a single-shard run of the *native* model on the same seed, for any
//! S (`tests/pipeline.rs`).  A plain `--shards 1` fleet serves through
//! the PJRT graphs instead — across that backend boundary outputs agree
//! to float tolerance, not bit-for-bit.

// lint: allow(indexing, "stage/sequence indices here come from membership scans computed lines above (running/ready sets over self.active, stages[0] of a non-empty chain); a bad index is a coordinator bug the supervised group converts into shard-death + recovery")

use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::api::Event;
use crate::config::ServeConfig;
use crate::coordinator::engine::{sample, x5wan_seed, DECODE_SLOTS_PER_WORKER};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{decode_tokens, Request, RequestStats, Response};
use crate::coordinator::scheduler::Scheduler;
use crate::kvcache::PolicyKind;
use crate::model::transformer::{SequenceState, StageInput, SwanModel};
use crate::obs::histogram::Histogram;
use crate::obs::registry::{Gauge, Registry};
use crate::obs::trace::{TraceKind, TraceRing, TRACE_RING_CAP};
use crate::pool::{pool_blocks_for_budget, seq_blocks, BlockPool, PagedSwanCache, PoolObs};
use crate::prefix::{
    cfg_key, chain_hashes, entry_key, shared_full_blocks, EntryStream, PendingInsert,
    PrefixPrefill, PrefixTree, StageEntry, StagePrefixStore,
};
use crate::shard::shard::{panic_reason, ShardCmd, ShardHandle, ShardStatus};
use crate::shard::supervisor::{FleetEvent, RecoveredReq, ShardHooks, StageFaults};
use crate::shard::ShardState;
use crate::swan::batch::WorkerPool;
use crate::util::sync::lock_recover;
use crate::util::Pcg64;

/// Split `n_layers` into `n_stages` contiguous ranges, earliest stages
/// taking the remainder (so stage loads differ by at most one layer).
pub fn partition_layers(n_layers: usize, n_stages: usize) -> anyhow::Result<Vec<Range<usize>>> {
    anyhow::ensure!(n_stages >= 1, "pipeline needs at least one stage");
    anyhow::ensure!(
        n_layers >= n_stages,
        "cannot split {n_layers} layers across {n_stages} stages (every stage needs >= 1 layer)"
    );
    let base = n_layers / n_stages;
    let rem = n_layers % n_stages;
    let mut out = Vec::with_capacity(n_stages);
    let mut start = 0;
    for s in 0..n_stages {
        let len = base + usize::from(s < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n_layers);
    Ok(out)
}

/// Commands a pipeline stage accepts.  `Prefill` and `Forward` travel the
/// stage chain (each stage transforms and hands off); the rest are
/// broadcast by the group coordinator.
pub enum StageCmd {
    /// Admit one sequence: run this stage's layers over the prompt's
    /// hidden rows (`[T, d_model]` flat), seed the stage caches, hand the
    /// transformed rows downstream.  The last stage answers the
    /// coordinator with the prompt's final logits.
    ///
    /// `prefix` switches the stage to prefix serving for this sequence:
    /// the carried rows cover only the prompt *suffix*, a cached prefix
    /// may be attached from the stage's prefix store first, and the
    /// suffix runs through the cache-consistent per-token path (see the
    /// handler) instead of the exact-attention bulk prefill.
    Prefill { seq: u64, h: Vec<f32>, k_active: usize, prefix: Option<PrefixPrefill> },
    /// One decode iteration for the whole ready set: stage 0 consumes
    /// `tokens` (one sampled token per sequence), later stages consume
    /// `h` (one hidden row per sequence).  The last stage answers the
    /// coordinator with one logits row per sequence.  `compute_ns`
    /// accumulates each stage's model time as the hop travels, so the
    /// coordinator can split its wall wait into compute vs bubble.
    Forward { seqs: Vec<u64>, tokens: Vec<u32>, h: Vec<Vec<f32>>, compute_ns: u64 },
    /// Drop the stage caches of retired sequences — both naturally
    /// finished ones and cancellations (`CANCEL <id>` / client
    /// disconnect): the group coordinator marks a cancelled sequence
    /// finished at its next iteration and this hop reclaims its KV on
    /// every stage.  Ids listed in `insert` (always a subset of `seqs`)
    /// commit their parked [`PendingInsert`] into the stage prefix
    /// store before the cache drops — sharing the retiring sequence's
    /// full winnowed blocks zero-copy; preemptions send `insert` empty.
    Retire { seqs: Vec<u64>, insert: Vec<u64> },
    /// Drop prefix-store entries (LRU shed under pool pressure, or the
    /// full flush of `SET prefix off`).  Running sequences that attached
    /// an evicted entry keep their block references — the pool frees a
    /// block only when its last holder lets go.
    PrefixEvict { entries: Vec<u64> },
    /// Record the compression level for newly admitted sequences; ack the
    /// applied (d_head-clamped) value.
    SetK { k: usize, ack: mpsc::Sender<usize> },
    /// Render this stage's stats line.
    Stats { reply: mpsc::Sender<String> },
    Shutdown,
}

/// What the stage chain sends back to the group coordinator.  Results
/// come from the last stage; `StageFailed` can come from ANY stage (via
/// its [`FailureGuard`]) — without it a dead middle stage would leave
/// the coordinator blocked forever, since the last stage's live sender
/// keeps the event channel open.
pub enum GroupEvent {
    /// Prompt fully prefilled through every stage.
    Prefilled { seq: u64, logits: Vec<f32> },
    /// Decode iteration complete: one logits row per forwarded sequence,
    /// plus the chain's summed per-stage compute time (see
    /// [`StageCmd::Forward`]).
    Stepped { seqs: Vec<u64>, logits: Vec<Vec<f32>>, compute_ns: u64 },
    /// A stage thread exited abnormally; the chain is unrecoverable.
    StageFailed { stage: usize },
}

/// Sends [`GroupEvent::StageFailed`] when a stage thread exits without
/// being disarmed.  Disarmed only on a clean `Shutdown`; every other
/// exit — downstream-gone breaks AND panics (drops run during
/// unwinding) — reports, so the coordinator's event wait always wakes.
struct FailureGuard {
    stage: usize,
    events: mpsc::Sender<GroupEvent>,
    armed: bool,
}

impl Drop for FailureGuard {
    fn drop(&mut self) {
        if self.armed {
            let _ = self.events.send(GroupEvent::StageFailed { stage: self.stage });
        }
    }
}

/// Lock-free per-stage load view, rendered into fleet STATS so pipeline
/// bubbles (a stage with a persistent command backlog) are visible.
/// Sequence counts and KV bytes are rendered from the stage's own state
/// in its `Stats` handler — only the cross-thread-read values live here.
#[derive(Debug, Default)]
pub struct StageStatus {
    /// Commands sent to the stage but not yet fully processed.
    pub queued: AtomicUsize,
    /// Compression level for newly admitted sequences.
    pub k_active: AtomicUsize,
}

/// Where a stage hands its output: the next stage, or (from the last
/// stage) back to the group coordinator.
enum Downstream {
    Stage(mpsc::Sender<StageCmd>, Arc<StageStatus>),
    Coordinator(mpsc::Sender<GroupEvent>),
}

/// The group coordinator's handle on one stage.
struct StageHandle {
    tx: mpsc::Sender<StageCmd>,
    status: Arc<StageStatus>,
    join: Option<JoinHandle<()>>,
}

impl StageHandle {
    /// Send with the queue-depth bump the status contract requires.
    fn send(&self, cmd: StageCmd) -> anyhow::Result<()> {
        self.status.queued.fetch_add(1, Ordering::Relaxed);
        self.tx.send(cmd).map_err(|_| anyhow::anyhow!("pipeline stage is gone"))
    }
}

/// Compression level a request is admitted at on the native path: its
/// own `k_active` override, d_head-clamped exactly like the fleet
/// retune clamps, else the group's current level.  The ONE spelling of
/// the rule — `Group::request_k` (admission + live accounting) and the
/// admission projection closure both call it, so the projected bytes
/// can never drift from the admitted level.
fn request_k_for(req: &Request, d_head: usize, k_now: usize) -> usize {
    req.params.k_active.map(|k| k.clamp(1, d_head)).unwrap_or(k_now)
}

/// Preemption-fairness cap: after this many evictions a sequence becomes
/// non-evictable and the pool-budget loop picks the next-youngest victim
/// instead.  Without it, sustained overload preempts the same youngest
/// sequence every iteration — it replays its whole history, gets evicted
/// again before committing a fresh token, and starves (thrash).  The cap
/// bounds each sequence's replay overhead at `MAX_PREEMPTIONS` rebuilds
/// while keeping the youngest-first heuristic (oldest sequences are
/// closest to finishing and have the most replay state).
pub const MAX_PREEMPTIONS: u32 = 3;

/// Cap on the prefix-entry fingerprints a group publishes in its
/// [`ShardStatus`] for cache-affinity routing: most-recently-used first,
/// so the router sees the entries most likely to still be resident.
/// Bounded so the router's per-placement scan stays O(P/bt · cap).
pub const PREFIX_FP_CAP: usize = 128;

fn policy_kind(cfg: &ServeConfig, k_active: usize) -> PolicyKind {
    if cfg.dense_baseline {
        PolicyKind::Dense
    } else {
        PolicyKind::Swan { k_active, buffer: cfg.buffer, mode: cfg.mode }
    }
}

// ----------------------------------------------------------------------
// stage thread
// ----------------------------------------------------------------------

struct StageCtx {
    group: usize,
    stage: usize,
    layers: Range<usize>,
    model: Arc<SwanModel>,
    cfg: ServeConfig,
    next: Downstream,
    status: Arc<StageStatus>,
    /// Direct line to the coordinator, used only by the [`FailureGuard`]
    /// (results travel the chain; failure must not).
    events: mpsc::Sender<GroupEvent>,
    /// This stage's block pool (`--pool`): every sequence cache this
    /// stage builds leases its storage here instead of owning it.
    block_pool: Option<Arc<BlockPool>>,
    /// Chaos fault injection (no-op outside chaos tests): counts this
    /// stage's prefills/forwards against the group's [`FaultPlan`].
    faults: StageFaults,
}

fn stage_loop(ctx: StageCtx, rx: mpsc::Receiver<StageCmd>) {
    let StageCtx { group, stage, layers, model, cfg, next, status, events, block_pool, faults } =
        ctx;
    let mut guard = FailureGuard { stage, events, armed: true };
    let first = layers.start == 0;
    let mut pool = WorkerPool::new(cfg.decode_workers);
    let mut seqs: HashMap<u64, SequenceState> = HashMap::new();
    // prefix serving: committed prefix payloads keyed by entry key, and
    // per-sequence captures parked between prefill and retire
    let mut store: StagePrefixStore = HashMap::new();
    let mut pending: HashMap<u64, PendingInsert> = HashMap::new();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            StageCmd::Prefill { seq, mut h, k_active, prefix } => {
                faults.on_prefill(stage);
                let mut st = match &block_pool {
                    // paged path: same SWAN policy, storage leased from
                    // the stage pool block by block (bit-identical to
                    // the contiguous caches; see `crate::pool`)
                    Some(bp) => {
                        let params = crate::swan::hybrid_cache::SwanParams::new(
                            k_active, cfg.buffer, cfg.mode,
                        );
                        let (d_h, bt) = (model.cfg.d_head, cfg.block_tokens);
                        SequenceState::for_layers_with(&model, layers.len(), || {
                            Box::new(PagedSwanCache::new(d_h, params, bt, bp.clone()))
                        })
                    }
                    None => SequenceState::for_layers(
                        &model,
                        policy_kind(&cfg, k_active),
                        layers.len(),
                    ),
                };
                match &prefix {
                    // Cache-consistent prefill: attach the cached prefix
                    // (if any), then run every suffix row through the
                    // SAME per-token step decode uses, so the winnowed
                    // state after P tokens is a pure function of the
                    // tokens — a warm hit (attach L, run P-L) lands on
                    // bit-identical state and logits to a cold run
                    // (attach 0, run P) of the same prompt.  The exact
                    // bulk prefill below computes *exact* attention over
                    // the prompt instead, which is NOT replayable from a
                    // block boundary — that's why prefix serving swaps
                    // the prefill flavor wholesale rather than mixing.
                    Some(px) => {
                        if let Some(key) = px.attach {
                            let entry = match store.get(&key) {
                                Some(e) => e,
                                // lint: allow(panic, "stage-protocol invariant: the coordinator only attaches keys it inserted, and evictions broadcast before any admission that could re-reference them; the supervised stage turns a violation into StageFailed -> shard-death + recovery")
                                None => panic!("stage {stage}: prefix entry missing at attach"),
                            };
                            for (c, stream) in st.caches.iter_mut().zip(&entry.streams) {
                                match c.as_paged() {
                                    Some(p) => p.attach_prefix(stream, entry.depth),
                                    // lint: allow(panic, "prefix implies the paged pool (launch_group_with forces pool_on), so every cache here is a PagedSwanCache")
                                    None => panic!("prefix attach over a non-paged cache"),
                                }
                            }
                            st.pos = entry.depth;
                        }
                        let d = model.cfg.d_model;
                        let n = if d == 0 { 0 } else { h.len() / d };
                        let mut out: Vec<f32> = Vec::with_capacity(h.len());
                        for s_i in 0..n {
                            let row = h[s_i * d..(s_i + 1) * d].to_vec();
                            let mut rows = model.decode_step_pipeline(
                                std::slice::from_mut(&mut st),
                                StageInput::Hidden(vec![row]),
                                layers.clone(),
                                false,
                                &mut pool,
                            );
                            // the cache now holds st.pos tokens; at
                            // exactly the insert depth, snapshot the
                            // dense rings (later winnowing destroys
                            // them) — committed into the store only if
                            // the sequence retires with an insert marker
                            if let Some((key, depth)) = px.insert {
                                if st.pos == depth {
                                    let rings: Vec<(Vec<f32>, Vec<f32>)> = st
                                        .caches
                                        .iter_mut()
                                        .map(|c| match c.as_paged() {
                                            Some(p) => p.ring_snapshot(),
                                            // lint: allow(panic, "prefix implies the paged pool (launch_group_with forces pool_on), so every cache here is a PagedSwanCache")
                                            None => panic!("prefix capture over a non-paged cache"),
                                        })
                                        .collect();
                                    pending.insert(seq, PendingInsert { key, depth, rings });
                                }
                            }
                            out.extend_from_slice(&rows.pop().unwrap_or_default());
                        }
                        h = out;
                    }
                    None => {
                        let pf = model.prefill_layers(&mut h, layers.clone(), &mut pool);
                        st.load_prefill(&pf);
                    }
                }
                seqs.insert(seq, st);
                let sent = match &next {
                    Downstream::Stage(tx, st_next) => {
                        st_next.queued.fetch_add(1, Ordering::Relaxed);
                        tx.send(StageCmd::Prefill { seq, h, k_active, prefix }).is_ok()
                    }
                    Downstream::Coordinator(tx) => {
                        let logits = model.prefill_logits(&h);
                        tx.send(GroupEvent::Prefilled { seq, logits }).is_ok()
                    }
                };
                if !sent {
                    log::error!("pipeline group {group} stage {stage}: downstream gone");
                    break;
                }
            }
            StageCmd::Forward { seqs: ids, tokens, h, compute_ns } => {
                if faults.on_forward(stage) {
                    // injected stage drop: exit without disarming the
                    // guard, so the coordinator sees StageFailed — the
                    // disconnect flavor of stage death
                    break;
                }
                // pull the batch's states out in forward order (disjoint
                // &mut for decode_step_pipeline), then put them back
                let mut states: Vec<SequenceState> = ids
                    .iter()
                    .map(|id| {
                        seqs.remove(id).unwrap_or_else(|| {
                            // lint: allow(panic, "stage-protocol invariant (coordinator forwards only prefilled ids); the supervised stage turns this panic into StageFailed -> shard-death + recovery instead of silent corruption")
                            panic!("stage {stage} has no state for sequence {id}")
                        })
                    })
                    .collect();
                let emit_logits = matches!(next, Downstream::Coordinator(_));
                let input = if first {
                    StageInput::Tokens(&tokens)
                } else {
                    StageInput::Hidden(h)
                };
                let t0 = Instant::now();
                let out = model.decode_step_pipeline(
                    &mut states,
                    input,
                    layers.clone(),
                    emit_logits,
                    &mut pool,
                );
                let compute_ns = compute_ns + t0.elapsed().as_nanos() as u64;
                for (id, st) in ids.iter().zip(states) {
                    seqs.insert(*id, st);
                }
                let sent = match &next {
                    Downstream::Stage(tx, st_next) => {
                        st_next.queued.fetch_add(1, Ordering::Relaxed);
                        tx.send(StageCmd::Forward {
                            seqs: ids,
                            tokens: Vec::new(),
                            h: out,
                            compute_ns,
                        })
                        .is_ok()
                    }
                    Downstream::Coordinator(tx) => {
                        tx.send(GroupEvent::Stepped { seqs: ids, logits: out, compute_ns }).is_ok()
                    }
                };
                if !sent {
                    log::error!("pipeline group {group} stage {stage}: downstream gone");
                    break;
                }
            }
            StageCmd::Retire { seqs: ids, insert } => {
                for id in ids {
                    let st = seqs.remove(&id);
                    let pi = pending.remove(&id);
                    if !insert.contains(&id) {
                        continue;
                    }
                    // commit the parked capture: share the retiring
                    // sequence's full winnowed blocks (refcount bump, no
                    // copy), keep owned copies of the partial tails and
                    // the captured rings
                    let (Some(mut st), Some(pi), Some(bp)) = (st, pi, block_pool.as_ref()) else {
                        continue;
                    };
                    let PendingInsert { key, depth, rings } = pi;
                    let streams: Vec<EntryStream> = st
                        .caches
                        .iter_mut()
                        .zip(rings)
                        .map(|(c, ring)| match c.as_paged() {
                            Some(p) => p.share_prefix(depth, ring, bp.clone()),
                            // lint: allow(panic, "prefix implies the paged pool (launch_group_with forces pool_on), so every cache here is a PagedSwanCache")
                            None => panic!("prefix commit over a non-paged cache"),
                        })
                        .collect();
                    store.insert(key, StageEntry { depth, streams });
                }
            }
            StageCmd::PrefixEvict { entries } => {
                for key in entries {
                    store.remove(&key);
                }
            }
            StageCmd::SetK { k, ack } => {
                let applied = k.clamp(1, model.cfg.d_head);
                status.k_active.store(applied, Ordering::Relaxed);
                let _ = ack.send(applied);
            }
            StageCmd::Stats { reply } => {
                let kv: usize = seqs.values().map(|s| s.storage_bytes()).sum();
                // appended last so existing line-prefix matchers hold
                let blocks = match &block_pool {
                    Some(bp) => format!(" blocks={}", bp.leased()),
                    None => String::new(),
                };
                let _ = reply.send(format!(
                    "stage {stage}: layers {}..{} k_active={} queued={} seqs={} kv={}{blocks}\n",
                    layers.start,
                    layers.end,
                    status.k_active.load(Ordering::Relaxed),
                    // this Stats command itself is still in flight
                    status.queued.load(Ordering::Relaxed).saturating_sub(1),
                    seqs.len(),
                    crate::sparse::memory::human_bytes(kv),
                ));
            }
            StageCmd::Shutdown => {
                guard.armed = false;
                break;
            }
        }
        status.queued.fetch_sub(1, Ordering::Relaxed);
    }
    // every other exit (downstream gone, rx disconnect, panic unwind)
    // leaves the guard armed: its Drop reports StageFailed — harmlessly
    // a no-op when the coordinator itself is already gone
}

// ----------------------------------------------------------------------
// group coordinator
// ----------------------------------------------------------------------

/// One live sequence from the coordinator's point of view (the stage
/// caches live on the stages; the coordinator owns sampling + stats).
struct GroupSeq {
    req: Request,
    produced: Vec<u32>,
    next_token: u32,
    rng: Pcg64,
    stats: RequestStats,
    /// Compression level the sequence was admitted at (fixed for life).
    k_active: usize,
    /// Prompt tokens actually prefilled (>= 1; empty prompts use a dummy).
    prompt_len: usize,
    /// Replay-resume queue of a preemption-resumed sequence: tokens it
    /// already produced, re-inserted by forced decode steps (no rng
    /// draw, no emission, no stats) until the cache state catches up to
    /// where preemption interrupted it.  Empty for normal sequences.
    replay: VecDeque<u32>,
    /// When the previous token committed — the ITL clock.  Carried
    /// across preemptions, so the first post-resume token charges the
    /// full user-observed stall.
    last_token: Instant,
    /// Whether the sequence was admitted under prefix serving (the
    /// per-token prefill flavor).  A preemption-resume must rebuild via
    /// the same flavor or the reconstructed cache would diverge.
    prefix_mode: bool,
    /// Prefix-tree entry this sequence attached at admission, if any —
    /// the sweeper never evicts attached entries.
    prefix_entry: Option<u64>,
    /// Full pool blocks the sequence shares with its attached entry
    /// (charged once, to the tree, not per attached sequence).
    shared_blocks: usize,
    /// `(entry_key, depth, charge_blocks)` the sequence will insert into
    /// the prefix tree when it retires (the stage side parked the ring
    /// capture during prefill).
    pending_insert: Option<(u64, usize, usize)>,
    finished: bool,
}

impl GroupSeq {
    /// Tokens resident in the stage caches right now: the prompt plus
    /// one token per decode forward that has run.  Every produced token
    /// except the pending `next_token` has been forwarded — minus the
    /// replay backlog, whose tokens exist in `produced` but have not
    /// been re-inserted yet after a preemption.
    fn cached_tokens(&self) -> usize {
        self.prompt_len + self.produced.len() - 1 - self.replay.len()
    }
}

/// Coordinator-side state carried across a preemption: everything needed
/// to resume the sequence bit-identically once its request (requeued at
/// the scheduler front) is re-admitted.  The stage caches are NOT
/// carried — they are rebuilt by re-prefilling the prompt and replaying
/// `produced` as forced decode steps, which reconstructs the exact
/// winnowed state an uninterrupted run would hold.
struct Carry {
    produced: Vec<u32>,
    rng: Pcg64,
    stats: RequestStats,
    /// Admission-time compression level — resume must reuse it, not the
    /// group's current level, or the rebuilt cache would diverge.
    k_active: usize,
    /// When the eviction happened (feeds `swan_preempt_wait_seconds`).
    preempted_at: Instant,
    /// ITL clock carried through the preemption (see [`GroupSeq`]).
    last_token: Instant,
    /// Prefill flavor the sequence was admitted under (see
    /// [`GroupSeq::prefix_mode`]) — resume must reuse it even if the
    /// prefix toggle flipped in between.
    prefix_mode: bool,
}

/// Pipeline-only instruments, registered in the group's shared
/// [`Metrics`] registry so the `METRICS` exposition renders them next
/// to the engine-style series.
struct GroupObs {
    /// Per-iteration bubble: coordinator wall wait minus the chain's
    /// summed stage compute ([`GroupEvent::Stepped`]'s `compute_ns`) —
    /// the handoff/queueing overhead the pipeline adds.
    stage_bubble_seconds: Arc<Histogram>,
    /// Eviction-to-resume wall time per preemption.
    preempt_wait_seconds: Arc<Histogram>,
    /// Forced decode steps per resume — the per-event distribution of
    /// the cache-rebuild cost.  The running total lives in the shared
    /// `swan_replay_tokens` counter ([`Metrics::replay_tokens`]); the
    /// two series must keep distinct names (same registry, and the
    /// exporter drops kind-conflicting series).
    replay_tokens: Arc<Histogram>,
    /// Per-stage live command-queue depth (the bubble indicator).
    stage_depth: Vec<Arc<Gauge>>,
    /// Per-stage leased pool blocks (empty when the pool is off).
    stage_leased: Vec<Arc<Gauge>>,
    /// Pool internal fragmentation, whole percent.
    frag_percent: Arc<Gauge>,
}

impl GroupObs {
    fn register(registry: &Registry, n_stages: usize, pool_on: bool) -> GroupObs {
        let per_stage = |name: &'static str| -> Vec<Arc<Gauge>> {
            (0..n_stages).map(|i| registry.gauge(name, &[("stage", &i.to_string())])).collect()
        };
        GroupObs {
            stage_bubble_seconds: registry.histogram("swan_stage_bubble_seconds", &[]),
            preempt_wait_seconds: registry.histogram("swan_preempt_wait_seconds", &[]),
            replay_tokens: registry.histogram("swan_replay_tokens_per_resume", &[]),
            stage_depth: per_stage("swan_stage_queue_depth"),
            stage_leased: if pool_on { per_stage("swan_pool_blocks_leased") } else { Vec::new() },
            frag_percent: registry.gauge("swan_pool_frag_percent", &[]),
        }
    }
}

struct Group {
    id: usize,
    model: Arc<SwanModel>,
    cfg: ServeConfig,
    stages: Vec<StageHandle>,
    ev_rx: mpsc::Receiver<GroupEvent>,
    scheduler: Scheduler,
    metrics: Arc<Metrics>,
    obs: GroupObs,
    /// Retired-request traces, bounded; live traces ride the requests.
    traces: TraceRing,
    active: Vec<GroupSeq>,
    /// Per-request event channels: token stream (when `params.stream`)
    /// plus the terminal `Done`/`Error` — the group-side mirror of the
    /// engine's sink map.
    sinks: HashMap<u64, mpsc::Sender<Event>>,
    /// Compression level for newly admitted sequences.
    k_now: usize,
    next_id: u64,
    /// Per-stage block pools (`--pool`; empty otherwise).  Leases are
    /// elastic — the *group* block budget is enforced analytically via
    /// [`seq_blocks`], the pools just provide recycled storage and the
    /// leased-block gauges.
    stage_pools: Vec<Arc<BlockPool>>,
    /// Group-wide pool block budget (`usize::MAX` = unbounded).
    total_blocks: usize,
    /// Preempted sequences parked between eviction and re-admission,
    /// keyed by request id (the request itself waits at the scheduler
    /// front; the sink stays in `sinks`).
    preempted: HashMap<u64, Carry>,
    /// Cross-request prefix index (`--prefix-cache` / `SET prefix on`;
    /// `None` when prefix serving is off).  Requires the pool — entries
    /// pin pool blocks by refcount.
    prefix: Option<PrefixTree>,
}

impl Group {
    /// Per-token KV byte rates `(sparse, dense)` across the whole model
    /// at compression `k` — the same closed form engine shards use
    /// ([`crate::sparse::memory::token_byte_rates`]), summed over every
    /// stage's layer slice.
    fn token_byte_rates(&self, k: usize) -> (usize, usize) {
        let mc = &self.model.cfg;
        crate::sparse::memory::token_byte_rates(
            mc.n_layers,
            mc.n_kv_heads,
            mc.d_head,
            self.cfg.mode,
            k,
        )
    }

    /// Serving-accounting bytes one sequence holds across all stages.
    /// Exact (not an estimate): sequences keep their admission-time
    /// `k_active` for life, and the hybrid cache charges precisely this
    /// closed form per token (locked down by `prop_hybrid_cache_conserves
    /// _tokens`), so no stage round trip is needed.
    fn seq_bytes(&self, seq: &GroupSeq) -> usize {
        let tokens = seq.cached_tokens();
        let (sparse_b, dense_b) = self.token_byte_rates(seq.k_active);
        if self.cfg.dense_baseline {
            return tokens * dense_b;
        }
        let dense_tokens = tokens.min(self.cfg.buffer);
        dense_tokens * dense_b + (tokens - dense_tokens) * sparse_b
    }

    fn live_bytes(&self) -> usize {
        self.active.iter().map(|s| self.seq_bytes(s)).sum()
    }

    /// Whether this group serves out of the paged block pool.
    fn pool_on(&self) -> bool {
        !self.stage_pools.is_empty()
    }

    /// Pool blocks a sequence of `tokens` cached tokens accounts for
    /// across every stage (the analytic [`seq_blocks`] rate — exact, see
    /// `tests/pool.rs`).
    fn blocks_for_tokens(&self, tokens: usize) -> usize {
        let mc = &self.model.cfg;
        seq_blocks(tokens, self.cfg.buffer, self.cfg.block_tokens, mc.n_layers, mc.n_kv_heads)
    }

    /// Block-accounted live load (pool mode's admission unit).  A
    /// sequence attached to a prefix entry doesn't re-charge the full
    /// blocks it shares — those are charged once, via the tree
    /// ([`Group::prefix_charge`]).
    fn live_blocks(&self) -> usize {
        self.active
            .iter()
            .map(|s| self.blocks_for_tokens(s.cached_tokens()).saturating_sub(s.shared_blocks))
            .sum()
    }

    /// Analytic block charge the prefix tree holds against the group
    /// budget (0 when prefix serving is off).
    fn prefix_charge(&self) -> usize {
        self.prefix.as_ref().map_or(0, |t| t.total_charge())
    }

    /// Blocks physically leased right now, across every stage pool.
    fn leased_blocks(&self) -> usize {
        self.stage_pools.iter().map(|p| p.leased()).sum()
    }

    fn dense_equiv_bytes(&self) -> usize {
        let (_, dense_b) = self.token_byte_rates(0);
        self.active.iter().map(|s| s.cached_tokens() * dense_b).sum()
    }

    /// Dense window for admission projections: a dense-baseline sequence
    /// stores *every* token at the dense rate, not just the buffer.
    fn projection_buffer(&self) -> usize {
        if self.cfg.dense_baseline {
            usize::MAX
        } else {
            self.cfg.buffer
        }
    }

    /// Compression level a request will be admitted at (see
    /// [`request_k_for`]).
    fn request_k(&self, r: &Request) -> usize {
        request_k_for(r, self.model.cfg.d_head, self.k_now)
    }

    /// Projected KV load given already-computed `live` bytes (callers
    /// hold one `live_bytes()` walk per publish/stats render).  Each
    /// queued request projects at its *own* compression level.
    fn projected_load_bytes(&self, live: usize) -> usize {
        let buf = self.projection_buffer();
        let queued: usize = self
            .scheduler
            .queued()
            .map(|r| {
                let (sparse_b, dense_b) = self.token_byte_rates(self.request_k(r));
                Scheduler::projected_bytes(r.prompt.len(), r.params.max_new, sparse_b, dense_b, buf)
            })
            .sum();
        live + queued
    }

    fn has_work(&self) -> bool {
        !self.active.is_empty() || self.scheduler.queue_len() > 0
    }

    /// Pool internal fragmentation in percent: rows the active set
    /// actually holds vs the row capacity of every leased block (ring
    /// blocks lease whole up front; sparse tail blocks fill gradually).
    fn frag_percent(&self) -> f64 {
        let leased = self.leased_blocks();
        let mc = &self.model.cfg;
        let used_rows: usize = self
            .active
            .iter()
            .map(|s| 2 * mc.n_layers * mc.n_kv_heads * s.cached_tokens())
            .sum();
        let cap_rows = leased.saturating_mul(self.cfg.block_tokens);
        if cap_rows > 0 {
            100.0 * (1.0 - used_rows as f64 / cap_rows as f64)
        } else {
            0.0
        }
    }

    fn publish(&self, status: &ShardStatus) {
        let live = self.live_bytes();
        status.queued.store(self.scheduler.queue_len(), Ordering::Relaxed);
        status.active.store(self.active.len(), Ordering::Relaxed);
        status.live_bytes.store(live, Ordering::Relaxed);
        status.projected_bytes.store(self.projected_load_bytes(live), Ordering::Relaxed);
        status.k_active.store(self.k_now, Ordering::Relaxed);
        self.metrics.cache_bytes.set(live as u64);
        self.metrics.dense_equiv_bytes.set(self.dense_equiv_bytes() as u64);
        for (s, g) in self.stages.iter().zip(&self.obs.stage_depth) {
            g.set(s.status.queued.load(Ordering::Relaxed) as u64);
        }
        if self.pool_on() {
            self.metrics.pool_blocks_total.set(self.total_blocks as u64);
            self.metrics.pool_blocks_leased.set(self.leased_blocks() as u64);
            for (p, g) in self.stage_pools.iter().zip(&self.obs.stage_leased) {
                g.set(p.leased() as u64);
            }
            self.obs.frag_percent.set(self.frag_percent() as u64);
        }
        // block-granular placement signal: total 0 = no block accounting
        // (pool off or unbounded budget), so MemAware falls back to the
        // byte projection
        let (total, free) = if self.pool_on() && self.total_blocks != usize::MAX {
            let used = self.live_blocks() + self.prefix_charge();
            (self.total_blocks, self.total_blocks.saturating_sub(used))
        } else {
            (0, 0)
        };
        status.total_blocks.store(total, Ordering::Relaxed);
        status.free_blocks.store(free, Ordering::Relaxed);
        // cache-affinity fingerprints (cleared when prefix serving is
        // off, so the router never routes on stale entries)
        match &self.prefix {
            Some(tree) => *lock_recover(&status.prefix_fps) = tree.fingerprints(PREFIX_FP_CAP),
            None => lock_recover(&status.prefix_fps).clear(),
        }
    }

    /// Broadcast a retune to every stage and gather the acks; returns the
    /// applied (clamped) level.
    fn set_k_active(&mut self, k: usize) -> usize {
        let mut applied = k.clamp(1, self.model.cfg.d_head);
        let mut pending = Vec::with_capacity(self.stages.len());
        for s in &self.stages {
            let (ack_tx, ack_rx) = mpsc::channel();
            if s.send(StageCmd::SetK { k, ack: ack_tx }).is_ok() {
                pending.push(ack_rx);
            }
        }
        for rx in pending {
            if let Ok(a) = rx.recv() {
                applied = a;
            }
        }
        self.k_now = applied;
        self.metrics.k_active.set(applied as u64);
        applied
    }

    /// `TRACE <id>` lookup: retired ring first (newest wins), then the
    /// active set, then still-queued requests.
    fn trace_jsonl(&self, id: u64) -> Option<String> {
        self.traces
            .jsonl(id)
            .or_else(|| self.active.iter().find(|s| s.req.id == id).map(|s| s.req.trace.jsonl()))
            .or_else(|| self.scheduler.queued().find(|r| r.id == id).map(|r| r.trace.jsonl()))
    }

    /// Admit every currently-admissible request: push its prompt through
    /// the stage chain, sample the first token from the returned logits.
    fn admit(&mut self) -> anyhow::Result<()> {
        // cancelled-while-queued requests: purge and answer immediately.
        // A preempted sequence cancelled while waiting to resume answers
        // with everything it produced before preemption.
        for mut p in self.scheduler.take_cancelled() {
            let (tokens, mut stats) = match self.preempted.remove(&p.req.id) {
                Some(c) => (c.produced, c.stats),
                None => (Vec::new(), RequestStats::default()),
            };
            stats.queue_time += p.enqueued.elapsed();
            stats.cancelled = true;
            stats.clamped_from = p.req.clamped_from;
            // a queued purge is a cancellation AND a completion (every
            // submitted request resolves exactly once)
            self.metrics.requests_cancelled.inc();
            self.metrics.requests_completed.inc();
            if let Some(tx) = self.sinks.remove(&p.req.id) {
                let _ = tx.send(Event::Done(Response {
                    id: p.req.id,
                    text: decode_tokens(&tokens),
                    tokens,
                    stats,
                }));
            }
            p.req.trace.record(TraceKind::Retire);
            self.traces.push(p.req.trace);
        }
        loop {
            let pool_on = self.pool_on();
            // pool mode admits in BLOCK units against the block budget
            // (the scheduler's `mem_budget` was constructed in blocks);
            // the classic path projects bytes exactly as before.  The
            // prefix tree's analytic charge rides on the live side, so
            // cached-but-idle prefixes compete with admissions (and lose:
            // see `shed_prefix_for_admission`).
            let live = if pool_on {
                self.live_blocks() + self.prefix_charge()
            } else {
                self.live_bytes()
            };
            let buf = self.projection_buffer();
            // projection locals (the closure must not re-borrow self
            // while admit_next holds the scheduler mutably); each
            // request projects at its own (d_head-clamped) k
            let (nl, nkv, dh) = {
                let mc = &self.model.cfg;
                (mc.n_layers, mc.n_kv_heads, mc.d_head)
            };
            let mode = self.cfg.mode;
            let k_now = self.k_now;
            let (bt, buffer) = (self.cfg.block_tokens, self.cfg.buffer);
            let tree = self.prefix.as_ref();
            let proj = |req: &Request| {
                if pool_on {
                    // whole allocation granules for the full lifetime
                    // (prompt + requested output); k does not change the
                    // block count, only how full each sparse block is.
                    // A prompt whose prefix is cached shares its full
                    // winnowed blocks instead of re-leasing them — peek
                    // (no LRU commitment) and project the difference.
                    let tokens = req.prompt.len().max(1) + req.params.max_new;
                    let mut blocks = seq_blocks(tokens, buffer, bt, nl, nkv);
                    if let Some(t) = tree {
                        let prompt: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
                        let params = crate::swan::hybrid_cache::SwanParams::new(
                            request_k_for(req, dh, k_now),
                            buffer,
                            mode,
                        );
                        if let Some((_, depth)) =
                            t.peek_longest(prompt, cfg_key(&params, t.block_tokens()))
                        {
                            blocks = blocks
                                .saturating_sub(shared_full_blocks(depth, buffer, bt, nl, nkv));
                        }
                    }
                    blocks
                } else {
                    let k = request_k_for(req, dh, k_now);
                    let (sparse_b, dense_b) =
                        crate::sparse::memory::token_byte_rates(nl, nkv, dh, mode, k);
                    Scheduler::projected_bytes(
                        req.prompt.len(),
                        req.params.max_new,
                        sparse_b,
                        dense_b,
                        buf,
                    )
                }
            };
            let Some(pending) = self.scheduler.admit_next(self.active.len(), live, proj) else {
                // the queue head may be blocked on blocks the prefix
                // tree is hoarding: shed one cold entry and retry —
                // admissions always win over idle cached prefixes
                if self.shed_prefix_for_admission() {
                    continue;
                }
                break;
            };
            let queue_time = pending.enqueued.elapsed();
            let mut req = pending.req;
            let rid = req.id;
            self.metrics.queue_wait_seconds.record(queue_time);
            // a preempted sequence resumes at its admission-time k (a
            // retune between preemption and resume must not change the
            // rebuilt cache), fresh requests at the current level
            let carry = self.preempted.remove(&rid);
            let k_seq = match &carry {
                Some(c) => c.k_active,
                None => self.request_k(&req),
            };
            req.trace.record(if carry.is_some() { TraceKind::Resume } else { TraceKind::Admit });
            let t0 = Instant::now();
            let tokens: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
            // prefix serving decision: resumes rebuild via the flavor
            // they were admitted under (full per-token re-prefill, no
            // attach — the entry may have been evicted since, and the
            // per-token path reconstructs the identical state from the
            // tokens alone); fresh requests match the tree and run only
            // the uncached suffix
            let prefix_mode = match &carry {
                Some(c) => c.prefix_mode,
                None => self.prefix.is_some(),
            };
            let mut hit_depth = 0usize;
            let mut seq_entry: Option<u64> = None;
            let mut seq_shared = 0usize;
            let mut pending_insert: Option<(u64, usize, usize)> = None;
            let prefix_cmd: Option<PrefixPrefill> = if !prefix_mode {
                None
            } else if carry.is_some() {
                Some(PrefixPrefill { attach: None, start_pos: 0, insert: None })
            } else if let Some(tree) = self.prefix.as_mut() {
                let params = crate::swan::hybrid_cache::SwanParams::new(
                    k_seq,
                    self.cfg.buffer,
                    self.cfg.mode,
                );
                let bt = tree.block_tokens();
                let cfgk = cfg_key(&params, bt);
                let mc = &self.model.cfg;
                if let Some((key, depth)) = tree.match_longest(tokens, cfgk) {
                    hit_depth = depth;
                    seq_entry = Some(key);
                    seq_shared = shared_full_blocks(
                        depth,
                        self.cfg.buffer,
                        bt,
                        mc.n_layers,
                        mc.n_kv_heads,
                    );
                    self.metrics.prefix_hits.inc();
                    self.metrics.prefix_tokens_saved.add(depth as u64);
                    self.metrics.prefix_blocks_shared.add(seq_shared as u64);
                    req.trace.record(TraceKind::PrefixHit);
                } else {
                    self.metrics.prefix_misses.inc();
                }
                // insert marker: the deepest full-block prefix that
                // still leaves one suffix token, when it extends past
                // what the tree already holds; charged analytically
                let m = tree.insert_depth(tokens.len());
                if m > hit_depth {
                    if let Some(&ch) = chain_hashes(&tokens[..m], bt).last() {
                        let charge =
                            seq_blocks(m, self.cfg.buffer, bt, mc.n_layers, mc.n_kv_heads);
                        pending_insert = Some((entry_key(ch, cfgk), m, charge));
                    }
                }
                Some(PrefixPrefill {
                    attach: seq_entry,
                    start_pos: hit_depth,
                    insert: pending_insert.map(|(k, d, _)| (k, d)),
                })
            } else {
                None
            };
            let h = self.model.embed_prompt(&tokens[hit_depth..]);
            let prefilled: anyhow::Result<Vec<f32>> = match self.stages[0].send(
                StageCmd::Prefill { seq: rid, h, k_active: k_seq, prefix: prefix_cmd },
            ) {
                    Err(e) => Err(e),
                    Ok(()) => loop {
                        match self.ev_rx.recv() {
                            Ok(GroupEvent::Prefilled { seq, logits }) if seq == rid => {
                                break Ok(logits);
                            }
                            Ok(GroupEvent::StageFailed { stage }) => {
                                break Err(anyhow::anyhow!(
                                    "pipeline group {}: stage {stage} died",
                                    self.id
                                ));
                            }
                            Ok(_) => {
                                break Err(anyhow::anyhow!(
                                    "pipeline group {}: out-of-order prefill event",
                                    self.id
                                ));
                            }
                            Err(_) => {
                                break Err(anyhow::anyhow!(
                                    "pipeline group {}: stage chain died",
                                    self.id
                                ));
                            }
                        }
                    },
                };
            let logits = match prefilled {
                Ok(l) => l,
                Err(e) => {
                    // hand the request (and its carry) back before
                    // surfacing the failure: a supervised death extracts
                    // recovery payloads from the queue and the carry map,
                    // so the admission hop dying must not strand the one
                    // request it was admitting
                    if let Some(c) = carry {
                        self.preempted.insert(rid, c);
                    }
                    self.scheduler.requeue_front(req);
                    return Err(e);
                }
            };
            if let Some(mut c) = carry {
                // replay-resume: the prompt is back in the stage caches;
                // the tokens produced before preemption re-insert via
                // forced decode steps (see `decode_iteration`).  The
                // prefill-sampled first token was drawn (and delivered)
                // in the original pass — do not re-sample or re-emit, and
                // do not record a second TTFT.
                c.stats.queue_time += queue_time;
                let re_prefill = t0.elapsed();
                c.stats.prefill_time += re_prefill;
                self.metrics.prefill_ns.record(re_prefill.as_nanos() as f64);
                self.metrics.prefill_seconds.record(re_prefill);
                self.metrics.prefill_tokens.add(tokens.len() as u64);
                self.obs.preempt_wait_seconds.record(c.preempted_at.elapsed());
                let mut replay: VecDeque<u32> = c.produced.iter().copied().collect();
                let next_token =
                    // lint: allow(panic, "preemption only evicts running sequences, which hold >= 1 produced token by the admission contract; a violation is coordinator state corruption the supervisor recovers from")
                replay.pop_front().expect("a preempted sequence produced >= 1 token");
                self.obs.replay_tokens.record_value(replay.len() as u64);
                self.active.push(GroupSeq {
                    rng: c.rng,
                    produced: c.produced,
                    next_token,
                    replay,
                    stats: c.stats,
                    k_active: k_seq,
                    prompt_len: tokens.len(),
                    last_token: c.last_token,
                    prefix_mode,
                    prefix_entry: None,
                    shared_blocks: 0,
                    pending_insert: None,
                    finished: false,
                    req,
                });
                continue;
            }
            let mut stats =
                RequestStats { queue_time, clamped_from: req.clamped_from, ..Default::default() };
            stats.prefill_time = t0.elapsed();
            self.metrics.prefill_ns.record(stats.prefill_time.as_nanos() as f64);
            self.metrics.prefill_seconds.record(stats.prefill_time);
            // a prefix hit prefills only the uncached suffix
            self.metrics.prefill_tokens.add((tokens.len() - hit_depth) as u64);
            // first token samples from the prefill logits on this path
            // too, so TTFT = queue wait + prefill
            stats.ttft_ns = (queue_time + stats.prefill_time).as_nanos() as u64;
            self.metrics.ttft_seconds.record_ns(stats.ttft_ns);
            req.trace.record(TraceKind::PrefillDone);
            let next_token =
                sample(&logits, &req.params, &[], &mut Pcg64::new(req.seed_base()));
            req.trace.record(TraceKind::FirstToken);
            if req.params.stream {
                if let Some(tx) = self.sinks.get(&rid) {
                    let _ = tx.send(Event::Token {
                        id: rid,
                        index: 0,
                        token: next_token,
                        text: decode_tokens(&[next_token]),
                    });
                }
            }
            self.active.push(GroupSeq {
                rng: Pcg64::new(req.seed_base() ^ x5wan_seed()),
                produced: vec![next_token],
                next_token,
                stats,
                k_active: k_seq,
                prompt_len: tokens.len(),
                replay: VecDeque::new(),
                last_token: Instant::now(),
                prefix_mode,
                prefix_entry: seq_entry,
                shared_blocks: seq_shared,
                pending_insert,
                finished: false,
                req,
            });
        }
        Ok(())
    }

    /// Preempt one running sequence to free its pool blocks: carry its
    /// coordinator state aside, drop its stage caches (the Retire hop
    /// releases every leased block), requeue its request at the
    /// scheduler front, keep its sink.  On re-admission the carried
    /// tokens replay as forced decode steps, so a resumed sequence's
    /// output is bit-identical to an uninterrupted run.  Safe even for a
    /// sequence that was itself mid-replay: `produced` and `rng` are
    /// not touched while replaying, so the carry is always consistent.
    fn preempt(&mut self, idx: usize) -> anyhow::Result<()> {
        let mut seq = self.active.remove(idx);
        let id = seq.req.id;
        self.metrics.requests_preempted.inc();
        seq.stats.preemptions += 1;
        seq.req.trace.record(TraceKind::Preempt);
        self.preempted.insert(
            id,
            Carry {
                produced: seq.produced,
                rng: seq.rng,
                stats: seq.stats,
                k_active: seq.k_active,
                preempted_at: Instant::now(),
                last_token: seq.last_token,
                prefix_mode: seq.prefix_mode,
            },
        );
        self.scheduler.requeue_front(seq.req);
        // the Retire hop runs AFTER the hand-back: if a stage is already
        // dead this surfaces the error with the sequence safely parked in
        // the queue + carry map, where a supervised death will extract it.
        // `insert` stays empty: a preempted sequence's parked prefix
        // capture dies with its stage caches (resume does not re-insert)
        for s in &self.stages {
            s.send(StageCmd::Retire { seqs: vec![id], insert: Vec::new() })?;
        }
        Ok(())
    }

    /// Supervised-death / drain-timeout extraction: every in-flight,
    /// preempted and queued request leaves as a recovery payload the
    /// supervisor re-places on a healthy shard.  Called only at
    /// iteration boundaries or after a failed step — both consistent
    /// points (no sequence is mid-commit), so `produced`/`rng` describe
    /// exactly the tokens the client has seen and the payload resumes
    /// bit-identically elsewhere.
    fn extract_work(&mut self) -> Vec<RecoveredReq> {
        let mut out = Vec::new();
        for mut seq in self.active.drain(..) {
            seq.req.trace.record(TraceKind::Die);
            let sink = self.sinks.remove(&seq.req.id);
            out.push(RecoveredReq {
                produced: seq.produced,
                rng: seq.rng,
                stats: seq.stats,
                k_active: seq.k_active,
                sink,
                req: seq.req,
            });
        }
        // queued requests: preempted carries resume where they left off,
        // never-prefilled ones are plain re-submissions
        for mut req in self.scheduler.take_all() {
            req.trace.record(TraceKind::Die);
            let sink = self.sinks.remove(&req.id);
            out.push(match self.preempted.remove(&req.id) {
                Some(c) => RecoveredReq {
                    produced: c.produced,
                    rng: c.rng,
                    stats: c.stats,
                    k_active: c.k_active,
                    sink,
                    req,
                },
                None => RecoveredReq::fresh(req, sink),
            });
        }
        out
    }

    /// Accept a recovery payload from the supervisor (a request pulled
    /// off a dead or draining shard): park it as a carry and requeue at
    /// the scheduler front, so re-admission runs the preemption-resume
    /// machinery — re-prefill, then forced replay of the committed
    /// tokens — generalized cross-shard.
    fn recover(&mut self, rec: RecoveredReq) {
        let RecoveredReq { mut req, produced, rng, mut stats, k_active, sink } = rec;
        self.next_id = self.next_id.max(req.id) + 1;
        req.trace.record(TraceKind::Recover);
        self.metrics.requests_recovered.inc();
        if let Some(tx) = sink {
            self.sinks.insert(req.id, tx);
        }
        if produced.is_empty() {
            // never prefilled on the dead shard: a plain re-run
            self.scheduler.enqueue(req);
            return;
        }
        stats.recoveries += 1;
        // resume at the admission-time compression level of the original
        // shard (0 = engine didn't pin one; derive from the request), or
        // the rebuilt cache would diverge from the one that died
        let k = if k_active > 0 {
            k_active.clamp(1, self.model.cfg.d_head)
        } else {
            self.request_k(&req)
        };
        self.preempted.insert(
            req.id,
            Carry {
                produced,
                rng,
                stats,
                k_active: k,
                preempted_at: Instant::now(),
                last_token: Instant::now(),
                // the prefix toggle is fleet-uniform (--prefix-cache /
                // broadcast SET prefix), so the receiving group's mode
                // matches the flavor the dead shard prefilled under
                prefix_mode: self.prefix.is_some(),
            },
        );
        self.scheduler.requeue_front(req);
    }

    /// Supervised death: mark Dead, extract all work, hand it to the
    /// supervisor for re-placement on healthy shards.
    fn die(&mut self, status: &ShardStatus, fleet: &mpsc::Sender<FleetEvent>, reason: String) {
        status.set_state(ShardState::Dead);
        let recovered = self.extract_work();
        log::error!(
            "pipeline group {} died ({reason}); handing {} request(s) to the supervisor",
            self.id,
            recovered.len()
        );
        self.publish(status);
        let _ = fleet.send(FleetEvent::ShardDead { id: self.id, reason, recovered });
    }

    /// Live budget retune (elastic scale events rebalance the fleet
    /// budget across the surviving shards): classic mode takes bytes
    /// straight; pool mode re-derives the group block budget at the
    /// current compression level.  Stage pool *targets* stay as
    /// launched — they are gauges, leases are elastic, and the budget
    /// is enforced analytically by the coordinator.
    fn set_mem_budget(&mut self, bytes: usize) {
        if self.pool_on() {
            let mc = &self.model.cfg;
            let total = pool_blocks_for_budget(
                bytes,
                self.cfg.block_tokens,
                mc.d_head,
                self.cfg.mode,
                self.k_now,
            );
            self.total_blocks = total;
            self.scheduler.set_mem_budget(if total == usize::MAX { 0 } else { total });
        } else {
            self.scheduler.set_mem_budget(bytes);
        }
    }

    /// Evict the least-recently-used prefix entry not attached by any
    /// running sequence and broadcast the eviction to the stages (their
    /// stores drop the pinned blocks).  Returns `false` when there is
    /// nothing evictable — prefix off, tree empty, or every entry
    /// attached (evicting those frees nothing until the sequences
    /// retire, so the sweeper skips them).
    fn evict_coldest_prefix_entry(&mut self) -> bool {
        let attached: Vec<u64> = self.active.iter().filter_map(|s| s.prefix_entry).collect();
        let Some(tree) = self.prefix.as_mut() else {
            return false;
        };
        let Some(key) = tree.lru_key_excluding(&attached) else {
            return false;
        };
        tree.remove(key);
        self.metrics.prefix_evictions.inc();
        for s in &self.stages {
            let _ = s.send(StageCmd::PrefixEvict { entries: vec![key] });
        }
        true
    }

    /// Admission-side prefix shed: when the queue head projects past the
    /// block budget while the tree still holds cold entries, evict one
    /// so the retried admission can fit.  Bounded — every call that
    /// returns `true` shrinks the tree by one entry.
    fn shed_prefix_for_admission(&mut self) -> bool {
        if !self.pool_on()
            || self.total_blocks == usize::MAX
            || self.active.len() >= self.cfg.max_batch
        {
            return false;
        }
        if self.prefix.as_ref().map_or(true, |t| t.is_empty()) {
            return false;
        }
        let head_over = match self.scheduler.queued().next() {
            Some(r) => {
                let tokens = r.prompt.len().max(1) + r.params.max_new;
                let proj = self.blocks_for_tokens(tokens);
                self.live_blocks() + self.prefix_charge() + proj > self.total_blocks
            }
            None => false,
        };
        head_over && self.evict_coldest_prefix_entry()
    }

    /// Live prefix toggle (`SET prefix on|off`).  Turning it on requires
    /// the paged pool (prefix entries pin pool blocks) — a group
    /// launched without `--pool`/`--prefix-cache` answers `false` and
    /// stays unchanged.  Turning it off flushes the tree, releases every
    /// stage-side pinned block, and detaches running sequences from
    /// their shared-block accounting (physically shared blocks stay
    /// alive until the last holder retires).
    fn set_prefix(&mut self, on: bool) -> bool {
        if on {
            if !self.pool_on() || self.cfg.dense_baseline {
                return false;
            }
            if self.prefix.is_none() {
                self.prefix = Some(PrefixTree::new(self.cfg.block_tokens));
            }
            true
        } else {
            if let Some(mut tree) = self.prefix.take() {
                let keys = tree.flush();
                if !keys.is_empty() {
                    self.metrics.prefix_evictions.add(keys.len() as u64);
                    for s in &self.stages {
                        let _ = s.send(StageCmd::PrefixEvict { entries: keys.clone() });
                    }
                }
                for seq in &mut self.active {
                    seq.shared_blocks = 0;
                    seq.prefix_entry = None;
                    seq.pending_insert = None;
                }
            }
            true
        }
    }

    /// One decode iteration: forward the whole ready set down the chain,
    /// sample from the last stage's logits, retire finished sequences.
    fn decode_iteration(&mut self) -> anyhow::Result<()> {
        // mark sequences that already hit their budget / stop token /
        // cancel flag — a flipped token retires the sequence this
        // iteration (the stage caches drop via the Retire hop below)
        // without touching its co-batched neighbours
        for seq in &mut self.active {
            if seq.req.cancel.is_cancelled() {
                seq.finished = true;
            }
            if seq.produced.len() >= seq.req.params.max_new {
                seq.finished = true;
            }
            if let Some(stop) = seq.req.params.stop {
                if seq.next_token == stop {
                    seq.finished = true;
                }
            }
        }

        // pool mode: this iteration's appends grow every running
        // sequence by one token — if that projects past the group's
        // block budget, preempt the youngest running sequence(s) and
        // requeue them instead of failing.  One running sequence is
        // always allowed through, however large: with nothing else to
        // evict, progress beats the budget (the same liveness call the
        // admission-side idle escape makes), so preemption can at worst
        // serialize the batch, never wedge it.
        if self.pool_on() && self.total_blocks != usize::MAX {
            loop {
                let after: usize = self
                    .active
                    .iter()
                    .map(|s| {
                        let grow = usize::from(!s.finished);
                        self.blocks_for_tokens(s.cached_tokens() + grow)
                            .saturating_sub(s.shared_blocks)
                    })
                    .sum::<usize>()
                    + self.prefix_charge();
                if after <= self.total_blocks {
                    break;
                }
                // shed cold prefix entries FIRST: reclaiming a cached but
                // unattached prefix costs a future warm hit, preempting a
                // running sequence costs a full replay — strictly worse
                if self.evict_coldest_prefix_entry() {
                    continue;
                }
                let running: Vec<usize> =
                    (0..self.active.len()).filter(|&i| !self.active[i].finished).collect();
                if running.len() <= 1 {
                    break;
                }
                // youngest evictable victim: skip sequences that already
                // burned their MAX_PREEMPTIONS budget (fairness — see the
                // constant's docs), falling back to the absolute youngest
                // when every runner has hit the cap (liveness beats the
                // cap: the loop must still converge on a batch that fits)
                let victim = running
                    .iter()
                    .rev()
                    .copied()
                    .find(|&i| self.active[i].stats.preemptions < MAX_PREEMPTIONS)
                    // lint: allow(panic, "running.len() > 1 is guaranteed by the break two lines up, so last() is always Some")
                    .unwrap_or(*running.last().unwrap());
                self.preempt(victim)?;
            }
        }

        let ready: Vec<usize> =
            (0..self.active.len()).filter(|&i| !self.active[i].finished).collect();
        if !ready.is_empty() {
            let ids: Vec<u64> = ready.iter().map(|&i| self.active[i].req.id).collect();
            let toks: Vec<u32> = ready.iter().map(|&i| self.active[i].next_token).collect();
            let t0 = Instant::now();
            self.stages[0].send(StageCmd::Forward {
                seqs: ids.clone(),
                tokens: toks,
                h: Vec::new(),
                compute_ns: 0,
            })?;
            let (logits, compute_ns) = loop {
                match self.ev_rx.recv() {
                    Ok(GroupEvent::Stepped { seqs, logits, compute_ns }) => {
                        anyhow::ensure!(seqs == ids, "pipeline group {}: iteration mismatch", self.id);
                        break (logits, compute_ns);
                    }
                    Ok(GroupEvent::StageFailed { stage }) => {
                        anyhow::bail!("pipeline group {}: stage {stage} died", self.id)
                    }
                    Ok(_) => anyhow::bail!("pipeline group {}: out-of-order step event", self.id),
                    Err(_) => anyhow::bail!("pipeline group {}: stage chain died", self.id),
                }
            };
            // full-chain latency; charged to every sequence of the
            // iteration (a pipeline shares its step wall-clock).  The
            // wall wait minus the chain's summed compute is this
            // iteration's bubble — handoff + stage-queue overhead.
            let step_time = t0.elapsed();
            let bubble_ns = (step_time.as_nanos() as u64).saturating_sub(compute_ns);
            self.obs.stage_bubble_seconds.record_ns(bubble_ns);
            for (&i, l) in ready.iter().zip(&logits) {
                let seq = &mut self.active[i];
                if let Some(tok) = seq.replay.pop_front() {
                    // replay-resume: this forward re-inserted an
                    // already-produced token, and the following token
                    // was sampled before preemption too — take it from
                    // the replay queue.  No rng draw, no produced push,
                    // no emission, no stats: the original pass already
                    // did all of that.
                    seq.next_token = tok;
                    self.metrics.replay_tokens.inc();
                    continue;
                }
                let next = sample(l, &seq.req.params, &seq.produced, &mut seq.rng);
                seq.next_token = next;
                seq.produced.push(next);
                if seq.req.params.stream {
                    if let Some(tx) = self.sinks.get(&seq.req.id) {
                        let _ = tx.send(Event::Token {
                            id: seq.req.id,
                            index: seq.produced.len() - 1,
                            token: next,
                            text: decode_tokens(&[next]),
                        });
                    }
                }
                // ITL commit accounting: the gap since the previous
                // committed token (spans preemptions), all lock-free
                let gap_ns = seq.last_token.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                seq.last_token = Instant::now();
                seq.stats.decode_steps += 1;
                seq.stats.decode_time += step_time;
                seq.stats.itl_sum_ns += gap_ns;
                seq.stats.itl_max_ns = seq.stats.itl_max_ns.max(gap_ns);
                seq.req.trace.record(TraceKind::Decode);
                self.metrics.itl_seconds.record_ns(gap_ns);
                self.metrics.decode_tokens.inc();
            }
            self.metrics.decode_step_ns.record(step_time.as_nanos() as f64);
            self.metrics.decode_step_seconds.record(step_time);
            let (_, dense_b) = self.token_byte_rates(0);
            for &i in &ready {
                let bytes = self.seq_bytes(&self.active[i]);
                let seq = &mut self.active[i];
                seq.stats.peak_cache_bytes = seq.stats.peak_cache_bytes.max(bytes);
                seq.stats.dense_equiv_bytes = seq.cached_tokens() * dense_b;
            }
        }

        // retire finished sequences (submission order preserved)
        if self.active.iter().any(|s| s.finished) {
            let mut done_ids = Vec::new();
            let mut insert_ids = Vec::new();
            let mut keep = Vec::with_capacity(self.active.len());
            for mut seq in self.active.drain(..) {
                if seq.finished {
                    done_ids.push(seq.req.id);
                    // commit the prefix insert decided at admission: the
                    // tree entry lands only if it's NEW (a concurrent
                    // sequence may have inserted the same prefix first —
                    // `insert` returning false dedups, and the stages
                    // then discard their parked captures)
                    if let Some((key, depth, charge)) = seq.pending_insert {
                        if let Some(tree) = self.prefix.as_mut() {
                            if depth <= seq.req.prompt.len()
                                && tree.insert(key, &seq.req.prompt[..depth], charge)
                            {
                                insert_ids.push(seq.req.id);
                            }
                        }
                    }
                    if seq.req.cancel.is_cancelled() {
                        // a mid-decode cancel is a cancellation AND a
                        // completion, mirroring the queued-purge path
                        self.metrics.requests_cancelled.inc();
                    }
                    self.metrics.requests_completed.inc();
                    seq.req.trace.record(TraceKind::Retire);
                    self.traces.push(seq.req.trace.clone());
                    let mut stats = seq.stats;
                    stats.cancelled = seq.req.cancel.is_cancelled();
                    let resp = Response {
                        id: seq.req.id,
                        text: decode_tokens(&seq.produced),
                        tokens: seq.produced,
                        stats,
                    };
                    if let Some(tx) = self.sinks.remove(&resp.id) {
                        let _ = tx.send(Event::Done(resp));
                    }
                } else {
                    keep.push(seq);
                }
            }
            self.active = keep;
            for s in &self.stages {
                let _ = s.send(StageCmd::Retire {
                    seqs: done_ids.clone(),
                    insert: insert_ids.clone(),
                });
            }
        }
        Ok(())
    }

    /// Render the group's STATS block: header, per-stage lines (queue
    /// depth = the bubble indicator), engine-style metrics.
    fn stats_block(&self) -> String {
        use crate::sparse::memory::human_bytes;
        let live = self.live_bytes();
        let mut out = format!(
            "shard {}: pipeline stages={} k_active={} queued={} active={} kv={} projected={}\n",
            self.id,
            self.stages.len(),
            self.k_now,
            self.scheduler.queue_len(),
            self.active.len(),
            human_bytes(live),
            human_bytes(self.projected_load_bytes(live)),
        );
        if self.pool_on() {
            let leased = self.leased_blocks();
            let frag = self.frag_percent();
            let budget = if self.total_blocks == usize::MAX {
                "unbounded".to_string()
            } else {
                self.total_blocks.to_string()
            };
            out.push_str(&format!(
                "  pool: blocks leased={leased}/{budget} bt={} frag={frag:.1}% preempted_live={}\n",
                self.cfg.block_tokens,
                self.preempted.len(),
            ));
        }
        if let Some(tree) = &self.prefix {
            let hits = self.metrics.prefix_hits.get();
            let misses = self.metrics.prefix_misses.get();
            let rate = if hits + misses > 0 {
                100.0 * hits as f64 / (hits + misses) as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "  prefix: entries={} charge_blocks={} hits={hits} misses={misses} hit_rate={rate:.1}% tokens_saved={}\n",
                tree.len(),
                tree.total_charge(),
                self.metrics.prefix_tokens_saved.get(),
            ));
        }
        let mut pending = Vec::with_capacity(self.stages.len());
        for s in &self.stages {
            let (tx, rx) = mpsc::channel();
            if s.send(StageCmd::Stats { reply: tx }).is_ok() {
                pending.push(rx);
            }
        }
        for rx in pending {
            if let Ok(line) = rx.recv() {
                out.push_str("  ");
                out.push_str(&line);
            }
        }
        for line in self.metrics.snapshot().lines() {
            out.push_str("  ");
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    fn shutdown(&mut self) {
        for s in &self.stages {
            let _ = s.send(StageCmd::Shutdown);
        }
        for s in &mut self.stages {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// The coordinator thread: the pipeline-group analogue of `shard_loop`.
/// With a fleet hook, every abnormal exit — stage death, coordinator
/// panic, injected fault, drain timeout — extracts the group's work and
/// hands it to the supervisor instead of failing the waiters.
fn group_loop(
    mut g: Group,
    rx: mpsc::Receiver<ShardCmd>,
    status: &ShardStatus,
    hooks: ShardHooks,
) {
    let mut iter: u64 = 0;
    let mut drain_deadline: Option<Instant> = None;
    loop {
        // chaos: scripted or externally-triggered coordinator kill,
        // checked at the iteration boundary — a consistent point (no
        // sequence is mid-commit), so the extracted payloads are exact
        if let Some(plan) = hooks.plan.as_deref() {
            if plan.coordinator_dies(iter) {
                if let Some(fleet) = &hooks.fleet {
                    g.die(status, fleet, "chaos: injected coordinator kill".to_string());
                }
                return g.shutdown();
            }
        }
        iter += 1;
        // drain commands (non-blocking while busy, blocking when idle)
        loop {
            let cmd = if g.has_work() {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return g.shutdown(),
                }
            } else if drain_deadline.is_some() {
                // draining and idle: fall through to the completion check
                break;
            } else {
                g.publish(status);
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return g.shutdown(),
                }
            };
            match cmd {
                ShardCmd::Gen { mut req, reply } => {
                    if req.id == 0 {
                        req.id = g.next_id;
                    }
                    g.next_id = g.next_id.max(req.id) + 1;
                    // same hard cap the engine shards enforce, equally
                    // surfaced (never silent)
                    req.clamp_max_new(g.cfg.max_new_hard_cap());
                    g.metrics.requests_submitted.inc();
                    req.trace.begin(req.id);
                    g.sinks.insert(req.id, reply);
                    g.scheduler.enqueue(req);
                    g.publish(status);
                }
                ShardCmd::Cancel { id } => {
                    if let Some(seq) = g.active.iter().find(|s| s.req.id == id) {
                        seq.req.cancel.cancel();
                    } else {
                        g.scheduler.cancel(id);
                    }
                }
                ShardCmd::SetK { k, ack } => {
                    let applied = g.set_k_active(k);
                    status.k_active.store(applied, Ordering::Relaxed);
                    let _ = ack.send(applied);
                }
                ShardCmd::SetPrefix { on, ack } => {
                    let _ = ack.send(g.set_prefix(on));
                    g.publish(status);
                }
                ShardCmd::Stats { reply } => {
                    let _ = reply.send(g.stats_block());
                }
                ShardCmd::Trace { id, reply } => {
                    let _ = reply.send(g.trace_jsonl(id));
                }
                ShardCmd::Recover(rec) => {
                    g.recover(*rec);
                    g.publish(status);
                }
                ShardCmd::Drain { timeout } => {
                    status.set_state(ShardState::Draining);
                    drain_deadline = Some(Instant::now() + timeout);
                    g.publish(status);
                }
                ShardCmd::SetMemBudget(bytes) => {
                    g.set_mem_budget(bytes);
                    g.publish(status);
                }
                ShardCmd::Crash => {
                    if let Some(fleet) = &hooks.fleet {
                        g.die(status, fleet, "crash command".to_string());
                    }
                    return g.shutdown();
                }
                ShardCmd::Shutdown => return g.shutdown(),
            }
        }
        if let Some(deadline) = drain_deadline {
            if !g.has_work() {
                // drained clean: every in-flight request finished locally
                status.set_state(ShardState::Dead);
                g.publish(status);
                if let Some(fleet) = &hooks.fleet {
                    let _ =
                        fleet.send(FleetEvent::ShardDrained { id: g.id, migrated: Vec::new() });
                }
                return g.shutdown();
            }
            if Instant::now() >= deadline {
                // drain timeout: migrate the stragglers via the recovery
                // path — they resume bit-identically on healthy shards
                status.set_state(ShardState::Dead);
                let migrated = g.extract_work();
                g.publish(status);
                if let Some(fleet) = &hooks.fleet {
                    let _ = fleet.send(FleetEvent::ShardDrained { id: g.id, migrated });
                }
                return g.shutdown();
            }
        }
        let step = catch_unwind(AssertUnwindSafe(|| g.admit().and_then(|()| g.decode_iteration())));
        match step {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if let Some(fleet) = &hooks.fleet {
                    g.die(status, fleet, format!("{e:#}"));
                    return g.shutdown();
                }
                log::error!("pipeline group {}: {e:#}", g.id);
                // unsupervised: the stage chain is unrecoverable — fail
                // every waiter and stop (the pre-fleet behavior)
                for (rid, tx) in g.sinks.drain() {
                    let _ = tx.send(Event::Error {
                        id: rid,
                        message: format!("request lost: pipeline group {} failed: {e:#}", g.id),
                    });
                }
                return g.shutdown();
            }
            Err(payload) => {
                if let Some(fleet) = &hooks.fleet {
                    g.die(status, fleet, panic_reason(payload.as_ref()));
                    return g.shutdown();
                }
                std::panic::resume_unwind(payload);
            }
        }
        g.publish(status);
    }
}

/// Launch one pipeline group of `cfg.pipeline` stages over `model` and
/// return it as a router-compatible [`ShardHandle`].  `cfg.mem_budget`
/// must already be this group's slice of the fleet budget; each stage's
/// share of it follows its layer count by construction (the stage only
/// holds caches for its own layers).
pub fn launch_group(
    id: usize,
    model: Arc<SwanModel>,
    cfg: &ServeConfig,
) -> anyhow::Result<ShardHandle> {
    launch_group_with(id, model, cfg, ShardHooks::default())
}

/// [`launch_group`] with supervision wiring: a fleet-event hook (the
/// router's supervisor re-places extracted work on death/drain) and an
/// optional chaos [`crate::shard::FaultPlan`].
pub fn launch_group_with(
    id: usize,
    model: Arc<SwanModel>,
    cfg: &ServeConfig,
    hooks: ShardHooks,
) -> anyhow::Result<ShardHandle> {
    let ranges = partition_layers(model.cfg.n_layers, cfg.pipeline.max(1))?;
    let k_now = cfg.k_active.clamp(1, model.cfg.d_head);

    // metrics come first: the stage pools register their latency
    // instruments in the same registry the METRICS verb renders
    let metrics = Arc::new(Metrics::default());
    metrics.k_active.set(k_now as u64);

    // paged pool mode: size the group's block budget from its byte
    // budget at the configured compression (Eq. 1 worst-of sparse/dense
    // per block row), then give each stage its own pool with a target
    // proportional to its layer count.  Targets are gauges — leases are
    // elastic, and the budget is enforced analytically by the group
    // coordinator in block units.  Prefix caching implies the pool:
    // prefix entries ARE shared pool blocks.
    let pool_on = (cfg.pool || cfg.prefix) && !cfg.dense_baseline;
    let (stage_pools, total_blocks) = if pool_on {
        let mc = &model.cfg;
        let total =
            pool_blocks_for_budget(cfg.mem_budget, cfg.block_tokens, mc.d_head, cfg.mode, k_now);
        let pools: Vec<Arc<BlockPool>> = ranges
            .iter()
            .enumerate()
            .map(|(s, r)| {
                let target = if total == usize::MAX {
                    usize::MAX
                } else {
                    (total / mc.n_layers).saturating_mul(r.len()).max(1)
                };
                Arc::new(BlockPool::with_obs(target, PoolObs::register(&metrics.registry, s)))
            })
            .collect();
        (pools, total)
    } else {
        (Vec::new(), usize::MAX)
    };

    // build the chain back to front so every stage knows its downstream
    let (ev_tx, ev_rx) = mpsc::channel();
    let mut stages: Vec<StageHandle> = Vec::with_capacity(ranges.len());
    let mut next: Option<(mpsc::Sender<StageCmd>, Arc<StageStatus>)> = None;
    for (s, layers) in ranges.iter().enumerate().rev() {
        let (tx, rx) = mpsc::channel();
        let status = Arc::new(StageStatus::default());
        status.k_active.store(k_now, Ordering::Relaxed);
        let downstream = match next.take() {
            Some((ntx, nst)) => Downstream::Stage(ntx, nst),
            None => Downstream::Coordinator(ev_tx.clone()),
        };
        let ctx = StageCtx {
            group: id,
            stage: s,
            layers: layers.clone(),
            model: model.clone(),
            cfg: cfg.clone(),
            next: downstream,
            status: status.clone(),
            events: ev_tx.clone(),
            block_pool: stage_pools.get(s).cloned(),
            faults: StageFaults::new(hooks.plan.clone()),
        };
        let join = std::thread::Builder::new()
            .name(format!("swan-stage-{id}-{s}"))
            .spawn(move || stage_loop(ctx, rx))
            // lint: allow(panic, "group bring-up, before the handle joins the fleet: no request has been placed on a group whose stages never spawned")
            .expect("spawning pipeline stage thread");
        next = Some((tx.clone(), status.clone()));
        stages.push(StageHandle { tx, status, join: Some(join) });
    }
    stages.reverse();

    // pool mode admits in BLOCK units (0 = unbounded either way)
    let sched_budget = if pool_on {
        if total_blocks == usize::MAX { 0 } else { total_blocks }
    } else {
        cfg.mem_budget
    };
    let mut scheduler = Scheduler::new(cfg.max_batch, sched_budget);
    scheduler.set_lookahead(cfg.admit_lookahead);
    if cfg.decode_workers > 0 {
        scheduler.set_decode_slots(cfg.decode_workers * DECODE_SLOTS_PER_WORKER);
    }
    let obs = GroupObs::register(&metrics.registry, ranges.len(), pool_on);
    let group = Group {
        id,
        model,
        cfg: cfg.clone(),
        stages,
        ev_rx,
        scheduler,
        metrics: metrics.clone(),
        obs,
        traces: TraceRing::new(TRACE_RING_CAP),
        active: Vec::new(),
        sinks: HashMap::new(),
        k_now,
        next_id: 1,
        stage_pools,
        total_blocks,
        preempted: HashMap::new(),
        prefix: if pool_on && cfg.prefix {
            Some(PrefixTree::new(cfg.block_tokens))
        } else {
            None
        },
    };

    let status = Arc::new(ShardStatus::default());
    status.k_active.store(k_now, Ordering::Relaxed);
    let (tx, rx) = mpsc::channel();
    let thread_status = status.clone();
    let join = std::thread::Builder::new()
        .name(format!("swan-pipegroup-{id}"))
        .spawn(move || group_loop(group, rx, &thread_status, hooks))
        // lint: allow(panic, "group bring-up, before the handle joins the fleet: no request has been placed on a group whose coordinator never spawned")
        .expect("spawning pipeline group thread");
    Ok(ShardHandle::from_parts(id, tx, status, metrics, Some(join)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_contiguously_and_balances() {
        for (nl, ns) in [(4usize, 1usize), (4, 2), (5, 2), (7, 3), (8, 4), (3, 3)] {
            let ranges = partition_layers(nl, ns).unwrap();
            assert_eq!(ranges.len(), ns);
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, nl);
            for w in ranges.windows(2) {
                assert_eq!(w[0].end, w[1].start, "ranges must be contiguous");
            }
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "stage loads differ by more than one layer: {lens:?}");
            assert!(lens.iter().all(|&l| l >= 1));
        }
    }

    #[test]
    fn partition_rejects_more_stages_than_layers() {
        assert!(partition_layers(2, 3).is_err());
        assert!(partition_layers(4, 0).is_err());
    }

    // End-to-end pipeline-vs-single-shard bit-identity lives in
    // rust/tests/pipeline.rs (synthetic model, no artifacts needed).
}
