//! Multi-shard serving: N independent engines behind one router.
//!
//! One [`crate::coordinator::Engine`] owns one thread, one
//! [`crate::coordinator::scheduler::Scheduler`], one
//! `swan::batch::WorkerPool` and one KV memory budget — which caps the
//! stack at a single host-thread's worth of decode.  This subsystem is
//! the layer between the wire protocol and the engine that removes the
//! cap:
//!
//! * [`shard::ShardHandle`] — one engine on its own thread, driven by a
//!   command channel, publishing a lock-free [`shard::ShardStatus`] load
//!   view (queued / active / projected KV bytes / current `k_active`);
//! * [`balance::BalancePolicy`] — pluggable placement over
//!   [`ShardSnapshot`]s: [`balance::RoundRobin`], [`balance::LeastQueued`]
//!   and [`balance::MemAware`] (routes by the projected KV bytes each
//!   shard's scheduler reports);
//! * [`router::Router`] — places `GEN` on one shard and fans `SET
//!   k_active` / `STATS` out to every shard (broadcast + gather), which
//!   is what makes SWAN's compression knob *fleet-wide* and live: one
//!   wire command retunes every engine without restarting any of them;
//! * [`admin`] — the fleet view: per-shard stats gathered concurrently
//!   plus aggregated totals across all shard metrics;
//! * [`pipeline`] — layer-sharding: with `--pipeline P` the fleet's
//!   shard slots form `shards / P` pipeline *groups* of `P` stages, each
//!   stage owning a contiguous layer range of the (rust-native) model
//!   with cross-stage activation handoff ([`pipeline::StageCmd::Forward`]).
//!   A group presents the same [`shard::ShardCmd`] interface an engine
//!   shard does, so placement, the fleet-wide `SET k_active` broadcast
//!   and STATS work identically — this is the mode that serves a model
//!   whose KV working set exceeds any single engine's budget.
//!
//! The TCP front-end (`crate::server::tcp`) talks only to the router;
//! `ServeConfig::shards` / `ServeConfig::balance` size the fleet, and
//! `ServeConfig::decode_workers` is per shard (per *stage* in pipeline
//! mode).

pub mod admin;
pub mod balance;
pub mod pipeline;
pub mod router;
pub mod shard;
pub mod supervisor;

pub use balance::{policy_from_name, BalancePolicy, LeastQueued, MemAware, RoundRobin};
pub use router::Router;
pub use shard::{ShardCmd, ShardHandle, ShardStatus};
pub use supervisor::{FaultPlan, FleetEvent, RecoveredReq, ShardHooks, ShardLostError};

/// Lifecycle state of a shard, published in its [`ShardSnapshot`].
///
/// The router filters placement to `Healthy` shards before any
/// [`BalancePolicy`] sees the snapshot list, so policies stay
/// state-oblivious.  `Draining` shards finish (or migrate) their
/// in-flight work and are then retired; `Dead` shards are awaiting
/// removal by the supervisor after their work was handed back.
#[repr(u8)]
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardState {
    #[default]
    Healthy = 0,
    Draining = 1,
    Dead = 2,
}

impl ShardState {
    /// Decode from the `AtomicU8` a `ShardStatus` stores.
    pub fn from_u8(v: u8) -> ShardState {
        match v {
            1 => ShardState::Draining,
            2 => ShardState::Dead,
            _ => ShardState::Healthy,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ShardState::Healthy => "healthy",
            ShardState::Draining => "draining",
            ShardState::Dead => "dead",
        }
    }
}

/// Point-in-time load view of one shard, consumed by placement policies.
///
/// Published by the shard thread after every engine iteration (plus an
/// optimistic bump at placement time), so values may trail the engine by
/// at most one iteration — good enough for load balancing, never for
/// accounting (the authoritative numbers live in the shard's `Metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardSnapshot {
    /// Shard id (index into the router's shard list).
    pub id: usize,
    /// Requests queued behind admission control.
    pub queued: usize,
    /// Sequences currently decoding.
    pub active: usize,
    /// Live KV bytes of the active set.
    pub live_bytes: usize,
    /// Projected KV load: live bytes + admission projection of the queue
    /// (see `Engine::projected_load_bytes`).
    pub projected_bytes: usize,
    /// The shard's current compression level.
    pub k_active: usize,
    /// Free / total allocation granules under block-accounted admission;
    /// both zero when the shard accounts bytes only (then `MemAware`
    /// falls back to `projected_bytes`).
    pub free_blocks: usize,
    pub total_blocks: usize,
    /// Cached-prefix overlap with the request being placed, in tokens
    /// (longest token-block chain of the request's prompt that matches
    /// this shard's published prefix fingerprints).  Filled per request
    /// by the router before policies run; zero outside placement.
    pub affinity: usize,
    /// Lifecycle state; the router places only on `Healthy` shards.
    pub state: ShardState,
}

impl ShardSnapshot {
    /// Total sequences this shard is responsible for (queued + active).
    pub fn load(&self) -> usize {
        self.queued + self.active
    }
}
