//! Magnitude top-k selection (Algorithm 1, `arg TopK(|v|, k_active)`).
//!
//! Matches the python oracle exactly: entries ordered by descending
//! magnitude, ties broken by lower index first.

/// Indices of the `k` largest-magnitude entries of `x`, magnitude-descending
/// (ties: lower index first).  O(d log d); see `topk_select` for the O(d)
/// partial-select variant used on the hot path.
pub fn topk_indices(x: &[f32], k: usize) -> Vec<u16> {
    let k = k.min(x.len());
    let mut idx: Vec<u16> = (0..x.len() as u16).collect();
    idx.sort_by(|&a, &b| {
        let ma = x[a as usize].abs();
        let mb = x[b as usize].abs();
        mb.partial_cmp(&ma).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// (values, indices) of the top-k magnitude entries, original signs kept.
pub fn topk_prune(x: &[f32], k: usize) -> (Vec<f32>, Vec<u16>) {
    let idx = topk_indices(x, k);
    let vals = idx.iter().map(|&i| x[i as usize]).collect();
    (vals, idx)
}

/// Partial-selection top-k: O(d) average via quickselect on magnitudes, then
/// sorts only the selected k entries.  Same output contract as
/// [`topk_indices`].  Used on the eviction hot path (see EXPERIMENTS.md
/// §Perf).
pub fn topk_indices_select(x: &[f32], k: usize) -> Vec<u16> {
    let d = x.len();
    let k = k.min(d);
    if k == 0 {
        // lint: allow(hot_alloc, "empty Vec::new() does not allocate")
        return Vec::new();
    }
    if k == d {
        return topk_indices(x, k);
    }
    let mut idx: Vec<u16> = (0..d as u16).collect();
    // quickselect so that the first k entries are the k largest magnitudes
    let cmp = |a: &u16, b: &u16| {
        let ma = x[*a as usize].abs();
        let mb = x[*b as usize].abs();
        mb.partial_cmp(&ma).unwrap().then(a.cmp(b))
    };
    idx.select_nth_unstable_by(k - 1, cmp);
    idx.truncate(k);
    idx.sort_by(cmp);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn selects_largest_magnitudes() {
        let x = [1.0f32, -5.0, 0.1, 3.0, -2.0];
        let (vals, idx) = topk_prune(&x, 3);
        assert_eq!(idx, vec![1, 3, 4]);
        assert_eq!(vals, vec![-5.0, 3.0, -2.0]);
    }

    #[test]
    fn tie_break_lower_index_first() {
        let x = [2.0f32, -2.0, 2.0];
        let idx = topk_indices(&x, 2);
        assert_eq!(idx, vec![0, 1]);
    }

    #[test]
    fn k_zero_and_k_full() {
        let x = [1.0f32, 2.0];
        assert!(topk_indices(&x, 0).is_empty());
        assert_eq!(topk_indices(&x, 5), vec![1, 0]);
    }

    #[test]
    fn select_variant_matches_sort_variant() {
        let mut r = Pcg64::new(0);
        for _ in 0..200 {
            let d = 1 + r.below(128) as usize;
            let k = r.below(d as u64 + 1) as usize;
            let x = r.normal_vec(d);
            assert_eq!(topk_indices(&x, k), topk_indices_select(&x, k), "d={d} k={k}");
        }
    }

    #[test]
    fn pruned_energy_is_maximal() {
        // no other k-subset can carry more L2 energy
        let mut r = Pcg64::new(1);
        let x = r.normal_vec(32);
        let (vals, _) = topk_prune(&x, 8);
        let kept: f32 = vals.iter().map(|v| v * v).sum();
        let mut sorted: Vec<f32> = x.iter().map(|v| v * v).collect();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let best: f32 = sorted[..8].iter().sum();
        assert!((kept - best).abs() < 1e-5);
    }
}
