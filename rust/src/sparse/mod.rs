//! Sparse KV-vector substrate: winnowed (top-k) vectors, their CSR-style
//! storage, quantized storage modes, and the paper's Eq. 1 byte accounting.

pub mod memory;
pub mod store;
pub mod topk;
pub mod vector;

pub use memory::{MemoryModel, StorageMode};
pub use store::{winnow_into, SparseStore};
pub use topk::{topk_indices, topk_prune};
pub use vector::SparseVec;
