//! The winnowed sparse vector: (values, indices) of the top-k_active
//! rotated dimensions, with value storage in f16 or fp8-E4M3.
//!
//! This is the unit of the paper's Eq. 1: a d_h-dim vector stored as
//! `k_active * (sizeof(value) + sizeof(int8)) + 2` bytes.  The in-memory
//! struct keeps f32 working copies for compute (dequantize-on-read happens
//! at construction); `storage_bytes` reports the bytes the *stored*
//! representation occupies, which is what the memory accounting and the
//! serving admission controller use.

use crate::sparse::memory::StorageMode;
use crate::sparse::topk::topk_indices_select;
use crate::tensor::ops::dot;
use crate::util::fp::{quantize_f16, quantize_fp8};

/// A magnitude-winnowed sparse vector in the rotated space.
#[derive(Clone, Debug)]
pub struct SparseVec {
    /// Values after storage quantization, dequantized to f32 for compute.
    pub vals: Vec<f32>,
    /// Dimension indices (u8-range for d_h <= 256; stored u16 for safety).
    pub idx: Vec<u16>,
    /// Original dense dimensionality d_h.
    pub dim: u16,
    /// Storage mode the values round-tripped through.
    pub mode: StorageMode,
}

impl SparseVec {
    /// Winnow a dense rotated vector to its top-`k_active` dimensions
    /// (Algorithm 1 lines 7-8), quantizing values per `mode`.
    pub fn prune(dense: &[f32], k_active: usize, mode: StorageMode) -> SparseVec {
        let idx = topk_indices_select(dense, k_active);
        let vals = idx
            .iter()
            .map(|&i| match mode {
                StorageMode::F16 => quantize_f16(dense[i as usize]),
                StorageMode::F8 => quantize_fp8(dense[i as usize]),
                StorageMode::F32 => dense[i as usize],
            })
            .collect();
        SparseVec { vals, idx, dim: dense.len() as u16, mode }
    }

    /// Number of retained dimensions (k_active, unless the vector was
    /// shorter).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Bytes of the stored representation (Eq. 1).
    pub fn storage_bytes(&self) -> usize {
        self.mode.vector_bytes(self.nnz())
    }

    /// Decompression-free inner product with a dense query:
    /// `sum_j vals[j] * q[idx[j]]` — Algorithm 1 line 15's sparse-dense
    /// mat-vec, one row.
    #[inline]
    pub fn dot_dense(&self, q: &[f32]) -> f32 {
        debug_assert!(q.len() >= self.dim as usize);
        let mut s = 0.0f32;
        for (v, &i) in self.vals.iter().zip(&self.idx) {
            s += v * q[i as usize];
        }
        s
    }

    /// Scatter-accumulate `weight * self` into a dense accumulator
    /// (Algorithm 1 line 16's output side).
    #[inline]
    pub fn axpy_into(&self, weight: f32, out: &mut [f32]) {
        debug_assert!(out.len() >= self.dim as usize);
        for (v, &i) in self.vals.iter().zip(&self.idx) {
            out[i as usize] += weight * v;
        }
    }

    /// Reconstruct the dense vector (NOT used on any hot path — only for
    /// tests and error analysis; SWAN's point is that attention never needs
    /// this).
    pub fn reconstruct(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim as usize];
        for (v, &i) in self.vals.iter().zip(&self.idx) {
            out[i as usize] = *v;
        }
        out
    }

    /// Relative L2 reconstruction error vs the original dense vector.
    pub fn rel_error(&self, dense: &[f32]) -> f32 {
        let rec = self.reconstruct();
        let mut err = 0.0f32;
        for (r, d) in rec.iter().zip(dense) {
            err += (r - d) * (r - d);
        }
        let norm = dot(dense, dense);
        if norm == 0.0 {
            0.0
        } else {
            (err / norm).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn prune_full_k_reconstructs_exactly_f32() {
        let mut r = Pcg64::new(0);
        let x = r.normal_vec(32);
        let sv = SparseVec::prune(&x, 32, StorageMode::F32);
        assert_eq!(sv.reconstruct(), x);
        assert_eq!(sv.rel_error(&x), 0.0);
    }

    #[test]
    fn dot_dense_matches_reconstructed_dot() {
        let mut r = Pcg64::new(1);
        let x = r.normal_vec(64);
        let q = r.normal_vec(64);
        let sv = SparseVec::prune(&x, 16, StorageMode::F32);
        let want = dot(&sv.reconstruct(), &q);
        assert!((sv.dot_dense(&q) - want).abs() < 1e-5);
    }

    #[test]
    fn axpy_matches_scaled_reconstruction() {
        let mut r = Pcg64::new(2);
        let x = r.normal_vec(32);
        let sv = SparseVec::prune(&x, 8, StorageMode::F16);
        let mut out = vec![0.0f32; 32];
        sv.axpy_into(0.5, &mut out);
        for (o, rec) in out.iter().zip(sv.reconstruct()) {
            assert!((o - 0.5 * rec).abs() < 1e-6);
        }
    }

    #[test]
    fn error_decreases_with_k() {
        let mut r = Pcg64::new(3);
        let x = r.normal_vec(128);
        let mut last = f32::INFINITY;
        for k in [8, 32, 64, 128] {
            let e = SparseVec::prune(&x, k, StorageMode::F32).rel_error(&x);
            assert!(e <= last + 1e-6, "k={k}");
            last = e;
        }
        assert_eq!(last, 0.0);
    }

    #[test]
    fn storage_bytes_eq1() {
        let x = vec![1.0f32; 128];
        // 16-bit: 3k + 2
        let sv = SparseVec::prune(&x, 64, StorageMode::F16);
        assert_eq!(sv.storage_bytes(), 3 * 64 + 2);
        // 8-bit: 2k + 2
        let sv8 = SparseVec::prune(&x, 64, StorageMode::F8);
        assert_eq!(sv8.storage_bytes(), 2 * 64 + 2);
    }

    #[test]
    fn fp8_values_are_quantized() {
        let x = vec![0.3f32; 8];
        let sv = SparseVec::prune(&x, 4, StorageMode::F8);
        for v in &sv.vals {
            // 0.3 is not representable in e4m3; must equal its quantization
            assert_eq!(*v, crate::util::fp::quantize_fp8(0.3));
            assert_ne!(*v, 0.3);
        }
    }
}
