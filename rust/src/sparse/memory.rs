//! Eq. 1 memory accounting and the §1 motivation calculator.
//!
//! `M_sparse = k_active * (sizeof(value) + sizeof(int8)) + 2` bytes per
//! vector; dense is `d_h * 2` bytes (f16 serving convention).  These
//! formulas drive Fig. 2a, the admission controller, and the `repro
//! motivation` table.

/// How sparse values are stored.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StorageMode {
    /// float16 values: 3*k + 2 bytes/vector (paper default).
    F16,
    /// fp8 E4M3 values: 2*k + 2 bytes/vector (aggressive mode).
    F8,
    /// float32 values (diagnostics only; never used for serving accounting).
    F32,
}

impl StorageMode {
    pub fn value_bytes(self) -> usize {
        match self {
            StorageMode::F16 => 2,
            StorageMode::F8 => 1,
            StorageMode::F32 => 4,
        }
    }

    /// Eq. 1: bytes for one winnowed vector with `k` retained dims
    /// (+1 byte/entry int8 index, +2 bytes CSR offset).
    pub fn vector_bytes(self, k: usize) -> usize {
        k * (self.value_bytes() + 1) + 2
    }

    pub fn label(self) -> &'static str {
        match self {
            StorageMode::F16 => "16-bit",
            StorageMode::F8 => "8-bit",
            StorageMode::F32 => "32-bit",
        }
    }
}

/// Dense vector bytes at serving precision (f16, as the paper assumes).
pub fn dense_vector_bytes(d_h: usize) -> usize {
    d_h * 2
}

/// Per-token whole-model KV byte rates `(sparse, dense)` at compression
/// level `k` — the single closed form behind engine admission control,
/// pipeline-group accounting and the router's `MemAware` projection
/// (k+v per (layer, kv-head): Eq. 1 for the sparse side, f16 dense).
pub fn token_byte_rates(
    n_layers: usize,
    n_kv_heads: usize,
    d_head: usize,
    mode: StorageMode,
    k: usize,
) -> (usize, usize) {
    let per_head = 2 * n_layers * n_kv_heads;
    (per_head * mode.vector_bytes(k.min(d_head)), per_head * dense_vector_bytes(d_head))
}

/// Compression ratio of the sparse representation vs dense
/// (Fig. 2a y-axis): `< 1` means the sparse form is smaller.
pub fn compression_ratio(d_h: usize, k_active: usize, mode: StorageMode) -> f64 {
    mode.vector_bytes(k_active) as f64 / dense_vector_bytes(d_h) as f64
}

/// Retention ratio at which sparse storage breaks even with dense
/// (Fig. 2a shaded-region boundary): solves vector_bytes(k) == 2*d_h for
/// k/d_h.
pub fn breakeven_retention(d_h: usize, mode: StorageMode) -> f64 {
    let per_entry = (mode.value_bytes() + 1) as f64;
    ((dense_vector_bytes(d_h) as f64 - 2.0) / per_entry) / d_h as f64
}

/// Whole-model KV-cache memory model (the §1 motivation numbers).
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    /// bytes per stored scalar for the dense cache (2 = f16).
    pub dense_scalar_bytes: usize,
}

impl MemoryModel {
    pub fn llama2_7b() -> MemoryModel {
        MemoryModel { n_layers: 32, n_kv_heads: 32, d_head: 128, dense_scalar_bytes: 2 }
    }

    /// Model for the swan-nano artifacts.
    pub fn nano(n_layers: usize, n_kv_heads: usize, d_head: usize) -> MemoryModel {
        MemoryModel { n_layers, n_kv_heads, d_head, dense_scalar_bytes: 2 }
    }

    /// Dense KV-cache bytes per token (K and V).
    pub fn dense_bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.d_head * self.dense_scalar_bytes
    }

    /// Dense KV-cache bytes for a full batch of sequences.
    pub fn dense_bytes(&self, seq_len: usize, batch: usize) -> usize {
        self.dense_bytes_per_token() * seq_len * batch
    }

    /// SWAN hybrid-cache bytes for one sequence: `buffer` recent tokens
    /// dense + the rest winnowed at `k_active` in `mode`.
    pub fn swan_bytes(&self, seq_len: usize, buffer: usize, k_active: usize,
                      mode: StorageMode) -> usize {
        let heads = self.n_layers * self.n_kv_heads;
        let dense_tokens = seq_len.min(buffer);
        let sparse_tokens = seq_len - dense_tokens;
        let dense = 2 * heads * self.d_head * self.dense_scalar_bytes * dense_tokens;
        let sparse = 2 * heads * mode.vector_bytes(k_active) * sparse_tokens;
        dense + sparse
    }

    /// Fraction of dense memory that the SWAN cache occupies.
    pub fn swan_ratio(&self, seq_len: usize, buffer: usize, k_active: usize,
                      mode: StorageMode) -> f64 {
        self.swan_bytes(seq_len, buffer, k_active, mode) as f64
            / self.dense_bytes(seq_len, 1) as f64
    }
}

/// Pretty-print byte counts.
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_paper_values() {
        // paper: d_h=128, f16 -> 3k+2; dense 256 B
        assert_eq!(StorageMode::F16.vector_bytes(64), 194);
        assert_eq!(StorageMode::F8.vector_bytes(64), 130);
        assert_eq!(dense_vector_bytes(128), 256);
    }

    #[test]
    fn token_byte_rates_match_eq1_and_clamp() {
        // 2 layers x 2 kv-heads, d_h 8: per token, k+v per (layer, head)
        let (sparse, dense) = token_byte_rates(2, 2, 8, StorageMode::F16, 4);
        assert_eq!(sparse, 2 * 2 * 2 * StorageMode::F16.vector_bytes(4));
        assert_eq!(dense, 2 * 2 * 2 * dense_vector_bytes(8));
        // over-range k clamps to d_head (full retention)
        let (s_clamped, _) = token_byte_rates(2, 2, 8, StorageMode::F16, 500);
        let (s_full, _) = token_byte_rates(2, 2, 8, StorageMode::F16, 8);
        assert_eq!(s_clamped, s_full);
    }

    #[test]
    fn paper_breakeven_is_66_percent_f16() {
        // paper: "must prune over 34% just to break even" for 16-bit
        let be = breakeven_retention(128, StorageMode::F16);
        assert!((be - 0.661).abs() < 0.01, "{be}");
        // 8-bit "almost one-to-one"
        let be8 = breakeven_retention(128, StorageMode::F8);
        assert!(be8 > 0.98, "{be8}");
    }

    #[test]
    fn compression_monotonic_in_k() {
        let mut last = 0.0;
        for k in (8..=128).step_by(8) {
            let r = compression_ratio(128, k, StorageMode::F16);
            assert!(r > last);
            last = r;
        }
    }

    #[test]
    fn motivation_llama2_7b_32k() {
        // paper §1: Llama-2 7B, 32k tokens, batch 16 -> ~256 GB KV cache
        let m = MemoryModel::llama2_7b();
        let bytes = m.dense_bytes(32 * 1024, 16);
        let gib = bytes as f64 / (1u64 << 30) as f64;
        assert!((gib - 256.0).abs() < 8.0, "{gib} GiB");
    }

    #[test]
    fn swan_ratio_limits() {
        let m = MemoryModel::nano(4, 1, 64);
        // no compression if everything fits in the buffer
        assert_eq!(m.swan_ratio(64, 128, 16, StorageMode::F16), 1.0);
        // long sequence, tiny buffer: approaches vector ratio
        let r = m.swan_ratio(100_000, 0, 16, StorageMode::F16);
        let expect = compression_ratio(64, 16, StorageMode::F16);
        assert!((r - expect).abs() < 1e-6);
    }

    #[test]
    fn swan_bytes_additive() {
        let m = MemoryModel::nano(2, 2, 64);
        let total = m.swan_bytes(100, 20, 16, StorageMode::F8);
        let dense_part = 2 * 4 * 64 * 2 * 20;
        let sparse_part = 2 * 4 * StorageMode::F8.vector_bytes(16) * 80;
        assert_eq!(total, dense_part + sparse_part);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(100), "100 B");
        assert!(human_bytes(10 * 1024).contains("KiB"));
        assert!(human_bytes(3 << 30).contains("GiB"));
    }
}
