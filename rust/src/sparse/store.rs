//! Contiguous CSR store for winnowed vectors (§Perf L3 optimization).
//!
//! The first implementation kept one heap-allocated [`SparseVec`] per
//! cached token; at L >= 2k tokens the pointer chasing dominated the
//! attention walk (see EXPERIMENTS.md §Perf "before").  This store packs
//! all rows into three flat arrays (values, indices, offsets) — the
//! actual CSR layout §5.1 accounts for — so the score/output loops stream
//! contiguous memory exactly like the dense baseline does.

use crate::sparse::memory::StorageMode;
use crate::sparse::topk::topk_indices_select;
use crate::util::fp::{quantize_f16, quantize_fp8};

/// Flat CSR store of winnowed rows, append-only.
#[derive(Clone, Debug, Default)]
pub struct SparseStore {
    vals: Vec<f32>,
    idx: Vec<u16>,
    /// Row boundaries; offsets.len() == rows + 1.  Rows may have different
    /// nnz (runtime-tunable k_active).
    offsets: Vec<u32>,
    /// Bytes of the stored representation (accumulated per Eq. 1, since
    /// rows can be written under different storage modes).
    bytes: usize,
}

impl SparseStore {
    pub fn new() -> SparseStore {
        SparseStore { vals: Vec::new(), idx: Vec::new(), offsets: vec![0], bytes: 0 }
    }

    pub fn with_capacity(rows: usize, k: usize) -> SparseStore {
        let mut s = SparseStore::new();
        s.vals.reserve(rows * k);
        s.idx.reserve(rows * k);
        s.offsets.reserve(rows + 1);
        s
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Winnow `dense` to its top-`k` dims and append as a new row.
    pub fn push_pruned(&mut self, dense: &[f32], k: usize, mode: StorageMode) {
        let ki = topk_indices_select(dense, k);
        for &i in &ki {
            let v = dense[i as usize];
            self.vals.push(match mode {
                StorageMode::F16 => quantize_f16(v),
                StorageMode::F8 => quantize_fp8(v),
                StorageMode::F32 => v,
            });
            self.idx.push(i);
        }
        self.offsets.push(self.vals.len() as u32);
        self.bytes += mode.vector_bytes(ki.len());
    }

    /// Row accessor: (values, indices).
    #[inline]
    pub fn row(&self, r: usize) -> (&[f32], &[u16]) {
        let lo = self.offsets[r] as usize;
        let hi = self.offsets[r + 1] as usize;
        (&self.vals[lo..hi], &self.idx[lo..hi])
    }

    pub fn nnz(&self, r: usize) -> usize {
        (self.offsets[r + 1] - self.offsets[r]) as usize
    }

    /// Decompression-free scores for ALL rows against a dense query:
    /// out[r] = sum_j vals[r,j] * q[idx[r,j]] * scale.  Contiguous walk;
    /// the inner gather uses unchecked indexing (indices are validated at
    /// insertion: every idx < d_h <= q.len()) with 2-way unrolling to
    /// hide gather latency — see EXPERIMENTS.md §Perf.
    pub fn scores_into(&self, q: &[f32], scale: f32, out: &mut Vec<f32>) {
        out.reserve(self.len());
        for r in 0..self.len() {
            let lo = self.offsets[r] as usize;
            let hi = self.offsets[r + 1] as usize;
            let vals = &self.vals[lo..hi];
            let idx = &self.idx[lo..hi];
            let n = vals.len();
            let mut s0 = 0.0f32;
            let mut s1 = 0.0f32;
            let pairs = n / 2;
            // SAFETY: idx entries are < d_h (checked at push), q.len() >= d_h
            // (debug-asserted by callers), and j bounds follow from `pairs`.
            unsafe {
                for p in 0..pairs {
                    let j = 2 * p;
                    s0 += vals.get_unchecked(j) * q.get_unchecked(*idx.get_unchecked(j) as usize);
                    s1 += vals.get_unchecked(j + 1)
                        * q.get_unchecked(*idx.get_unchecked(j + 1) as usize);
                }
                if n % 2 == 1 {
                    s0 += vals.get_unchecked(n - 1)
                        * q.get_unchecked(*idx.get_unchecked(n - 1) as usize);
                }
            }
            out.push((s0 + s1) * scale);
        }
    }

    /// Weighted scatter-add of all rows: out += sum_r w[r] * row_r.
    /// Unchecked indexing as in [`SparseStore::scores_into`].
    pub fn axpy_all(&self, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(w.len(), self.len());
        for r in 0..self.len() {
            let lo = self.offsets[r] as usize;
            let hi = self.offsets[r + 1] as usize;
            let wr = w[r];
            // SAFETY: idx entries < d_h <= out.len() (validated at push).
            unsafe {
                for j in lo..hi {
                    let i = *self.idx.get_unchecked(j) as usize;
                    *out.get_unchecked_mut(i) += wr * self.vals.get_unchecked(j);
                }
            }
        }
    }

    /// Eq. 1 bytes of everything stored.
    pub fn storage_bytes(&self) -> usize {
        self.bytes
    }

    /// Read-only view of the CSR row boundaries (`offsets.len() == rows + 1`).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Check the store's structural invariants, returning the first
    /// violation: offsets start at 0 and are monotone non-decreasing, the
    /// final offset equals the value count, and values/indices stay in
    /// lock-step.  Used by the property tests; cheap enough to call after
    /// every mutation in a shrink loop.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.offsets.first() != Some(&0) {
            return Err(format!("offsets must start at 0, got {:?}", self.offsets.first()));
        }
        for (r, w) in self.offsets.windows(2).enumerate() {
            if w[1] < w[0] {
                return Err(format!("offsets not monotone at row {r}: {} -> {}", w[0], w[1]));
            }
        }
        let last = *self.offsets.last().unwrap() as usize;
        if last != self.vals.len() {
            return Err(format!("last offset {last} != vals.len() {}", self.vals.len()));
        }
        if self.vals.len() != self.idx.len() {
            return Err(format!(
                "vals.len() {} != idx.len() {}",
                self.vals.len(),
                self.idx.len()
            ));
        }
        Ok(())
    }

    /// Reconstruct row `r` densely (tests/error analysis only).
    pub fn reconstruct(&self, r: usize, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        let (vals, idx) = self.row(r);
        for (v, &i) in vals.iter().zip(idx) {
            out[i as usize] = *v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::Pcg64;

    #[test]
    fn rows_match_sparsevec() {
        let mut rng = Pcg64::new(0);
        let mut store = SparseStore::new();
        let rows: Vec<Vec<f32>> = (0..20).map(|_| rng.normal_vec(64)).collect();
        for r in &rows {
            store.push_pruned(r, 16, StorageMode::F16);
        }
        assert_eq!(store.len(), 20);
        for (i, r) in rows.iter().enumerate() {
            let sv = SparseVec::prune(r, 16, StorageMode::F16);
            let (vals, idx) = store.row(i);
            assert_eq!(vals, sv.vals.as_slice());
            let idx16: Vec<u16> = idx.to_vec();
            assert_eq!(idx16, sv.idx);
        }
    }

    #[test]
    fn scores_and_axpy_match_per_row_ops() {
        let mut rng = Pcg64::new(1);
        let mut store = SparseStore::new();
        let rows: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(32)).collect();
        for r in &rows {
            store.push_pruned(r, 8, StorageMode::F32);
        }
        let q = rng.normal_vec(32);
        let mut scores = Vec::new();
        store.scores_into(&q, 0.5, &mut scores);
        for (i, r) in rows.iter().enumerate() {
            let sv = SparseVec::prune(r, 8, StorageMode::F32);
            assert!((scores[i] - 0.5 * sv.dot_dense(&q)).abs() < 1e-5);
        }
        let w: Vec<f32> = (0..12).map(|i| 0.1 * i as f32).collect();
        let mut out = vec![0.0f32; 32];
        store.axpy_all(&w, &mut out);
        let mut want = vec![0.0f32; 32];
        for (i, r) in rows.iter().enumerate() {
            SparseVec::prune(r, 8, StorageMode::F32).axpy_into(w[i], &mut want);
        }
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn mixed_k_rows_supported() {
        // runtime-tunable k: rows written at different k coexist
        let mut store = SparseStore::new();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        store.push_pruned(&x, 4, StorageMode::F16);
        store.push_pruned(&x, 8, StorageMode::F8);
        assert_eq!(store.nnz(0), 4);
        assert_eq!(store.nnz(1), 8);
        assert_eq!(
            store.storage_bytes(),
            StorageMode::F16.vector_bytes(4) + StorageMode::F8.vector_bytes(8)
        );
    }

    /// Hand-computed fixture for the invariant checker, offsets accessor
    /// and mixed-mode byte accounting (the randomized sweep lives in
    /// `tests/prop_invariants.rs`; this pins exact expected values).
    #[test]
    fn invariants_and_walks_on_hand_computed_fixture() {
        let mut store = SparseStore::new();
        store.check_invariants().unwrap();
        // row 0: top-2 of [1, -2, 3] -> idx [2, 1], vals [3, -2]
        store.push_pruned(&[1.0, -2.0, 3.0], 2, StorageMode::F32);
        // row 1: top-1 of [0.5, 4, -0.25] -> idx [1], vals [4]
        store.push_pruned(&[0.5, 4.0, -0.25], 1, StorageMode::F32);
        store.check_invariants().unwrap();
        assert_eq!(store.offsets(), &[0, 2, 3]);
        assert_eq!(store.nnz(0), 2);
        assert_eq!(store.nnz(1), 1);
        assert_eq!(
            store.storage_bytes(),
            StorageMode::F32.vector_bytes(2) + StorageMode::F32.vector_bytes(1)
        );

        let q = [1.0f32, 2.0, 3.0];
        let mut scores = Vec::new();
        store.scores_into(&q, 1.0, &mut scores);
        // row 0: 3*q[2] + (-2)*q[1] = 5;  row 1: 4*q[1] = 8
        assert_eq!(scores, vec![5.0, 8.0]);

        let mut out = vec![0.0f32; 3];
        store.axpy_all(&[0.5, 0.25], &mut out);
        // out[2] = 0.5*3; out[1] = 0.5*(-2) + 0.25*4 = 0
        assert_eq!(out, vec![0.0, 0.0, 1.5]);
    }

    #[test]
    fn scores_append_preserves_existing() {
        let mut store = SparseStore::new();
        store.push_pruned(&[1.0, -2.0, 3.0], 2, StorageMode::F32);
        let q = [1.0f32, 1.0, 1.0];
        let mut scores = vec![99.0];
        store.scores_into(&q, 1.0, &mut scores);
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[0], 99.0);
        assert_eq!(scores[1], 1.0); // 3.0 + (-2.0)
    }
}
