//! Contiguous CSR store for winnowed vectors (§Perf L3 optimization).
//!
//! The first implementation kept one heap-allocated [`SparseVec`] per
//! cached token; at L >= 2k tokens the pointer chasing dominated the
//! attention walk (see EXPERIMENTS.md §Perf "before").  This store packs
//! all rows into three flat arrays (values, indices, offsets) — the
//! actual CSR layout §5.1 accounts for — so the score/output loops stream
//! contiguous memory exactly like the dense baseline does.
//!
//! # Lane padding
//!
//! A store built with [`SparseStore::with_lanes`] zero-pads every row to
//! a multiple of the kernel lane width (value `0.0`, index `0` sentinels)
//! so the AVX2 gather walk runs with no scalar tail.  The *real* nnz of
//! each row is kept in offsets-adjacent metadata (`nnz`), which is what
//! [`SparseStore::row`]/[`SparseStore::nnz`] report and what the Eq. 1
//! byte accounting charges — padding changes neither results (sentinels
//! contribute exactly zero to scores and scatter-adds) nor accounting.
//!
//! Note on accounting: Eq. 1 models the *serving representation* the
//! paper costs out, not process RSS — this store already holds f32s in
//! memory while charging f16/f8 bytes, and the sentinel slots follow the
//! same convention (real heap, zero charged bytes).  At worst (lane 8,
//! `k_active % 8 == 1`) padding adds 7 slots/row of working memory that
//! the `mem_budget` admission model does not see.

use crate::simd::Kernels;
use crate::sparse::memory::StorageMode;
use crate::sparse::topk::topk_indices_select;
use crate::util::fp::{quantize_f16, quantize_fp8};

/// Winnow `dense` to its top-`k` dims and append the quantized
/// (value, index) pairs — zero-padded to a multiple of `lane` — onto
/// `vals`/`idx`.  Returns the *real* (unpadded) nnz written.
///
/// This is the ONE spelling of the winnow-quantize-pad step:
/// [`SparseStore::push_pruned`] (contiguous CSR) and the block-pool's
/// paged rows ([`crate::pool::paged_cache`]) both append through it, so a
/// pool-backed row is bit-identical to the per-sequence store's row by
/// construction.
pub fn winnow_into(
    dense: &[f32],
    k: usize,
    mode: StorageMode,
    lane: usize,
    vals: &mut Vec<f32>,
    idx: &mut Vec<u16>,
) -> usize {
    let ki = topk_indices_select(dense, k);
    for &i in &ki {
        let v = dense[i as usize];
        vals.push(match mode {
            StorageMode::F16 => quantize_f16(v),
            StorageMode::F8 => quantize_fp8(v),
            StorageMode::F32 => v,
        });
        idx.push(i);
    }
    let pad = (lane - ki.len() % lane) % lane;
    for _ in 0..pad {
        vals.push(0.0);
        idx.push(0);
    }
    ki.len()
}

/// Flat CSR store of winnowed rows, append-only.
#[derive(Clone, Debug)]
pub struct SparseStore {
    vals: Vec<f32>,
    idx: Vec<u16>,
    /// Padded row boundaries; offsets.len() == rows + 1.  Rows may have
    /// different nnz (runtime-tunable k_active).
    offsets: Vec<u32>,
    /// Real (unpadded) nnz per row; `offsets[r] + nnz[r]` bounds the live
    /// entries, the rest of the row (if any) is sentinel padding.
    nnz: Vec<u32>,
    /// Rows are padded to a multiple of this lane count (1 = unpadded).
    lane: usize,
    /// Bytes of the stored representation (accumulated per Eq. 1 over the
    /// *real* nnz, since rows can be written under different storage
    /// modes and padding is never charged).
    bytes: usize,
}

impl Default for SparseStore {
    fn default() -> SparseStore {
        SparseStore::new()
    }
}

impl SparseStore {
    pub fn new() -> SparseStore {
        SparseStore::with_lanes(1)
    }

    /// A store whose rows are zero-padded to a multiple of `lane`
    /// (use [`Kernels::lanes`] of the active kernel set; 1 = unpadded).
    pub fn with_lanes(lane: usize) -> SparseStore {
        SparseStore {
            vals: Vec::new(),
            idx: Vec::new(),
            offsets: vec![0],
            nnz: Vec::new(),
            lane: lane.max(1),
            bytes: 0,
        }
    }

    pub fn with_capacity(rows: usize, k: usize) -> SparseStore {
        SparseStore::with_capacity_lanes(rows, k, 1)
    }

    pub fn with_capacity_lanes(rows: usize, k: usize, lane: usize) -> SparseStore {
        let mut s = SparseStore::with_lanes(lane);
        let padded_k = k.div_ceil(s.lane) * s.lane;
        s.vals.reserve(rows * padded_k);
        s.idx.reserve(rows * padded_k);
        s.offsets.reserve(rows + 1);
        s.nnz.reserve(rows);
        s
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The lane multiple rows are padded to (1 = unpadded).
    pub fn lanes(&self) -> usize {
        self.lane
    }

    /// Winnow `dense` to its top-`k` dims and append as a new row
    /// (zero-padded to the store's lane multiple).
    pub fn push_pruned(&mut self, dense: &[f32], k: usize, mode: StorageMode) {
        let nnz = winnow_into(dense, k, mode, self.lane, &mut self.vals, &mut self.idx);
        self.offsets.push(self.vals.len() as u32);
        self.nnz.push(nnz as u32);
        self.bytes += mode.vector_bytes(nnz);
    }

    /// Row accessor: (values, indices) of the *live* entries (padding
    /// sentinels excluded).
    #[inline]
    pub fn row(&self, r: usize) -> (&[f32], &[u16]) {
        let lo = self.offsets[r] as usize;
        let hi = lo + self.nnz[r] as usize;
        (&self.vals[lo..hi], &self.idx[lo..hi])
    }

    /// Real (unpadded) nnz of row `r`.
    pub fn nnz(&self, r: usize) -> usize {
        self.nnz[r] as usize
    }

    /// Padded width of row `r` (== [`SparseStore::nnz`] when unpadded).
    pub fn padded_nnz(&self, r: usize) -> usize {
        (self.offsets[r + 1] - self.offsets[r]) as usize
    }

    /// Decompression-free scores for ALL rows against a dense query:
    /// out[r] = sum_j vals[r,j] * q[idx[r,j]] * scale, through the
    /// process-wide active kernel set (scalar 2-way-unrolled gather or
    /// AVX2 `vgatherdps` — see [`crate::simd`]).  Padding sentinels
    /// contribute exactly zero.
    pub fn scores_into(&self, q: &[f32], scale: f32, out: &mut Vec<f32>) {
        self.scores_into_with(crate::simd::active(), q, scale, out);
    }

    /// [`SparseStore::scores_into`] on an explicit kernel set (benches and
    /// the dispatch-parity property tests force paths through this).
    pub fn scores_into_with(&self, ks: Kernels, q: &[f32], scale: f32, out: &mut Vec<f32>) {
        ks.csr_scores_into(&self.vals, &self.idx, &self.offsets, scale, q, out);
    }

    /// Fused scores + running max: as [`SparseStore::scores_into`], also
    /// returning the max pushed score (`NEG_INFINITY` for an empty store)
    /// so the downstream softmax drops its max pass.
    pub fn scores_max_into(&self, q: &[f32], scale: f32, out: &mut Vec<f32>) -> f32 {
        self.scores_max_into_with(crate::simd::active(), q, scale, out)
    }

    /// [`SparseStore::scores_max_into`] on an explicit kernel set.
    pub fn scores_max_into_with(
        &self,
        ks: Kernels,
        q: &[f32],
        scale: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        ks.csr_scores_max_into(&self.vals, &self.idx, &self.offsets, scale, q, out)
    }

    /// Weighted scatter-add of all rows: out += sum_r w[r] * row_r,
    /// through the active kernel set.
    pub fn axpy_all(&self, w: &[f32], out: &mut [f32]) {
        self.axpy_all_with(crate::simd::active(), w, out);
    }

    /// [`SparseStore::axpy_all`] on an explicit kernel set.
    pub fn axpy_all_with(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(w.len(), self.len());
        ks.csr_axpy_all(&self.vals, &self.idx, &self.offsets, w, out);
    }

    /// Eq. 1 bytes of everything stored.
    pub fn storage_bytes(&self) -> usize {
        self.bytes
    }

    /// Read-only view of the CSR row boundaries (`offsets.len() == rows + 1`).
    pub fn offsets(&self) -> &[u32] {
        &self.offsets
    }

    /// Check the store's structural invariants, returning the first
    /// violation: offsets start at 0 and are monotone non-decreasing, the
    /// final offset equals the value count, values/indices stay in
    /// lock-step, and the lane-padding metadata is consistent (real nnz
    /// within the padded row, padded width the smallest lane multiple
    /// covering it, sentinel entries exactly `(0.0, 0)`).  Used by the
    /// property tests; cheap enough to call after every mutation in a
    /// shrink loop.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.offsets.first() != Some(&0) {
            return Err(format!("offsets must start at 0, got {:?}", self.offsets.first()));
        }
        for (r, w) in self.offsets.windows(2).enumerate() {
            if w[1] < w[0] {
                return Err(format!("offsets not monotone at row {r}: {} -> {}", w[0], w[1]));
            }
        }
        let last = *self.offsets.last().unwrap() as usize;
        if last != self.vals.len() {
            return Err(format!("last offset {last} != vals.len() {}", self.vals.len()));
        }
        if self.vals.len() != self.idx.len() {
            return Err(format!(
                "vals.len() {} != idx.len() {}",
                self.vals.len(),
                self.idx.len()
            ));
        }
        if self.lane == 0 {
            return Err("lane must be >= 1".into());
        }
        if self.nnz.len() != self.len() {
            return Err(format!("nnz.len() {} != rows {}", self.nnz.len(), self.len()));
        }
        for r in 0..self.len() {
            let width = self.padded_nnz(r);
            let live = self.nnz[r] as usize;
            if live > width {
                return Err(format!("row {r}: nnz {live} > padded width {width}"));
            }
            if width != live.div_ceil(self.lane) * self.lane {
                return Err(format!(
                    "row {r}: padded width {width} is not nnz {live} rounded to lane {}",
                    self.lane
                ));
            }
            let lo = self.offsets[r] as usize;
            for j in lo + live..lo + width {
                if self.vals[j] != 0.0 || self.idx[j] != 0 {
                    return Err(format!(
                        "row {r}: padding slot {} holds ({}, {}), expected (0, 0)",
                        j - lo,
                        self.vals[j],
                        self.idx[j]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Reconstruct row `r` densely (tests/error analysis only).
    pub fn reconstruct(&self, r: usize, dim: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; dim];
        let (vals, idx) = self.row(r);
        for (v, &i) in vals.iter().zip(idx) {
            out[i as usize] = *v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SparseVec;
    use crate::util::Pcg64;

    #[test]
    fn rows_match_sparsevec() {
        let mut rng = Pcg64::new(0);
        let mut store = SparseStore::new();
        let rows: Vec<Vec<f32>> = (0..20).map(|_| rng.normal_vec(64)).collect();
        for r in &rows {
            store.push_pruned(r, 16, StorageMode::F16);
        }
        assert_eq!(store.len(), 20);
        for (i, r) in rows.iter().enumerate() {
            let sv = SparseVec::prune(r, 16, StorageMode::F16);
            let (vals, idx) = store.row(i);
            assert_eq!(vals, sv.vals.as_slice());
            let idx16: Vec<u16> = idx.to_vec();
            assert_eq!(idx16, sv.idx);
        }
    }

    #[test]
    fn scores_and_axpy_match_per_row_ops() {
        let mut rng = Pcg64::new(1);
        let mut store = SparseStore::new();
        let rows: Vec<Vec<f32>> = (0..12).map(|_| rng.normal_vec(32)).collect();
        for r in &rows {
            store.push_pruned(r, 8, StorageMode::F32);
        }
        let q = rng.normal_vec(32);
        let mut scores = Vec::new();
        store.scores_into(&q, 0.5, &mut scores);
        for (i, r) in rows.iter().enumerate() {
            let sv = SparseVec::prune(r, 8, StorageMode::F32);
            assert!((scores[i] - 0.5 * sv.dot_dense(&q)).abs() < 1e-5);
        }
        let w: Vec<f32> = (0..12).map(|i| 0.1 * i as f32).collect();
        let mut out = vec![0.0f32; 32];
        store.axpy_all(&w, &mut out);
        let mut want = vec![0.0f32; 32];
        for (i, r) in rows.iter().enumerate() {
            SparseVec::prune(r, 8, StorageMode::F32).axpy_into(w[i], &mut want);
        }
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn mixed_k_rows_supported() {
        // runtime-tunable k: rows written at different k coexist
        let mut store = SparseStore::new();
        let x: Vec<f32> = (0..16).map(|i| i as f32).collect();
        store.push_pruned(&x, 4, StorageMode::F16);
        store.push_pruned(&x, 8, StorageMode::F8);
        assert_eq!(store.nnz(0), 4);
        assert_eq!(store.nnz(1), 8);
        assert_eq!(
            store.storage_bytes(),
            StorageMode::F16.vector_bytes(4) + StorageMode::F8.vector_bytes(8)
        );
    }

    /// Hand-computed fixture for the invariant checker, offsets accessor
    /// and mixed-mode byte accounting (the randomized sweep lives in
    /// `tests/prop_invariants.rs`; this pins exact expected values).
    #[test]
    fn invariants_and_walks_on_hand_computed_fixture() {
        let mut store = SparseStore::new();
        store.check_invariants().unwrap();
        // row 0: top-2 of [1, -2, 3] -> idx [2, 1], vals [3, -2]
        store.push_pruned(&[1.0, -2.0, 3.0], 2, StorageMode::F32);
        // row 1: top-1 of [0.5, 4, -0.25] -> idx [1], vals [4]
        store.push_pruned(&[0.5, 4.0, -0.25], 1, StorageMode::F32);
        store.check_invariants().unwrap();
        assert_eq!(store.offsets(), &[0, 2, 3]);
        assert_eq!(store.nnz(0), 2);
        assert_eq!(store.nnz(1), 1);
        assert_eq!(
            store.storage_bytes(),
            StorageMode::F32.vector_bytes(2) + StorageMode::F32.vector_bytes(1)
        );

        let q = [1.0f32, 2.0, 3.0];
        let mut scores = Vec::new();
        store.scores_into(&q, 1.0, &mut scores);
        // row 0: 3*q[2] + (-2)*q[1] = 5;  row 1: 4*q[1] = 8
        assert_eq!(scores, vec![5.0, 8.0]);

        let mut out = vec![0.0f32; 3];
        store.axpy_all(&[0.5, 0.25], &mut out);
        // out[2] = 0.5*3; out[1] = 0.5*(-2) + 0.25*4 = 0
        assert_eq!(out, vec![0.0, 0.0, 1.5]);
    }

    #[test]
    fn scores_append_preserves_existing() {
        let mut store = SparseStore::new();
        store.push_pruned(&[1.0, -2.0, 3.0], 2, StorageMode::F32);
        let q = [1.0f32, 1.0, 1.0];
        let mut scores = vec![99.0];
        store.scores_into(&q, 1.0, &mut scores);
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[0], 99.0);
        assert_eq!(scores[1], 1.0); // 3.0 + (-2.0)
    }

    /// Lane padding is invisible to every accessor and walk: rows report
    /// their real nnz, Eq. 1 bytes never charge padding, and scores/axpy
    /// match the unpadded store on identical pushes.
    #[test]
    fn lane_padded_store_matches_unpadded() {
        let mut rng = Pcg64::new(8);
        let d = 32usize;
        let mut plain = SparseStore::new();
        let mut padded = SparseStore::with_lanes(8);
        for (i, k) in [3usize, 8, 5, 13, 1, 32].into_iter().enumerate() {
            let x = rng.normal_vec(d);
            plain.push_pruned(&x, k, StorageMode::F16);
            padded.push_pruned(&x, k, StorageMode::F16);
            padded.check_invariants().unwrap();
            assert_eq!(padded.nnz(i), plain.nnz(i));
            assert_eq!(padded.padded_nnz(i), k.div_ceil(8) * 8);
            assert_eq!(padded.row(i), plain.row(i));
        }
        assert_eq!(padded.storage_bytes(), plain.storage_bytes());
        assert_eq!(padded.lanes(), 8);
        assert_eq!(plain.lanes(), 1);

        // pin the scalar kernel: on that path padding is bit-invisible
        // (sentinel terms land in the same unroll partials as +0.0); the
        // cross-kernel tolerance sweep lives in tests/prop_invariants.rs
        let sc = crate::simd::Kernels::scalar();
        let q = rng.normal_vec(d);
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        plain.scores_into_with(sc, &q, 0.5, &mut s1);
        let m = padded.scores_max_into_with(sc, &q, 0.5, &mut s2);
        assert_eq!(s1, s2); // sentinels contribute exactly zero
        assert_eq!(m, s1.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)));

        let w: Vec<f32> = (0..plain.len()).map(|i| 0.3 - 0.05 * i as f32).collect();
        let (mut o1, mut o2) = (vec![0.0f32; d], vec![0.0f32; d]);
        plain.axpy_all_with(sc, &w, &mut o1);
        padded.axpy_all_with(sc, &w, &mut o2);
        assert_eq!(o1, o2);
    }

    /// The fused scores+max walk returns NEG_INFINITY on an empty store
    /// and agrees with a post-hoc fold otherwise, on every kernel path.
    #[test]
    fn fused_max_matches_fold_on_every_kernel() {
        use crate::simd::Kernels;
        let mut rng = Pcg64::new(21);
        for ks in Kernels::available() {
            let empty = SparseStore::new();
            let mut out = Vec::new();
            assert_eq!(empty.scores_max_into_with(ks, &[1.0; 4], 1.0, &mut out), f32::NEG_INFINITY);
            assert!(out.is_empty());

            let mut store = SparseStore::with_lanes(ks.lanes());
            for k in [1usize, 7, 16] {
                store.push_pruned(&rng.normal_vec(24), k, StorageMode::F32);
            }
            let q = rng.normal_vec(24);
            let mut scores = Vec::new();
            let m = store.scores_max_into_with(ks, &q, 0.7, &mut scores);
            assert_eq!(m, scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)));
        }
    }
}
