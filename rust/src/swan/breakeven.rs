//! Eq. 2: the computational break-even model (§5.2, Appendix A.2).
//!
//! `C_std  ≈ 4 L d_h`
//! `C_swan ≈ 4 d_h² + 4 (L − b) k_active + 4 b d_h`
//! break-even: `L > d_h² / (d_h − k_active) + b`.
//!
//! The `repro breakeven` command and the `attention_breakeven` bench verify
//! both the algebra (exact FLOP counts) and the measured-wallclock shape.

/// FLOPs of standard dense decode attention at sequence length `l`
/// (Proposition A.3).
pub fn flops_std(l: usize, d_h: usize) -> u64 {
    4 * l as u64 * d_h as u64
}

/// FLOPs of SWAN decode attention (Proposition A.4).
pub fn flops_swan(l: usize, d_h: usize, b: usize, k_active: usize) -> u64 {
    let dense_part = l.min(b);
    let sparse_part = l - dense_part;
    4 * (d_h as u64) * (d_h as u64)
        + 4 * sparse_part as u64 * k_active as u64
        + 4 * dense_part as u64 * d_h as u64
}

/// The break-even sequence length of Proposition A.5 (`None` when
/// `k_active >= d_h`, i.e. no per-token savings exist).
pub fn breakeven_length(d_h: usize, b: usize, k_active: usize) -> Option<f64> {
    if k_active >= d_h {
        return None;
    }
    Some((d_h * d_h) as f64 / (d_h - k_active) as f64 + b as f64)
}

/// Empirical break-even from the FLOP counters: smallest L where SWAN's
/// count drops below standard attention (scans up to `max_l`).
pub fn breakeven_by_counting(d_h: usize, b: usize, k_active: usize, max_l: usize) -> Option<usize> {
    (1..=max_l).find(|&l| flops_swan(l, d_h, b, k_active) < flops_std(l, d_h))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Appendix A.2.1 numeric examples, no buffer.
    #[test]
    fn paper_examples_b0() {
        assert_eq!(breakeven_length(128, 0, 32).unwrap().ceil() as usize, 171);
        assert_eq!(breakeven_length(128, 0, 64).unwrap() as usize, 256);
        assert_eq!(breakeven_length(128, 0, 96).unwrap() as usize, 512);
    }

    /// Appendix A.2.1 numeric examples, b = 128.
    #[test]
    fn paper_examples_b128() {
        assert_eq!(breakeven_length(128, 128, 32).unwrap().ceil() as usize, 299);
        assert_eq!(breakeven_length(128, 128, 64).unwrap() as usize, 384);
        assert_eq!(breakeven_length(128, 128, 96).unwrap() as usize, 640);
    }

    /// The closed form and the FLOP counters must agree.
    #[test]
    fn closed_form_matches_counters() {
        for d_h in [64usize, 128] {
            for b in [0usize, 64, 128] {
                for k in [d_h / 4, d_h / 2, 3 * d_h / 4] {
                    let formula = breakeven_length(d_h, b, k).unwrap();
                    let counted = breakeven_by_counting(d_h, b, k, 10_000).unwrap();
                    // counted L is the first strictly-cheaper length
                    assert!(
                        (counted as f64 - formula).abs() <= 2.0,
                        "d_h={d_h} b={b} k={k}: formula {formula} counted {counted}"
                    );
                }
            }
        }
    }

    #[test]
    fn no_breakeven_without_pruning() {
        assert!(breakeven_length(128, 0, 128).is_none());
        assert!(breakeven_by_counting(128, 0, 128, 100_000).is_none());
    }

    #[test]
    fn aggressive_pruning_breaks_even_sooner() {
        let a = breakeven_length(128, 64, 32).unwrap();
        let b = breakeven_length(128, 64, 96).unwrap();
        assert!(a < b);
    }

    #[test]
    fn flops_swan_below_std_beyond_breakeven() {
        let (d_h, b, k) = (128, 128, 64);
        let be = breakeven_length(d_h, b, k).unwrap() as usize;
        assert!(flops_swan(be + 1, d_h, b, k) < flops_std(be + 1, d_h));
        assert!(flops_swan(be.saturating_sub(10), d_h, b, k) >= flops_std(be - 10, d_h));
    }
}
