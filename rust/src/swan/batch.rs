//! Parallel batched execution: a small std-thread worker pool with a
//! reusable per-worker [`AttentionScratch`].
//!
//! The decompression-free kernel is embarrassingly parallel across the
//! `(sequence, layer, kv-head)` attention tasks that an iteration-level
//! scheduler forms every decode step, but the serial path paid two costs:
//! a fresh `Vec` allocation per `swan_attention` call, and one core.  This
//! module removes both:
//!
//! * [`AttentionScratch`] owns the score buffer so steady-state
//!   attention is allocation-free;
//! * [`WorkerPool`] keeps `n` workers alive across decode iterations, each
//!   with its *own* scratch — no sharing, no locking on the hot path.
//!
//! Determinism contract: the pool only changes *where* a task runs, never
//! what it computes.  Tasks must write exclusively to their own output
//! slices (the [`WorkerPool::for_each_mut`] API enforces this by handing
//! each task `&mut` access to one element), so batched-parallel results
//! are bit-identical to serial execution.  `tests/batch_decode.rs` locks
//! this down end-to-end.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::{lock_recover, wait_recover};

/// Reusable per-worker buffer for the attention walk: `scores` backs the
/// softmax row (sparse + buffer + current slots) and `tmp` backs whatever
/// per-task working set a fan-out needs (the parallel prefill packs its
/// norm/projection/MLP buffers into it).  Both keep their capacity across
/// tasks, so a warmed-up worker never reallocates.
#[derive(Default, Debug)]
pub struct AttentionScratch {
    pub scores: Vec<f32>,
    pub tmp: Vec<f32>,
}

impl AttentionScratch {
    pub fn new() -> AttentionScratch {
        AttentionScratch::default()
    }
}

/// A unit of work: runs on some worker with that worker's scratch.
type Job<'a> = Box<dyn FnOnce(&mut AttentionScratch) + Send + 'a>;
type StaticJob = Box<dyn FnOnce(&mut AttentionScratch) + Send + 'static>;

struct PoolState {
    jobs: VecDeque<StaticJob>,
    /// Jobs queued or currently running.
    pending: usize,
    /// Set when a job panicked; re-raised on the submitting thread.
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Signals workers that jobs (or shutdown) are available.
    work_cv: Condvar,
    /// Signals the submitter that `pending` reached zero.
    done_cv: Condvar,
}

/// A fixed-size worker pool for decode-step fan-out.
///
/// `threads == 0` is the *serial* pool: jobs run inline on the calling
/// thread against a single owned scratch.  This keeps one code path for
/// both execution modes (the engine just constructs a different pool),
/// which is what makes the serial-vs-parallel determinism test meaningful.
///
/// Submission takes `&mut self`: one batch in flight at a time, by
/// construction.  [`WorkerPool::run`] does not return until every
/// submitted job has completed, which is what makes it sound to run
/// non-`'static` jobs (see the safety note there).
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    /// Scratch for the serial (0-thread) pool.
    serial_scratch: AttentionScratch,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (0 = run jobs inline).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("swan-decode-{i}"))
                    .spawn(move || worker_loop(&shared))
                    // lint: allow(panic, "pool construction, before any request is admitted: a host that cannot spawn threads cannot serve, and no in-flight work exists to recover")
                    .expect("spawning decode worker")
            })
            .collect();
        WorkerPool { shared, handles, threads, serial_scratch: AttentionScratch::new() }
    }

    /// Serial pool: every job runs inline on the caller's thread.
    pub fn serial() -> WorkerPool {
        WorkerPool::new(0)
    }

    /// Worker count for the host: `available_parallelism`, capped at 16
    /// (decode tasks are memory-bound and stop scaling past that).
    pub fn recommended_threads() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
    }

    /// Pool sized to the host via [`WorkerPool::recommended_threads`].
    pub fn host_sized() -> WorkerPool {
        WorkerPool::new(WorkerPool::recommended_threads())
    }

    /// Number of worker threads (0 = serial).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run a batch of jobs to completion.  Blocks until every job has
    /// finished; re-raises a panic if any job panicked.
    pub fn run<'a, I>(&mut self, jobs: I)
    where
        I: IntoIterator<Item = Job<'a>>,
    {
        if self.threads == 0 {
            for job in jobs {
                job(&mut self.serial_scratch);
            }
            return;
        }
        // SAFETY: the jobs may borrow data with lifetime 'a (shorter than
        // 'static).  Erasing the lifetime is sound because this function
        // does not return until `pending` drops back to zero, i.e. until
        // every erased job has been executed (or the panic flag traded for
        // it); no job can outlive the borrows it captured.  The panic path
        // still decrements `pending` (see `worker_loop`), so the wait
        // below cannot be skipped or starved.
        let jobs: Vec<StaticJob> = jobs
            .into_iter()
            .map(|j| unsafe { std::mem::transmute::<Job<'a>, StaticJob>(j) })
            .collect();
        if jobs.is_empty() {
            return;
        }
        let n = jobs.len();
        {
            let mut st = lock_recover(&self.shared.state);
            st.pending += n;
            st.jobs.extend(jobs);
        }
        self.shared.work_cv.notify_all();
        let mut st = lock_recover(&self.shared.state);
        while st.pending > 0 {
            st = wait_recover(&self.shared.done_cv, st);
        }
        if st.panicked {
            st.panicked = false;
            drop(st);
            // lint: allow(panic, "deliberate re-raise of a caught worker panic on the submitting thread; the shard supervisor converts it into shard-death + exact-replay recovery")
            panic!("a decode worker task panicked");
        }
    }

    /// Run `f` once per element of `tasks`, fanned across the workers in
    /// contiguous chunks.  Each invocation gets the executing worker's
    /// scratch and exclusive `&mut` access to its task — tasks cannot
    /// observe each other, so the result is identical to the serial loop
    /// `for t in tasks { f(scratch, t) }` regardless of thread count.
    pub fn for_each_mut<T, F>(&mut self, tasks: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut AttentionScratch, &mut T) + Sync,
    {
        if tasks.is_empty() {
            return;
        }
        if self.threads == 0 {
            for t in tasks.iter_mut() {
                f(&mut self.serial_scratch, t);
            }
            return;
        }
        // Small chunks (4 per worker) balance load when per-task cost is
        // skewed (sequences at different lengths) without boxing one job
        // per task.
        let chunk = tasks.len().div_ceil(self.threads * 4).max(1);
        let f = &f;
        let jobs = tasks.chunks_mut(chunk).map(|c| {
            // lint: allow(hot_alloc, "one boxed closure per worker chunk (threads*4 per step), amortized over the chunk's sequences")
            Box::new(move |scratch: &mut AttentionScratch| {
                for t in c {
                    f(scratch, t);
                }
            }) as Job<'_>
        });
        // collect into Vec so `run` sees the concrete iterator type
        let jobs: Vec<Job<'_>> = jobs.collect();
        self.run(jobs);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    let mut scratch = AttentionScratch::new();
    loop {
        let job = {
            let mut st = lock_recover(&shared.state);
            loop {
                if let Some(j) = st.jobs.pop_front() {
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = wait_recover(&shared.work_cv, st);
            }
        };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            job(&mut scratch);
        }))
        .is_ok();
        let mut st = lock_recover(&shared.state);
        st.pending -= 1;
        if !ok {
            st.panicked = true;
        }
        if st.pending == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_pool_runs_inline() {
        let mut pool = WorkerPool::serial();
        let mut xs = vec![0usize; 10];
        pool.for_each_mut(&mut xs, |_s, x| *x += 1);
        assert!(xs.iter().all(|&x| x == 1));
        assert_eq!(pool.threads(), 0);
    }

    #[test]
    fn parallel_pool_executes_every_task_once() {
        let mut pool = WorkerPool::new(4);
        let mut xs: Vec<usize> = (0..1000).collect();
        pool.for_each_mut(&mut xs, |_s, x| *x *= 2);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(x, i * 2);
        }
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let mut pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..5 {
            let mut xs = vec![(); 64];
            pool.for_each_mut(&mut xs, |_s, _x| {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(counter.load(Ordering::Relaxed), 5 * 64);
    }

    #[test]
    fn borrowed_jobs_complete_before_run_returns() {
        let mut pool = WorkerPool::new(3);
        let data: Vec<usize> = (0..256).collect();
        // explicit run() with closures borrowing non-'static stack data
        let total = Mutex::new(0usize);
        let jobs: Vec<Job<'_>> = data
            .chunks(64)
            .map(|c| {
                let total = &total;
                Box::new(move |_s: &mut AttentionScratch| {
                    let sum: usize = c.iter().sum();
                    *total.lock().unwrap() += sum;
                }) as Job<'_>
            })
            .collect();
        pool.run(jobs);
        assert_eq!(*total.lock().unwrap(), (0..256).sum::<usize>());
    }

    #[test]
    fn scratch_capacity_is_retained() {
        let mut pool = WorkerPool::serial();
        let mut once = [()];
        pool.for_each_mut(&mut once, |s, _| {
            s.scores.extend_from_slice(&[1.0; 128]);
            s.scores.clear();
        });
        let mut caps = [0usize];
        pool.for_each_mut(&mut caps, |s, c| *c = s.scores.capacity());
        assert!(caps[0] >= 128, "scratch capacity lost: {}", caps[0]);
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let result = std::panic::catch_unwind(|| {
            let mut pool = WorkerPool::new(2);
            let mut xs = vec![0usize; 8];
            pool.for_each_mut(&mut xs, |_s, x| {
                if *x == 0 {
                    panic!("boom");
                }
            });
        });
        assert!(result.is_err());
    }
}
