//! Projection matrices (§4.1) on the rust side: loading the calibrated
//! P_QK / P_VO from the weight artifacts, applying rotations, and building
//! the Table-3 ablation variants (random / layer-shuffle / head-shuffle /
//! KV-shuffle).

use crate::tensor::linalg::gram_schmidt_orthonormal;
use crate::tensor::ops::vecmat;
use crate::util::Pcg64;

/// Per-model projection set: `[n_layers][n_kv]` matrices of `d_h x d_h`
/// (row-major; rotation is `x @ P`).
#[derive(Clone, Debug)]
pub struct ProjectionSet {
    pub d_h: usize,
    pub n_layers: usize,
    pub n_kv: usize,
    /// p_qk[layer][kv_head] flattened d_h*d_h
    pub p_qk: Vec<Vec<Vec<f32>>>,
    pub p_vo: Vec<Vec<Vec<f32>>>,
}

/// Table-3 ablation variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionVariant {
    /// The calibrated, component-specific projections (ours).
    Calibrated,
    /// Orthonormalised Gaussian matrices (data-free baseline).
    Random,
    /// Projections shuffled across layers.
    LayerShuffle,
    /// Projections shuffled across heads within each layer.
    HeadShuffle,
    /// P_QK and P_VO interchanged.
    KvShuffle,
    /// Identity rotation (sanity floor: pure magnitude pruning in the
    /// original basis).
    Identity,
}

impl ProjectionVariant {
    pub fn label(self) -> &'static str {
        match self {
            ProjectionVariant::Calibrated => "Our Projection",
            ProjectionVariant::Random => "Random Projection",
            ProjectionVariant::LayerShuffle => "Layer-Shuffle",
            ProjectionVariant::HeadShuffle => "Head-Shuffle",
            ProjectionVariant::KvShuffle => "KV-Shuffle",
            ProjectionVariant::Identity => "Identity (no rotation)",
        }
    }

    pub const ALL: [ProjectionVariant; 6] = [
        ProjectionVariant::Calibrated,
        ProjectionVariant::HeadShuffle,
        ProjectionVariant::LayerShuffle,
        ProjectionVariant::KvShuffle,
        ProjectionVariant::Random,
        ProjectionVariant::Identity,
    ];
}

impl ProjectionSet {
    pub fn identity(n_layers: usize, n_kv: usize, d_h: usize) -> ProjectionSet {
        let mut eye = vec![0.0f32; d_h * d_h];
        for i in 0..d_h {
            eye[i * d_h + i] = 1.0;
        }
        ProjectionSet {
            d_h,
            n_layers,
            n_kv,
            p_qk: vec![vec![eye.clone(); n_kv]; n_layers],
            p_vo: vec![vec![eye; n_kv]; n_layers],
        }
    }

    /// Random orthogonal projections (Table 3 "Random Projection").
    pub fn random(n_layers: usize, n_kv: usize, d_h: usize, seed: u64) -> ProjectionSet {
        let mut rng = Pcg64::new(seed);
        let mut gen = || {
            let mut m = rng.normal_vec(d_h * d_h);
            gram_schmidt_orthonormal(&mut m, d_h);
            m
        };
        ProjectionSet {
            d_h,
            n_layers,
            n_kv,
            p_qk: (0..n_layers).map(|_| (0..n_kv).map(|_| gen()).collect()).collect(),
            p_vo: (0..n_layers).map(|_| (0..n_kv).map(|_| gen()).collect()).collect(),
        }
    }

    /// Apply a Table-3 ablation to this (calibrated) set.
    pub fn ablate(&self, variant: ProjectionVariant, seed: u64) -> ProjectionSet {
        let mut rng = Pcg64::new(seed);
        match variant {
            ProjectionVariant::Calibrated => self.clone(),
            ProjectionVariant::Identity => {
                ProjectionSet::identity(self.n_layers, self.n_kv, self.d_h)
            }
            ProjectionVariant::Random => {
                ProjectionSet::random(self.n_layers, self.n_kv, self.d_h, seed)
            }
            ProjectionVariant::LayerShuffle => {
                let mut order: Vec<usize> = (0..self.n_layers).collect();
                // derangement-ish: rotate by one then shuffle lightly
                order.rotate_left(1);
                if self.n_layers > 2 {
                    rng.shuffle(&mut order[..self.n_layers - 1]);
                }
                let mut out = self.clone();
                for (l, &src) in order.iter().enumerate() {
                    out.p_qk[l] = self.p_qk[src].clone();
                    out.p_vo[l] = self.p_vo[src].clone();
                }
                out
            }
            ProjectionVariant::HeadShuffle => {
                let mut out = self.clone();
                for l in 0..self.n_layers {
                    let mut order: Vec<usize> = (0..self.n_kv).collect();
                    order.rotate_left(1.min(self.n_kv - 1));
                    if self.n_kv > 2 {
                        rng.shuffle(&mut order[..self.n_kv - 1]);
                    }
                    for (h, &src) in order.iter().enumerate() {
                        out.p_qk[l][h] = self.p_qk[l][src].clone();
                        out.p_vo[l][h] = self.p_vo[l][src].clone();
                    }
                }
                out
            }
            ProjectionVariant::KvShuffle => {
                let mut out = self.clone();
                std::mem::swap(&mut out.p_qk, &mut out.p_vo);
                out
            }
        }
    }

    /// Rotate a d_h vector: `out = x @ p_qk[layer][head]`.
    pub fn rotate_qk(&self, layer: usize, head: usize, x: &[f32], out: &mut [f32]) {
        vecmat(x, &self.p_qk[layer][head], self.d_h, self.d_h, out);
    }

    pub fn rotate_vo(&self, layer: usize, head: usize, x: &[f32], out: &mut [f32]) {
        vecmat(x, &self.p_vo[layer][head], self.d_h, self.d_h, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg::orthonormality_error;
    use crate::tensor::ops::dot;

    #[test]
    fn identity_rotation_is_noop() {
        let ps = ProjectionSet::identity(2, 2, 8);
        let x: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let mut out = vec![0.0; 8];
        ps.rotate_qk(0, 1, &x, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn random_projections_orthogonal_and_distinct() {
        let ps = ProjectionSet::random(2, 2, 16, 7);
        for l in 0..2 {
            for h in 0..2 {
                assert!(orthonormality_error(&ps.p_qk[l][h], 16) < 1e-4);
            }
        }
        assert_ne!(ps.p_qk[0][0], ps.p_qk[1][0]);
        assert_ne!(ps.p_qk[0][0], ps.p_vo[0][0]);
    }

    #[test]
    fn rotation_preserves_dot_products() {
        let ps = ProjectionSet::random(1, 1, 32, 3);
        let mut r = Pcg64::new(0);
        let q = r.normal_vec(32);
        let k = r.normal_vec(32);
        let mut qr = vec![0.0; 32];
        let mut kr = vec![0.0; 32];
        ps.rotate_qk(0, 0, &q, &mut qr);
        ps.rotate_qk(0, 0, &k, &mut kr);
        assert!((dot(&q, &k) - dot(&qr, &kr)).abs() < 1e-3);
    }

    #[test]
    fn layer_shuffle_permutes() {
        let base = ProjectionSet::random(4, 1, 8, 1);
        let sh = base.ablate(ProjectionVariant::LayerShuffle, 2);
        // every layer's matrix still exists somewhere, but at least one moved
        let mut moved = false;
        for l in 0..4 {
            if sh.p_qk[l][0] != base.p_qk[l][0] {
                moved = true;
            }
            assert!(base.p_qk.iter().any(|layer| layer[0] == sh.p_qk[l][0]));
        }
        assert!(moved);
    }

    #[test]
    fn kv_shuffle_swaps() {
        let base = ProjectionSet::random(2, 1, 8, 1);
        let sh = base.ablate(ProjectionVariant::KvShuffle, 0);
        assert_eq!(sh.p_qk[0][0], base.p_vo[0][0]);
        assert_eq!(sh.p_vo[1][0], base.p_qk[1][0]);
    }

    #[test]
    fn head_shuffle_within_layer() {
        let base = ProjectionSet::random(1, 4, 8, 1);
        let sh = base.ablate(ProjectionVariant::HeadShuffle, 3);
        let mut moved = false;
        for h in 0..4 {
            if sh.p_qk[0][h] != base.p_qk[0][h] {
                moved = true;
            }
            assert!(base.p_qk[0].iter().any(|m| *m == sh.p_qk[0][h]));
        }
        assert!(moved);
    }
}
