//! The hybrid KV-cache of Algorithm 1: a small dense ring buffer of recent
//! rotated vectors plus the growing sparse (winnowed) historical store.
//!
//! One `HybridCache` instance serves one (layer, kv-head) pair of one
//! sequence.  Appending a new rotated (k̂, v̂) pair may evict the oldest
//! buffer entry, which is magnitude-pruned (separate I_k / I_v index sets)
//! and moved to the sparse store — compression work happens once per token,
//! attention never decompresses.
//!
//! # Buffer layout
//!
//! The dense recency buffer really is a ring: a fixed `[buffer, d_h]`
//! allocation plus a `head` index pointing at the oldest row.  Eviction
//! winnows the row under `head` straight out of the ring and advances the
//! index — no element is ever moved, so steady-state appends cost
//! O(k log d) for the winnow plus one row copy, never an O(buffer · d_h)
//! shift.  Readers get the logically-oldest-first contents as a two-slice
//! view ([`HybridCache::k_buffer`] / [`HybridCache::v_buffer`]): the run
//! from `head` to the end of the allocation, then the wrapped run from the
//! start.  Either slice may be empty; their concatenation is always the
//! FIFO order the attention kernel walks.

use crate::sparse::{SparseStore, StorageMode};

/// Tunable SWAN parameters.  `k_active` may be changed *at runtime*
/// between steps (the paper's runtime-adaptability claim): already-pruned
/// entries keep their old k, new evictions use the new value.
#[derive(Clone, Copy, Debug)]
pub struct SwanParams {
    /// Retained dims for evicted key vectors.
    pub k_active_keys: usize,
    /// Retained dims for evicted value vectors (Table 2 studies asymmetric
    /// settings; defaults equal).
    pub k_active_vals: usize,
    /// Dense buffer capacity in tokens (`bt` in the figures).
    pub buffer: usize,
    /// Value storage precision.
    pub mode: StorageMode,
    /// Lane multiple the sparse stores pad rows to.  `0` (the
    /// [`SwanParams::new`] default) means "resolve from the active kernel
    /// set when the cache is built" — deferring the lookup to
    /// [`HybridCache::new`] keeps params constructed *before* a
    /// `--kernels`/`SWAN_KERNELS` pin consistent with the final selection.
    pub lanes: usize,
}

impl SwanParams {
    pub fn new(k_active: usize, buffer: usize, mode: StorageMode) -> SwanParams {
        SwanParams {
            k_active_keys: k_active,
            k_active_vals: k_active,
            buffer,
            mode,
            lanes: 0, // auto: resolved against simd::active() at cache build
        }
    }

    /// Override the sparse-row lane padding (tests/benches pin layouts).
    pub fn with_lanes(mut self, lanes: usize) -> SwanParams {
        self.lanes = lanes.max(1);
        self
    }

    /// The lane padding this params set resolves to right now: the pinned
    /// value, or the active kernel set's width when left on auto.
    pub fn resolved_lanes(&self) -> usize {
        if self.lanes == 0 {
            crate::simd::active().lanes()
        } else {
            self.lanes
        }
    }

    /// Retention ratio (k_active / d_h) for reporting.
    pub fn retention(&self, d_h: usize) -> f64 {
        self.k_active_keys as f64 / d_h as f64
    }
}

/// Hybrid sparse + buffer cache for one (layer, kv-head).
#[derive(Clone, Debug)]
pub struct HybridCache {
    pub params: SwanParams,
    d_h: usize,
    /// Sparse historical store, oldest first (contiguous CSR — see
    /// EXPERIMENTS.md §Perf for the layout rationale).
    pub k_sparse: SparseStore,
    pub v_sparse: SparseStore,
    /// Dense recency ring, fixed `[params.buffer, d_h]` allocation.
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    /// Ring slot of the oldest live row (0 when empty).
    head: usize,
    buf_len: usize,
}

impl HybridCache {
    pub fn new(d_h: usize, params: SwanParams) -> HybridCache {
        let mut params = params;
        // resolve auto lane padding against the *current* kernel selection
        // (not whenever the params happened to be constructed)
        params.lanes = params.resolved_lanes();
        HybridCache {
            params,
            d_h,
            k_sparse: SparseStore::with_lanes(params.lanes),
            v_sparse: SparseStore::with_lanes(params.lanes),
            k_buf: vec![0.0; params.buffer * d_h],
            v_buf: vec![0.0; params.buffer * d_h],
            head: 0,
            buf_len: 0,
        }
    }

    pub fn d_h(&self) -> usize {
        self.d_h
    }

    /// Tokens in the dense buffer.
    pub fn buffer_len(&self) -> usize {
        self.buf_len
    }

    /// Tokens in the sparse store.
    pub fn sparse_len(&self) -> usize {
        self.k_sparse.len()
    }

    /// Total tokens cached.
    pub fn len(&self) -> usize {
        self.buf_len + self.k_sparse.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Oldest-first view of one ring: the run from `head` up, then the
    /// wrapped run from slot 0.
    fn ring_view<'a>(&self, buf: &'a [f32]) -> (&'a [f32], &'a [f32]) {
        let d = self.d_h;
        let cap = self.params.buffer;
        if self.buf_len == 0 {
            return (&[], &[]);
        }
        let first = (cap - self.head).min(self.buf_len);
        let wrapped = self.buf_len - first;
        (
            &buf[self.head * d..(self.head + first) * d],
            &buf[..wrapped * d],
        )
    }

    /// Buffer keys as an oldest-first two-slice view (`[n0, d_h]` then
    /// `[n1, d_h]`, either possibly empty); concatenated they are the FIFO
    /// contents.  Callers iterate both runs without any copy.
    pub fn k_buffer(&self) -> (&[f32], &[f32]) {
        self.ring_view(&self.k_buf)
    }

    /// Buffer values, same two-slice contract as [`HybridCache::k_buffer`].
    pub fn v_buffer(&self) -> (&[f32], &[f32]) {
        self.ring_view(&self.v_buf)
    }

    /// Change the compression level at runtime (paper §"runtime
    /// adaptability").  Existing sparse entries are untouched.
    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.params.k_active_keys = k_keys.min(self.d_h);
        self.params.k_active_vals = k_vals.min(self.d_h);
    }

    /// One head's attention over this cache plus the current token —
    /// read-only, so a step's attention tasks can borrow a sequence's
    /// caches immutably across workers (the batched decode read phase).
    /// `scores` is the caller's reusable buffer (cleared here); see
    /// [`crate::swan::batch::AttentionScratch`].
    pub fn attend(
        &self,
        q_hat: &[f32],
        k_hat_cur: &[f32],
        v_hat_cur: &[f32],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        crate::swan::attention::swan_attention_scratch(
            q_hat, self, k_hat_cur, v_hat_cur, scores, out,
        );
    }

    /// Append a rotated (k̂, v̂) pair (Algorithm 1 lines 3-12).  If the
    /// buffer is at capacity, the oldest entry is winnowed into the sparse
    /// store first (FIFO); with a zero-capacity buffer the incoming pair
    /// is winnowed directly.
    pub fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        debug_assert_eq!(k_hat.len(), self.d_h);
        debug_assert_eq!(v_hat.len(), self.d_h);
        let cap = self.params.buffer;
        if cap == 0 {
            // bt = 0: every token is winnowed the step it arrives —
            // identical to passing through a 1-deep staging slot
            self.k_sparse.push_pruned(k_hat, self.params.k_active_keys, self.params.mode);
            self.v_sparse.push_pruned(v_hat, self.params.k_active_vals, self.params.mode);
            return;
        }
        if self.buf_len == cap {
            self.evict_oldest();
        }
        let d = self.d_h;
        let slot = (self.head + self.buf_len) % cap;
        self.k_buf[slot * d..(slot + 1) * d].copy_from_slice(k_hat);
        self.v_buf[slot * d..(slot + 1) * d].copy_from_slice(v_hat);
        self.buf_len += 1;
    }

    /// Winnow the oldest dense pair (separate I_k / I_v) into the sparse
    /// store and advance the ring head.  No data moves: the row is pruned
    /// in place and its slot is simply reused by a later append.
    fn evict_oldest(&mut self) {
        debug_assert!(self.buf_len > 0);
        let d = self.d_h;
        let off = self.head * d;
        self.k_sparse.push_pruned(
            &self.k_buf[off..off + d],
            self.params.k_active_keys,
            self.params.mode,
        );
        self.v_sparse.push_pruned(
            &self.v_buf[off..off + d],
            self.params.k_active_vals,
            self.params.mode,
        );
        self.head = (self.head + 1) % self.params.buffer;
        self.buf_len -= 1;
    }

    /// Bulk-load a prefill history: all but the last `buffer` tokens are
    /// winnowed straight into the sparse stores (one pass, no per-token
    /// buffer traffic), the tail is copied into the ring.  `k_hats` /
    /// `v_hats` are `[n, d_h]` flat (oldest first).  Works on a non-empty
    /// cache too: existing buffered rows spill first, in FIFO order —
    /// bit-identical to appending token by token.
    pub fn load_prefill(&mut self, k_hats: &[f32], v_hats: &[f32]) {
        let d = self.d_h;
        let n = k_hats.len() / d;
        debug_assert_eq!(k_hats.len(), n * d);
        debug_assert_eq!(v_hats.len(), n * d);
        let cap = self.params.buffer;
        let spill = (self.buf_len + n).saturating_sub(cap);
        // oldest spilled rows come from the existing ring ...
        let spill_old = spill.min(self.buf_len);
        for _ in 0..spill_old {
            self.evict_oldest();
        }
        // ... then from the head of the incoming stream, winnowed without
        // ever touching the buffer
        let spill_new = spill - spill_old;
        for t in 0..spill_new {
            self.k_sparse.push_pruned(
                &k_hats[t * d..(t + 1) * d],
                self.params.k_active_keys,
                self.params.mode,
            );
            self.v_sparse.push_pruned(
                &v_hats[t * d..(t + 1) * d],
                self.params.k_active_vals,
                self.params.mode,
            );
        }
        // the tail stays dense (cap == 0 never reaches here: everything
        // spilled, spill_new == n)
        for t in spill_new..n {
            let slot = (self.head + self.buf_len) % cap;
            self.k_buf[slot * d..(slot + 1) * d].copy_from_slice(&k_hats[t * d..(t + 1) * d]);
            self.v_buf[slot * d..(slot + 1) * d].copy_from_slice(&v_hats[t * d..(t + 1) * d]);
            self.buf_len += 1;
        }
    }

    /// Stored bytes of the cache under serving accounting (Eq. 1 for the
    /// sparse part, f16 convention for the dense buffer).
    pub fn storage_bytes(&self) -> usize {
        let sparse = self.k_sparse.storage_bytes() + self.v_sparse.storage_bytes();
        let dense = 2 * self.buf_len * self.d_h * 2; // k+v, f16
        sparse + dense
    }

    /// Bytes a dense cache of the same token count would use.
    pub fn dense_equiv_bytes(&self) -> usize {
        2 * self.len() * self.d_h * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn mk(buffer: usize, k: usize) -> HybridCache {
        HybridCache::new(32, SwanParams::new(k, buffer, StorageMode::F16))
    }

    /// Flatten the two-slice ring view into oldest-first rows.
    fn flat(view: (&[f32], &[f32])) -> Vec<f32> {
        let mut v = view.0.to_vec();
        v.extend_from_slice(view.1);
        v
    }

    #[test]
    fn buffer_fills_before_sparse() {
        let mut c = mk(4, 8);
        let mut r = Pcg64::new(0);
        for _ in 0..4 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        assert_eq!(c.buffer_len(), 4);
        assert_eq!(c.sparse_len(), 0);
        c.append(&r.normal_vec(32), &r.normal_vec(32));
        assert_eq!(c.buffer_len(), 4);
        assert_eq!(c.sparse_len(), 1);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn eviction_is_fifo() {
        let mut c = mk(2, 32); // full retention: values survive exactly
        let mut vecs = Vec::new();
        let mut r = Pcg64::new(1);
        for _ in 0..5 {
            let k = r.normal_vec(32);
            let v = r.normal_vec(32);
            c.append(&k, &v);
            vecs.push(k);
        }
        assert_eq!(c.sparse_len(), 3);
        for i in 0..c.k_sparse.len() {
            let rec = c.k_sparse.reconstruct(i, 32);
            for (a, b) in rec.iter().zip(&vecs[i]) {
                assert!((a - crate::util::fp::quantize_f16(*b)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_buffer_prunes_all_but_current() {
        let mut c = mk(0, 8);
        let mut r = Pcg64::new(2);
        for _ in 0..3 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        assert_eq!(c.buffer_len(), 0);
        assert_eq!(c.sparse_len(), 3);
    }

    #[test]
    fn runtime_k_change_applies_to_new_evictions_only() {
        let mut c = mk(1, 16);
        let mut r = Pcg64::new(3);
        c.append(&r.normal_vec(32), &r.normal_vec(32));
        c.append(&r.normal_vec(32), &r.normal_vec(32)); // evicts with k=16
        c.set_k_active(4, 4);
        c.append(&r.normal_vec(32), &r.normal_vec(32)); // evicts with k=4
        assert_eq!(c.k_sparse.nnz(0), 16);
        assert_eq!(c.k_sparse.nnz(1), 4);
    }

    #[test]
    fn storage_accounting() {
        let mut c = mk(2, 8);
        let mut r = Pcg64::new(4);
        for _ in 0..6 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        // 4 sparse tokens * 2 vectors * (3*8+2) + 2 dense tokens * 2 * 32 * 2
        assert_eq!(c.storage_bytes(), 4 * 2 * 26 + 2 * 2 * 32 * 2);
        assert_eq!(c.dense_equiv_bytes(), 6 * 2 * 32 * 2);
        assert!(c.storage_bytes() < c.dense_equiv_bytes());
    }

    #[test]
    fn load_prefill_splits_correctly() {
        let mut c = mk(3, 8);
        let mut r = Pcg64::new(5);
        let n = 10;
        let ks = r.normal_vec(n * 32);
        let vs = r.normal_vec(n * 32);
        c.load_prefill(&ks, &vs);
        assert_eq!(c.buffer_len(), 3);
        assert_eq!(c.sparse_len(), 7);
        // buffer holds the *last* 3 tokens, oldest first
        let kb = flat(c.k_buffer());
        assert_eq!(&kb[..32], &ks[7 * 32..8 * 32]);
        assert_eq!(&kb[2 * 32..3 * 32], &ks[9 * 32..10 * 32]);
    }

    /// The ring view is oldest-first across the wrap point: after more
    /// appends than capacity, concatenating the two slices must equal the
    /// last `buffer` appended rows in order.
    #[test]
    fn ring_view_is_fifo_across_wraparound() {
        let d = 32;
        for buffer in [1usize, 2, 3, 5] {
            let mut c = mk(buffer, 32);
            let mut r = Pcg64::new(6);
            let mut rows: Vec<Vec<f32>> = Vec::new();
            for i in 0..(3 * buffer + 1) {
                let k = r.normal_vec(d);
                let v = r.normal_vec(d);
                c.append(&k, &v);
                rows.push(k.clone());
                let (a, b) = c.k_buffer();
                assert_eq!(a.len() + b.len(), c.buffer_len() * d, "bt={buffer} i={i}");
                let got = flat(c.k_buffer());
                let want: Vec<f32> = rows
                    [rows.len().saturating_sub(buffer)..]
                    .iter()
                    .flat_map(|r| r.iter().copied())
                    .collect();
                assert_eq!(got, want, "bt={buffer} after {} appends", i + 1);
            }
        }
    }

    /// Bulk load on a partially-filled cache spills the existing rows
    /// first, exactly like token-by-token appends would.
    #[test]
    fn load_prefill_on_nonempty_cache_matches_appends() {
        let d = 32;
        let mut r = Pcg64::new(7);
        let pre: Vec<(Vec<f32>, Vec<f32>)> =
            (0..2).map(|_| (r.normal_vec(d), r.normal_vec(d))).collect();
        let n = 6;
        let ks = r.normal_vec(n * d);
        let vs = r.normal_vec(n * d);

        let mut bulk = mk(3, 8);
        let mut serial = mk(3, 8);
        for (k, v) in &pre {
            bulk.append(k, v);
            serial.append(k, v);
        }
        bulk.load_prefill(&ks, &vs);
        for t in 0..n {
            serial.append(&ks[t * d..(t + 1) * d], &vs[t * d..(t + 1) * d]);
        }
        assert_eq!(bulk.sparse_len(), serial.sparse_len());
        assert_eq!(bulk.buffer_len(), serial.buffer_len());
        assert_eq!(flat(bulk.k_buffer()), flat(serial.k_buffer()));
        assert_eq!(flat(bulk.v_buffer()), flat(serial.v_buffer()));
        for i in 0..bulk.sparse_len() {
            assert_eq!(
                bulk.k_sparse.reconstruct(i, d),
                serial.k_sparse.reconstruct(i, d),
                "sparse row {i}"
            );
        }
    }

    /// Auto lane params resolve against the kernel selection at *cache*
    /// construction; pinned params stay pinned.
    #[test]
    fn lanes_resolve_at_cache_build() {
        let auto = SwanParams::new(8, 2, StorageMode::F16);
        assert_eq!(auto.lanes, 0, "new() must defer lane resolution");
        let c = HybridCache::new(16, auto);
        assert_eq!(c.params.lanes, crate::simd::active().lanes());
        assert_eq!(c.k_sparse.lanes(), crate::simd::active().lanes());
        let pinned = SwanParams::new(8, 2, StorageMode::F16).with_lanes(4);
        let c2 = HybridCache::new(16, pinned);
        assert_eq!(c2.params.lanes, 4);
        assert_eq!(c2.k_sparse.lanes(), 4);
    }
}
