//! The hybrid KV-cache of Algorithm 1: a small dense ring buffer of recent
//! rotated vectors plus the growing sparse (winnowed) historical store.
//!
//! One `HybridCache` instance serves one (layer, kv-head) pair of one
//! sequence.  Appending a new rotated (k̂, v̂) pair may evict the oldest
//! buffer entry, which is magnitude-pruned (separate I_k / I_v index sets)
//! and moved to the sparse store — compression work happens once per token,
//! attention never decompresses.

use crate::sparse::{SparseStore, StorageMode};

/// Tunable SWAN parameters.  `k_active` may be changed *at runtime*
/// between steps (the paper's runtime-adaptability claim): already-pruned
/// entries keep their old k, new evictions use the new value.
#[derive(Clone, Copy, Debug)]
pub struct SwanParams {
    /// Retained dims for evicted key vectors.
    pub k_active_keys: usize,
    /// Retained dims for evicted value vectors (Table 2 studies asymmetric
    /// settings; defaults equal).
    pub k_active_vals: usize,
    /// Dense buffer capacity in tokens (`bt` in the figures).
    pub buffer: usize,
    /// Value storage precision.
    pub mode: StorageMode,
    /// Lane multiple the sparse stores pad rows to (defaults to the
    /// active kernel set's width, so AVX2 hosts get tail-free gather rows
    /// transparently; results and Eq. 1 accounting are unaffected).
    pub lanes: usize,
}

impl SwanParams {
    pub fn new(k_active: usize, buffer: usize, mode: StorageMode) -> SwanParams {
        SwanParams {
            k_active_keys: k_active,
            k_active_vals: k_active,
            buffer,
            mode,
            lanes: crate::simd::active().lanes(),
        }
    }

    /// Override the sparse-row lane padding (tests/benches pin layouts).
    pub fn with_lanes(mut self, lanes: usize) -> SwanParams {
        self.lanes = lanes.max(1);
        self
    }

    /// Retention ratio (k_active / d_h) for reporting.
    pub fn retention(&self, d_h: usize) -> f64 {
        self.k_active_keys as f64 / d_h as f64
    }
}

/// Hybrid sparse + buffer cache for one (layer, kv-head).
#[derive(Clone, Debug)]
pub struct HybridCache {
    pub params: SwanParams,
    d_h: usize,
    /// Sparse historical store, oldest first (contiguous CSR — see
    /// EXPERIMENTS.md §Perf for the layout rationale).
    pub k_sparse: SparseStore,
    pub v_sparse: SparseStore,
    /// Dense recency buffer, oldest first (flat [n, d_h] storage).
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    buf_len: usize,
}

impl HybridCache {
    pub fn new(d_h: usize, params: SwanParams) -> HybridCache {
        HybridCache {
            params,
            d_h,
            k_sparse: SparseStore::with_lanes(params.lanes),
            v_sparse: SparseStore::with_lanes(params.lanes),
            k_buf: Vec::with_capacity((params.buffer + 1) * d_h),
            v_buf: Vec::with_capacity((params.buffer + 1) * d_h),
            buf_len: 0,
        }
    }

    pub fn d_h(&self) -> usize {
        self.d_h
    }

    /// Tokens in the dense buffer.
    pub fn buffer_len(&self) -> usize {
        self.buf_len
    }

    /// Tokens in the sparse store.
    pub fn sparse_len(&self) -> usize {
        self.k_sparse.len()
    }

    /// Total tokens cached.
    pub fn len(&self) -> usize {
        self.buf_len + self.k_sparse.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Buffer contents as flat [buffer_len, d_h] slices (oldest first).
    pub fn k_buffer(&self) -> &[f32] {
        &self.k_buf[..self.buf_len * self.d_h]
    }

    pub fn v_buffer(&self) -> &[f32] {
        &self.v_buf[..self.buf_len * self.d_h]
    }

    /// Change the compression level at runtime (paper §"runtime
    /// adaptability").  Existing sparse entries are untouched.
    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.params.k_active_keys = k_keys.min(self.d_h);
        self.params.k_active_vals = k_vals.min(self.d_h);
    }

    /// One head's attention over this cache plus the current token —
    /// read-only, so a step's attention tasks can borrow a sequence's
    /// caches immutably across workers (the batched decode read phase).
    /// `scores` is the caller's reusable buffer (cleared here); see
    /// [`crate::swan::batch::AttentionScratch`].
    pub fn attend(
        &self,
        q_hat: &[f32],
        k_hat_cur: &[f32],
        v_hat_cur: &[f32],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        crate::swan::attention::swan_attention_scratch(
            q_hat, self, k_hat_cur, v_hat_cur, scores, out,
        );
    }

    /// Append a rotated (k̂, v̂) pair (Algorithm 1 lines 3-12).  If the
    /// buffer is over capacity, the oldest entry is winnowed into the
    /// sparse store.
    pub fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        debug_assert_eq!(k_hat.len(), self.d_h);
        debug_assert_eq!(v_hat.len(), self.d_h);
        self.k_buf.extend_from_slice(k_hat);
        self.v_buf.extend_from_slice(v_hat);
        self.buf_len += 1;
        while self.buf_len > self.params.buffer {
            self.evict_oldest();
        }
    }

    /// Pop the oldest dense pair, winnow it (separate I_k / I_v) and move
    /// it to the sparse store.
    fn evict_oldest(&mut self) {
        let d = self.d_h;
        let k_old: Vec<f32> = self.k_buf.drain(..d).collect();
        let v_old: Vec<f32> = self.v_buf.drain(..d).collect();
        self.buf_len -= 1;
        self.k_sparse.push_pruned(&k_old, self.params.k_active_keys, self.params.mode);
        self.v_sparse.push_pruned(&v_old, self.params.k_active_vals, self.params.mode);
    }

    /// Bulk-load a prefill history: all but the last `buffer` tokens are
    /// winnowed directly, the tail stays dense.  `k_hats`/`v_hats` are
    /// [n, d_h] flat (oldest first).
    pub fn load_prefill(&mut self, k_hats: &[f32], v_hats: &[f32]) {
        let n = k_hats.len() / self.d_h;
        debug_assert_eq!(k_hats.len(), n * self.d_h);
        for t in 0..n {
            self.append(
                &k_hats[t * self.d_h..(t + 1) * self.d_h],
                &v_hats[t * self.d_h..(t + 1) * self.d_h],
            );
        }
    }

    /// Stored bytes of the cache under serving accounting (Eq. 1 for the
    /// sparse part, f16 convention for the dense buffer).
    pub fn storage_bytes(&self) -> usize {
        let sparse = self.k_sparse.storage_bytes() + self.v_sparse.storage_bytes();
        let dense = 2 * self.buf_len * self.d_h * 2; // k+v, f16
        sparse + dense
    }

    /// Bytes a dense cache of the same token count would use.
    pub fn dense_equiv_bytes(&self) -> usize {
        2 * self.len() * self.d_h * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn mk(buffer: usize, k: usize) -> HybridCache {
        HybridCache::new(32, SwanParams::new(k, buffer, StorageMode::F16))
    }

    #[test]
    fn buffer_fills_before_sparse() {
        let mut c = mk(4, 8);
        let mut r = Pcg64::new(0);
        for _ in 0..4 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        assert_eq!(c.buffer_len(), 4);
        assert_eq!(c.sparse_len(), 0);
        c.append(&r.normal_vec(32), &r.normal_vec(32));
        assert_eq!(c.buffer_len(), 4);
        assert_eq!(c.sparse_len(), 1);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn eviction_is_fifo() {
        let mut c = mk(2, 32); // full retention: values survive exactly
        let mut vecs = Vec::new();
        let mut r = Pcg64::new(1);
        for _ in 0..5 {
            let k = r.normal_vec(32);
            let v = r.normal_vec(32);
            c.append(&k, &v);
            vecs.push(k);
        }
        assert_eq!(c.sparse_len(), 3);
        for i in 0..c.k_sparse.len() {
            let rec = c.k_sparse.reconstruct(i, 32);
            for (a, b) in rec.iter().zip(&vecs[i]) {
                assert!((a - crate::util::fp::quantize_f16(*b)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_buffer_prunes_all_but_current() {
        let mut c = mk(0, 8);
        let mut r = Pcg64::new(2);
        for _ in 0..3 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        assert_eq!(c.buffer_len(), 0);
        assert_eq!(c.sparse_len(), 3);
    }

    #[test]
    fn runtime_k_change_applies_to_new_evictions_only() {
        let mut c = mk(1, 16);
        let mut r = Pcg64::new(3);
        c.append(&r.normal_vec(32), &r.normal_vec(32));
        c.append(&r.normal_vec(32), &r.normal_vec(32)); // evicts with k=16
        c.set_k_active(4, 4);
        c.append(&r.normal_vec(32), &r.normal_vec(32)); // evicts with k=4
        assert_eq!(c.k_sparse.nnz(0), 16);
        assert_eq!(c.k_sparse.nnz(1), 4);
    }

    #[test]
    fn storage_accounting() {
        let mut c = mk(2, 8);
        let mut r = Pcg64::new(4);
        for _ in 0..6 {
            c.append(&r.normal_vec(32), &r.normal_vec(32));
        }
        // 4 sparse tokens * 2 vectors * (3*8+2) + 2 dense tokens * 2 * 32 * 2
        assert_eq!(c.storage_bytes(), 4 * 2 * 26 + 2 * 2 * 32 * 2);
        assert_eq!(c.dense_equiv_bytes(), 6 * 2 * 32 * 2);
        assert!(c.storage_bytes() < c.dense_equiv_bytes());
    }

    #[test]
    fn load_prefill_splits_correctly() {
        let mut c = mk(3, 8);
        let mut r = Pcg64::new(5);
        let n = 10;
        let ks = r.normal_vec(n * 32);
        let vs = r.normal_vec(n * 32);
        c.load_prefill(&ks, &vs);
        assert_eq!(c.buffer_len(), 3);
        assert_eq!(c.sparse_len(), 7);
        // buffer holds the *last* 3 tokens
        let kb = c.k_buffer();
        assert_eq!(&kb[..32], &ks[7 * 32..8 * 32]);
    }
}
