//! Decompression-free attention over the hybrid cache (Algorithm 1 lines
//! 13-17) — the rust-native mirror of the L1 Pallas kernel, used by the
//! experiment harness and as the reference the PJRT path is tested against.

use crate::simd::Kernels;
use crate::swan::hybrid_cache::HybridCache;

/// A cache layout the decompression-free attention walk can run over.
///
/// Two implementations exist: the contiguous per-sequence
/// [`HybridCache`] and the block-pool-backed
/// [`crate::pool::PagedHybridCache`].  The generic [`swan_attend`] is the
/// ONE spelling of Algorithm 1 lines 13-17; because every per-row
/// operation (CSR score, ring dot, scatter-add) is independent and both
/// layouts present rows in the same oldest-first order, the two layouts
/// produce bit-identical outputs (locked by `tests/pool.rs`).
///
/// Not object-safe (the ring visitors take `impl FnMut`) — used via
/// generics only.
pub trait SwanAttendable {
    fn d_h(&self) -> usize;
    /// Rows in the winnowed (sparse) half, oldest first.
    fn sparse_len(&self) -> usize;
    /// Rows in the dense recency ring.
    fn buffer_len(&self) -> usize;
    /// Fused CSR scores + running max over the key store: push one score
    /// per sparse row onto `out`, return the max pushed score
    /// (`NEG_INFINITY` when there are no rows).
    fn k_scores_max_into(&self, ks: Kernels, q: &[f32], scale: f32, out: &mut Vec<f32>) -> f32;
    /// Visit every dense-ring key row, oldest first.
    fn for_each_ring_k(&self, f: impl FnMut(&[f32]));
    /// Weighted scatter-add of all sparse value rows: `out += Σ w[r] * row_r`.
    fn v_axpy_all(&self, ks: Kernels, w: &[f32], out: &mut [f32]);
    /// Visit every dense-ring value row, oldest first.
    fn for_each_ring_v(&self, f: impl FnMut(&[f32]));
}

impl SwanAttendable for HybridCache {
    fn d_h(&self) -> usize {
        HybridCache::d_h(self)
    }

    fn sparse_len(&self) -> usize {
        HybridCache::sparse_len(self)
    }

    fn buffer_len(&self) -> usize {
        HybridCache::buffer_len(self)
    }

    fn k_scores_max_into(&self, ks: Kernels, q: &[f32], scale: f32, out: &mut Vec<f32>) -> f32 {
        self.k_sparse.scores_max_into_with(ks, q, scale, out)
    }

    fn for_each_ring_k(&self, mut f: impl FnMut(&[f32])) {
        let d = HybridCache::d_h(self);
        let (b0, b1) = self.k_buffer();
        for row in b0.chunks_exact(d).chain(b1.chunks_exact(d)) {
            f(row);
        }
    }

    fn v_axpy_all(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        self.v_sparse.axpy_all_with(ks, w, out);
    }

    fn for_each_ring_v(&self, mut f: impl FnMut(&[f32])) {
        let d = HybridCache::d_h(self);
        let (b0, b1) = self.v_buffer();
        for row in b0.chunks_exact(d).chain(b1.chunks_exact(d)) {
            f(row);
        }
    }
}

/// Compute one head's attention output for query `q_hat` over `cache`
/// plus the current token's `(k_hat_cur, v_hat_cur)` (which Algorithm 1
/// conceptually appends to the buffer before attending).
///
/// Scores on the sparse half are sparse-dense dot products; the output's
/// sparse half is a scatter-add — no d_h-dim reconstruction anywhere.
///
/// Allocates a fresh score row per call; the batched serving path uses
/// [`swan_attention_scratch`] with a per-worker reusable buffer instead.
pub fn swan_attention(
    q_hat: &[f32],
    cache: &HybridCache,
    k_hat_cur: &[f32],
    v_hat_cur: &[f32],
    out: &mut [f32],
) {
    let mut scores = Vec::with_capacity(cache.len() + 1);
    swan_attention_scratch(q_hat, cache, k_hat_cur, v_hat_cur, &mut scores, out);
}

/// Allocation-free variant of [`swan_attention`]: the caller provides the
/// score buffer (cleared here, capacity retained), typically the
/// `scores` field of a per-worker
/// [`AttentionScratch`](crate::swan::batch::AttentionScratch).  Only reads
/// `cache` — a sequence's caches can be attended by many workers (one per
/// kv-head/query-head task) concurrently, with appends deferred to the
/// step's write phase.
pub fn swan_attention_scratch(
    q_hat: &[f32],
    cache: &HybridCache,
    k_hat_cur: &[f32],
    v_hat_cur: &[f32],
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    swan_attend(q_hat, cache, k_hat_cur, v_hat_cur, scores, out);
}

/// The generic decompression-free walk over any [`SwanAttendable`]
/// layout: sparse scores fused with the running max, dense-ring dots,
/// the current token, one max-free softmax, then the value accumulation
/// (CSR scatter-add + ring axpys).  The exact operation sequence the
/// contiguous path has always run — kernel calls, accumulation order and
/// all — so any layout whose rows match the contiguous store's produces
/// bit-identical outputs.
pub fn swan_attend<C: SwanAttendable>(
    q_hat: &[f32],
    cache: &C,
    k_hat_cur: &[f32],
    v_hat_cur: &[f32],
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let ks = crate::simd::active();
    let d = cache.d_h();
    debug_assert_eq!(q_hat.len(), d);
    debug_assert_eq!(out.len(), d);
    let scale = 1.0 / (d as f32).sqrt();

    let ns = cache.sparse_len();
    let nb = cache.buffer_len();
    scores.clear();
    scores.reserve(ns + nb + 1);

    // sparse-dense mat-vec over the CSR rows (no reconstruction, no
    // per-row pointer chasing), fused with the softmax's running max so
    // the score row is walked once
    let mut m = cache.k_scores_max_into(ks, q_hat, scale, scores);
    // dense ring buffer: oldest-first rows, walked in place
    cache.for_each_ring_k(|row| {
        let s = ks.dot(row, q_hat) * scale;
        m = m.max(s);
        scores.push(s);
    });
    // current token
    let s = ks.dot(k_hat_cur, q_hat) * scale;
    m = m.max(s);
    scores.push(s);

    ks.softmax_inplace_with_max(scores, m);

    out.iter_mut().for_each(|o| *o = 0.0);
    cache.v_axpy_all(ks, &scores[..ns], out);
    let mut t = 0;
    cache.for_each_ring_v(|row| {
        ks.axpy(scores[ns + t], row, out);
        t += 1;
    });
    ks.axpy(scores[ns + nb], v_hat_cur, out);
}

/// Dense reference attention over explicit caches (for tests/baselines):
/// `k_cache`/`v_cache` are flat [n, d] plus the current row.
pub fn dense_attention(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    k_cur: &[f32],
    v_cur: &[f32],
    d: usize,
    out: &mut [f32],
) {
    let mut scores = Vec::with_capacity(k_cache.len() / d + 1);
    dense_attention_scratch(q, k_cache, v_cache, k_cur, v_cur, d, &mut scores, out);
}

/// Allocation-free variant of [`dense_attention`] (caller-provided score
/// buffer, cleared here) — the dense-baseline leg of the batched decode
/// path.
#[allow(clippy::too_many_arguments)]
pub fn dense_attention_scratch(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    k_cur: &[f32],
    v_cur: &[f32],
    d: usize,
    scores: &mut Vec<f32>,
    out: &mut [f32],
) {
    let ks = crate::simd::active();
    let n = k_cache.len() / d;
    let scale = 1.0 / (d as f32).sqrt();
    scores.clear();
    scores.reserve(n + 1);
    let mut m = f32::NEG_INFINITY;
    for t in 0..n {
        let s = ks.dot(&k_cache[t * d..(t + 1) * d], q) * scale;
        m = m.max(s);
        scores.push(s);
    }
    let s = ks.dot(k_cur, q) * scale;
    m = m.max(s);
    scores.push(s);
    ks.softmax_inplace_with_max(scores, m);
    out.iter_mut().for_each(|o| *o = 0.0);
    for t in 0..n {
        ks.axpy(scores[t], &v_cache[t * d..(t + 1) * d], out);
    }
    ks.axpy(scores[n], v_cur, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::StorageMode;
    use crate::swan::hybrid_cache::SwanParams;
    use crate::util::Pcg64;

    /// Full retention + f32 storage must reproduce dense attention exactly.
    #[test]
    fn full_retention_equals_dense() {
        let d = 32;
        let mut r = Pcg64::new(0);
        let mut cache = HybridCache::new(d, SwanParams::new(d, 4, StorageMode::F32));
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for _ in 0..12 {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            cache.append(&k, &v);
            ks.extend_from_slice(&k);
            vs.extend_from_slice(&v);
        }
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut out = vec![0.0; d];
        swan_attention(&q, &cache, &kc, &vc, &mut out);
        let mut want = vec![0.0; d];
        dense_attention(&q, &ks, &vs, &kc, &vc, d, &mut want);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    /// Output weights sum to 1: constant values give a constant output.
    #[test]
    fn convexity() {
        let d = 16;
        let mut r = Pcg64::new(1);
        let mut cache = HybridCache::new(d, SwanParams::new(d, 2, StorageMode::F32));
        for _ in 0..8 {
            let k = r.normal_vec(d);
            cache.append(&k, &vec![1.0; d]);
        }
        let q = r.normal_vec(d);
        let mut out = vec![0.0; d];
        swan_attention(&q, &cache, &r.normal_vec(d), &vec![1.0; d], &mut out);
        for &o in &out {
            assert!((o - 1.0).abs() < 1e-4, "{o}");
        }
    }

    /// Pruning error decreases as k_active rises.
    #[test]
    fn error_monotone_in_k() {
        let d = 64;
        let mut r = Pcg64::new(2);
        let tokens: Vec<(Vec<f32>, Vec<f32>)> =
            (0..24).map(|_| (r.normal_vec(d), r.normal_vec(d))).collect();
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        for (k, v) in &tokens {
            kflat.extend_from_slice(k);
            vflat.extend_from_slice(v);
        }
        let mut dense = vec![0.0; d];
        dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut dense);

        let mut last_err = f32::INFINITY;
        for k_active in [8, 16, 32, 64] {
            let mut cache =
                HybridCache::new(d, SwanParams::new(k_active, 0, StorageMode::F32));
            for (k, v) in &tokens {
                cache.append(k, v);
            }
            let mut out = vec![0.0; d];
            swan_attention(&q, &cache, &kc, &vc, &mut out);
            let err: f32 = out
                .iter()
                .zip(&dense)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            assert!(err <= last_err + 1e-4, "k={k_active} err={err} last={last_err}");
            last_err = err;
        }
        assert!(last_err < 1e-4); // k = d is exact
    }

    /// The scratch-based entry point is bit-identical to the allocating
    /// one and retains buffer capacity across calls.
    #[test]
    fn scratch_variant_matches_and_reuses_buffer() {
        let d = 32;
        let mut r = Pcg64::new(7);
        let mut cache = HybridCache::new(d, SwanParams::new(8, 3, StorageMode::F16));
        for _ in 0..20 {
            cache.append(&r.normal_vec(d), &r.normal_vec(d));
        }
        let mut scores = Vec::new();
        for _ in 0..4 {
            let q = r.normal_vec(d);
            let kc = r.normal_vec(d);
            let vc = r.normal_vec(d);
            let mut a = vec![0.0; d];
            let mut b = vec![0.0; d];
            swan_attention(&q, &cache, &kc, &vc, &mut a);
            swan_attention_scratch(&q, &cache, &kc, &vc, &mut scores, &mut b);
            assert_eq!(a, b);
        }
        assert!(scores.capacity() >= cache.len() + 1);
    }

    /// Current token participates even with an empty cache.
    #[test]
    fn empty_cache_attends_to_current() {
        let d = 8;
        let cache = HybridCache::new(d, SwanParams::new(4, 2, StorageMode::F16));
        let q = vec![1.0; d];
        let kc = vec![0.5; d];
        let vc: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let mut out = vec![0.0; d];
        swan_attention(&q, &cache, &kc, &vc, &mut out);
        for (o, v) in out.iter().zip(&vc) {
            assert!((o - v).abs() < 1e-6);
        }
    }
}
