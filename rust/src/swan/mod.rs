//! The paper's core contribution: SWAN hybrid cache + decompression-free
//! attention (Algorithm 1), projection handling (§4.1-4.2), and the Eq. 2
//! computational break-even model.

pub mod attention;
pub mod breakeven;
pub mod hybrid_cache;
pub mod projection;

pub use attention::swan_attention;
pub use breakeven::{breakeven_length, flops_std, flops_swan};
pub use hybrid_cache::{HybridCache, SwanParams};
pub use projection::ProjectionSet;
