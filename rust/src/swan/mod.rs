//! The paper's core contribution: SWAN hybrid cache + decompression-free
//! attention (Algorithm 1), projection handling (§4.1-4.2), and the Eq. 2
//! computational break-even model.
//!
//! # Batched execution model
//!
//! The serving hot path is the sparse-dense score/scatter walk in
//! [`attention`].  Because attention only *reads* the [`HybridCache`]
//! (compression work happens once per token at append time), a decode
//! step splits cleanly into a read phase and a write phase:
//!
//! 1. **Read phase** — the iteration-level scheduler forms one attention
//!    task per `(sequence, layer, kv-head)` and fans them across the
//!    [`batch::WorkerPool`].  Each task borrows its caches immutably
//!    (query heads of a GQA group share one task so H2O-style policies
//!    can still update per-head statistics under `&mut`), and runs the
//!    kernel through the executing worker's reusable
//!    [`batch::AttentionScratch`] — steady-state attention performs no
//!    heap allocation.
//! 2. **Write phase** — each sequence appends the new rotated `(k̂, v̂)`
//!    rows to its own caches (exclusive `&mut`, no synchronization).
//!
//! Tasks write only to their own output slices, so batched-parallel
//! decode is bit-identical to serial decode — `tests/batch_decode.rs`
//! asserts equal token streams across batch sizes and worker counts.
//! [`crate::model::SwanModel::decode_step_batch`] is the native-model
//! entry point; `coordinator::engine` applies the same fan-out to the
//! PJRT graph path.

pub mod attention;
pub mod batch;
pub mod breakeven;
pub mod hybrid_cache;
pub mod projection;

pub use attention::{swan_attend, swan_attention, swan_attention_scratch, SwanAttendable};
pub use batch::{AttentionScratch, WorkerPool};
pub use breakeven::{breakeven_length, flops_std, flops_swan};
pub use hybrid_cache::{HybridCache, SwanParams};
pub use projection::ProjectionSet;
