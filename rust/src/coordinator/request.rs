//! Request/response types for the serving engine.

use std::time::Duration;

/// Character-level tokenizer shared with the python side: ids 0..95 map to
/// ASCII 32..127.
pub const VOCAB_SIZE: usize = 96;
pub const CHAR_BASE: u8 = 32;

pub fn encode_text(s: &str) -> Vec<u32> {
    s.bytes()
        .map(|b| {
            let x = b.wrapping_sub(CHAR_BASE);
            if (x as usize) < VOCAB_SIZE {
                x as u32
            } else {
                0
            }
        })
        .collect()
}

pub fn decode_tokens(ids: &[u32]) -> String {
    ids.iter().map(|&i| (i as u8 + CHAR_BASE) as char).collect()
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Softmax temperature; 0 = greedy.
    pub temperature: f32,
    /// Optional stop token.
    pub stop_token: Option<u32>,
}

impl Request {
    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt: encode_text(text),
            max_new_tokens,
            temperature: 0.0,
            stop_token: None,
        }
    }
}

/// Per-request latency/throughput accounting.
#[derive(Clone, Debug, Default)]
pub struct RequestStats {
    pub queue_time: Duration,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub decode_steps: usize,
    /// Peak KV-cache bytes for this sequence.
    pub peak_cache_bytes: usize,
    /// Bytes an uncompressed cache would have used at completion.
    pub dense_equiv_bytes: usize,
}

impl RequestStats {
    /// Decode throughput in tokens/s.
    pub fn decode_tps(&self) -> f64 {
        if self.decode_time.is_zero() {
            0.0
        } else {
            self.decode_steps as f64 / self.decode_time.as_secs_f64()
        }
    }

    /// Cache memory saving vs dense (1 - used/dense).
    pub fn memory_saving(&self) -> f64 {
        if self.dense_equiv_bytes == 0 {
            0.0
        } else {
            1.0 - self.peak_cache_bytes as f64 / self.dense_equiv_bytes as f64
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub stats: RequestStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip() {
        let s = "the passkey is 41579 .";
        assert_eq!(decode_tokens(&encode_text(s)), s);
    }

    #[test]
    fn out_of_alphabet_maps_to_space() {
        let ids = encode_text("a\nb");
        assert_eq!(decode_tokens(&ids), "a b");
    }

    #[test]
    fn stats_derivations() {
        let st = RequestStats {
            decode_time: Duration::from_secs(2),
            decode_steps: 100,
            peak_cache_bytes: 250,
            dense_equiv_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(st.decode_tps(), 50.0);
        assert_eq!(st.memory_saving(), 0.75);
    }
}
