//! Request/response types for the serving engine.
//!
//! A [`Request`] carries its generation parameters as a typed
//! [`GenParams`] (see [`crate::api`]) plus a shared [`CancelToken`];
//! the loose `max_new_tokens` / `temperature` / `stop_token` fields of
//! the v1 request live inside `params` now, so every serving path —
//! engine, shard, pipeline group, wire — consumes one parameter type.

use std::time::Duration;

use crate::api::{CancelToken, GenParams};
use crate::obs::trace::Trace;

/// Character-level tokenizer shared with the python side: ids 0..95 map to
/// ASCII 32..127.
pub const VOCAB_SIZE: usize = 96;
pub const CHAR_BASE: u8 = 32;

pub fn encode_text(s: &str) -> Vec<u32> {
    s.bytes()
        .map(|b| {
            let x = b.wrapping_sub(CHAR_BASE);
            if (x as usize) < VOCAB_SIZE {
                x as u32
            } else {
                0
            }
        })
        .collect()
}

pub fn decode_tokens(ids: &[u32]) -> String {
    ids.iter().map(|&i| (i as u8 + CHAR_BASE) as char).collect()
}

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Typed generation parameters (sampling, budget, per-request
    /// compression override, streaming).
    pub params: GenParams,
    /// Cooperative cancellation flag; clones (held by [`crate::api::
    /// GenHandle`] and connection registries) share it.
    pub cancel: CancelToken,
    /// Set by the admitting engine when it clamped `params.max_new`:
    /// the value originally requested (so stats never lie about it).
    pub clamped_from: Option<usize>,
    /// Lifecycle timeline (submit→admit→…→retire), recorded by the one
    /// coordinator thread driving this request — plain pushes, no lock.
    /// Dumpable post-retire via the `TRACE <id>` wire verb.
    pub trace: Trace,
}

impl Request {
    pub fn from_text(id: u64, text: &str, max_new_tokens: usize) -> Request {
        Request::with_params(id, text, GenParams::new(max_new_tokens))
    }

    /// Build a request from text with explicit generation parameters.
    pub fn with_params(id: u64, text: &str, params: GenParams) -> Request {
        Request {
            id,
            prompt: encode_text(text),
            params,
            cancel: CancelToken::new(),
            clamped_from: None,
            trace: Trace::new(),
        }
    }

    /// Base seed of this request's RNG streams: the explicit
    /// `params.seed` when given, the request id otherwise (the
    /// historical derivation, so legacy requests keep their streams).
    pub fn seed_base(&self) -> u64 {
        self.params.seed.unwrap_or(self.id)
    }

    /// Clamp `params.max_new` to a server cap, recording the original
    /// request so the clamp is surfaced (reply + stats), never silent.
    pub fn clamp_max_new(&mut self, cap: usize) {
        if self.params.max_new > cap {
            self.clamped_from = Some(self.params.max_new);
            self.params.max_new = cap;
        }
    }
}

/// Per-request latency/throughput accounting.
#[derive(Clone, Debug, Default)]
pub struct RequestStats {
    pub queue_time: Duration,
    pub prefill_time: Duration,
    pub decode_time: Duration,
    pub decode_steps: usize,
    /// Peak KV-cache bytes for this sequence.
    pub peak_cache_bytes: usize,
    /// Bytes an uncompressed cache would have used at completion.
    pub dense_equiv_bytes: usize,
    /// The request was cancelled; `tokens`/`text` hold the partial
    /// output produced before the sequence retired.
    pub cancelled: bool,
    /// `Some(requested)` when the server clamped `max_new` below what
    /// the request asked for.
    pub clamped_from: Option<usize>,
    /// `Some(original prompt length)` when prefill suffix-truncated the
    /// prompt to the largest compiled bucket — surfaced exactly like the
    /// `max_new` clamp so truncation is never silent.
    pub truncated_prompt_from: Option<usize>,
    /// Time to first token: queue wait + prefill (the first token is
    /// sampled from the prefill logits on every serving path).
    pub ttft_ns: u64,
    /// Inter-token latency accounting over decode commits: the sum and
    /// max of commit-to-commit gaps. One gap per decode step, so the
    /// mean is `itl_sum_ns / decode_steps`. Gaps span preemptions —
    /// the first post-resume token charges the full user-observed stall.
    pub itl_sum_ns: u64,
    pub itl_max_ns: u64,
    /// Times this request was preempted (pool-budget eviction).  Capped
    /// by the coordinator's per-request preemption limit, after which
    /// the sequence becomes non-evictable (fairness under overload).
    pub preemptions: u32,
    /// Times this request survived a shard death or drain migration
    /// (each recovery re-prefilled and replayed bit-exactly).
    pub recoveries: u32,
}

impl RequestStats {
    /// Decode throughput in tokens/s.
    pub fn decode_tps(&self) -> f64 {
        if self.decode_time.is_zero() {
            0.0
        } else {
            self.decode_steps as f64 / self.decode_time.as_secs_f64()
        }
    }

    /// Mean inter-token gap in ns (0 when no decode steps ran).
    pub fn itl_mean_ns(&self) -> u64 {
        if self.decode_steps == 0 {
            0
        } else {
            self.itl_sum_ns / self.decode_steps as u64
        }
    }

    /// Cache memory saving vs dense (1 - used/dense).
    pub fn memory_saving(&self) -> f64 {
        if self.dense_equiv_bytes == 0 {
            0.0
        } else {
            1.0 - self.peak_cache_bytes as f64 / self.dense_equiv_bytes as f64
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub stats: RequestStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip() {
        let s = "the passkey is 41579 .";
        assert_eq!(decode_tokens(&encode_text(s)), s);
    }

    #[test]
    fn out_of_alphabet_maps_to_space() {
        let ids = encode_text("a\nb");
        assert_eq!(decode_tokens(&ids), "a b");
    }

    #[test]
    fn from_text_uses_default_params() {
        let r = Request::from_text(3, "hi", 12);
        assert_eq!(r.params.max_new, 12);
        assert_eq!(r.params.temperature, 0.0);
        assert_eq!(r.params.k_active, None);
        assert!(!r.cancel.is_cancelled());
        assert_eq!(r.seed_base(), 3);
        let s = Request::with_params(3, "hi", GenParams::new(12).seed(99));
        assert_eq!(s.seed_base(), 99);
    }

    #[test]
    fn clamp_records_the_original_request() {
        let mut r = Request::from_text(1, "hi", 100);
        r.clamp_max_new(512);
        assert_eq!(r.clamped_from, None, "under the cap: untouched");
        r.clamp_max_new(40);
        assert_eq!(r.params.max_new, 40);
        assert_eq!(r.clamped_from, Some(100));
    }

    #[test]
    fn stats_derivations() {
        let st = RequestStats {
            decode_time: Duration::from_secs(2),
            decode_steps: 100,
            peak_cache_bytes: 250,
            dense_equiv_bytes: 1000,
            itl_sum_ns: 1000,
            itl_max_ns: 40,
            ..Default::default()
        };
        assert_eq!(st.decode_tps(), 50.0);
        assert_eq!(st.memory_saving(), 0.75);
        assert_eq!(st.itl_mean_ns(), 10);
        assert_eq!(RequestStats::default().itl_mean_ns(), 0);
    }
}
