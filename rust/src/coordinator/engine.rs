//! The serving engine: PJRT-backed prefill/decode over hybrid caches with
//! iteration-level continuous batching.
//!
//! One [`Engine::step`] performs: (1) admission — pop admissible requests
//! from the scheduler, run their prefill graph, winnow the history into a
//! fresh [`SeqCache`]; (2) one decode iteration — a single decode-graph
//! call per active sequence (the batch is re-formed every iteration, so
//! short and long requests interleave without head-of-line blocking);
//! (3) completion — finished sequences are emitted with their stats.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::config::ServeConfig;
use crate::coordinator::autotune::AutoTuner;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{decode_tokens, Request, RequestStats, Response};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::sequence::{CacheShape, SeqCache};
use crate::runtime::engine::{ArgView, HostTensor, LoadedModel};
use crate::swan::batch::WorkerPool;

use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::Pcg64;

/// Sequences each pool worker can decode before admission defers: matches
/// the pool's load-balancing chunk factor (`WorkerPool::for_each_mut`
/// forms ~4 chunks per worker), so a "full" pool still balances skewed
/// sequence lengths but never stretches an iteration past ~4 tasks deep.
/// (Shared with the pipeline-group coordinator, which sizes its
/// admission the same way against its stage worker pools.)
pub(crate) const DECODE_SLOTS_PER_WORKER: usize = 4;

/// Backend cache of one active sequence: SWAN hybrid or dense baseline.
enum SeqBackend {
    Swan(SeqCache),
    Dense { k: Vec<f32>, v: Vec<f32>, len: usize, cap: usize },
}

struct ActiveSeq {
    req: Request,
    backend: SeqBackend,
    produced: Vec<u32>,
    next_token: u32,
    stats: RequestStats,
    rng: Pcg64,
    decode_graph: String,
    /// Set by the commit phase; the sequence is retired at iteration end.
    finished: bool,
}

/// The serving engine (single-threaded stepper; wrap in a thread for the
/// TCP server).  With `cfg.decode_workers > 0` each decode iteration fans
/// the per-sequence graph executions across a worker pool — the batch is
/// still re-formed every iteration, so continuous-batching semantics are
/// unchanged and results are identical to serial stepping.
pub struct Engine {
    pub lm: LoadedModel,
    pub cfg: ServeConfig,
    pub metrics: Arc<Metrics>,
    scheduler: Scheduler,
    tuner: AutoTuner,
    active: Vec<ActiveSeq>,
    finished: VecDeque<Response>,
    /// Ids rejected at admission (prefill failure) — drained by callers
    /// that hold per-request reply channels, so no waiter leaks.
    rejected: VecDeque<u64>,
    shape: CacheShape,
    decode_l_buckets: Vec<usize>,
    prefill_buckets: Vec<usize>,
    next_id: u64,
    pool: WorkerPool,
}

impl Engine {
    pub fn new(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Engine> {
        // an explicit kernel choice pins the process-wide path (idempotent
        // across shards — every engine of a fleet carries the same config
        // value); "auto" leaves any selection an embedder already made
        // untouched rather than re-resolving and clobbering it
        if !matches!(cfg.kernels.as_str(), "auto" | "") {
            crate::simd::init_from_name(&cfg.kernels)?;
        }
        let lm = LoadedModel::open(artifacts_dir, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let arts = lm.store.model(&cfg.model)?;
        let mc = &arts.config;
        let shape = CacheShape {
            n_layers: mc.n_layers,
            n_kv: mc.n_kv_heads,
            d_head: mc.d_head,
            buf_cap: arts.buf,
        };
        let buckets = arts.decode_buckets();
        let mut k_buckets: Vec<usize> = buckets.iter().map(|&(_, k)| k).collect();
        k_buckets.sort_unstable();
        k_buckets.dedup();
        anyhow::ensure!(!k_buckets.is_empty(), "no decode graphs in manifest");
        let mut decode_l_buckets: Vec<usize> = buckets.iter().map(|&(l, _)| l).collect();
        decode_l_buckets.sort_unstable();
        decode_l_buckets.dedup();
        let mut tuner = AutoTuner::new(cfg.mem_budget, k_buckets);
        tuner.pin(cfg.k_active);
        let mut scheduler = Scheduler::new(cfg.max_batch, cfg.mem_budget);
        scheduler.set_lookahead(cfg.admit_lookahead);
        if cfg.decode_workers > 0 {
            scheduler.set_decode_slots(cfg.decode_workers * DECODE_SLOTS_PER_WORKER);
        }
        Ok(Engine {
            shape,
            decode_l_buckets,
            prefill_buckets: arts.prefill_buckets(),
            scheduler,
            tuner,
            active: Vec::new(),
            finished: VecDeque::new(),
            rejected: VecDeque::new(),
            metrics: Arc::new(Metrics::default()),
            next_id: 1,
            pool: WorkerPool::new(cfg.decode_workers),
            lm,
            cfg,
        })
    }

    /// Pre-compile the graphs the engine will hit (optional warmup).
    pub fn warmup(&self) -> anyhow::Result<()> {
        let arts = self.lm.store.model(&self.cfg.model)?;
        let k = self.tuner.current_k();
        for (name, meta) in &arts.graphs {
            let is_needed = name.starts_with("prefill_")
                || name == &format!("decode_l{}_k{k}", self.decode_l_buckets[0])
                || (self.cfg.dense_baseline && name.starts_with("decode_dense"));
            if is_needed {
                self.lm.runtime.warmup(&self.cfg.model, name, meta)?;
            }
        }
        Ok(())
    }

    /// Change the compression level for newly admitted sequences.
    pub fn set_k_active(&mut self, k: usize) {
        self.tuner.pin(k);
    }

    pub fn current_k_active(&self) -> usize {
        self.tuner.current_k()
    }

    /// Submit a request; returns its id.
    pub fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id;
        }
        self.next_id = self.next_id.max(req.id) + 1;
        self.metrics.requests_submitted.fetch_add(1, Ordering::Relaxed);
        self.scheduler.enqueue(req);
        self.next_id - 1
    }

    pub fn submit_text(&mut self, text: &str, max_new: usize) -> u64 {
        let id = self.next_id;
        self.submit(Request::from_text(id, text, max_new))
    }

    /// Live KV bytes across active sequences.
    pub fn live_cache_bytes(&self) -> usize {
        self.active
            .iter()
            .map(|s| match &s.backend {
                SeqBackend::Swan(c) => c.storage_bytes(),
                SeqBackend::Dense { len, .. } => {
                    2 * self.shape.n_layers * self.shape.n_kv * self.shape.d_head * 2 * len
                }
            })
            .sum()
    }

    /// Requests queued behind admission control.
    pub fn queue_len(&self) -> usize {
        self.scheduler.queue_len()
    }

    /// Sequences currently decoding.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Projected total KV load: live bytes of the active set plus the
    /// admission projection ([`Scheduler::projected_bytes`]) of every
    /// queued request at the current compression level.  The shard
    /// router's `MemAware` placement policy balances on this figure.
    pub fn projected_load_bytes(&self) -> usize {
        let (sparse_b, dense_b) = self.token_byte_rates(self.tuner.current_k());
        let buf = self.shape.buf_cap;
        let queued: usize = self
            .scheduler
            .queued()
            .map(|r| Scheduler::projected_bytes(r.prompt.len(), r.max_new_tokens, sparse_b, dense_b, buf))
            .sum();
        self.live_cache_bytes() + queued
    }

    pub fn has_work(&self) -> bool {
        !self.active.is_empty() || self.scheduler.queue_len() > 0
    }

    pub fn pop_finished(&mut self) -> Option<Response> {
        self.finished.pop_front()
    }

    /// Drain one id that was rejected at admission (its request will
    /// never produce a [`Response`]); serving fronts answer the waiting
    /// client with an error instead of leaving it blocked.
    pub fn pop_rejected(&mut self) -> Option<u64> {
        self.rejected.pop_front()
    }

    /// One engine iteration: admit, decode every active sequence once,
    /// retire finished sequences.
    pub fn step(&mut self) -> anyhow::Result<()> {
        self.admit()?;
        self.decode_iteration()?;
        Ok(())
    }

    /// Run until all queued + active work is done; returns responses in
    /// completion order.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            self.step()?;
            while let Some(r) = self.pop_finished() {
                out.push(r);
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    /// Per-token KV byte rates `(sparse, dense)` at compression level
    /// `k` — the single source feeding both admission control and the
    /// router's `MemAware` projection ([`Engine::projected_load_bytes`]);
    /// the closed form is shared with the pipeline groups
    /// ([`crate::sparse::memory::token_byte_rates`]).
    fn token_byte_rates(&self, k: usize) -> (usize, usize) {
        crate::sparse::memory::token_byte_rates(
            self.shape.n_layers,
            self.shape.n_kv,
            self.shape.d_head,
            self.cfg.mode,
            k,
        )
    }

    fn admit(&mut self) -> anyhow::Result<()> {
        let k_now = {
            let live = self.live_cache_bytes();
            let t = &mut self.tuner;
            t.observe(live)
        };
        let (sparse_b, dense_b) = self.token_byte_rates(k_now);
        let buf = self.shape.buf_cap;
        loop {
            // re-read live bytes per admission: each admitted prefill
            // grows the active set, and a burst gated against one stale
            // snapshot could collectively overshoot the budget
            let live = self.live_cache_bytes();
            let proj = |req: &Request| {
                Scheduler::projected_bytes(req.prompt.len(), req.max_new_tokens, sparse_b, dense_b, buf)
            };
            let Some(pending) = self.scheduler.admit_next(self.active.len(), live, proj) else {
                break;
            };
            let queue_time = pending.enqueued.elapsed();
            let rid = pending.req.id;
            match self.prefill(pending.req, k_now, queue_time) {
                Ok(seq) => self.active.push(seq),
                Err(e) => {
                    self.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                    self.rejected.push_back(rid);
                    log::warn!("prefill failed: {e:#}");
                }
            }
        }
        Ok(())
    }

    fn prefill(&mut self, req: Request, k_active: usize, queue_time: std::time::Duration) -> anyhow::Result<ActiveSeq> {
        let t0 = Instant::now();
        // one pass, no copies: borrow the request's prompt (or a static
        // dummy token for empty prompts) and slice the suffix in place —
        // prompts longer than the largest bucket keep their suffix (the
        // bucket limit is a compile-time artifact knob, not a model limit)
        let full: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
        let cap = self
            .prefill_buckets
            .iter()
            .copied()
            .find(|&t| t >= full.len())
            .or(self.prefill_buckets.last().copied())
            .context("no prefill graphs")?;
        let prompt = &full[full.len().saturating_sub(cap)..];

        let mut tokens = vec![0i32; cap];
        let mut tmask = vec![0.0f32; cap];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
            tmask[i] = 1.0;
        }
        let outs = self.lm.execute(
            &format!("prefill_t{cap}"),
            &[
                HostTensor::i32(tokens, vec![cap]),
                HostTensor::f32(tmask, vec![cap]),
            ],
        )?;
        let logits = outs[0].as_f32()?.to_vec();
        let khat = outs[1].as_f32()?;
        let vhat = outs[2].as_f32()?;

        let mut stats = RequestStats { queue_time, ..Default::default() };
        stats.prefill_time = t0.elapsed();
        self.metrics.prefill_ns.record(stats.prefill_time.as_nanos() as f64);
        self.metrics.prefill_tokens.fetch_add(prompt.len() as u64, Ordering::Relaxed);

        let backend = if self.cfg.dense_baseline {
            let dense_cap = 512; // decode_dense_l512 bucket
            let heads = self.shape.n_layers * self.shape.n_kv;
            let dh = self.shape.d_head;
            let mut k = vec![0.0f32; heads * dense_cap * dh];
            let mut v = vec![0.0f32; heads * dense_cap * dh];
            for hh in 0..heads {
                for t in 0..prompt.len() {
                    let src = (hh * cap + t) * dh;
                    let dst = (hh * dense_cap + t) * dh;
                    k[dst..dst + dh].copy_from_slice(&khat[src..src + dh]);
                    v[dst..dst + dh].copy_from_slice(&vhat[src..src + dh]);
                }
            }
            SeqBackend::Dense { k, v, len: prompt.len(), cap: dense_cap }
        } else {
            let sparse_need = prompt.len().saturating_sub(self.shape.buf_cap);
            let l_cap = self
                .decode_l_buckets
                .iter()
                .copied()
                .find(|&l| l >= sparse_need + 1)
                .or(self.decode_l_buckets.last().copied())
                .context("no decode buckets")?;
            let mut cache = SeqCache::new(self.shape, l_cap, k_active, self.cfg.mode);
            cache.load_prefill(khat, vhat, cap, prompt.len());
            SeqBackend::Swan(cache)
        };

        let next_token = sample(&logits, req.temperature, &mut Pcg64::new(req.id));
        Ok(ActiveSeq {
            rng: Pcg64::new(req.id ^ x5wan_seed()),
            decode_graph: String::new(),
            produced: vec![next_token],
            next_token,
            stats,
            backend,
            req,
            finished: false,
        })
    }

    /// One decode iteration, in two phases:
    ///
    /// * **read/execute + sample** — every active sequence runs its decode
    ///   graph and samples its next token; with `decode_workers > 0` these
    ///   independent executions fan across the pool (each task owns its
    ///   sequence `&mut` — including its private RNG stream — and the PJRT
    ///   runtime is shared immutably).  Sampling lives here rather than on
    ///   the coordinator thread so per-token costs beyond argmax (top-p,
    ///   repetition penalties) scale with the pool;
    /// * **commit** — serially, in submission order: append the new
    ///   (k̂, v̂) rows, record the sampled token, account stats, retire
    ///   finished sequences.
    ///
    /// Each sequence's compute (and RNG consumption) depends only on its
    /// own pre-iteration state, so the fan-out produces the same tokens as
    /// serial stepping.
    fn decode_iteration(&mut self) -> anyhow::Result<()> {
        let shape = self.shape;
        // SWAN_CLONE_ARGS=1 forces the pre-optimization clone-per-step
        // path (kept for the §Perf before/after measurement).
        let clone_args = std::env::var("SWAN_CLONE_ARGS").is_ok();

        struct StepTask<'a> {
            seq: &'a mut ActiveSeq,
            out: Option<anyhow::Result<Option<Vec<HostTensor>>>>,
            /// Token sampled in the execute phase (None when the sequence
            /// finished, errored, or produced non-f32 logits).
            next: Option<u32>,
            exec: Duration,
        }

        // phase 1: execute + sample (parallel when the pool has workers)
        {
            let lm = &self.lm;
            let l_buckets = &self.decode_l_buckets;
            let mut tasks: Vec<StepTask> = self
                .active
                .iter_mut()
                .map(|seq| StepTask { seq, out: None, next: None, exec: Duration::ZERO })
                .collect();
            self.pool.for_each_mut(&mut tasks, |_scratch, t| {
                let t0 = Instant::now();
                let out = decode_execute(lm, shape, l_buckets, clone_args, t.seq);
                if let Ok(Some(outs)) = &out {
                    if let Ok(logits) = outs[0].as_f32() {
                        t.next = Some(sample(logits, t.seq.req.temperature, &mut t.seq.rng));
                    }
                }
                t.out = Some(out);
                t.exec = t0.elapsed();
            });

            // phase 2: commit serially, in submission order
            for t in tasks.iter_mut() {
                let t0 = Instant::now();
                let outs = match t.out.take().expect("phase 1 ran for every task") {
                    Ok(Some(outs)) => outs,
                    Ok(None) => {
                        t.seq.finished = true;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                let seq = &mut *t.seq;
                let Some(next) = t.next else {
                    // outs[0] existed but was not f32 — surface the type
                    // error the sampler hit in the execute phase
                    outs[0].as_f32()?;
                    anyhow::bail!("decode graph produced no sampleable logits");
                };
                let khat = outs[1].as_f32()?;
                let vhat = outs[2].as_f32()?;

                match &mut seq.backend {
                    SeqBackend::Swan(cache) => cache.append(khat, vhat),
                    SeqBackend::Dense { k, v, len, cap } => {
                        let dh = shape.d_head;
                        let heads = shape.n_layers * shape.n_kv;
                        for hh in 0..heads {
                            let dst = (hh * *cap + *len) * dh;
                            k[dst..dst + dh].copy_from_slice(&khat[hh * dh..(hh + 1) * dh]);
                            v[dst..dst + dh].copy_from_slice(&vhat[hh * dh..(hh + 1) * dh]);
                        }
                        *len += 1;
                    }
                }

                seq.next_token = next;
                seq.produced.push(next);
                seq.stats.decode_steps += 1;
                let step_time = t.exec + t0.elapsed();
                seq.stats.decode_time += step_time;
                let bytes = match &seq.backend {
                    SeqBackend::Swan(c) => c.storage_bytes(),
                    SeqBackend::Dense { len, .. } => {
                        2 * shape.n_layers * shape.n_kv * shape.d_head * 2 * len
                    }
                };
                seq.stats.peak_cache_bytes = seq.stats.peak_cache_bytes.max(bytes);
                seq.stats.dense_equiv_bytes = match &seq.backend {
                    SeqBackend::Swan(c) => c.dense_equiv_bytes(),
                    SeqBackend::Dense { len, .. } => {
                        2 * shape.n_layers * shape.n_kv * shape.d_head * 2 * len
                    }
                };
                self.metrics.decode_step_ns.record(step_time.as_nanos() as f64);
                self.metrics.decode_tokens.fetch_add(1, Ordering::Relaxed);
            }
        }

        // retire finished sequences, preserving submission order (skip the
        // rebuild entirely on the common nothing-finished iteration)
        if self.active.iter().any(|s| s.finished) {
            let mut keep = Vec::with_capacity(self.active.len());
            for seq in self.active.drain(..) {
                if seq.finished {
                    self.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
                    self.finished.push_back(finish(seq));
                } else {
                    keep.push(seq);
                }
            }
            self.active = keep;
        }

        // metrics snapshot of live cache
        self.metrics.cache_bytes.store(self.live_cache_bytes(), Ordering::Relaxed);
        let dense_equiv: usize = self
            .active
            .iter()
            .map(|s| match &s.backend {
                SeqBackend::Swan(c) => c.dense_equiv_bytes(),
                SeqBackend::Dense { len, .. } => {
                    2 * self.shape.n_layers * self.shape.n_kv * self.shape.d_head * 2 * len
                }
            })
            .sum();
        self.metrics.dense_equiv_bytes.store(dense_equiv, Ordering::Relaxed);
        Ok(())
    }
}

/// Run one sequence's decode graph (the parallel phase of an iteration).
/// Returns `Ok(None)` when the sequence is finished (token budget, stop
/// token, or length limit) and nothing was executed.
fn decode_execute(
    lm: &LoadedModel,
    shape: CacheShape,
    l_buckets: &[usize],
    clone_args: bool,
    seq: &mut ActiveSeq,
) -> anyhow::Result<Option<Vec<HostTensor>>> {
    if seq.produced.len() >= seq.req.max_new_tokens {
        return Ok(None);
    }
    if let Some(stop) = seq.req.stop_token {
        if seq.next_token == stop {
            return Ok(None);
        }
    }

    let outs = match &mut seq.backend {
        SeqBackend::Swan(cache) => {
            if cache.needs_growth() {
                let next = l_buckets.iter().copied().find(|&l| l > cache.l_cap);
                match next {
                    Some(l) => cache.grow(l),
                    None => return Ok(None), // length limit reached
                }
            }
            let nl = shape.n_layers;
            let nkv = shape.n_kv;
            let graph = format!("decode_l{}_k{}", cache.l_cap, cache.k_active);
            seq.decode_graph = graph.clone();
            let sp_shape = vec![nl, nkv, cache.l_cap, cache.k_active];
            let buf_shape = vec![nl, nkv, shape.buf_cap, shape.d_head];
            let tok = [seq.next_token as i32];
            let pos = [cache.pos as i32];
            let smask = cache.smask();
            let bmask = cache.bmask();
            let scalar: [usize; 0] = [];
            let l_shape = [cache.l_cap];
            let b_shape = [shape.buf_cap];
            let views = [
                ArgView::I32(&tok, &scalar),
                ArgView::I32(&pos, &scalar),
                ArgView::F32(&cache.sp_kvals, &sp_shape),
                ArgView::I32(&cache.sp_kidx, &sp_shape),
                ArgView::F32(&cache.sp_vvals, &sp_shape),
                ArgView::I32(&cache.sp_vidx, &sp_shape),
                ArgView::F32(&cache.kbuf, &buf_shape),
                ArgView::F32(&cache.vbuf, &buf_shape),
                ArgView::F32(smask, &l_shape),
                ArgView::F32(bmask, &b_shape),
            ];
            if clone_args {
                let args = vec![
                    HostTensor::scalar_i32(seq.next_token as i32),
                    HostTensor::scalar_i32(cache.pos as i32),
                    HostTensor::f32(cache.sp_kvals.clone(), sp_shape.clone()),
                    HostTensor::i32(cache.sp_kidx.clone(), sp_shape.clone()),
                    HostTensor::f32(cache.sp_vvals.clone(), sp_shape.clone()),
                    HostTensor::i32(cache.sp_vidx.clone(), sp_shape.clone()),
                    HostTensor::f32(cache.kbuf.clone(), buf_shape.clone()),
                    HostTensor::f32(cache.vbuf.clone(), buf_shape.clone()),
                    HostTensor::f32(smask.to_vec(), vec![cache.l_cap]),
                    HostTensor::f32(bmask.to_vec(), vec![shape.buf_cap]),
                ];
                lm.execute(&graph, &args)?
            } else {
                lm.execute_views(&graph, &views)?
            }
        }
        SeqBackend::Dense { k, v, len, cap } => {
            if *len >= *cap {
                return Ok(None);
            }
            let nl = shape.n_layers;
            let nkv = shape.n_kv;
            let graph = format!("decode_dense_l{cap}");
            seq.decode_graph = graph.clone();
            let mut cmask = vec![0.0f32; *cap];
            cmask[..*len].iter_mut().for_each(|x| *x = 1.0);
            let tok = [seq.next_token as i32];
            let pos = [*len as i32];
            let scalar: [usize; 0] = [];
            let kv_shape = vec![nl, nkv, *cap, shape.d_head];
            let c_shape = [*cap];
            let views = [
                ArgView::I32(&tok, &scalar),
                ArgView::I32(&pos, &scalar),
                ArgView::F32(k, &kv_shape),
                ArgView::F32(v, &kv_shape),
                ArgView::F32(&cmask, &c_shape),
            ];
            lm.execute_views(&graph, &views)?
        }
    };
    Ok(Some(outs))
}

fn finish(seq: ActiveSeq) -> Response {
    Response {
        id: seq.req.id,
        text: decode_tokens(&seq.produced),
        tokens: seq.produced,
        stats: seq.stats,
    }
}

/// Sample one token from a logits row: greedy at `temperature <= 0`,
/// softmax sampling otherwise.  Shared by the PJRT engine and the
/// pipeline-group coordinator ([`crate::shard::pipeline`]) so both paths
/// consume identical RNG streams for identical logits — the basis of the
/// pipeline-vs-single-shard bit-identity guarantee.
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Pcg64) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits) as u32;
    }
    let mut p: Vec<f32> = logits.iter().map(|l| l / temperature).collect();
    softmax_inplace(&mut p);
    let mut u = rng.next_f32();
    for (i, &pi) in p.iter().enumerate() {
        if u < pi {
            return i as u32;
        }
        u -= pi;
    }
    (p.len() - 1) as u32
}

/// Seed XOR'd into every sequence's decode RNG stream (shared with the
/// pipeline-group coordinator so both serving paths derive the same
/// per-request streams).
#[allow(non_snake_case)]
pub(crate) fn x5wan_seed() -> u64 {
    0x53_57_41_4e // "SWAN"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_and_temperature() {
        let logits = vec![0.0f32, 5.0, 1.0];
        let mut rng = Pcg64::new(0);
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // high temperature explores
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample(&logits, 5.0, &mut rng));
        }
        assert!(seen.len() > 1);
    }

    // Engine integration tests (needing artifacts) live in
    // rust/tests/serve_integration.rs.
}
