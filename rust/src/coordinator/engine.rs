//! The serving engine: PJRT-backed prefill/decode over hybrid caches with
//! iteration-level continuous batching.
//!
//! One [`Engine::step`] performs: (1) admission — pop admissible requests
//! from the scheduler, run their prefill graph, winnow the history into a
//! fresh [`SeqCache`]; (2) one decode iteration — a single decode-graph
//! call per active sequence (the batch is re-formed every iteration, so
//! short and long requests interleave without head-of-line blocking);
//! (3) completion — finished sequences are emitted with their stats.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::api::{Event, GenHandle, GenParams};
use crate::config::ServeConfig;
use crate::coordinator::autotune::AutoTuner;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{decode_tokens, Request, RequestStats, Response};
use crate::coordinator::scheduler::{Scheduler, SchedulerObs};
use crate::coordinator::sequence::{CacheShape, SeqCache};
use crate::obs::trace::{TraceKind, TraceRing, TRACE_RING_CAP};
use crate::runtime::engine::{ArgView, HostTensor, LoadedModel};
use crate::shard::supervisor::RecoveredReq;
use crate::swan::batch::WorkerPool;

use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::Pcg64;

/// Sequences each pool worker can decode before admission defers: matches
/// the pool's load-balancing chunk factor (`WorkerPool::for_each_mut`
/// forms ~4 chunks per worker), so a "full" pool still balances skewed
/// sequence lengths but never stretches an iteration past ~4 tasks deep.
/// (Shared with the pipeline-group coordinator, which sizes its
/// admission the same way against its stage worker pools.)
pub(crate) const DECODE_SLOTS_PER_WORKER: usize = 4;

/// Backend cache of one active sequence: SWAN hybrid or dense baseline.
enum SeqBackend {
    Swan(SeqCache),
    Dense { k: Vec<f32>, v: Vec<f32>, len: usize, cap: usize },
}

struct ActiveSeq {
    req: Request,
    backend: SeqBackend,
    produced: Vec<u32>,
    next_token: u32,
    stats: RequestStats,
    rng: Pcg64,
    decode_graph: String,
    /// Instant of the last committed token (prefill's first token to
    /// start): each decode commit measures its inter-token gap from it.
    last_token: Instant,
    /// Tokens to replay as forced decode steps (cross-shard recovery:
    /// `produced[1..]` of the sequence as committed on the dead shard).
    /// A forced step rebuilds KV but draws no RNG, emits nothing, and
    /// accounts nothing — once drained, decode resumes sampling at
    /// exactly the stream position an uninterrupted run would be at.
    replay: VecDeque<u32>,
    /// Set by the commit phase; the sequence is retired at iteration end.
    finished: bool,
}

/// State carried for a recovered request between [`Engine::recover`]
/// (which requeues it at the queue front) and its re-admission (which
/// restores it onto the fresh [`ActiveSeq`]).
struct RecoverCarry {
    produced: Vec<u32>,
    rng: Pcg64,
    stats: RequestStats,
    k_active: usize,
}

/// The serving engine (single-threaded stepper; wrap in a thread for the
/// TCP server).  With `cfg.decode_workers > 0` each decode iteration fans
/// the per-sequence graph executions across a worker pool — the batch is
/// still re-formed every iteration, so continuous-batching semantics are
/// unchanged and results are identical to serial stepping.
pub struct Engine {
    pub lm: LoadedModel,
    pub cfg: ServeConfig,
    pub metrics: Arc<Metrics>,
    scheduler: Scheduler,
    tuner: AutoTuner,
    active: Vec<ActiveSeq>,
    finished: VecDeque<Response>,
    /// Ids rejected at admission (prefill failure) — drained by callers
    /// that hold per-request reply channels, so no waiter leaks.
    rejected: VecDeque<u64>,
    /// Per-request event channels ([`crate::api::Event`]): requests
    /// submitted with a sink get their token stream (when
    /// `params.stream`) and terminal `Done`/`Error` delivered here;
    /// sink-less requests fall back to the `finished`/`rejected` queues.
    sinks: HashMap<u64, mpsc::Sender<Event>>,
    /// Recovery carries keyed by request id: inserted by
    /// [`Engine::recover`], consumed when admission re-prefills the
    /// request (see [`RecoverCarry`]).
    recovering: HashMap<u64, RecoverCarry>,
    shape: CacheShape,
    decode_l_buckets: Vec<usize>,
    prefill_buckets: Vec<usize>,
    next_id: u64,
    pool: WorkerPool,
    /// Retired request traces, bounded; served by the `TRACE <id>` verb.
    traces: TraceRing,
}

impl Engine {
    pub fn new(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<Engine> {
        // an explicit kernel choice pins the process-wide path (idempotent
        // across shards — every engine of a fleet carries the same config
        // value); "auto" leaves any selection an embedder already made
        // untouched rather than re-resolving and clobbering it
        if !matches!(cfg.kernels.as_str(), "auto" | "") {
            crate::simd::init_from_name(&cfg.kernels)?;
        }
        let lm = LoadedModel::open(artifacts_dir, &cfg.model)
            .with_context(|| format!("loading model {}", cfg.model))?;
        let arts = lm.store.model(&cfg.model)?;
        let mc = &arts.config;
        let shape = CacheShape {
            n_layers: mc.n_layers,
            n_kv: mc.n_kv_heads,
            d_head: mc.d_head,
            buf_cap: arts.buf,
        };
        let buckets = arts.decode_buckets();
        let mut k_buckets: Vec<usize> = buckets.iter().map(|&(_, k)| k).collect();
        k_buckets.sort_unstable();
        k_buckets.dedup();
        anyhow::ensure!(!k_buckets.is_empty(), "no decode graphs in manifest");
        let mut decode_l_buckets: Vec<usize> = buckets.iter().map(|&(l, _)| l).collect();
        decode_l_buckets.sort_unstable();
        decode_l_buckets.dedup();
        let mut tuner = AutoTuner::new(cfg.mem_budget, k_buckets);
        tuner.pin(cfg.k_active);
        let mut scheduler = Scheduler::new(cfg.max_batch, cfg.mem_budget);
        scheduler.set_lookahead(cfg.admit_lookahead);
        if cfg.decode_workers > 0 {
            scheduler.set_decode_slots(cfg.decode_workers * DECODE_SLOTS_PER_WORKER);
        }
        let metrics = Arc::new(Metrics::default());
        scheduler.set_obs(SchedulerObs::register(&metrics.registry));
        metrics.k_active.set(tuner.current_k() as u64);
        Ok(Engine {
            shape,
            decode_l_buckets,
            prefill_buckets: arts.prefill_buckets(),
            scheduler,
            tuner,
            active: Vec::new(),
            finished: VecDeque::new(),
            rejected: VecDeque::new(),
            sinks: HashMap::new(),
            recovering: HashMap::new(),
            metrics,
            next_id: 1,
            pool: WorkerPool::new(cfg.decode_workers),
            traces: TraceRing::new(TRACE_RING_CAP),
            lm,
            cfg,
        })
    }

    /// Pre-compile the graphs the engine will hit (optional warmup).
    pub fn warmup(&self) -> anyhow::Result<()> {
        let arts = self.lm.store.model(&self.cfg.model)?;
        let k = self.tuner.current_k();
        for (name, meta) in &arts.graphs {
            let is_needed = name.starts_with("prefill_")
                || name == &format!("decode_l{}_k{k}", self.decode_l_buckets[0])
                || (self.cfg.dense_baseline && name.starts_with("decode_dense"));
            if is_needed {
                self.lm.runtime.warmup(&self.cfg.model, name, meta)?;
            }
        }
        Ok(())
    }

    /// Change the compression level for newly admitted sequences.
    pub fn set_k_active(&mut self, k: usize) {
        self.tuner.pin(k);
        self.metrics.k_active.set(self.tuner.current_k() as u64);
    }

    pub fn current_k_active(&self) -> usize {
        self.tuner.current_k()
    }

    /// Submit a request; returns its id.  `params.max_new` is clamped to
    /// [`ServeConfig::max_new_hard_cap`] (the original ask is recorded on
    /// the request and surfaced in the response stats).
    pub fn submit(&mut self, mut req: Request) -> u64 {
        if req.id == 0 {
            req.id = self.next_id;
        }
        self.next_id = self.next_id.max(req.id) + 1;
        req.clamp_max_new(self.cfg.max_new_hard_cap());
        self.metrics.requests_submitted.inc();
        let id = req.id;
        req.trace.begin(id);
        self.scheduler.enqueue(req);
        id
    }

    /// Submit with an event sink: the sequence's token events (when
    /// `params.stream`) and its terminal `Done`/`Error` are delivered on
    /// `tx` instead of the `pop_finished`/`pop_rejected` queues.  The
    /// shard loop feeds `ShardCmd::Gen` reply channels through here.
    pub fn submit_with_sink(&mut self, req: Request, tx: mpsc::Sender<Event>) -> u64 {
        let id = self.submit(req);
        self.sinks.insert(id, tx);
        id
    }

    /// Submit and get a [`GenHandle`] back (the in-process v2 API): the
    /// caller drives the engine (`step`) and polls `handle.try_recv()`,
    /// or drains the handle from another thread while something else
    /// steps.
    pub fn submit_handle(&mut self, req: Request) -> GenHandle {
        let cancel = req.cancel.clone();
        // reserve the id first so the handle and sink agree on it
        let id = self.submit(req);
        let (tx, handle) = GenHandle::channel(id, cancel);
        self.sinks.insert(id, tx);
        handle
    }

    pub fn submit_text(&mut self, text: &str, max_new: usize) -> u64 {
        let id = self.next_id;
        self.submit(Request::from_text(id, text, max_new))
    }

    /// Cancel a request by id, wherever it is: queued (the scheduler
    /// flips its token; it is purged and answered at the next admission
    /// pass) or actively decoding (the sequence retires at the next
    /// decode iteration with its partial output).  Unknown ids are a
    /// no-op.  Returns whether the id was found.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(seq) = self.active.iter().find(|s| s.req.id == id) {
            seq.req.cancel.cancel();
            return true;
        }
        self.scheduler.cancel(id)
    }

    /// JSONL lifecycle timeline for request `id`: retired traces come
    /// from the bounded ring; live requests (active or still queued)
    /// render their in-progress trace. `None` once a retired trace has
    /// aged out of the ring (or the id was never seen).
    pub fn trace_jsonl(&self, id: u64) -> Option<String> {
        self.traces
            .jsonl(id)
            .or_else(|| self.active.iter().find(|s| s.req.id == id).map(|s| s.req.trace.jsonl()))
            .or_else(|| self.scheduler.queued().find(|r| r.id == id).map(|r| r.trace.jsonl()))
    }

    /// Live KV bytes across active sequences.
    pub fn live_cache_bytes(&self) -> usize {
        self.active
            .iter()
            .map(|s| match &s.backend {
                SeqBackend::Swan(c) => c.storage_bytes(),
                SeqBackend::Dense { len, .. } => {
                    2 * self.shape.n_layers * self.shape.n_kv * self.shape.d_head * 2 * len
                }
            })
            .sum()
    }

    /// Requests queued behind admission control.
    pub fn queue_len(&self) -> usize {
        self.scheduler.queue_len()
    }

    /// Sequences currently decoding.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Projected total KV load: live bytes of the active set plus the
    /// admission projection ([`Scheduler::projected_bytes`]) of every
    /// queued request — each projected at the *request's own*
    /// compression level (its `params.k_active` override, snapped to a
    /// compiled bucket) rather than the fleet default.  The shard
    /// router's `MemAware` placement policy balances on this figure.
    pub fn projected_load_bytes(&self) -> usize {
        let buf = self.shape.buf_cap;
        let queued: usize = self
            .scheduler
            .queued()
            .map(|r| {
                let (sparse_b, dense_b) = self.token_byte_rates(self.request_k(r));
                Scheduler::projected_bytes(
                    projected_prompt_tokens(r.prompt.len(), &self.prefill_buckets),
                    r.params.max_new,
                    sparse_b,
                    dense_b,
                    buf,
                )
            })
            .sum();
        self.live_cache_bytes() + queued
    }

    /// Snap a requested compression level to the nearest compiled k
    /// bucket — the same rule the autotuner's manual pin applies, so a
    /// per-request `k=<n>` lands on exactly the bucket a fleet-wide
    /// `SET k_active <n>` would.
    pub fn snap_k(&self, k: usize) -> usize {
        snap_to_bucket(&self.tuner.k_buckets, k, self.tuner.current_k())
    }

    /// Compression level a request will be admitted at: its own
    /// override when present, the fleet level otherwise.
    fn request_k(&self, r: &Request) -> usize {
        r.params.k_active.map(|k| self.snap_k(k)).unwrap_or_else(|| self.tuner.current_k())
    }

    pub fn has_work(&self) -> bool {
        !self.active.is_empty() || self.scheduler.queue_len() > 0
    }

    /// Retarget the KV memory budget (live `SET shards` rebalance: the
    /// fleet total re-split over the new member count).
    pub fn set_mem_budget(&mut self, bytes: usize) {
        self.scheduler.set_mem_budget(bytes);
    }

    /// Block-granular budget view for the router's placement policies:
    /// `(total, free)` allocation granules under this engine's byte
    /// budget, sized at the fleet compression level.  Both zero when
    /// block-accounted admission is off (`--pool` unset) or the budget
    /// is unbounded — `MemAware` then falls back to projected bytes.
    pub fn block_budget(&self) -> (usize, usize) {
        if !self.cfg.pool || self.scheduler.mem_budget == 0 {
            return (0, 0);
        }
        let granule = 2
            * self.shape.n_layers
            * self.shape.n_kv
            * crate::pool::block_bytes(
                self.cfg.block_tokens,
                self.shape.d_head,
                self.cfg.mode,
                self.tuner.current_k(),
            );
        if granule == 0 {
            return (0, 0);
        }
        let total = self.scheduler.mem_budget / granule;
        let used = self.live_cache_bytes().div_ceil(granule);
        (total, total.saturating_sub(used))
    }

    /// Extract every in-flight and queued request as recovery payloads
    /// (shard death / drain-timeout migration).  Active sequences carry
    /// their committed tokens and RNG position; queued ones are fresh
    /// re-submissions — unless they were themselves awaiting a replay
    /// resume, in which case their carry travels on.  Records a `Die`
    /// trace event on each; the receiving shard records `Recover`.
    pub fn take_work(&mut self) -> Vec<RecoveredReq> {
        let mut out = Vec::new();
        for mut seq in self.active.drain(..) {
            seq.req.trace.record(TraceKind::Die);
            let sink = self.sinks.remove(&seq.req.id);
            let k = match &seq.backend {
                SeqBackend::Swan(c) => c.k_active,
                SeqBackend::Dense { .. } => 0,
            };
            out.push(RecoveredReq {
                req: seq.req,
                produced: seq.produced,
                rng: seq.rng,
                stats: seq.stats,
                k_active: k,
                sink,
            });
        }
        for mut req in self.scheduler.take_all() {
            req.trace.record(TraceKind::Die);
            let sink = self.sinks.remove(&req.id);
            match self.recovering.remove(&req.id) {
                Some(c) => out.push(RecoveredReq {
                    req,
                    produced: c.produced,
                    rng: c.rng,
                    stats: c.stats,
                    k_active: c.k_active,
                    sink,
                }),
                None => out.push(RecoveredReq::fresh(req, sink)),
            }
        }
        out
    }

    /// Accept a request recovered from a dead or draining shard:
    /// re-prefill at the original compression level, replay its
    /// committed tokens as forced decode steps (no RNG draw, no
    /// re-emission), then continue its RNG stream — the continued output
    /// is bit-identical to an uninterrupted run.  Recovered requests go
    /// to the queue *front*, like same-shard preemption resumes.
    pub fn recover(&mut self, rec: RecoveredReq) {
        let RecoveredReq { mut req, produced, rng, mut stats, k_active, sink } = rec;
        self.next_id = self.next_id.max(req.id) + 1;
        req.trace.record(TraceKind::Recover);
        self.metrics.requests_recovered.inc();
        if let Some(tx) = sink {
            self.sinks.insert(req.id, tx);
        }
        if produced.is_empty() {
            // never prefilled on the dead shard: a plain re-run
            self.scheduler.enqueue(req);
            return;
        }
        stats.recoveries += 1;
        if k_active > 0 {
            // pin re-admission to the level the dead shard ran at —
            // replay is bit-exact only over an identical cache shape
            req.params.k_active = Some(k_active);
        }
        self.recovering.insert(req.id, RecoverCarry { produced, rng, stats, k_active });
        self.scheduler.requeue_front(req);
    }

    pub fn pop_finished(&mut self) -> Option<Response> {
        self.finished.pop_front()
    }

    /// Drain one id that was rejected at admission (its request will
    /// never produce a [`Response`]); serving fronts answer the waiting
    /// client with an error instead of leaving it blocked.
    pub fn pop_rejected(&mut self) -> Option<u64> {
        self.rejected.pop_front()
    }

    /// One engine iteration: admit, decode every active sequence once,
    /// retire finished sequences.
    pub fn step(&mut self) -> anyhow::Result<()> {
        self.admit()?;
        self.decode_iteration()?;
        Ok(())
    }

    /// Run until all queued + active work is done; returns responses in
    /// completion order.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.has_work() {
            self.step()?;
            while let Some(r) = self.pop_finished() {
                out.push(r);
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // internals
    // ------------------------------------------------------------------

    /// Per-token KV byte rates `(sparse, dense)` at compression level
    /// `k` — the single source feeding both admission control and the
    /// router's `MemAware` projection ([`Engine::projected_load_bytes`]);
    /// the closed form is shared with the pipeline groups
    /// ([`crate::sparse::memory::token_byte_rates`]).
    fn token_byte_rates(&self, k: usize) -> (usize, usize) {
        crate::sparse::memory::token_byte_rates(
            self.shape.n_layers,
            self.shape.n_kv,
            self.shape.d_head,
            self.cfg.mode,
            k,
        )
    }

    /// Deliver a terminal `Done`: through the request's event sink when
    /// one is attached, the `pop_finished` queue otherwise.
    fn deliver_done(&mut self, resp: Response) {
        match self.sinks.remove(&resp.id) {
            Some(tx) => {
                let _ = tx.send(Event::Done(resp));
            }
            None => self.finished.push_back(resp),
        }
    }

    /// Deliver a terminal `Error` (sink or `pop_rejected` queue).
    fn deliver_error(&mut self, id: u64, message: String) {
        match self.sinks.remove(&id) {
            Some(tx) => {
                let _ = tx.send(Event::Error { id, message });
            }
            None => self.rejected.push_back(id),
        }
    }

    fn admit(&mut self) -> anyhow::Result<()> {
        // cancelled-while-queued requests first: purge them and answer
        // their waiters with an empty cancelled response — they must not
        // hold queue slots or inflate the projected load
        for mut p in self.scheduler.take_cancelled() {
            let stats = RequestStats {
                queue_time: p.enqueued.elapsed(),
                cancelled: true,
                clamped_from: p.req.clamped_from,
                ..Default::default()
            };
            // a queued purge is a cancellation AND a completion: every
            // submitted request resolves exactly once, and the cancel
            // counter records how it resolved
            self.metrics.requests_cancelled.inc();
            self.metrics.requests_completed.inc();
            let resp =
                Response { id: p.req.id, tokens: Vec::new(), text: String::new(), stats };
            p.req.trace.record(TraceKind::Retire);
            self.traces.push(p.req.trace);
            self.deliver_done(resp);
        }
        let k_now = {
            let live = self.live_cache_bytes();
            let t = &mut self.tuner;
            t.observe(live)
        };
        self.metrics.k_active.set(k_now as u64);
        // locals for the projection closure (admit_next holds the
        // scheduler mutably, so the closure must not re-borrow self)
        let shape = self.shape;
        let mode = self.cfg.mode;
        let k_buckets = self.tuner.k_buckets.clone();
        let snap = move |k: usize| snap_to_bucket(&k_buckets, k, k_now);
        let buf = shape.buf_cap;
        let prefill_buckets = self.prefill_buckets.clone();
        let pool_bt = if self.cfg.pool { self.cfg.block_tokens } else { 0 };
        loop {
            // re-read live bytes per admission: each admitted prefill
            // grows the active set, and a burst gated against one stale
            // snapshot could collectively overshoot the budget
            let live = self.live_cache_bytes();
            // project each request at its own compression level (the
            // per-request override, snapped) — a k=8 request must be
            // charged k=8 bytes, not the fleet default's — and from the
            // bucket-truncated prompt length it will actually cache
            let proj = |req: &Request| {
                let k = req.params.k_active.map(&snap).unwrap_or(k_now);
                let (sparse_b, dense_b) = crate::sparse::memory::token_byte_rates(
                    shape.n_layers,
                    shape.n_kv,
                    shape.d_head,
                    mode,
                    k,
                );
                let bytes = Scheduler::projected_bytes(
                    projected_prompt_tokens(req.prompt.len(), &prefill_buckets),
                    req.params.max_new,
                    sparse_b,
                    dense_b,
                    buf,
                );
                if pool_bt > 0 {
                    // block-accounted admission: a sequence acquires
                    // storage a whole block per stream at a time (all
                    // 2 * n_layers * n_kv streams grow in lockstep), so
                    // charge whole allocation granules
                    let granule = 2
                        * shape.n_layers
                        * shape.n_kv
                        * crate::pool::block_bytes(pool_bt, shape.d_head, mode, k);
                    crate::pool::block_ceil_bytes(bytes, granule)
                } else {
                    bytes
                }
            };
            let Some(pending) = self.scheduler.admit_next(self.active.len(), live, proj) else {
                break;
            };
            let queue_time = pending.enqueued.elapsed();
            self.metrics.queue_wait_seconds.record(queue_time);
            let mut req = pending.req;
            let rid = req.id;
            let k_req = req.params.k_active.map(&snap).unwrap_or(k_now);
            req.trace.record(TraceKind::Admit);
            match self.prefill(req, k_req, queue_time) {
                Ok(mut seq) => {
                    if let Some(c) = self.recovering.remove(&rid) {
                        // cross-shard resume: restore the committed
                        // tokens, RNG position and carried stats; queue
                        // the tail for forced replay.  Nothing is
                        // re-emitted — the client already holds every
                        // committed token, including the first.
                        let fresh = seq.stats.clone();
                        seq.stats = c.stats;
                        seq.stats.queue_time += fresh.queue_time;
                        seq.stats.prefill_time += fresh.prefill_time;
                        seq.rng = c.rng;
                        seq.next_token = c.produced[0];
                        seq.replay = c.produced[1..].iter().copied().collect();
                        seq.produced = c.produced;
                    } else if seq.req.params.stream {
                        // the first token was sampled from the prefill
                        // logits — streaming clients see it immediately
                        if let Some(tx) = self.sinks.get(&rid) {
                            let _ = tx.send(Event::Token {
                                id: rid,
                                index: 0,
                                token: seq.next_token,
                                text: decode_tokens(&[seq.next_token]),
                            });
                        }
                    }
                    self.active.push(seq);
                }
                Err(e) => {
                    // a failed re-prefill of a recovered request is
                    // terminal too — drop its carry with it
                    self.recovering.remove(&rid);
                    self.metrics.requests_rejected.inc();
                    log::warn!("prefill failed: {e:#}");
                    self.deliver_error(rid, format!("rejected at admission: {e:#}"));
                }
            }
        }
        Ok(())
    }

    fn prefill(&mut self, mut req: Request, k_active: usize, queue_time: std::time::Duration) -> anyhow::Result<ActiveSeq> {
        let t0 = Instant::now();
        // one pass, no copies: borrow the request's prompt (or a static
        // dummy token for empty prompts) and slice the suffix in place —
        // prompts longer than the largest bucket keep their suffix (the
        // bucket limit is a compile-time artifact knob, not a model limit)
        let full: &[u32] = if req.prompt.is_empty() { &[0] } else { &req.prompt };
        let cap = self
            .prefill_buckets
            .iter()
            .copied()
            .find(|&t| t >= full.len())
            .or(self.prefill_buckets.last().copied())
            .context("no prefill graphs")?;
        let prompt = &full[full.len().saturating_sub(cap)..];

        let mut tokens = vec![0i32; cap];
        let mut tmask = vec![0.0f32; cap];
        for (i, &t) in prompt.iter().enumerate() {
            tokens[i] = t as i32;
            tmask[i] = 1.0;
        }
        let outs = self.lm.execute(
            &format!("prefill_t{cap}"),
            &[
                HostTensor::i32(tokens, vec![cap]),
                HostTensor::f32(tmask, vec![cap]),
            ],
        )?;
        let logits = outs[0].as_f32()?.to_vec();
        let khat = outs[1].as_f32()?;
        let vhat = outs[2].as_f32()?;

        let mut stats =
            RequestStats { queue_time, clamped_from: req.clamped_from, ..Default::default() };
        // surface bucket truncation the way max_new clamping is surfaced:
        // the response records the originally requested prompt length
        if full.len() > cap {
            stats.truncated_prompt_from = Some(full.len());
        }
        stats.prefill_time = t0.elapsed();
        self.metrics.prefill_ns.record(stats.prefill_time.as_nanos() as f64);
        self.metrics.prefill_seconds.record(stats.prefill_time);
        self.metrics.prefill_tokens.add(prompt.len() as u64);

        let backend = if self.cfg.dense_baseline {
            let dense_cap = 512; // decode_dense_l512 bucket
            let heads = self.shape.n_layers * self.shape.n_kv;
            let dh = self.shape.d_head;
            let mut k = vec![0.0f32; heads * dense_cap * dh];
            let mut v = vec![0.0f32; heads * dense_cap * dh];
            for hh in 0..heads {
                for t in 0..prompt.len() {
                    let src = (hh * cap + t) * dh;
                    let dst = (hh * dense_cap + t) * dh;
                    k[dst..dst + dh].copy_from_slice(&khat[src..src + dh]);
                    v[dst..dst + dh].copy_from_slice(&vhat[src..src + dh]);
                }
            }
            SeqBackend::Dense { k, v, len: prompt.len(), cap: dense_cap }
        } else {
            let sparse_need = prompt.len().saturating_sub(self.shape.buf_cap);
            let l_cap = self
                .decode_l_buckets
                .iter()
                .copied()
                .find(|&l| l >= sparse_need + 1)
                .or(self.decode_l_buckets.last().copied())
                .context("no decode buckets")?;
            let mut cache = SeqCache::new(self.shape, l_cap, k_active, self.cfg.mode);
            cache.load_prefill(khat, vhat, cap, prompt.len());
            SeqBackend::Swan(cache)
        };

        let next_token = sample(&logits, &req.params, &[], &mut Pcg64::new(req.seed_base()));
        // TTFT: the first token is sampled from the prefill logits, so
        // time-to-first-token is the queue wait plus the prefill pass.
        stats.ttft_ns = (queue_time + stats.prefill_time).as_nanos() as u64;
        self.metrics.ttft_seconds.record_ns(stats.ttft_ns);
        req.trace.record(TraceKind::PrefillDone);
        req.trace.record(TraceKind::FirstToken);
        Ok(ActiveSeq {
            rng: Pcg64::new(req.seed_base() ^ x5wan_seed()),
            decode_graph: String::new(),
            produced: vec![next_token],
            next_token,
            stats,
            backend,
            req,
            last_token: Instant::now(),
            replay: VecDeque::new(),
            finished: false,
        })
    }

    /// One decode iteration, in two phases:
    ///
    /// * **read/execute + sample** — every active sequence runs its decode
    ///   graph and samples its next token; with `decode_workers > 0` these
    ///   independent executions fan across the pool (each task owns its
    ///   sequence `&mut` — including its private RNG stream — and the PJRT
    ///   runtime is shared immutably).  Sampling lives here rather than on
    ///   the coordinator thread so per-token costs beyond argmax (top-p,
    ///   repetition penalties) scale with the pool;
    /// * **commit** — serially, in submission order: append the new
    ///   (k̂, v̂) rows, record the sampled token, account stats, retire
    ///   finished sequences.
    ///
    /// Each sequence's compute (and RNG consumption) depends only on its
    /// own pre-iteration state, so the fan-out produces the same tokens as
    /// serial stepping.
    fn decode_iteration(&mut self) -> anyhow::Result<()> {
        let shape = self.shape;
        // SWAN_CLONE_ARGS=1 forces the pre-optimization clone-per-step
        // path (kept for the §Perf before/after measurement).
        let clone_args = std::env::var("SWAN_CLONE_ARGS").is_ok();

        struct StepTask<'a> {
            seq: &'a mut ActiveSeq,
            out: Option<anyhow::Result<Option<Vec<HostTensor>>>>,
            /// Token sampled in the execute phase (None when the sequence
            /// finished, errored, or produced non-f32 logits).
            next: Option<u32>,
            /// This step replayed a recovered token: commit appends KV
            /// and advances the cursor but emits and accounts nothing.
            replayed: bool,
            exec: Duration,
        }

        // phase 1: execute + sample (parallel when the pool has workers)
        {
            let lm = &self.lm;
            let l_buckets = &self.decode_l_buckets;
            let mut tasks: Vec<StepTask> = self
                .active
                .iter_mut()
                .map(|seq| StepTask {
                    seq,
                    out: None,
                    next: None,
                    replayed: false,
                    exec: Duration::ZERO,
                })
                .collect();
            self.pool.for_each_mut(&mut tasks, |_scratch, t| {
                let t0 = Instant::now();
                let out = decode_execute(lm, shape, l_buckets, clone_args, t.seq);
                if let Ok(Some(outs)) = &out {
                    if let Ok(logits) = outs[0].as_f32() {
                        let s = &mut *t.seq;
                        if let Some(forced) = s.replay.pop_front() {
                            // forced replay step (cross-shard recovery):
                            // the token is already committed — rebuild
                            // KV, draw nothing from the RNG stream
                            t.next = Some(forced);
                            t.replayed = true;
                        } else {
                            // top-p / repetition-penalty live here in the
                            // parallel phase: the draw depends only on this
                            // sequence's own state (params, produced
                            // history, private RNG stream), so serial and
                            // parallel stepping stay bit-identical
                            t.next =
                                Some(sample(logits, &s.req.params, &s.produced, &mut s.rng));
                        }
                    }
                }
                t.out = Some(out);
                t.exec = t0.elapsed();
            });

            // phase 2: commit serially, in submission order
            for t in tasks.iter_mut() {
                let t0 = Instant::now();
                let outs = match t.out.take().expect("phase 1 ran for every task") {
                    Ok(Some(outs)) => outs,
                    Ok(None) => {
                        t.seq.finished = true;
                        continue;
                    }
                    Err(e) => return Err(e),
                };
                let seq = &mut *t.seq;
                let Some(next) = t.next else {
                    // outs[0] existed but was not f32 — surface the type
                    // error the sampler hit in the execute phase
                    outs[0].as_f32()?;
                    anyhow::bail!("decode graph produced no sampleable logits");
                };
                let khat = outs[1].as_f32()?;
                let vhat = outs[2].as_f32()?;

                match &mut seq.backend {
                    SeqBackend::Swan(cache) => cache.append(khat, vhat),
                    SeqBackend::Dense { k, v, len, cap } => {
                        let dh = shape.d_head;
                        let heads = shape.n_layers * shape.n_kv;
                        for hh in 0..heads {
                            let dst = (hh * *cap + *len) * dh;
                            k[dst..dst + dh].copy_from_slice(&khat[hh * dh..(hh + 1) * dh]);
                            v[dst..dst + dh].copy_from_slice(&vhat[hh * dh..(hh + 1) * dh]);
                        }
                        *len += 1;
                    }
                }

                if t.replayed {
                    // forced replay commit: the token was committed (and
                    // for streams, emitted) before the shard died — KV
                    // is rebuilt, the cursor advances, nothing else
                    seq.next_token = next;
                    self.metrics.replay_tokens.inc();
                    continue;
                }

                seq.next_token = next;
                seq.produced.push(next);
                if seq.req.params.stream {
                    if let Some(tx) = self.sinks.get(&seq.req.id) {
                        let _ = tx.send(Event::Token {
                            id: seq.req.id,
                            index: seq.produced.len() - 1,
                            token: next,
                            text: decode_tokens(&[next]),
                        });
                    }
                }
                seq.stats.decode_steps += 1;
                let step_time = t.exec + t0.elapsed();
                seq.stats.decode_time += step_time;
                // inter-token gap: committed-token to committed-token
                // wall time, the user-observed stream cadence. Recording
                // is lock-free (trace push is a plain Vec push on this
                // coordinator-owned struct; histograms are atomics).
                let gap_ns = seq.last_token.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                seq.last_token = Instant::now();
                seq.stats.itl_sum_ns += gap_ns;
                seq.stats.itl_max_ns = seq.stats.itl_max_ns.max(gap_ns);
                seq.req.trace.record(TraceKind::Decode);
                self.metrics.itl_seconds.record_ns(gap_ns);
                self.metrics.decode_step_seconds.record(step_time);
                let bytes = match &seq.backend {
                    SeqBackend::Swan(c) => c.storage_bytes(),
                    SeqBackend::Dense { len, .. } => {
                        2 * shape.n_layers * shape.n_kv * shape.d_head * 2 * len
                    }
                };
                seq.stats.peak_cache_bytes = seq.stats.peak_cache_bytes.max(bytes);
                seq.stats.dense_equiv_bytes = match &seq.backend {
                    SeqBackend::Swan(c) => c.dense_equiv_bytes(),
                    SeqBackend::Dense { len, .. } => {
                        2 * shape.n_layers * shape.n_kv * shape.d_head * 2 * len
                    }
                };
                self.metrics.decode_step_ns.record(step_time.as_nanos() as f64);
                self.metrics.decode_tokens.inc();
            }
        }

        // retire finished sequences, preserving submission order (skip the
        // rebuild entirely on the common nothing-finished iteration)
        if self.active.iter().any(|s| s.finished) {
            let mut keep = Vec::with_capacity(self.active.len());
            for mut seq in self.active.drain(..) {
                if seq.finished {
                    if seq.req.cancel.is_cancelled() {
                        self.metrics.requests_cancelled.inc();
                    }
                    self.metrics.requests_completed.inc();
                    // retain the finished lifecycle for `TRACE <id>` —
                    // once per request, off the per-token path
                    seq.req.trace.record(TraceKind::Retire);
                    self.traces.push(seq.req.trace.clone());
                    let resp = finish(seq);
                    // route through the event sink when one is attached
                    // (self.active is still mutably borrowed by drain,
                    // so deliver inline rather than via deliver_done)
                    match self.sinks.remove(&resp.id) {
                        Some(tx) => {
                            let _ = tx.send(Event::Done(resp));
                        }
                        None => self.finished.push_back(resp),
                    }
                } else {
                    keep.push(seq);
                }
            }
            self.active = keep;
        }

        // metrics snapshot of live cache
        self.metrics.cache_bytes.set(self.live_cache_bytes() as u64);
        let dense_equiv: usize = self
            .active
            .iter()
            .map(|s| match &s.backend {
                SeqBackend::Swan(c) => c.dense_equiv_bytes(),
                SeqBackend::Dense { len, .. } => {
                    2 * self.shape.n_layers * self.shape.n_kv * self.shape.d_head * 2 * len
                }
            })
            .sum();
        self.metrics.dense_equiv_bytes.set(dense_equiv as u64);
        Ok(())
    }
}

/// Run one sequence's decode graph (the parallel phase of an iteration).
/// Returns `Ok(None)` when the sequence is finished (token budget, stop
/// token, or length limit) and nothing was executed.
fn decode_execute(
    lm: &LoadedModel,
    shape: CacheShape,
    l_buckets: &[usize],
    clone_args: bool,
    seq: &mut ActiveSeq,
) -> anyhow::Result<Option<Vec<HostTensor>>> {
    // a flipped cancel token retires the sequence here — checked once
    // per iteration, so cancellation lands within one decode step and
    // co-batched sequences are untouched
    if seq.req.cancel.is_cancelled() {
        return Ok(None);
    }
    if seq.produced.len() >= seq.req.params.max_new {
        return Ok(None);
    }
    if let Some(stop) = seq.req.params.stop {
        if seq.next_token == stop {
            return Ok(None);
        }
    }

    let outs = match &mut seq.backend {
        SeqBackend::Swan(cache) => {
            if cache.needs_growth() {
                let next = l_buckets.iter().copied().find(|&l| l > cache.l_cap);
                match next {
                    Some(l) => cache.grow(l),
                    None => return Ok(None), // length limit reached
                }
            }
            let nl = shape.n_layers;
            let nkv = shape.n_kv;
            let graph = format!("decode_l{}_k{}", cache.l_cap, cache.k_active);
            seq.decode_graph = graph.clone();
            let sp_shape = vec![nl, nkv, cache.l_cap, cache.k_active];
            let buf_shape = vec![nl, nkv, shape.buf_cap, shape.d_head];
            let tok = [seq.next_token as i32];
            let pos = [cache.pos as i32];
            let smask = cache.smask();
            let bmask = cache.bmask();
            let scalar: [usize; 0] = [];
            let l_shape = [cache.l_cap];
            let b_shape = [shape.buf_cap];
            let views = [
                ArgView::I32(&tok, &scalar),
                ArgView::I32(&pos, &scalar),
                ArgView::F32(&cache.sp_kvals, &sp_shape),
                ArgView::I32(&cache.sp_kidx, &sp_shape),
                ArgView::F32(&cache.sp_vvals, &sp_shape),
                ArgView::I32(&cache.sp_vidx, &sp_shape),
                ArgView::F32(&cache.kbuf, &buf_shape),
                ArgView::F32(&cache.vbuf, &buf_shape),
                ArgView::F32(smask, &l_shape),
                ArgView::F32(bmask, &b_shape),
            ];
            if clone_args {
                let args = vec![
                    HostTensor::scalar_i32(seq.next_token as i32),
                    HostTensor::scalar_i32(cache.pos as i32),
                    HostTensor::f32(cache.sp_kvals.clone(), sp_shape.clone()),
                    HostTensor::i32(cache.sp_kidx.clone(), sp_shape.clone()),
                    HostTensor::f32(cache.sp_vvals.clone(), sp_shape.clone()),
                    HostTensor::i32(cache.sp_vidx.clone(), sp_shape.clone()),
                    HostTensor::f32(cache.kbuf.clone(), buf_shape.clone()),
                    HostTensor::f32(cache.vbuf.clone(), buf_shape.clone()),
                    HostTensor::f32(smask.to_vec(), vec![cache.l_cap]),
                    HostTensor::f32(bmask.to_vec(), vec![shape.buf_cap]),
                ];
                lm.execute(&graph, &args)?
            } else {
                lm.execute_views(&graph, &views)?
            }
        }
        SeqBackend::Dense { k, v, len, cap } => {
            if *len >= *cap {
                return Ok(None);
            }
            let nl = shape.n_layers;
            let nkv = shape.n_kv;
            let graph = format!("decode_dense_l{cap}");
            seq.decode_graph = graph.clone();
            let mut cmask = vec![0.0f32; *cap];
            cmask[..*len].iter_mut().for_each(|x| *x = 1.0);
            let tok = [seq.next_token as i32];
            let pos = [*len as i32];
            let scalar: [usize; 0] = [];
            let kv_shape = vec![nl, nkv, *cap, shape.d_head];
            let c_shape = [*cap];
            let views = [
                ArgView::I32(&tok, &scalar),
                ArgView::I32(&pos, &scalar),
                ArgView::F32(k, &kv_shape),
                ArgView::F32(v, &kv_shape),
                ArgView::F32(&cmask, &c_shape),
            ];
            lm.execute_views(&graph, &views)?
        }
    };
    Ok(Some(outs))
}

/// Nearest compiled bucket to `k` (ties break low via `min_by_key`
/// order) — the ONE spelling of the per-request/fleet snap rule, shared
/// by [`Engine::snap_k`], [`AutoTuner::pin`]-equivalent admission, and
/// the projection closure, so admission can never project at a
/// different k than the sequence is admitted at.
fn snap_to_bucket(buckets: &[usize], k: usize, fallback: usize) -> usize {
    buckets.iter().copied().min_by_key(|b| b.abs_diff(k)).unwrap_or(fallback)
}

/// Prompt tokens that will actually be cached after prefill.  Prompts
/// longer than the largest compiled prefill bucket are suffix-truncated
/// by [`Engine::prefill`], so admission must project KV bytes from the
/// truncated length — charging the full prompt makes a single over-bucket
/// request over-project (sometimes past the whole budget) and starve
/// admissible batchmates behind it.  Empty prompts prefill one dummy
/// token.  The ONE spelling of the truncation rule, shared by admission
/// projection, `projected_load_bytes`, and `prefill` itself.
pub(crate) fn projected_prompt_tokens(prompt_len: usize, prefill_buckets: &[usize]) -> usize {
    let full = prompt_len.max(1);
    match prefill_buckets
        .iter()
        .copied()
        .find(|&t| t >= full)
        .or(prefill_buckets.last().copied())
    {
        Some(cap) => full.min(cap),
        None => full,
    }
}

fn finish(seq: ActiveSeq) -> Response {
    let mut stats = seq.stats;
    stats.cancelled = seq.req.cancel.is_cancelled();
    Response {
        id: seq.req.id,
        text: decode_tokens(&seq.produced),
        tokens: seq.produced,
        stats,
    }
}

/// Sample one token from a logits row under [`GenParams`]: greedy at
/// `temperature <= 0`, softmax sampling otherwise, with optional
/// CTRL-style repetition penalty over `produced` and nucleus (top-p)
/// filtering.  Shared by the PJRT engine and the pipeline-group
/// coordinator ([`crate::shard::pipeline`]) so both paths consume
/// identical RNG streams for identical logits — the basis of the
/// pipeline-vs-single-shard bit-identity guarantee.  Exactly one RNG
/// draw is consumed per non-greedy call regardless of top-p/penalty, so
/// streams are reproducible across worker counts and serving paths.
pub fn sample(logits: &[f32], params: &GenParams, produced: &[u32], rng: &mut Pcg64) -> u32 {
    let penalize = params.repetition_penalty != 1.0 && !produced.is_empty();
    if !penalize && params.top_p >= 1.0 {
        // fast path — bit-identical to the v1 sampler, which legacy
        // (temperature-only) request streams are locked to
        if params.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        let mut p: Vec<f32> = logits.iter().map(|l| l / params.temperature).collect();
        softmax_inplace(&mut p);
        let mut u = rng.next_f32();
        for (i, &pi) in p.iter().enumerate() {
            if u < pi {
                return i as u32;
            }
            u -= pi;
        }
        return (p.len() - 1) as u32;
    }

    let mut l = logits.to_vec();
    if penalize {
        // CTRL: shrink positive logits, amplify negative ones; each
        // distinct produced token is penalized once
        let mut seen = vec![false; l.len()];
        for &t in produced {
            let t = t as usize;
            if t < l.len() && !seen[t] {
                seen[t] = true;
                l[t] = if l[t] > 0.0 {
                    l[t] / params.repetition_penalty
                } else {
                    l[t] * params.repetition_penalty
                };
            }
        }
    }
    if params.temperature <= 0.0 {
        return argmax(&l) as u32;
    }
    let mut p: Vec<f32> = l.iter().map(|x| x / params.temperature).collect();
    softmax_inplace(&mut p);
    // nucleus: the smallest probability-descending prefix whose mass
    // reaches top_p (ties break by index, so the order is total and the
    // draw deterministic)
    let (kept, mass) = if params.top_p < 1.0 {
        let mut idx: Vec<usize> = (0..p.len()).collect();
        idx.sort_by(|&a, &b| {
            p[b].partial_cmp(&p[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let mut kept = Vec::new();
        let mut mass = 0.0f32;
        for &i in &idx {
            kept.push(i);
            mass += p[i];
            if mass >= params.top_p {
                break;
            }
        }
        (kept, mass)
    } else {
        ((0..p.len()).collect(), 1.0)
    };
    let mut u = rng.next_f32() * mass;
    for &i in &kept {
        if u < p[i] {
            return i as u32;
        }
        u -= p[i];
    }
    *kept.last().unwrap_or(&(p.len() - 1)) as u32
}

/// Seed XOR'd into every sequence's decode RNG stream (shared with the
/// pipeline-group coordinator so both serving paths derive the same
/// per-request streams).
#[allow(non_snake_case)]
pub(crate) fn x5wan_seed() -> u64 {
    0x53_57_41_4e // "SWAN"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(t: f32) -> GenParams {
        GenParams::new(8).temperature(t)
    }

    #[test]
    fn sample_greedy_and_temperature() {
        let logits = vec![0.0f32, 5.0, 1.0];
        let mut rng = Pcg64::new(0);
        assert_eq!(sample(&logits, &temp(0.0), &[], &mut rng), 1);
        // high temperature explores
        let mut seen = std::collections::HashSet::new();
        for _ in 0..200 {
            seen.insert(sample(&logits, &temp(5.0), &[], &mut rng));
        }
        assert!(seen.len() > 1);
    }

    /// The fast path IS the v1 sampler: with top_p=1 / rep=1 the general
    /// machinery must never engage, so legacy streams are bit-stable.
    #[test]
    fn default_params_reproduce_v1_streams() {
        let mut r = Pcg64::new(11);
        let logits: Vec<f32> = (0..16).map(|_| r.normal_f32()).collect();
        // hand-rolled v1 sampler
        let v1 = |logits: &[f32], t: f32, rng: &mut Pcg64| -> u32 {
            if t <= 0.0 {
                return argmax(logits) as u32;
            }
            let mut p: Vec<f32> = logits.iter().map(|l| l / t).collect();
            softmax_inplace(&mut p);
            let mut u = rng.next_f32();
            for (i, &pi) in p.iter().enumerate() {
                if u < pi {
                    return i as u32;
                }
                u -= pi;
            }
            (p.len() - 1) as u32
        };
        for t in [0.0f32, 0.5, 1.0, 3.0] {
            let mut a = Pcg64::new(7);
            let mut b = Pcg64::new(7);
            for _ in 0..50 {
                assert_eq!(
                    sample(&logits, &temp(t), &[9, 9, 2], &mut a),
                    v1(&logits, t, &mut b),
                    "t={t}"
                );
            }
        }
    }

    #[test]
    fn top_p_restricts_to_the_nucleus() {
        // token 1 holds almost all mass; tight top_p must always pick it
        let logits = vec![0.0f32, 8.0, 1.0, -2.0];
        let p = temp(1.0).top_p(0.5);
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            assert_eq!(sample(&logits, &p, &[], &mut rng), 1);
        }
        // wide top_p still explores
        let p = temp(5.0).top_p(0.99);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample(&logits, &p, &[], &mut rng));
        }
        assert!(seen.len() > 1, "{seen:?}");
    }

    #[test]
    fn repetition_penalty_demotes_produced_tokens() {
        // tokens 1 and 2 are nearly tied; after producing 1 a strong
        // penalty must flip even the greedy choice to 2
        let logits = vec![0.0f32, 2.0, 1.9, -1.0];
        assert_eq!(sample(&logits, &temp(0.0), &[], &mut Pcg64::new(0)), 1);
        let pen = temp(0.0).repetition_penalty(1.5);
        assert_eq!(sample(&logits, &pen, &[1], &mut Pcg64::new(0)), 2);
        // each distinct token is penalized once, not per occurrence
        let once = sample(&logits, &pen, &[1], &mut Pcg64::new(0));
        let thrice = sample(&logits, &pen, &[1, 1, 1], &mut Pcg64::new(0));
        assert_eq!(once, thrice);
    }

    #[test]
    fn sampling_consumes_one_draw_per_call() {
        // identical RNG positions must follow identical streams no
        // matter which sampler features are active
        let logits = vec![0.5f32, 1.0, 0.2, 0.9];
        let runs: Vec<Vec<u32>> = [
            temp(0.9),
            temp(0.9).top_p(0.8),
            temp(0.9).repetition_penalty(1.3),
            temp(0.9).top_p(0.8).repetition_penalty(1.3),
        ]
        .iter()
        .map(|p| {
            let mut rng = Pcg64::new(42);
            (0..20).map(|_| sample(&logits, p, &[0], &mut rng)).collect()
        })
        .collect();
        // all runs drew 20 times from the same stream: re-running any
        // config reproduces itself exactly
        for (i, p) in [
            temp(0.9),
            temp(0.9).top_p(0.8),
            temp(0.9).repetition_penalty(1.3),
            temp(0.9).top_p(0.8).repetition_penalty(1.3),
        ]
        .iter()
        .enumerate()
        {
            let mut rng = Pcg64::new(42);
            let again: Vec<u32> = (0..20).map(|_| sample(&logits, p, &[0], &mut rng)).collect();
            assert_eq!(again, runs[i]);
        }
    }

    /// Regression: admission must project KV bytes from the prompt
    /// length prefill will actually cache — prompts past the largest
    /// compiled bucket are suffix-truncated there, and charging the full
    /// length over-projects (a 10k-token prompt against a 128-bucket
    /// model used to project ~78x its real footprint and starve
    /// admissible batchmates).
    #[test]
    fn projection_caps_prompt_at_largest_prefill_bucket() {
        let buckets = [32usize, 128];
        // under every bucket: the real length projects
        assert_eq!(projected_prompt_tokens(20, &buckets), 20);
        // between buckets: still the real length (prefill pads, the
        // cache only ever holds the prompt's own rows)
        assert_eq!(projected_prompt_tokens(100, &buckets), 100);
        // at the cap, and past it: truncated to the largest bucket
        assert_eq!(projected_prompt_tokens(128, &buckets), 128);
        assert_eq!(projected_prompt_tokens(10_000, &buckets), 128);
        // empty prompts prefill one dummy token
        assert_eq!(projected_prompt_tokens(0, &buckets), 1);
        // no compiled buckets (native path): full length, untruncated
        assert_eq!(projected_prompt_tokens(10_000, &[]), 10_000);
    }

    // Engine integration tests (needing artifacts) live in
    // rust/tests/serve_integration.rs; cancellation/streaming/mixed-k
    // coverage that runs without artifacts lives in rust/tests/pipeline.rs.
}
