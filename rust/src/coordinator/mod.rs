//! Layer-3 serving coordinator.
//!
//! The request path is rust-only: requests enter via [`engine::Engine`]
//! (in-process) or the TCP front-end in [`crate::server`]; the scheduler
//! admits them (admission control against a KV-memory budget), runs
//! prefill on the AOT PJRT graphs, then interleaves decode steps across
//! active sequences (iteration-level continuous batching, as in
//! Orca/vLLM).  Each sequence's hybrid cache lives in
//! [`sequence::SeqCache`]: a dense recency buffer plus winnowed sparse
//! arrays shaped for the compiled shape buckets.
//!
//! Runtime compression tuning: [`engine::Engine::set_k_active`] re-points
//! the pruner for newly admitted sequences and the autotuner
//! ([`autotune::AutoTuner`]) lowers/raises the level under memory pressure.
//!
//! One `Engine` is one *shard*: [`crate::shard`] runs N of them behind a
//! front-end router, holding the engine by the load-introspection handles
//! exposed here (`queue_len` / `active_len` / `projected_load_bytes`).

pub mod autotune;
pub mod pool;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod sequence;

pub use engine::Engine;
pub use metrics::Metrics;
pub use request::{Request, Response};
