//! Scheduling policy pieces: FIFO request queue with memory- and
//! pool-aware admission control and iteration-level batch selection
//! (Orca-style continuous batching: the decode "batch" is re-formed every
//! iteration from whatever sequences are alive).
//!
//! Admission projects two resources before popping the queue:
//! * **memory** — the caller supplies a per-request KV-byte projection
//!   (see [`Scheduler::projected_bytes`]) checked against `mem_budget`;
//! * **decode-pool occupancy** — when `decode_slots > 0`, admission stops
//!   once the active set would oversubscribe the shard's worker pool, so
//!   per-token latency SLOs survive mixed long/short batches.
//!
//! One oversized request must not head-of-line-block admissible followers
//! under a tight budget, so memory-gated admission scans a bounded
//! lookahead window: the first admissible request among the first
//! [`Scheduler::lookahead`] pending ones is admitted (relative order of
//! everything else is untouched, so service stays FIFO apart from the
//! skipped-over giants).  Skipping ages: after [`MAX_HEAD_SKIPS`]
//! skip-overs the head becomes *sticky* (the window collapses to 1), the
//! queue stops draining around it, and the always-admit-when-idle escape
//! eventually takes it — so even a request whose projection exceeds the
//! whole budget is never starved, exactly the liveness the old head-only
//! gate provided.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::request::Request;
use crate::obs::registry::{Counter, Registry};

/// Pending queue entry.
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
}

/// Scheduler-side observability: admission deferrals by reason plus
/// head-skip/aging events, as registry counters. All increments are
/// single relaxed atomics, so admission stays lock-free past the one
/// registration at engine startup.
pub struct SchedulerObs {
    /// Deferred because the active set hit `max_batch`.
    pub defer_batch: Arc<Counter>,
    /// Deferred because the decode worker pool is saturated.
    pub defer_slots: Arc<Counter>,
    /// Deferred because every windowed request over-projects the budget.
    pub defer_mem: Arc<Counter>,
    /// Times the lookahead admitted a follower over the queue head.
    pub head_skips: Arc<Counter>,
    /// Times a head aged into sticky (window collapsed to head-only).
    pub sticky_heads: Arc<Counter>,
}

impl SchedulerObs {
    pub fn register(registry: &Registry) -> SchedulerObs {
        SchedulerObs {
            defer_batch: registry.counter("swan_admit_defer_total", &[("reason", "batch")]),
            defer_slots: registry.counter("swan_admit_defer_total", &[("reason", "slots")]),
            defer_mem: registry.counter("swan_admit_defer_total", &[("reason", "mem")]),
            head_skips: registry.counter("swan_admit_head_skips_total", &[]),
            sticky_heads: registry.counter("swan_admit_sticky_heads_total", &[]),
        }
    }
}

/// FIFO queue + admission control.
pub struct Scheduler {
    queue: VecDeque<Pending>,
    /// Max sequences decoding concurrently.
    pub max_batch: usize,
    /// KV memory budget in bytes (0 = unlimited).
    pub mem_budget: usize,
    /// Decode-pool capacity in sequences (0 = unlimited): admission defers
    /// once the active set would oversubscribe the shard's worker pool.
    pub decode_slots: usize,
    /// Memory-gated admission scans the first `lookahead` pending requests
    /// for the first admissible one (1 = strict head-only FIFO).
    pub lookahead: usize,
    /// Times the current head has been skipped over by the lookahead;
    /// at [`MAX_HEAD_SKIPS`] the head turns sticky (see the module doc).
    head_skips: usize,
    /// Which request id `head_skips` is aging.  The counter is a
    /// property of one specific head *request*, not of the front
    /// position: purging a cancelled head (or requeueing a preempted
    /// sequence ahead of it) changes who the head IS, and the new head
    /// must start with its full skip allowance rather than inherit the
    /// old head's aging.
    skipped_head: Option<u64>,
    /// Deferral/skip counters (None until the engine wires a registry).
    obs: Option<SchedulerObs>,
}

/// Default admission lookahead window (see [`Scheduler::lookahead`]).
pub const DEFAULT_LOOKAHEAD: usize = 4;

/// Skip-overs before the queue head becomes sticky and the lookahead
/// window collapses to head-only — the aging bound that guarantees even
/// a never-fitting head is eventually admitted through the idle escape.
pub const MAX_HEAD_SKIPS: usize = 16;

impl Scheduler {
    pub fn new(max_batch: usize, mem_budget: usize) -> Scheduler {
        Scheduler {
            queue: VecDeque::new(),
            max_batch,
            mem_budget,
            decode_slots: 0,
            lookahead: DEFAULT_LOOKAHEAD,
            head_skips: 0,
            skipped_head: None,
            obs: None,
        }
    }

    /// Attach admission observability counters (engine startup).
    pub fn set_obs(&mut self, obs: SchedulerObs) {
        self.obs = Some(obs);
    }

    fn reset_skips(&mut self) {
        self.head_skips = 0;
        self.skipped_head = None;
    }

    /// Cap concurrent decodes to the worker pool's capacity (0 disables).
    pub fn set_decode_slots(&mut self, slots: usize) {
        self.decode_slots = slots;
    }

    /// Set the admission lookahead window (clamped to >= 1; 1 restores
    /// strict head-only admission).
    pub fn set_lookahead(&mut self, window: usize) {
        self.lookahead = window.max(1);
    }

    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(Pending { req, enqueued: Instant::now() });
    }

    /// Put a preempted request back at the very front of the queue so it
    /// is the next admission candidate once blocks free (preemption
    /// resumes newest-victim-first).
    pub fn requeue_front(&mut self, req: Request) {
        self.queue.push_front(Pending { req, enqueued: Instant::now() });
    }

    /// Remove every queued request whose [`crate::api::CancelToken`] has
    /// been flipped, preserving the order of the rest.  The engine calls
    /// this each admission pass and answers the removed requests'
    /// waiters with a cancelled (empty) response — a cancelled request
    /// must neither hold its queue slot nor inflate the shard's
    /// projected KV load until the admission window happens to reach it.
    pub fn take_cancelled(&mut self) -> Vec<Pending> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].req.cancel.is_cancelled() {
                if let Some(p) = self.queue.remove(i) {
                    out.push(p);
                }
            } else {
                i += 1;
            }
        }
        out
    }

    /// Drain the whole queue in FIFO order (shard death / drain-timeout
    /// hand-back: every queued request is extracted for recovery on a
    /// healthy shard).  Resets the head-aging state — the next head this
    /// scheduler sees, if any, is a brand-new request.
    pub fn take_all(&mut self) -> Vec<Request> {
        self.reset_skips();
        self.queue.drain(..).map(|p| p.req).collect()
    }

    /// Retarget the KV memory budget (live `SET shards` rebalance: the
    /// fleet total is re-split over the new member count).
    pub fn set_mem_budget(&mut self, bytes: usize) {
        self.mem_budget = bytes;
    }

    /// Flip the cancel token of a queued request by id (the shard-level
    /// `CANCEL <id>` hop lands here when the request has not been
    /// admitted yet).  Returns whether the id was found.
    pub fn cancel(&self, id: u64) -> bool {
        match self.queue.iter().find(|p| p.req.id == id) {
            Some(p) => {
                p.req.cancel.cancel();
                true
            }
            None => false,
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Iterate the queued (not yet admitted) requests in FIFO order; used
    /// by the shard router to project a shard's total KV load.
    pub fn queued(&self) -> impl Iterator<Item = &Request> {
        self.queue.iter().map(|p| &p.req)
    }

    /// Estimate of the KV bytes a new sequence will need at admission
    /// (prompt + expected output at the configured compression).
    pub fn projected_bytes(
        prompt_len: usize,
        max_new: usize,
        bytes_per_token_sparse: usize,
        bytes_per_token_dense: usize,
        buffer: usize,
    ) -> usize {
        let total = prompt_len + max_new;
        let dense_tokens = total.min(buffer);
        dense_tokens * bytes_per_token_dense
            + (total - dense_tokens) * bytes_per_token_sparse
    }

    /// Pop the next admissible request, if capacity, memory and the
    /// decode pool allow.  Under memory pressure the first admissible
    /// request among the first [`Scheduler::lookahead`] pending ones is
    /// taken, so one oversized head cannot starve admissible followers;
    /// with no budget (or an idle engine) this is plain FIFO pop.
    pub fn admit_next(
        &mut self,
        active: usize,
        live_bytes: usize,
        project: impl Fn(&Request) -> usize,
    ) -> Option<Pending> {
        // deferral counters only tick when work is actually waiting — an
        // idle saturated engine polling an empty queue is not a deferral
        let waiting = !self.queue.is_empty();
        if active >= self.max_batch {
            if let Some(obs) = self.obs.as_ref().filter(|_| waiting) {
                obs.defer_batch.inc();
            }
            return None;
        }
        // pool-aware admission: the worker pool is saturated — admitting
        // more sequences would stretch every iteration without raising
        // throughput (decode_slots >= 1 implies active >= 1 here, so the
        // no-deadlock invariant of the memory check below still holds).
        if self.decode_slots > 0 && active >= self.decode_slots {
            if let Some(obs) = self.obs.as_ref().filter(|_| waiting) {
                obs.defer_slots.inc();
            }
            return None;
        }
        self.queue.front()?;
        // unlimited memory, or an idle engine (always admit when idle so
        // we cannot deadlock): strict FIFO
        if self.mem_budget == 0 || active == 0 {
            self.reset_skips();
            return self.queue.pop_front();
        }
        // the skip counter ages one specific head request: if a
        // cancellation purge or a preemption requeue changed who the
        // head is, the new head starts with its full allowance
        if self.skipped_head.is_some()
            && self.skipped_head != self.queue.front().map(|p| p.req.id)
        {
            self.reset_skips();
        }
        // a head that has been skipped too often is sticky: collapse to
        // head-only so the active set drains and the idle escape above
        // eventually admits it (liveness for never-fitting projections)
        let width = if self.head_skips >= MAX_HEAD_SKIPS { 1 } else { self.lookahead.max(1) };
        let window = width.min(self.queue.len());
        for i in 0..window {
            let projected = project(&self.queue[i].req);
            if live_bytes + projected <= self.mem_budget {
                if i == 0 {
                    self.reset_skips();
                } else {
                    self.head_skips += 1;
                    self.skipped_head = self.queue.front().map(|p| p.req.id);
                    if let Some(obs) = &self.obs {
                        obs.head_skips.inc();
                        if self.head_skips == MAX_HEAD_SKIPS {
                            obs.sticky_heads.inc();
                        }
                    }
                }
                // remove(i) preserves the relative order of the rest
                return self.queue.remove(i);
            }
        }
        // every windowed request over-projects: defer until memory frees
        if let Some(obs) = &self.obs {
            obs.defer_mem.inc();
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize) -> Request {
        Request {
            id,
            prompt: vec![0; prompt],
            params: crate::api::GenParams::new(8),
            cancel: crate::api::CancelToken::new(),
            clamped_from: None,
            trace: crate::obs::trace::Trace::new(),
        }
    }

    #[test]
    fn fifo_order() {
        let mut s = Scheduler::new(4, 0);
        s.enqueue(req(1, 4));
        s.enqueue(req(2, 4));
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 1);
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 2);
        assert!(s.admit_next(0, 0, |_| 0).is_none());
    }

    #[test]
    fn batch_cap_blocks() {
        let mut s = Scheduler::new(2, 0);
        s.enqueue(req(1, 4));
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert!(s.admit_next(1, 0, |_| 0).is_some());
    }

    #[test]
    fn memory_budget_defers_but_never_deadlocks() {
        let mut s = Scheduler::new(4, 1000);
        s.enqueue(req(1, 4));
        // over budget with other sequences active -> defer
        assert!(s.admit_next(1, 900, |_| 200).is_none());
        assert_eq!(s.queue_len(), 1);
        // same pressure but engine idle -> admit anyway
        assert!(s.admit_next(0, 900, |_| 200).is_some());
    }

    #[test]
    fn decode_slots_defer_when_pool_saturated() {
        let mut s = Scheduler::new(16, 0);
        s.set_decode_slots(2);
        s.enqueue(req(1, 4));
        // pool full (2 active vs 2 slots) -> defer, request stays queued
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert_eq!(s.queue_len(), 1);
        // a slot frees up -> admit
        assert!(s.admit_next(1, 0, |_| 0).is_some());
        // slots disabled (0) -> never defers on occupancy
        let mut u = Scheduler::new(16, 0);
        u.enqueue(req(2, 4));
        assert!(u.admit_next(15, 0, |_| 0).is_some());
    }

    #[test]
    fn lookahead_skips_oversized_head() {
        let mut s = Scheduler::new(8, 1000);
        s.set_lookahead(4);
        s.enqueue(req(1, 900)); // projects over budget
        s.enqueue(req(2, 100));
        s.enqueue(req(3, 100));
        let proj = |r: &Request| r.prompt.len();
        // engine busy (active=1), 500 bytes live: head (900) doesn't fit,
        // follower (100) does — admit it, keep the giant queued at front
        let got = s.admit_next(1, 500, proj).unwrap();
        assert_eq!(got.req.id, 2);
        let ids: Vec<u64> = s.queued().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 3], "relative order preserved");
        // memory frees up -> the giant is admitted first (FIFO restored)
        let got = s.admit_next(1, 0, proj).unwrap();
        assert_eq!(got.req.id, 1);
    }

    #[test]
    fn lookahead_window_is_bounded() {
        let mut s = Scheduler::new(8, 1000);
        s.set_lookahead(2);
        s.enqueue(req(1, 900));
        s.enqueue(req(2, 900));
        s.enqueue(req(3, 100)); // admissible, but outside the window
        let proj = |r: &Request| r.prompt.len();
        assert!(s.admit_next(1, 500, proj).is_none());
        assert_eq!(s.queue_len(), 3);
        // widening the window finds it
        s.set_lookahead(3);
        assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 3);
    }

    #[test]
    fn lookahead_one_is_head_only_and_idle_still_admits() {
        let mut s = Scheduler::new(8, 1000);
        s.set_lookahead(1);
        s.enqueue(req(1, 900));
        s.enqueue(req(2, 100));
        let proj = |r: &Request| r.prompt.len();
        // busy: head-only gate defers even though id=2 would fit
        assert!(s.admit_next(1, 500, proj).is_none());
        // idle: the head is admitted regardless of projection (no deadlock)
        assert_eq!(s.admit_next(0, 500, proj).unwrap().req.id, 1);
        // set_lookahead(0) clamps to 1 rather than disabling admission
        s.set_lookahead(0);
        assert_eq!(s.lookahead, 1);
    }

    /// A head whose projection exceeds the whole budget must not be
    /// starved by a stream of admissible followers: after
    /// `MAX_HEAD_SKIPS` skip-overs it turns sticky, followers stop
    /// bypassing it, and the idle escape finally admits it.
    #[test]
    fn skipped_head_ages_into_sticky_and_is_never_starved() {
        let mut s = Scheduler::new(64, 1000);
        s.set_lookahead(4);
        s.enqueue(req(1, 1500)); // can NEVER fit under the budget
        let proj = |r: &Request| r.prompt.len();
        // sustained small traffic bypasses the giant... but only
        // MAX_HEAD_SKIPS times
        for i in 0..MAX_HEAD_SKIPS as u64 {
            s.enqueue(req(100 + i, 100));
            assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 100 + i);
        }
        // sticky now: admissible followers no longer pass the head
        s.enqueue(req(999, 100));
        assert!(s.admit_next(1, 500, proj).is_none());
        assert_eq!(s.queue_len(), 2);
        // the active set drains -> the idle escape admits the giant
        assert_eq!(s.admit_next(0, 500, proj).unwrap().req.id, 1);
        // and the skip counter reset: the waiting follower pops head-first
        assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 999);
        // lookahead skipping works again for the next giant head
        s.enqueue(req(2, 1500));
        s.enqueue(req(3, 100));
        assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 3);
    }

    /// Regression: the skip counter must age one specific head request.
    /// Cancelling a part-aged head used to leave its skip count behind
    /// for whichever request became the head next, making it sticky (or
    /// near-sticky) before it was ever skipped once.
    #[test]
    fn cancelling_a_skipped_head_resets_the_aging_counter() {
        let mut s = Scheduler::new(64, 1000);
        s.set_lookahead(4);
        let proj = |r: &Request| r.prompt.len();
        s.enqueue(req(1, 1500)); // giant head, accrues skip-overs
        for i in 0..(MAX_HEAD_SKIPS as u64 - 1) {
            s.enqueue(req(100 + i, 100));
            assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 100 + i);
        }
        // head 1 is one skip from sticky; cancel it out of the queue
        assert!(s.cancel(1));
        assert_eq!(s.take_cancelled().len(), 1);
        // a NEW giant head gets the full MAX_HEAD_SKIPS allowance — it
        // must not inherit the cancelled head's aging
        s.enqueue(req(2, 1500));
        for i in 0..MAX_HEAD_SKIPS as u64 {
            s.enqueue(req(200 + i, 100));
            assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 200 + i, "skip {i}");
        }
        // only now does it turn sticky
        s.enqueue(req(999, 100));
        assert!(s.admit_next(1, 500, proj).is_none());
    }

    /// A preempted request requeued at the front is the next admission
    /// candidate, ahead of everything that was already waiting.
    #[test]
    fn requeue_front_resumes_before_waiting_queue() {
        let mut s = Scheduler::new(8, 0);
        s.enqueue(req(1, 4));
        s.enqueue(req(2, 4));
        let p = s.admit_next(0, 0, |_| 0).unwrap();
        assert_eq!(p.req.id, 1);
        s.requeue_front(p.req);
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 1);
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 2);
    }

    /// Death/drain hand-back empties the queue in FIFO order and resets
    /// the head-aging state for whatever is enqueued next.
    #[test]
    fn take_all_drains_fifo_and_resets_aging() {
        let mut s = Scheduler::new(8, 1000);
        let proj = |r: &Request| r.prompt.len();
        s.enqueue(req(1, 1500)); // giant head, accrues a skip
        s.enqueue(req(2, 100));
        assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 2);
        s.enqueue(req(3, 100));
        let taken = s.take_all();
        assert_eq!(taken.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.queue_len(), 0);
        assert!(s.take_all().is_empty(), "drain is idempotent");
    }

    #[test]
    fn take_cancelled_purges_in_place_and_preserves_order() {
        let mut s = Scheduler::new(8, 0);
        for id in 1..=4 {
            s.enqueue(req(id, 4));
        }
        // cancel 1 and 3 through the queue-side hop
        assert!(s.cancel(1));
        assert!(s.cancel(3));
        assert!(!s.cancel(99), "unknown id is not found");
        let taken = s.take_cancelled();
        assert_eq!(taken.iter().map(|p| p.req.id).collect::<Vec<_>>(), vec![1, 3]);
        let left: Vec<u64> = s.queued().map(|r| r.id).collect();
        assert_eq!(left, vec![2, 4], "survivors keep FIFO order");
        assert!(s.take_cancelled().is_empty(), "purge is idempotent");
    }

    #[test]
    fn queued_iterates_fifo() {
        let mut s = Scheduler::new(4, 0);
        s.enqueue(req(7, 4));
        s.enqueue(req(8, 4));
        let ids: Vec<u64> = s.queued().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 8]);
    }

    /// Deferral/skip counters tick by reason, and never on an empty
    /// queue (a saturated idle engine is not "deferring" anything).
    #[test]
    fn obs_counters_track_deferral_reasons() {
        let registry = crate::obs::Registry::new();
        let obs = SchedulerObs::register(&registry);
        let (batch, slots, mem, skips) = (
            obs.defer_batch.clone(),
            obs.defer_slots.clone(),
            obs.defer_mem.clone(),
            obs.head_skips.clone(),
        );
        let mut s = Scheduler::new(2, 1000);
        s.set_obs(obs);
        // empty queue: a full batch is not a deferral
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert_eq!(batch.get(), 0);
        s.enqueue(req(1, 900));
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert_eq!(batch.get(), 1);
        s.set_decode_slots(1);
        assert!(s.admit_next(1, 0, |_| 0).is_none());
        assert_eq!(slots.get(), 1);
        s.set_decode_slots(0);
        // busy + over budget everywhere in the window -> mem deferral
        let proj = |r: &Request| r.prompt.len();
        assert!(s.admit_next(1, 500, proj).is_none());
        assert_eq!(mem.get(), 1);
        // an admissible follower skips the head -> head_skips ticks
        s.enqueue(req(2, 100));
        assert_eq!(s.admit_next(1, 500, proj).unwrap().req.id, 2);
        assert_eq!(skips.get(), 1);
    }

    #[test]
    fn projection_accounts_buffer_split() {
        // 10 tokens total: 4 dense (buffer), 6 sparse
        let b = Scheduler::projected_bytes(6, 4, 10, 100, 4);
        assert_eq!(b, 4 * 100 + 6 * 10);
        // everything fits in buffer
        let b2 = Scheduler::projected_bytes(2, 1, 10, 100, 8);
        assert_eq!(b2, 3 * 100);
    }
}
