//! Scheduling policy pieces: FIFO request queue with memory- and
//! pool-aware admission control and iteration-level batch selection
//! (Orca-style continuous batching: the decode "batch" is re-formed every
//! iteration from whatever sequences are alive).
//!
//! Admission projects two resources before popping the queue:
//! * **memory** — the caller supplies a per-request KV-byte projection
//!   (see [`Scheduler::projected_bytes`]) checked against `mem_budget`;
//! * **decode-pool occupancy** — when `decode_slots > 0`, admission stops
//!   once the active set would oversubscribe the shard's worker pool, so
//!   per-token latency SLOs survive mixed long/short batches.

use std::collections::VecDeque;
use std::time::Instant;

use crate::coordinator::request::Request;

/// Pending queue entry.
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
}

/// FIFO queue + admission control.
pub struct Scheduler {
    queue: VecDeque<Pending>,
    /// Max sequences decoding concurrently.
    pub max_batch: usize,
    /// KV memory budget in bytes (0 = unlimited).
    pub mem_budget: usize,
    /// Decode-pool capacity in sequences (0 = unlimited): admission defers
    /// once the active set would oversubscribe the shard's worker pool.
    pub decode_slots: usize,
}

impl Scheduler {
    pub fn new(max_batch: usize, mem_budget: usize) -> Scheduler {
        Scheduler { queue: VecDeque::new(), max_batch, mem_budget, decode_slots: 0 }
    }

    /// Cap concurrent decodes to the worker pool's capacity (0 disables).
    pub fn set_decode_slots(&mut self, slots: usize) {
        self.decode_slots = slots;
    }

    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(Pending { req, enqueued: Instant::now() });
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Iterate the queued (not yet admitted) requests in FIFO order; used
    /// by the shard router to project a shard's total KV load.
    pub fn queued(&self) -> impl Iterator<Item = &Request> {
        self.queue.iter().map(|p| &p.req)
    }

    /// Estimate of the KV bytes a new sequence will need at admission
    /// (prompt + expected output at the configured compression).
    pub fn projected_bytes(
        prompt_len: usize,
        max_new: usize,
        bytes_per_token_sparse: usize,
        bytes_per_token_dense: usize,
        buffer: usize,
    ) -> usize {
        let total = prompt_len + max_new;
        let dense_tokens = total.min(buffer);
        dense_tokens * bytes_per_token_dense
            + (total - dense_tokens) * bytes_per_token_sparse
    }

    /// Pop the next admissible request, if capacity, memory and the
    /// decode pool allow.
    pub fn admit_next(
        &mut self,
        active: usize,
        live_bytes: usize,
        project: impl Fn(&Request) -> usize,
    ) -> Option<Pending> {
        if active >= self.max_batch {
            return None;
        }
        // pool-aware admission: the worker pool is saturated — admitting
        // more sequences would stretch every iteration without raising
        // throughput (decode_slots >= 1 implies active >= 1 here, so the
        // no-deadlock invariant of the memory check below still holds).
        if self.decode_slots > 0 && active >= self.decode_slots {
            return None;
        }
        let head = self.queue.front()?;
        if self.mem_budget > 0 {
            let projected = project(&head.req);
            if live_bytes + projected > self.mem_budget && active > 0 {
                // defer until memory frees up (always admit when idle so we
                // cannot deadlock)
                return None;
            }
        }
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize) -> Request {
        Request { id, prompt: vec![0; prompt], max_new_tokens: 8, temperature: 0.0, stop_token: None }
    }

    #[test]
    fn fifo_order() {
        let mut s = Scheduler::new(4, 0);
        s.enqueue(req(1, 4));
        s.enqueue(req(2, 4));
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 1);
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 2);
        assert!(s.admit_next(0, 0, |_| 0).is_none());
    }

    #[test]
    fn batch_cap_blocks() {
        let mut s = Scheduler::new(2, 0);
        s.enqueue(req(1, 4));
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert!(s.admit_next(1, 0, |_| 0).is_some());
    }

    #[test]
    fn memory_budget_defers_but_never_deadlocks() {
        let mut s = Scheduler::new(4, 1000);
        s.enqueue(req(1, 4));
        // over budget with other sequences active -> defer
        assert!(s.admit_next(1, 900, |_| 200).is_none());
        assert_eq!(s.queue_len(), 1);
        // same pressure but engine idle -> admit anyway
        assert!(s.admit_next(0, 900, |_| 200).is_some());
    }

    #[test]
    fn decode_slots_defer_when_pool_saturated() {
        let mut s = Scheduler::new(16, 0);
        s.set_decode_slots(2);
        s.enqueue(req(1, 4));
        // pool full (2 active vs 2 slots) -> defer, request stays queued
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert_eq!(s.queue_len(), 1);
        // a slot frees up -> admit
        assert!(s.admit_next(1, 0, |_| 0).is_some());
        // slots disabled (0) -> never defers on occupancy
        let mut u = Scheduler::new(16, 0);
        u.enqueue(req(2, 4));
        assert!(u.admit_next(15, 0, |_| 0).is_some());
    }

    #[test]
    fn queued_iterates_fifo() {
        let mut s = Scheduler::new(4, 0);
        s.enqueue(req(7, 4));
        s.enqueue(req(8, 4));
        let ids: Vec<u64> = s.queued().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 8]);
    }

    #[test]
    fn projection_accounts_buffer_split() {
        // 10 tokens total: 4 dense (buffer), 6 sparse
        let b = Scheduler::projected_bytes(6, 4, 10, 100, 4);
        assert_eq!(b, 4 * 100 + 6 * 10);
        // everything fits in buffer
        let b2 = Scheduler::projected_bytes(2, 1, 10, 100, 8);
        assert_eq!(b2, 3 * 100);
    }
}
