//! Scheduling policy pieces: FIFO request queue with memory-aware
//! admission control and iteration-level batch selection
//! (Orca-style continuous batching: the decode "batch" is re-formed every
//! iteration from whatever sequences are alive).

use std::collections::VecDeque;
use std::time::Instant;

use crate::coordinator::request::Request;

/// Pending queue entry.
pub struct Pending {
    pub req: Request,
    pub enqueued: Instant,
}

/// FIFO queue + admission control.
pub struct Scheduler {
    queue: VecDeque<Pending>,
    /// Max sequences decoding concurrently.
    pub max_batch: usize,
    /// KV memory budget in bytes (0 = unlimited).
    pub mem_budget: usize,
}

impl Scheduler {
    pub fn new(max_batch: usize, mem_budget: usize) -> Scheduler {
        Scheduler { queue: VecDeque::new(), max_batch, mem_budget }
    }

    pub fn enqueue(&mut self, req: Request) {
        self.queue.push_back(Pending { req, enqueued: Instant::now() });
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Estimate of the KV bytes a new sequence will need at admission
    /// (prompt + expected output at the configured compression).
    pub fn projected_bytes(
        prompt_len: usize,
        max_new: usize,
        bytes_per_token_sparse: usize,
        bytes_per_token_dense: usize,
        buffer: usize,
    ) -> usize {
        let total = prompt_len + max_new;
        let dense_tokens = total.min(buffer);
        dense_tokens * bytes_per_token_dense
            + (total - dense_tokens) * bytes_per_token_sparse
    }

    /// Pop the next admissible request, if capacity and memory allow.
    pub fn admit_next(
        &mut self,
        active: usize,
        live_bytes: usize,
        project: impl Fn(&Request) -> usize,
    ) -> Option<Pending> {
        if active >= self.max_batch {
            return None;
        }
        let head = self.queue.front()?;
        if self.mem_budget > 0 {
            let projected = project(&head.req);
            if live_bytes + projected > self.mem_budget && active > 0 {
                // defer until memory frees up (always admit when idle so we
                // cannot deadlock)
                return None;
            }
        }
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize) -> Request {
        Request { id, prompt: vec![0; prompt], max_new_tokens: 8, temperature: 0.0, stop_token: None }
    }

    #[test]
    fn fifo_order() {
        let mut s = Scheduler::new(4, 0);
        s.enqueue(req(1, 4));
        s.enqueue(req(2, 4));
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 1);
        assert_eq!(s.admit_next(0, 0, |_| 0).unwrap().req.id, 2);
        assert!(s.admit_next(0, 0, |_| 0).is_none());
    }

    #[test]
    fn batch_cap_blocks() {
        let mut s = Scheduler::new(2, 0);
        s.enqueue(req(1, 4));
        assert!(s.admit_next(2, 0, |_| 0).is_none());
        assert!(s.admit_next(1, 0, |_| 0).is_some());
    }

    #[test]
    fn memory_budget_defers_but_never_deadlocks() {
        let mut s = Scheduler::new(4, 1000);
        s.enqueue(req(1, 4));
        // over budget with other sequences active -> defer
        assert!(s.admit_next(1, 900, |_| 200).is_none());
        assert_eq!(s.queue_len(), 1);
        // same pressure but engine idle -> admit anyway
        assert!(s.admit_next(0, 900, |_| 200).is_some());
    }

    #[test]
    fn projection_accounts_buffer_split() {
        // 10 tokens total: 4 dense (buffer), 6 sparse
        let b = Scheduler::projected_bytes(6, 4, 10, 100, 4);
        assert_eq!(b, 4 * 100 + 6 * 10);
        // everything fits in buffer
        let b2 = Scheduler::projected_bytes(2, 1, 10, 100, 8);
        assert_eq!(b2, 3 * 100);
    }
}
