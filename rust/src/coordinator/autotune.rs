//! Runtime compression auto-tuner.
//!
//! SWAN's `k_active` is runtime-tunable (the paper's key operational
//! flexibility): this controller watches live KV memory against a budget
//! and recommends the largest available compression bucket that keeps
//! projected usage under the high watermark — operators can also pin the
//! level manually (`swan serve --k-active`, or `SET k_active` over TCP).

/// Hysteresis thresholds as fractions of the budget.
const HIGH_WATERMARK: f64 = 0.85;
const LOW_WATERMARK: f64 = 0.60;

#[derive(Clone, Debug)]
pub struct AutoTuner {
    /// KV byte budget (0 disables tuning).
    pub budget: usize,
    /// Available k buckets, ascending (from the compiled graphs).
    pub k_buckets: Vec<usize>,
    /// Currently recommended bucket index.
    idx: usize,
}

impl AutoTuner {
    /// Start at the largest (least compressed) bucket.
    pub fn new(budget: usize, mut k_buckets: Vec<usize>) -> AutoTuner {
        k_buckets.sort_unstable();
        k_buckets.dedup();
        assert!(!k_buckets.is_empty());
        let idx = k_buckets.len() - 1;
        AutoTuner { budget, k_buckets, idx }
    }

    pub fn current_k(&self) -> usize {
        self.k_buckets[self.idx]
    }

    /// Pin to the bucket closest to `k` (manual override).
    pub fn pin(&mut self, k: usize) {
        self.idx = self
            .k_buckets
            .iter()
            .enumerate()
            .min_by_key(|(_, &b)| b.abs_diff(k))
            .map(|(i, _)| i)
            .unwrap();
    }

    /// Observe live usage; returns the (possibly changed) recommended k.
    pub fn observe(&mut self, live_bytes: usize) -> usize {
        if self.budget == 0 {
            return self.current_k();
        }
        let frac = live_bytes as f64 / self.budget as f64;
        if frac > HIGH_WATERMARK && self.idx > 0 {
            self.idx -= 1; // compress harder
        } else if frac < LOW_WATERMARK && self.idx + 1 < self.k_buckets.len() {
            self.idx += 1; // relax toward quality
        }
        self.current_k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_largest() {
        let t = AutoTuner::new(1000, vec![32, 16, 48]);
        assert_eq!(t.current_k(), 48);
    }

    #[test]
    fn tightens_under_pressure_relaxes_when_free() {
        let mut t = AutoTuner::new(1000, vec![16, 32, 48]);
        assert_eq!(t.observe(900), 32);
        assert_eq!(t.observe(900), 16);
        assert_eq!(t.observe(900), 16); // floor
        assert_eq!(t.observe(100), 32);
        assert_eq!(t.observe(100), 48);
        assert_eq!(t.observe(100), 48); // ceiling
    }

    #[test]
    fn hysteresis_band_is_stable() {
        let mut t = AutoTuner::new(1000, vec![16, 32, 48]);
        t.observe(900); // -> 32
        // inside the band: no change either way
        assert_eq!(t.observe(700), 32);
        assert_eq!(t.observe(700), 32);
    }

    #[test]
    fn disabled_budget_never_moves() {
        let mut t = AutoTuner::new(0, vec![16, 32, 48]);
        assert_eq!(t.observe(usize::MAX / 2), 48);
    }

    #[test]
    fn pin_selects_nearest() {
        let mut t = AutoTuner::new(0, vec![16, 32, 48]);
        t.pin(30);
        assert_eq!(t.current_k(), 32);
        t.pin(100);
        assert_eq!(t.current_k(), 48);
    }
}
