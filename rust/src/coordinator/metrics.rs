//! Engine-level metrics: counters and latency reservoirs, shared across
//! scheduler threads.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fixed-size latency reservoir (keeps the most recent N samples).
pub struct Reservoir {
    samples: Mutex<Vec<f64>>,
    cap: usize,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        Reservoir { samples: Mutex::new(Vec::with_capacity(cap)), cap }
    }

    pub fn record(&self, ns: f64) {
        let mut s = self.samples.lock().unwrap();
        if s.len() == self.cap {
            s.remove(0);
        }
        s.push(ns);
    }

    pub fn summary(&self) -> Option<crate::util::stats::Summary> {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            None
        } else {
            Some(crate::util::stats::Summary::from_ns(s.clone()))
        }
    }
}

/// Serving metrics.
pub struct Metrics {
    pub requests_submitted: AtomicU64,
    pub requests_completed: AtomicU64,
    pub requests_rejected: AtomicU64,
    /// Requests that ended by cancellation (queued purge or mid-decode).
    /// Cancels also count as completed — every submitted request resolves
    /// exactly once — so `cancelled <= completed`.
    pub requests_cancelled: AtomicU64,
    /// Times a sequence was preempted (blocks reclaimed, requeued) to fit
    /// the pool budget.  Preemption is not terminal: the sequence resumes
    /// later, so this can exceed the request count under churn.
    pub requests_preempted: AtomicU64,
    pub prefill_tokens: AtomicU64,
    pub decode_tokens: AtomicU64,
    pub cache_bytes: AtomicUsize,
    pub dense_equiv_bytes: AtomicUsize,
    /// Block-pool gauges (0/0 when the paged pool is off).
    pub pool_blocks_total: AtomicUsize,
    pub pool_blocks_leased: AtomicUsize,
    pub prefill_ns: Reservoir,
    pub decode_step_ns: Reservoir,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests_submitted: AtomicU64::new(0),
            requests_completed: AtomicU64::new(0),
            requests_rejected: AtomicU64::new(0),
            requests_cancelled: AtomicU64::new(0),
            requests_preempted: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            decode_tokens: AtomicU64::new(0),
            cache_bytes: AtomicUsize::new(0),
            dense_equiv_bytes: AtomicUsize::new(0),
            pool_blocks_total: AtomicUsize::new(0),
            pool_blocks_leased: AtomicUsize::new(0),
            prefill_ns: Reservoir::new(1024),
            decode_step_ns: Reservoir::new(4096),
        }
    }
}

impl Metrics {
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: submitted={} completed={} rejected={} cancelled={} preempted={}\n",
            self.requests_submitted.load(Ordering::Relaxed),
            self.requests_completed.load(Ordering::Relaxed),
            self.requests_rejected.load(Ordering::Relaxed),
            self.requests_cancelled.load(Ordering::Relaxed),
            self.requests_preempted.load(Ordering::Relaxed),
        ));
        out.push_str(&format!(
            "tokens: prefill={} decode={}\n",
            self.prefill_tokens.load(Ordering::Relaxed),
            self.decode_tokens.load(Ordering::Relaxed),
        ));
        let used = self.cache_bytes.load(Ordering::Relaxed);
        let dense = self.dense_equiv_bytes.load(Ordering::Relaxed);
        let saving = if dense > 0 { 100.0 * (1.0 - used as f64 / dense as f64) } else { 0.0 };
        out.push_str(&format!(
            "kv-cache: {} live (dense-equiv {}, saving {saving:.1}%)\n",
            crate::sparse::memory::human_bytes(used),
            crate::sparse::memory::human_bytes(dense),
        ));
        let pool_total = self.pool_blocks_total.load(Ordering::Relaxed);
        if pool_total > 0 {
            let leased = self.pool_blocks_leased.load(Ordering::Relaxed);
            let total = if pool_total == usize::MAX {
                "unbounded".to_string()
            } else {
                pool_total.to_string()
            };
            out.push_str(&format!("pool: blocks leased={leased} target={total}\n"));
        }
        if let Some(s) = self.prefill_ns.summary() {
            out.push_str(&format!("prefill:     {}\n", s.row("")));
        }
        if let Some(s) = self.decode_step_ns.summary() {
            out.push_str(&format!("decode-step: {}\n", s.row("")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_caps() {
        let r = Reservoir::new(3);
        for i in 0..10 {
            r.record(i as f64);
        }
        let s = r.summary().unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min_ns, 7.0);
    }

    #[test]
    fn snapshot_renders() {
        let m = Metrics::default();
        m.requests_submitted.store(5, Ordering::Relaxed);
        m.cache_bytes.store(512, Ordering::Relaxed);
        m.dense_equiv_bytes.store(1024, Ordering::Relaxed);
        let s = m.snapshot();
        assert!(s.contains("submitted=5"));
        assert!(s.contains("cancelled=0 preempted=0"));
        assert!(s.contains("saving 50.0%"));
        assert!(!s.contains("pool:"), "pool line hidden when pool is off");
        m.pool_blocks_total.store(64, Ordering::Relaxed);
        m.pool_blocks_leased.store(7, Ordering::Relaxed);
        assert!(m.snapshot().contains("pool: blocks leased=7 target=64"));
    }
}
