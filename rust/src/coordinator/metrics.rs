//! Engine-level metrics, re-based on the `swan::obs` registry.
//!
//! Every counter/gauge below is an `Arc` handle registered in
//! `self.registry`, so the human-readable `snapshot()` (the `STATS`
//! verb) and the Prometheus exposition (the `METRICS` verb) read the
//! exact same atomics and can never disagree. The two `Reservoir`s are
//! a display-only extra: they keep the last-N exact samples behind the
//! `prefill:`/`decode-step:` Summary rows (min/max/std need raw
//! samples, which log2 histogram buckets cannot reconstruct).

use std::sync::{Arc, Mutex};

use crate::obs::histogram::Histogram;
use crate::obs::registry::{Counter, Gauge, Registry};
use crate::util::stats::Summary;
use crate::util::sync::lock_recover;

/// Fixed-size latency reservoir keeping the most recent N samples in a
/// ring: when full, the oldest sample is overwritten in place — O(1),
/// no `Vec::remove(0)` memmove on the decode path.
pub struct Reservoir {
    inner: Mutex<Ring>,
    cap: usize,
}

struct Ring {
    buf: Vec<f64>,
    /// Index of the oldest sample once the buffer is full; the next
    /// overwrite lands here.
    head: usize,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        let cap = cap.max(1);
        Reservoir { inner: Mutex::new(Ring { buf: Vec::with_capacity(cap), head: 0 }), cap }
    }

    pub fn record(&self, ns: f64) {
        let mut r = lock_recover(&self.inner);
        if r.buf.len() < self.cap {
            r.buf.push(ns);
        } else {
            let h = r.head;
            r.buf[h] = ns;
            r.head = (h + 1) % self.cap;
        }
    }

    /// Summary over the retained (most recent N) samples. Order within
    /// the ring is irrelevant: `Summary::from_ns` sorts.
    pub fn summary(&self) -> Option<Summary> {
        let r = lock_recover(&self.inner);
        if r.buf.is_empty() {
            None
        } else {
            Some(Summary::from_ns(r.buf.clone()))
        }
    }
}

/// Serving metrics: registry-backed handles shared across scheduler
/// threads. Field names are stable; only the handle types changed when
/// the registry landed (`.inc()`/`.add()`/`.get()` for counters,
/// `.set()`/`.get()` for gauges).
pub struct Metrics {
    /// The registry all handles below live in; `METRICS` renders it.
    pub registry: Arc<Registry>,
    pub requests_submitted: Arc<Counter>,
    pub requests_completed: Arc<Counter>,
    pub requests_rejected: Arc<Counter>,
    /// Requests that ended by cancellation (queued purge or mid-decode).
    /// Cancels also count as completed — every submitted request resolves
    /// exactly once — so `cancelled <= completed` (and the exposition's
    /// `outcome="cancelled"` is a subset of `outcome="completed"`).
    pub requests_cancelled: Arc<Counter>,
    /// Times a sequence was preempted (blocks reclaimed, requeued) to fit
    /// the pool budget.  Preemption is not terminal: the sequence resumes
    /// later, so this can exceed the request count under churn.
    pub requests_preempted: Arc<Counter>,
    /// Recovered requests this engine/group accepted with committed
    /// tokens to replay (cross-shard resume after a death or drain).
    pub requests_recovered: Arc<Counter>,
    /// Tokens rebuilt as forced replay steps (no RNG draw, no emission)
    /// while resuming recovered or preempted sequences — the KV-rebuild
    /// overhead of exact recovery.
    pub replay_tokens: Arc<Counter>,
    pub prefill_tokens: Arc<Counter>,
    pub decode_tokens: Arc<Counter>,
    pub cache_bytes: Arc<Gauge>,
    pub dense_equiv_bytes: Arc<Gauge>,
    /// Block-pool gauges (0/0 when the paged pool is off; target is
    /// `u64::MAX` when the pool is unbounded).
    pub pool_blocks_total: Arc<Gauge>,
    pub pool_blocks_leased: Arc<Gauge>,
    /// Prefix-cache counters (all zero unless prefix serving is on).
    /// A hit attaches the longest cached prefix; `tokens_saved` sums the
    /// attach depths (prompt tokens that skipped prefill), and
    /// `blocks_shared` the pool blocks attached copy-on-write.
    pub prefix_hits: Arc<Counter>,
    pub prefix_misses: Arc<Counter>,
    pub prefix_evictions: Arc<Counter>,
    pub prefix_blocks_shared: Arc<Counter>,
    pub prefix_tokens_saved: Arc<Counter>,
    /// Current fleet-tuned compression level on this engine/group.
    pub k_active: Arc<Gauge>,
    /// SLO histograms (lock-free; safe on the per-token commit path).
    pub queue_wait_seconds: Arc<Histogram>,
    pub ttft_seconds: Arc<Histogram>,
    pub itl_seconds: Arc<Histogram>,
    pub prefill_seconds: Arc<Histogram>,
    pub decode_step_seconds: Arc<Histogram>,
    /// Display-only exact-sample reservoirs (see module docs).
    pub prefill_ns: Reservoir,
    pub decode_step_ns: Reservoir,
}

impl Default for Metrics {
    fn default() -> Metrics {
        let registry = Arc::new(Registry::new());
        Metrics {
            requests_submitted: registry.counter("swan_requests_submitted_total", &[]),
            requests_completed: registry.counter("swan_requests_total", &[("outcome", "completed")]),
            requests_rejected: registry.counter("swan_requests_total", &[("outcome", "rejected")]),
            requests_cancelled: registry.counter("swan_requests_total", &[("outcome", "cancelled")]),
            requests_preempted: registry.counter("swan_preemptions_total", &[]),
            requests_recovered: registry.counter("swan_requests_recovered", &[]),
            replay_tokens: registry.counter("swan_replay_tokens", &[]),
            prefill_tokens: registry.counter("swan_tokens_total", &[("phase", "prefill")]),
            decode_tokens: registry.counter("swan_tokens_total", &[("phase", "decode")]),
            cache_bytes: registry.gauge("swan_kv_bytes", &[]),
            dense_equiv_bytes: registry.gauge("swan_kv_dense_equiv_bytes", &[]),
            pool_blocks_total: registry.gauge("swan_pool_blocks_target", &[]),
            pool_blocks_leased: registry.gauge("swan_pool_blocks_leased", &[]),
            prefix_hits: registry.counter("swan_prefix_hits", &[]),
            prefix_misses: registry.counter("swan_prefix_misses", &[]),
            prefix_evictions: registry.counter("swan_prefix_evictions", &[]),
            prefix_blocks_shared: registry.counter("swan_prefix_blocks_shared", &[]),
            prefix_tokens_saved: registry.counter("swan_prefix_tokens_saved", &[]),
            k_active: registry.gauge("swan_k_active", &[]),
            queue_wait_seconds: registry.histogram("swan_queue_wait_seconds", &[]),
            ttft_seconds: registry.histogram("swan_ttft_seconds", &[]),
            itl_seconds: registry.histogram("swan_itl_seconds", &[]),
            prefill_seconds: registry.histogram("swan_prefill_seconds", &[]),
            decode_step_seconds: registry.histogram("swan_decode_step_seconds", &[]),
            prefill_ns: Reservoir::new(1024),
            decode_step_ns: Reservoir::new(4096),
            registry,
        }
    }
}

impl Metrics {
    pub fn snapshot(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: submitted={} completed={} rejected={} cancelled={} preempted={} recovered={}\n",
            self.requests_submitted.get(),
            self.requests_completed.get(),
            self.requests_rejected.get(),
            self.requests_cancelled.get(),
            self.requests_preempted.get(),
            self.requests_recovered.get(),
        ));
        out.push_str(&format!(
            "tokens: prefill={} decode={}\n",
            self.prefill_tokens.get(),
            self.decode_tokens.get(),
        ));
        let used = self.cache_bytes.get() as usize;
        let dense = self.dense_equiv_bytes.get() as usize;
        let saving = if dense > 0 { 100.0 * (1.0 - used as f64 / dense as f64) } else { 0.0 };
        out.push_str(&format!(
            "kv-cache: {} live (dense-equiv {}, saving {saving:.1}%)\n",
            crate::sparse::memory::human_bytes(used),
            crate::sparse::memory::human_bytes(dense),
        ));
        let pool_total = self.pool_blocks_total.get();
        if pool_total > 0 {
            let leased = self.pool_blocks_leased.get();
            let total = if pool_total == u64::MAX {
                "unbounded".to_string()
            } else {
                pool_total.to_string()
            };
            out.push_str(&format!("pool: blocks leased={leased} target={total}\n"));
        }
        let (hits, misses) = (self.prefix_hits.get(), self.prefix_misses.get());
        if hits + misses > 0 {
            let rate = 100.0 * hits as f64 / (hits + misses) as f64;
            out.push_str(&format!(
                "prefix: hits={hits} misses={misses} hit_rate={rate:.1}% tokens_saved={} blocks_shared={} evictions={}\n",
                self.prefix_tokens_saved.get(),
                self.prefix_blocks_shared.get(),
                self.prefix_evictions.get(),
            ));
        }
        if let Some(s) = self.prefill_ns.summary() {
            out.push_str(&format!("prefill:     {}\n", s.row("")));
        }
        if let Some(s) = self.decode_step_ns.summary() {
            out.push_str(&format!("decode-step: {}\n", s.row("")));
        }
        for (name, h) in [("ttft", &self.ttft_seconds), ("itl ", &self.itl_seconds)] {
            let snap = h.snapshot();
            if snap.count() > 0 {
                out.push_str(&format!(
                    "{name}:        p50={} p95={} p99={} (n={})\n",
                    Summary::fmt_time(snap.quantile_ns(0.50)),
                    Summary::fmt_time(snap.quantile_ns(0.95)),
                    Summary::fmt_time(snap.quantile_ns(0.99)),
                    snap.count(),
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reservoir_caps() {
        let r = Reservoir::new(3);
        for i in 0..10 {
            r.record(i as f64);
        }
        let s = r.summary().unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min_ns, 7.0);
    }

    #[test]
    fn reservoir_ring_keeps_most_recent_without_shift() {
        // cap 4, samples 1..=10: survivors must be exactly {7, 8, 9, 10}.
        let r = Reservoir::new(4);
        for i in 1..=10 {
            r.record(i as f64);
        }
        let s = r.summary().unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min_ns, 7.0);
        assert_eq!(s.max_ns, 10.0);
        assert_eq!(s.mean_ns, 8.5);
        // Below-cap behavior unchanged: everything retained.
        let r = Reservoir::new(8);
        for i in 1..=5 {
            r.record(i as f64);
        }
        let s = r.summary().unwrap();
        assert_eq!((s.n, s.min_ns, s.max_ns), (5, 1.0, 5.0));
    }

    #[test]
    fn snapshot_renders() {
        let m = Metrics::default();
        m.requests_submitted.add(5);
        m.cache_bytes.set(512);
        m.dense_equiv_bytes.set(1024);
        let s = m.snapshot();
        assert!(s.contains("submitted=5"));
        assert!(s.contains("cancelled=0 preempted=0"));
        assert!(s.contains("saving 50.0%"));
        assert!(!s.contains("pool:"), "pool line hidden when pool is off");
        m.pool_blocks_total.set(64);
        m.pool_blocks_leased.set(7);
        assert!(m.snapshot().contains("pool: blocks leased=7 target=64"));
        assert!(!s.contains("prefix:"), "prefix line hidden before any lookup");
        m.prefix_hits.add(3);
        m.prefix_misses.add(1);
        m.prefix_tokens_saved.add(96);
        let s = m.snapshot();
        assert!(s.contains("prefix: hits=3 misses=1 hit_rate=75.0% tokens_saved=96"), "{s}");
    }

    #[test]
    fn snapshot_and_exposition_read_the_same_atomics() {
        let m = Metrics::default();
        m.requests_submitted.add(3);
        m.requests_completed.add(2);
        m.k_active.set(8);
        m.ttft_seconds.record_ns(5_000_000);
        let stats = m.snapshot();
        let text = crate::obs::export::render_one(&m.registry);
        assert!(stats.contains("submitted=3 completed=2"));
        assert!(text.contains("swan_requests_submitted_total 3\n"), "{text}");
        assert!(text.contains("swan_requests_total{outcome=\"completed\"} 2\n"), "{text}");
        assert!(text.contains("swan_k_active 8\n"));
        assert!(text.contains("swan_ttft_seconds_count 1\n"));
        assert!(stats.contains("ttft:"), "SLO row rendered once samples exist: {stats}");
    }
}
