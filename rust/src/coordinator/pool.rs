//! Paged KV-cache block pool (PagedAttention-style, Kwon et al. 2023).
//!
//! The paper positions SWAN as orthogonal to system-level memory managers
//! like PagedAttention: SWAN shrinks the bytes per token, paging removes
//! fragmentation across sequences.  This pool composes the two: fixed-size
//! byte blocks are leased to sequences, and because SWAN's winnowed tokens
//! occupy `mode.vector_bytes(k)` bytes instead of `2·d_h`, the same pool
//! holds proportionally more tokens.  The serving engine uses it for
//! admission accounting; `repro motivation` reports the composition.

use crate::sparse::StorageMode;

/// A fixed-size block pool with per-sequence leases.
pub struct BlockPool {
    pub block_bytes: usize,
    pub n_blocks: usize,
    free: Vec<u32>,
    /// lease id -> blocks held
    leases: std::collections::HashMap<u64, Vec<u32>>,
    next_lease: u64,
}

/// Errors from the pool.
#[derive(Debug, PartialEq)]
pub enum PoolError {
    Exhausted { requested: usize, available: usize },
    UnknownLease(u64),
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PoolError::Exhausted { requested, available } => {
                write!(f, "pool exhausted: requested {requested} blocks, {available} free")
            }
            PoolError::UnknownLease(id) => write!(f, "unknown lease {id}"),
        }
    }
}

impl std::error::Error for PoolError {}

impl BlockPool {
    pub fn new(block_bytes: usize, n_blocks: usize) -> BlockPool {
        assert!(block_bytes > 0 && n_blocks > 0);
        BlockPool {
            block_bytes,
            n_blocks,
            free: (0..n_blocks as u32).rev().collect(),
            leases: std::collections::HashMap::new(),
            next_lease: 1,
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.n_blocks as f64
    }

    /// Blocks needed for `bytes` of cache.
    pub fn blocks_for(&self, bytes: usize) -> usize {
        bytes.div_ceil(self.block_bytes)
    }

    /// Open a lease with an initial reservation.
    pub fn lease(&mut self, bytes: usize) -> Result<u64, PoolError> {
        let need = self.blocks_for(bytes);
        if need > self.free.len() {
            return Err(PoolError::Exhausted { requested: need, available: self.free.len() });
        }
        let id = self.next_lease;
        self.next_lease += 1;
        let blocks: Vec<u32> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.leases.insert(id, blocks);
        Ok(id)
    }

    /// Grow a lease to cover `total_bytes` (no-op if already covered).
    pub fn grow(&mut self, lease: u64, total_bytes: usize) -> Result<(), PoolError> {
        let need = self.blocks_for(total_bytes);
        let have = self.leases.get(&lease).ok_or(PoolError::UnknownLease(lease))?.len();
        if need <= have {
            return Ok(());
        }
        let extra = need - have;
        if extra > self.free.len() {
            return Err(PoolError::Exhausted { requested: extra, available: self.free.len() });
        }
        let blocks = self.leases.get_mut(&lease).unwrap();
        for _ in 0..extra {
            blocks.push(self.free.pop().unwrap());
        }
        Ok(())
    }

    /// Release a lease, returning its blocks to the pool.
    pub fn release(&mut self, lease: u64) -> Result<(), PoolError> {
        let blocks = self.leases.remove(&lease).ok_or(PoolError::UnknownLease(lease))?;
        self.free.extend(blocks);
        Ok(())
    }

    /// Tokens one block holds under a given SWAN setting (vs dense).
    pub fn tokens_per_block(&self, d_h: usize, heads: usize, k_active: usize,
                            mode: StorageMode, dense: bool) -> usize {
        let per_token = if dense {
            2 * heads * d_h * 2
        } else {
            2 * heads * mode.vector_bytes(k_active)
        };
        self.block_bytes / per_token.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_grow_release_cycle() {
        let mut p = BlockPool::new(1024, 8);
        let a = p.lease(3000).unwrap(); // 3 blocks
        assert_eq!(p.used_blocks(), 3);
        p.grow(a, 5000).unwrap(); // 5 blocks total
        assert_eq!(p.used_blocks(), 5);
        p.grow(a, 100).unwrap(); // shrink request is a no-op
        assert_eq!(p.used_blocks(), 5);
        p.release(a).unwrap();
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn exhaustion_is_reported_not_panicked() {
        let mut p = BlockPool::new(1024, 4);
        let _a = p.lease(4096).unwrap();
        let err = p.lease(1).unwrap_err();
        assert_eq!(err, PoolError::Exhausted { requested: 1, available: 0 });
    }

    #[test]
    fn unknown_lease_errors() {
        let mut p = BlockPool::new(64, 2);
        assert_eq!(p.release(99).unwrap_err(), PoolError::UnknownLease(99));
        assert_eq!(p.grow(99, 10).unwrap_err(), PoolError::UnknownLease(99));
    }

    #[test]
    fn no_block_leaks_under_churn() {
        let mut p = BlockPool::new(256, 32);
        let mut rng = crate::util::Pcg64::new(0);
        let mut live = Vec::new();
        for _ in 0..500 {
            if rng.next_f64() < 0.6 || live.is_empty() {
                if let Ok(id) = p.lease(1 + rng.below(2048) as usize) {
                    live.push(id);
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                p.release(live.swap_remove(idx)).unwrap();
            }
        }
        for id in live.drain(..) {
            p.release(id).unwrap();
        }
        assert_eq!(p.free_blocks(), 32);
    }

    #[test]
    fn swan_multiplies_block_capacity() {
        // the composition claim: SWAN tokens/block > dense tokens/block
        let p = BlockPool::new(64 * 1024, 4);
        let dense = p.tokens_per_block(128, 8, 0, StorageMode::F16, true);
        let swan16 = p.tokens_per_block(128, 8, 32, StorageMode::F16, false);
        let swan8 = p.tokens_per_block(128, 8, 32, StorageMode::F8, false);
        assert!(swan16 > 2 * dense);
        assert!(swan8 > swan16);
    }
}
