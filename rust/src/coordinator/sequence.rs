//! Per-sequence hybrid-cache state for the PJRT serving path.
//!
//! The AOT decode graphs take the sparse cache as zero-padded
//! `[L, n_kv, Ls, k]` (values, indices) arrays plus a dense buffer
//! `[L, n_kv, BUF, d_h]` and validity masks.  `SeqCache` owns those flat
//! arrays, performs Algorithm 1's buffer/evict/winnow bookkeeping in
//! place, and grows to the next compiled length bucket when the sparse
//! store fills up.  Zero-padding is lossless: padded value entries
//! contribute 0 to scores/outputs and masked slots are -inf'd in softmax.

use crate::sparse::topk::topk_indices_select;
use crate::sparse::StorageMode;
use crate::util::fp::{quantize_f16, quantize_fp8};

/// Static shape info shared by all sequences of a model.
#[derive(Clone, Copy, Debug)]
pub struct CacheShape {
    pub n_layers: usize,
    pub n_kv: usize,
    pub d_head: usize,
    /// Dense buffer capacity in the compiled graphs.
    pub buf_cap: usize,
}

/// One sequence's hybrid cache, shaped for bucket (`l_cap`, `k_active`).
pub struct SeqCache {
    pub shape: CacheShape,
    pub k_active: usize,
    pub mode: StorageMode,
    /// Current sparse length bucket (capacity).
    pub l_cap: usize,
    /// Live sparse tokens (<= l_cap).
    pub sparse_len: usize,
    /// Live buffer tokens (<= buf_cap).
    pub buf_len: usize,
    /// [L, n_kv, l_cap, k] flattened.
    pub sp_kvals: Vec<f32>,
    pub sp_kidx: Vec<i32>,
    pub sp_vvals: Vec<f32>,
    pub sp_vidx: Vec<i32>,
    /// [L, n_kv, buf_cap, d_h] flattened (slot 0 oldest).
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    /// Total tokens represented.
    pub pos: usize,
    /// Cached sparse-slot validity mask (1.0 = live), maintained on
    /// append/grow/load so the decode hot path borrows it instead of
    /// allocating one per step.
    smask_buf: Vec<f32>,
    /// Cached buffer validity mask.
    bmask_buf: Vec<f32>,
}

impl SeqCache {
    pub fn new(shape: CacheShape, l_cap: usize, k_active: usize, mode: StorageMode) -> SeqCache {
        let sp = shape.n_layers * shape.n_kv * l_cap * k_active;
        let bf = shape.n_layers * shape.n_kv * shape.buf_cap * shape.d_head;
        SeqCache {
            shape,
            k_active,
            mode,
            l_cap,
            sparse_len: 0,
            buf_len: 0,
            sp_kvals: vec![0.0; sp],
            sp_kidx: vec![0; sp],
            sp_vvals: vec![0.0; sp],
            sp_vidx: vec![0; sp],
            kbuf: vec![0.0; bf],
            vbuf: vec![0.0; bf],
            pos: 0,
            smask_buf: vec![0.0; l_cap],
            bmask_buf: vec![0.0; shape.buf_cap],
        }
    }

    #[inline]
    fn sp_off(&self, l: usize, h: usize, t: usize) -> usize {
        ((l * self.shape.n_kv + h) * self.l_cap + t) * self.k_active
    }

    #[inline]
    fn buf_off(&self, l: usize, h: usize, t: usize) -> usize {
        ((l * self.shape.n_kv + h) * self.shape.buf_cap + t) * self.shape.d_head
    }

    fn quant(&self, x: f32) -> f32 {
        match self.mode {
            StorageMode::F16 => quantize_f16(x),
            StorageMode::F8 => quantize_fp8(x),
            StorageMode::F32 => x,
        }
    }

    /// Winnow one dense vector into sparse slot `t` of (l, h).
    fn write_sparse(&mut self, l: usize, h: usize, t: usize, k_vec: &[f32], v_vec: &[f32]) {
        let k = self.k_active;
        let off = self.sp_off(l, h, t);
        let ki = topk_indices_select(k_vec, k);
        let vi = topk_indices_select(v_vec, k);
        for j in 0..k {
            self.sp_kvals[off + j] = self.quant(k_vec[ki[j] as usize]);
            self.sp_kidx[off + j] = ki[j] as i32;
            self.sp_vvals[off + j] = self.quant(v_vec[vi[j] as usize]);
            self.sp_vidx[off + j] = vi[j] as i32;
        }
    }

    /// Grow the sparse arrays to a bigger length bucket.
    pub fn grow(&mut self, new_l_cap: usize) {
        assert!(new_l_cap >= self.l_cap);
        if new_l_cap == self.l_cap {
            return;
        }
        let (nl, nkv, k) = (self.shape.n_layers, self.shape.n_kv, self.k_active);
        let mut grown = SeqCache::new(self.shape, new_l_cap, k, self.mode);
        for l in 0..nl {
            for h in 0..nkv {
                let src = self.sp_off(l, h, 0);
                let dst = grown.sp_off(l, h, 0);
                let n = self.sparse_len * k;
                grown.sp_kvals[dst..dst + n].copy_from_slice(&self.sp_kvals[src..src + n]);
                grown.sp_kidx[dst..dst + n].copy_from_slice(&self.sp_kidx[src..src + n]);
                grown.sp_vvals[dst..dst + n].copy_from_slice(&self.sp_vvals[src..src + n]);
                grown.sp_vidx[dst..dst + n].copy_from_slice(&self.sp_vidx[src..src + n]);
            }
        }
        grown.sparse_len = self.sparse_len;
        grown.buf_len = self.buf_len;
        grown.kbuf = std::mem::take(&mut self.kbuf);
        grown.vbuf = std::mem::take(&mut self.vbuf);
        grown.pos = self.pos;
        grown.smask_buf[..self.sparse_len].iter_mut().for_each(|m| *m = 1.0);
        grown.bmask_buf = std::mem::take(&mut self.bmask_buf);
        *self = grown;
    }

    /// True if appending one more token would need a bigger bucket.
    pub fn needs_growth(&self) -> bool {
        self.buf_len == self.shape.buf_cap && self.sparse_len == self.l_cap
    }

    /// Append one token's rotated (k̂, v̂) rows, `[L * n_kv * d_h]` each in
    /// layer-major order (the decode graph's output layout).  Evicts the
    /// oldest buffer token into the sparse store when the buffer is full.
    pub fn append(&mut self, khat: &[f32], vhat: &[f32]) {
        let (nl, nkv, dh) = (self.shape.n_layers, self.shape.n_kv, self.shape.d_head);
        debug_assert_eq!(khat.len(), nl * nkv * dh);
        if self.buf_len == self.shape.buf_cap {
            // evict oldest buffer row of every (l, h) into the sparse store
            assert!(self.sparse_len < self.l_cap, "grow() must be called first");
            let t = self.sparse_len;
            for l in 0..nl {
                for h in 0..nkv {
                    let b0 = self.buf_off(l, h, 0);
                    // lint: allow(hot_alloc, "one d_h-row copy per eviction (not per token); copy_within below needs the source unborrowed")
                    let k_old: Vec<f32> = self.kbuf[b0..b0 + dh].to_vec();
                    // lint: allow(hot_alloc, "see k_old above — paired eviction copy")
                    let v_old: Vec<f32> = self.vbuf[b0..b0 + dh].to_vec();
                    self.write_sparse(l, h, t, &k_old, &v_old);
                    // shift the ring left one slot
                    let span = self.shape.buf_cap * dh;
                    let base = self.buf_off(l, h, 0);
                    self.kbuf.copy_within(base + dh..base + span, base);
                    self.vbuf.copy_within(base + dh..base + span, base);
                }
            }
            self.sparse_len += 1;
            self.buf_len -= 1;
            self.smask_buf[self.sparse_len - 1] = 1.0;
        }
        let t = self.buf_len;
        for l in 0..nl {
            for h in 0..nkv {
                let src = (l * nkv + h) * dh;
                let dst = self.buf_off(l, h, t);
                self.kbuf[dst..dst + dh].copy_from_slice(&khat[src..src + dh]);
                self.vbuf[dst..dst + dh].copy_from_slice(&vhat[src..src + dh]);
            }
        }
        self.buf_len += 1;
        self.bmask_buf[self.buf_len - 1] = 1.0;
        self.pos += 1;
    }

    /// Load a prefill history: `khat`/`vhat` are `[L, n_kv, T, d_h]`
    /// (the prefill graph's output), `t_real` = actual prompt tokens.
    /// The last `buf_cap` tokens stay dense; older ones are winnowed.
    pub fn load_prefill(&mut self, khat: &[f32], vhat: &[f32], t_cap: usize, t_real: usize) {
        let (nl, nkv, dh) = (self.shape.n_layers, self.shape.n_kv, self.shape.d_head);
        let n_buf = t_real.min(self.shape.buf_cap);
        let n_sparse = t_real - n_buf;
        while n_sparse > self.l_cap {
            // caller should have sized the bucket; grow defensively
            let next = self.l_cap * 2;
            self.grow(next);
        }
        let row = |l: usize, h: usize, t: usize| ((l * nkv + h) * t_cap + t) * dh;
        for l in 0..nl {
            for h in 0..nkv {
                for t in 0..n_sparse {
                    let r = row(l, h, t);
                    let kv: Vec<f32> = khat[r..r + dh].to_vec();
                    let vv: Vec<f32> = vhat[r..r + dh].to_vec();
                    self.write_sparse(l, h, t, &kv, &vv);
                }
                for (slot, t) in (n_sparse..t_real).enumerate() {
                    let r = row(l, h, t);
                    let dst = self.buf_off(l, h, slot);
                    self.kbuf[dst..dst + dh].copy_from_slice(&khat[r..r + dh]);
                    self.vbuf[dst..dst + dh].copy_from_slice(&vhat[r..r + dh]);
                }
            }
        }
        self.sparse_len = n_sparse;
        self.buf_len = n_buf;
        self.pos = t_real;
        for (i, m) in self.smask_buf.iter_mut().enumerate() {
            *m = if i < n_sparse { 1.0 } else { 0.0 };
        }
        for (i, m) in self.bmask_buf.iter_mut().enumerate() {
            *m = if i < n_buf { 1.0 } else { 0.0 };
        }
    }

    /// Sparse-slot validity mask (1.0 = live).  Borrowed from the cache's
    /// maintained buffer — no per-step allocation on the decode path.
    pub fn smask(&self) -> &[f32] {
        &self.smask_buf
    }

    /// Buffer validity mask (borrowed, see [`SeqCache::smask`]).
    pub fn bmask(&self) -> &[f32] {
        &self.bmask_buf
    }

    /// Serving-accounting bytes of this cache (Eq. 1 sparse + f16 buffer).
    pub fn storage_bytes(&self) -> usize {
        let heads = self.shape.n_layers * self.shape.n_kv;
        let per_vec = self.mode.vector_bytes(self.k_active);
        let sparse = 2 * heads * per_vec * self.sparse_len;
        let dense = 2 * heads * self.shape.d_head * 2 * self.buf_len;
        sparse + dense
    }

    /// Bytes an uncompressed cache of the same token count would use.
    pub fn dense_equiv_bytes(&self) -> usize {
        2 * self.shape.n_layers * self.shape.n_kv * self.shape.d_head * 2 * self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn shape() -> CacheShape {
        CacheShape { n_layers: 2, n_kv: 2, d_head: 8, buf_cap: 4 }
    }

    fn rows(r: &mut Pcg64, shape: &CacheShape) -> (Vec<f32>, Vec<f32>) {
        let n = shape.n_layers * shape.n_kv * shape.d_head;
        (r.normal_vec(n), r.normal_vec(n))
    }

    #[test]
    fn append_fills_buffer_then_sparse() {
        let mut c = SeqCache::new(shape(), 16, 4, StorageMode::F32);
        let mut r = Pcg64::new(0);
        for i in 0..6 {
            let (k, v) = rows(&mut r, &shape());
            c.append(&k, &v);
            assert_eq!(c.pos, i + 1);
        }
        assert_eq!(c.buf_len, 4);
        assert_eq!(c.sparse_len, 2);
        // masks
        assert_eq!(c.smask().iter().sum::<f32>(), 2.0);
        assert_eq!(c.bmask().iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn eviction_preserves_topk_content() {
        let sh = CacheShape { n_layers: 1, n_kv: 1, d_head: 8, buf_cap: 1 };
        let mut c = SeqCache::new(sh, 8, 8, StorageMode::F32); // full retention
        let k1: Vec<f32> = (0..8).map(|i| i as f32 + 1.0).collect();
        let v1: Vec<f32> = (0..8).map(|i| -(i as f32) - 1.0).collect();
        c.append(&k1, &v1);
        c.append(&vec![9.0; 8], &vec![9.0; 8]); // evicts token 0
        assert_eq!(c.sparse_len, 1);
        // reconstruct slot 0: values at their indices must equal k1
        let mut rec = vec![0.0f32; 8];
        for j in 0..8 {
            rec[c.sp_kidx[j] as usize] = c.sp_kvals[j];
        }
        assert_eq!(rec, k1);
        let mut recv = vec![0.0f32; 8];
        for j in 0..8 {
            recv[c.sp_vidx[j] as usize] = c.sp_vvals[j];
        }
        assert_eq!(recv, v1);
    }

    #[test]
    fn buffer_is_fifo_after_eviction() {
        let sh = CacheShape { n_layers: 1, n_kv: 1, d_head: 4, buf_cap: 2 };
        let mut c = SeqCache::new(sh, 8, 2, StorageMode::F32);
        c.append(&[1.0; 4], &[1.0; 4]);
        c.append(&[2.0; 4], &[2.0; 4]);
        c.append(&[3.0; 4], &[3.0; 4]); // evicts "1"
        assert_eq!(&c.kbuf[0..4], &[2.0; 4]);
        assert_eq!(&c.kbuf[4..8], &[3.0; 4]);
        assert_eq!(c.sparse_len, 1);
    }

    #[test]
    fn grow_preserves_content() {
        let mut c = SeqCache::new(shape(), 4, 4, StorageMode::F16);
        let mut r = Pcg64::new(1);
        for _ in 0..8 {
            let (k, v) = rows(&mut r, &shape());
            c.append(&k, &v);
        }
        assert_eq!(c.sparse_len, 4);
        assert!(c.needs_growth());
        let kvals_before = c.sp_kvals.clone();
        let off_before = c.sp_off(1, 1, 0);
        c.grow(16);
        assert_eq!(c.l_cap, 16);
        let off_after = c.sp_off(1, 1, 0);
        // content preserved per (l, h) block
        assert_eq!(
            &c.sp_kvals[off_after..off_after + 4 * 4],
            &kvals_before[off_before..off_before + 4 * 4]
        );
        // appending now works
        let (k, v) = rows(&mut r, &shape());
        c.append(&k, &v);
        assert_eq!(c.sparse_len, 5);
    }

    #[test]
    fn load_prefill_layout() {
        let sh = CacheShape { n_layers: 1, n_kv: 1, d_head: 4, buf_cap: 2 };
        let mut c = SeqCache::new(sh, 8, 4, StorageMode::F32);
        let t_cap = 8;
        let t_real = 5;
        // khat[t] = [t+1; 4]
        let mut khat = vec![0.0f32; t_cap * 4];
        for t in 0..t_real {
            for j in 0..4 {
                khat[t * 4 + j] = (t + 1) as f32;
            }
        }
        let vhat = khat.clone();
        c.load_prefill(&khat, &vhat, t_cap, t_real);
        assert_eq!(c.sparse_len, 3);
        assert_eq!(c.buf_len, 2);
        assert_eq!(c.pos, 5);
        // buffer holds tokens 4,5 (values 4.0 and 5.0)
        assert_eq!(&c.kbuf[0..4], &[4.0; 4]);
        assert_eq!(&c.kbuf[4..8], &[5.0; 4]);
        // sparse slot 0 reconstructs token 1 (all-equal vector: top-4 = all)
        assert_eq!(c.sp_kvals[0], 1.0);
    }

    #[test]
    fn masks_track_counters_through_growth_and_prefill() {
        let mut c = SeqCache::new(shape(), 4, 4, StorageMode::F32);
        let mut r = Pcg64::new(9);
        for _ in 0..8 {
            let (k, v) = rows(&mut r, &shape());
            c.append(&k, &v);
        }
        assert_eq!(c.smask().iter().sum::<f32>() as usize, c.sparse_len);
        assert_eq!(c.bmask().iter().sum::<f32>() as usize, c.buf_len);
        c.grow(16);
        assert_eq!(c.smask().len(), 16);
        assert_eq!(c.smask().iter().sum::<f32>() as usize, c.sparse_len);
        let (k, v) = rows(&mut r, &shape());
        c.append(&k, &v);
        assert_eq!(c.smask().iter().sum::<f32>() as usize, c.sparse_len);
        assert_eq!(c.bmask().iter().sum::<f32>() as usize, c.buf_len);

        let sh = CacheShape { n_layers: 1, n_kv: 1, d_head: 4, buf_cap: 2 };
        let mut p = SeqCache::new(sh, 8, 4, StorageMode::F32);
        let khat = vec![1.0f32; 8 * 4];
        p.load_prefill(&khat, &khat, 8, 5);
        assert_eq!(p.smask(), &[1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(p.bmask(), &[1.0, 1.0]);
    }

    #[test]
    fn storage_bytes_tracks_eq1() {
        let mut c = SeqCache::new(shape(), 16, 4, StorageMode::F16);
        let mut r = Pcg64::new(2);
        for _ in 0..10 {
            let (k, v) = rows(&mut r, &shape());
            c.append(&k, &v);
        }
        // 6 sparse + 4 buffer; heads = 4
        let expect = 2 * 4 * (3 * 4 + 2) * 6 + 2 * 4 * 8 * 2 * 4;
        assert_eq!(c.storage_bytes(), expect);
        assert!(c.storage_bytes() < c.dense_equiv_bytes());
    }
}
