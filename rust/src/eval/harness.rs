//! Evaluation harness: runs (model x policy x task) grids.
//!
//! Protocol per case (the paper's serving flow): the prompt is prefilled
//! *exactly* (prompt-phase attention is dense), the resulting rotated KV
//! history is loaded into the cache policy (winnowing everything beyond
//! the buffer), and the answer is generated greedily through the
//! compressed cache.  Perplexity instead teacher-forces every token
//! through the policy so compression applies to the whole history — the
//! regime where zero-buffer SWAN collapses (Fig 2b/4).

use crate::coordinator::request::{decode_tokens, encode_text};
use crate::eval::tasks::Task;
use crate::kvcache::PolicyKind;
use crate::model::transformer::{Prefill, SequenceState, SwanModel};
use crate::tensor::ops::argmax;
use crate::util::Pcg64;

/// Result of one (policy, task) cell.
#[derive(Clone, Debug)]
pub struct EvalResult {
    pub task: String,
    pub policy: String,
    pub accuracy: f64,
    pub n_cases: usize,
    /// Mean measured cache bytes / dense-equivalent bytes at answer time.
    pub compression_ratio: f64,
}

/// Harness over one model.
pub struct Harness<'m> {
    pub model: &'m SwanModel,
    /// Cache of exact prefills keyed by prompt (prefill is
    /// policy-independent, so it is shared across the policy grid).
    prefills: std::collections::HashMap<Vec<u32>, std::rc::Rc<Prefill>>,
}

impl<'m> Harness<'m> {
    pub fn new(model: &'m SwanModel) -> Harness<'m> {
        Harness { model, prefills: std::collections::HashMap::new() }
    }

    fn prefill_cached(&mut self, tokens: &[u32]) -> std::rc::Rc<Prefill> {
        if let Some(p) = self.prefills.get(tokens) {
            return p.clone();
        }
        let p = std::rc::Rc::new(self.model.prefill(tokens));
        self.prefills.insert(tokens.to_vec(), p.clone());
        p
    }

    /// Exact-match accuracy of `policy` on `task`.
    pub fn run_task(&mut self, task: &Task, policy: PolicyKind) -> EvalResult {
        self.run_cases(&task.kind.label(), &task.cases(), policy)
    }

    /// Exact-match accuracy of `policy` over explicit cases.
    pub fn run_cases(
        &mut self,
        label: &str,
        cases: &[crate::eval::tasks::TaskCase],
        policy: PolicyKind,
    ) -> EvalResult {
        let mut correct = 0usize;
        let mut ratio_sum = 0.0f64;
        for case in cases {
            let tokens = encode_text(&case.prompt);
            let pf = self.prefill_cached(&tokens);
            let mut st = SequenceState::new(self.model, policy);
            st.load_prefill(&pf);
            // measured compression at answer time
            let used = st.storage_bytes() as f64;
            let dense = {
                let cfg = &self.model.cfg;
                (2 * cfg.n_layers * cfg.n_kv_heads * cfg.d_head * 2 * st.pos) as f64
            };
            ratio_sum += used / dense;

            let first = argmax(&pf.logits) as u32;
            let max_new = case.answer.len() + 2;
            let mut produced = vec![first];
            let mut tok = first;
            for _ in 1..max_new {
                let logits = self.model.decode_step(&mut st, tok);
                tok = argmax(&logits) as u32;
                produced.push(tok);
            }
            let text = decode_tokens(&produced);
            if text.trim_start().starts_with(&case.answer) {
                correct += 1;
            }
        }
        EvalResult {
            task: label.to_string(),
            policy: policy.label(),
            accuracy: correct as f64 / cases.len() as f64,
            n_cases: cases.len(),
            compression_ratio: ratio_sum / cases.len() as f64,
        }
    }

    /// Teacher-forced per-character negative log-likelihood under a
    /// policy-compressed history (WikiText-perplexity analogue; lower is
    /// better).  Compression applies from token 0 — the bt=0 stress
    /// regime.
    pub fn perplexity(&mut self, text: &str, policy: PolicyKind) -> f64 {
        let ids = encode_text(text);
        assert!(ids.len() >= 8, "text too short");
        let mut st = SequenceState::new(self.model, policy);
        let mut nll = 0.0f64;
        let mut count = 0usize;
        let mut tok = ids[0];
        for &next in &ids[1..] {
            let logits = self.model.decode_step(&mut st, tok);
            let lse = crate::tensor::ops::logsumexp(&logits);
            nll += (lse - logits[next as usize]) as f64;
            count += 1;
            tok = next;
        }
        (nll / count as f64).exp()
    }

    /// Continuation-choice accuracy (HellaSwag/Winogrande analogue): after
    /// a context processed through `policy`, the model must assign higher
    /// likelihood to the true continuation than to a distractor sampled
    /// from elsewhere in the corpus.
    pub fn continuation_choice(
        &mut self,
        policy: PolicyKind,
        n_cases: usize,
        ctx_chars: usize,
        cont_chars: usize,
        seed: u64,
    ) -> f64 {
        let mut rng = Pcg64::new(seed ^ 0xc0ac_u64);
        let mut wins = 0usize;
        for case in 0..n_cases {
            let text = crate::eval::corpus::mixed_text(
                &mut rng.fork(case as u64),
                ctx_chars + cont_chars + 8,
            );
            let ids = encode_text(&text);
            let (ctx, rest) = ids.split_at(ctx_chars.min(ids.len() - cont_chars - 1));
            let truth: Vec<u32> = rest[..cont_chars].to_vec();
            let distractor_text =
                crate::eval::corpus::mixed_text(&mut rng.fork(10_000 + case as u64), cont_chars + 8);
            let distractor: Vec<u32> = encode_text(&distractor_text)[..cont_chars].to_vec();

            let lp_true = self.continuation_logprob(ctx, &truth, policy);
            let lp_dis = self.continuation_logprob(ctx, &distractor, policy);
            if lp_true > lp_dis {
                wins += 1;
            }
        }
        wins as f64 / n_cases as f64
    }

    fn continuation_logprob(&mut self, ctx: &[u32], cont: &[u32], policy: PolicyKind) -> f64 {
        // context through the policy (compressed), continuation scored
        // token by token
        let mut st = SequenceState::new(self.model, policy);
        if ctx.len() > 1 {
            let pf = self.prefill_cached(ctx);
            st.load_prefill(&pf);
        }
        let mut lp = 0.0f64;
        let mut tok = *ctx.last().unwrap_or(&0);
        for &next in cont {
            let logits = self.model.decode_step(&mut st, tok);
            let lse = crate::tensor::ops::logsumexp(&logits);
            lp += (logits[next as usize] - lse) as f64;
            tok = next;
        }
        lp
    }
}

/// Format a grid of results as an aligned table.
pub fn format_table(title: &str, rows: &[EvalResult]) -> String {
    let mut out = format!("## {title}\n");
    out.push_str(&format!(
        "{:<34} {:<28} {:>9} {:>8} {:>7}\n",
        "policy", "task", "accuracy", "ratio", "n"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<34} {:<28} {:>8.3} {:>8.3} {:>7}\n",
            r.policy, r.task, r.accuracy, r.compression_ratio, r.n_cases
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::tests::tiny_model;
    use crate::eval::tasks::TaskKind;

    #[test]
    fn harness_runs_on_tiny_model() {
        // the tiny random model scores ~0, but the plumbing must work and
        // dense must not crash across tasks
        let m = tiny_model(2);
        let mut h = Harness::new(&m);
        let task = Task { kind: TaskKind::Arith { steps: 2 }, n_cases: 2, seed: 0 };
        let r = h.run_task(&task, PolicyKind::Dense);
        assert_eq!(r.n_cases, 2);
        assert!((0.0..=1.0).contains(&r.accuracy));
        assert!((r.compression_ratio - 1.0).abs() < 1e-6, "dense ratio must be 1");
    }

    #[test]
    fn swan_ratio_below_one_on_long_prompts() {
        let m = tiny_model(2);
        let mut h = Harness::new(&m);
        let task = Task { kind: TaskKind::Passkey { distance: 150 }, n_cases: 1, seed: 1 };
        let r = h.run_task(
            &task,
            PolicyKind::Swan {
                k_active: 2,
                buffer: 8,
                mode: crate::sparse::StorageMode::F16,
            },
        );
        assert!(r.compression_ratio < 0.8, "ratio {}", r.compression_ratio);
    }

    #[test]
    fn perplexity_is_finite_and_reasonable() {
        let m = tiny_model(2);
        let mut h = Harness::new(&m);
        let text = crate::eval::corpus::mixed_text(&mut Pcg64::new(0), 120);
        let p = h.perplexity(&text, PolicyKind::Dense);
        assert!(p.is_finite() && p > 1.0 && p < 200.0, "ppl {p}");
    }

    #[test]
    fn prefill_cache_is_shared() {
        let m = tiny_model(2);
        let mut h = Harness::new(&m);
        let task = Task { kind: TaskKind::Arith { steps: 2 }, n_cases: 2, seed: 0 };
        h.run_task(&task, PolicyKind::Dense);
        let n1 = h.prefills.len();
        h.run_task(&task, PolicyKind::Dense);
        assert_eq!(h.prefills.len(), n1, "second run must reuse prefills");
    }
}
