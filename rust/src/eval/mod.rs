//! Synthetic evaluation suite (the repro substitutes for GSM8K / MMLU /
//! LongBench — see DESIGN.md for the task-by-task mapping).
//!
//! * [`corpus`]  — rust-side generators over the same grammar the model
//!   was trained on (python `compile/corpus.py`).
//! * [`tasks`]   — prompted tasks with exact-match answers (arithmetic
//!   chains, fact recall, passkey retrieval, code completion, long copy).
//! * [`harness`] — runs (model x cache-policy x task) grids, teacher-forced
//!   perplexity and continuation-choice scoring, measured compression
//!   ratios.

pub mod corpus;
pub mod harness;
pub mod tasks;

pub use harness::{EvalResult, Harness};
pub use tasks::{Task, TaskCase, TaskKind};
