//! Prompted evaluation tasks with exact-match answers.
//!
//! Paper-benchmark analogues (DESIGN.md table):
//!   Arith        -> GSM8K (multi-step reasoning; unforgiving to KV loss)
//!   FactRecall   -> MMLU/ARC (mid-context factual recall)
//!   Passkey      -> LongBench PassageRetrieval
//!   Code         -> LongBench LCC (code completion)
//!   LongRecall   -> LongBench summarisation proxy (recall the gist of an
//!                   early declaration after a long document)

use crate::eval::corpus;
use crate::util::Pcg64;

/// One evaluation case.
#[derive(Clone, Debug)]
pub struct TaskCase {
    pub prompt: String,
    /// Expected generation prefix (exact match after trimming).
    pub answer: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// Chained arithmetic with `steps` operations.
    Arith { steps: usize },
    /// A fact declared early, recalled after `distance` chars of filler.
    FactRecall { distance: usize },
    /// Passkey retrieval across `distance` chars of filler.
    Passkey { distance: usize },
    /// Code-call completion after `clutter` other definitions.
    Code { clutter: usize },
    /// Early passkey + long document + recall (summarisation-gist proxy).
    LongRecall { distance: usize },
}

impl TaskKind {
    pub fn label(&self) -> String {
        match self {
            TaskKind::Arith { steps } => format!("arith({steps})"),
            TaskKind::FactRecall { distance } => format!("fact-recall(d={distance})"),
            TaskKind::Passkey { distance } => format!("passkey(d={distance})"),
            TaskKind::Code { clutter } => format!("code(c={clutter})"),
            TaskKind::LongRecall { distance } => format!("long-recall(d={distance})"),
        }
    }

    /// Generate one case.
    pub fn gen(&self, rng: &mut Pcg64) -> TaskCase {
        match *self {
            TaskKind::Arith { steps } => {
                let (prompt, answer) = corpus::arith_chain(rng, steps);
                TaskCase { prompt, answer }
            }
            TaskKind::FactRecall { distance } => {
                let (decl, key, val) = corpus::fact(rng);
                // the training grammar always pairs decl+recall adjacently;
                // distance stresses the cache beyond the training regime
                let fill = corpus::filler(rng, distance);
                TaskCase {
                    prompt: format!("{decl}{fill}recall {key} -> "),
                    answer: val,
                }
            }
            TaskKind::Passkey { distance } => {
                let (decl, key) = corpus::passkey(rng);
                let fill = corpus::filler(rng, distance);
                TaskCase {
                    prompt: format!("{decl}{fill}. the passkey was "),
                    answer: key,
                }
            }
            TaskKind::Code { clutter } => {
                let (def, arg) = corpus::code_def(rng);
                let mut mid = String::new();
                for _ in 0..clutter {
                    let (d2, a2) = corpus::code_def(rng);
                    mid.push_str(&d2);
                    mid.push_str(&a2);
                    mid.push_str(") ; ");
                }
                TaskCase { prompt: format!("{mid}{def}"), answer: arg }
            }
            TaskKind::LongRecall { distance } => {
                let (decl, key) = corpus::passkey(rng);
                let doc = corpus::mixed_text(rng, distance);
                TaskCase {
                    prompt: format!("{decl}{doc} . the passkey was "),
                    answer: key,
                }
            }
        }
    }
}

/// A named task = kind + number of cases + seed.
#[derive(Clone, Debug)]
pub struct Task {
    pub kind: TaskKind,
    pub n_cases: usize,
    pub seed: u64,
}

impl Task {
    pub fn cases(&self) -> Vec<TaskCase> {
        let mut rng = Pcg64::new(self.seed ^ 0xe7a1);
        (0..self.n_cases).map(|_| self.kind.gen(&mut rng)).collect()
    }
}

/// The standard NLP-benchmark battery (Fig 3 / Table 1 analogue).
pub fn standard_battery(n_cases: usize, seed: u64) -> Vec<Task> {
    vec![
        Task { kind: TaskKind::Arith { steps: 5 }, n_cases, seed },
        Task { kind: TaskKind::FactRecall { distance: 120 }, n_cases, seed: seed + 1 },
        Task { kind: TaskKind::Passkey { distance: 120 }, n_cases, seed: seed + 2 },
        Task { kind: TaskKind::Code { clutter: 3 }, n_cases, seed: seed + 3 },
    ]
}

/// The long-context battery (Fig 4/6 analogue).
pub fn long_battery(n_cases: usize, seed: u64) -> Vec<Task> {
    vec![
        Task { kind: TaskKind::Passkey { distance: 300 }, n_cases, seed },
        Task { kind: TaskKind::FactRecall { distance: 300 }, n_cases, seed: seed + 1 },
        Task { kind: TaskKind::LongRecall { distance: 350 }, n_cases, seed: seed + 2 },
        Task { kind: TaskKind::Code { clutter: 10 }, n_cases, seed: seed + 3 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let t = Task { kind: TaskKind::Arith { steps: 4 }, n_cases: 5, seed: 7 };
        let a = t.cases();
        let b = t.cases();
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn prompts_contain_answers_context() {
        let mut rng = Pcg64::new(1);
        let c = TaskKind::Passkey { distance: 100 }.gen(&mut rng);
        assert!(c.prompt.contains(&format!("the passkey is {}", c.answer)));
        assert!(c.prompt.ends_with("the passkey was "));

        let c = TaskKind::FactRecall { distance: 50 }.gen(&mut rng);
        assert!(c.prompt.contains(&format!("is {}", c.answer)));

        let c = TaskKind::Code { clutter: 2 }.gen(&mut rng);
        assert!(c.prompt.ends_with('('));
    }

    #[test]
    fn batteries_have_distinct_kinds() {
        let b = standard_battery(3, 0);
        let kinds: std::collections::HashSet<_> = b.iter().map(|t| t.kind).collect();
        assert_eq!(kinds.len(), b.len());
    }
}
