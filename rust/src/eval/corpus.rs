//! Rust-side synthetic corpus generators over the same grammar as
//! `python/compile/corpus.py` (same alphabet and word lists; streams need
//! not be bit-identical — the model generalises over the grammar).

use crate::util::Pcg64;

pub const ADJS: [&str; 10] = [
    "quick", "sparse", "dense", "rotated", "pruned", "long", "short", "hidden", "salient", "quiet",
];
pub const NOUNS: [&str; 10] = [
    "cache", "vector", "token", "model", "matrix", "buffer", "kernel", "query", "key", "value",
];
pub const VERBS: [&str; 10] = [
    "stores", "rotates", "prunes", "reads", "writes", "scans", "maps", "folds", "splits", "joins",
];

pub fn prose(rng: &mut Pcg64) -> String {
    format!(
        "the {} {} {} the {} {} . ",
        rng.choose(&ADJS),
        rng.choose(&NOUNS),
        rng.choose(&VERBS),
        rng.choose(&ADJS),
        rng.choose(&NOUNS)
    )
}

pub fn fact(rng: &mut Pcg64) -> (String, String, String) {
    let key = format!("{}{}", rng.choose(&NOUNS), rng.below(100));
    let val = rng.below(1000).to_string();
    let decl = format!("fact {key} is {val} . ");
    (decl, key, val)
}

/// Arithmetic chain: returns (text-without-answer, answer-string).
/// Mirrors the training grammar `start x ; add d = y ; ... answer y .`
pub fn arith_chain(rng: &mut Pcg64, steps: usize) -> (String, String) {
    let mut x = rng.range(1, 50);
    let mut s = format!("start {x} ;");
    for _ in 0..steps {
        let d = rng.range(1, 10);
        if rng.next_f64() < 0.5 {
            x += d;
            s.push_str(&format!(" add {d} = {x} ;"));
        } else {
            x -= d;
            s.push_str(&format!(" sub {d} = {x} ;"));
        }
    }
    s.push_str(" answer ");
    (s, x.to_string())
}

/// Code definition: returns (definition + call prefix, expected arg digits).
pub fn code_def(rng: &mut Pcg64) -> (String, String) {
    let i = rng.below(100);
    let n = rng.range(1, 20);
    let op = *rng.choose(&["+", "-", "*"]);
    (format!("def f{i}(x): return x {op} {n} ; f{i}("), n.to_string())
}

/// Passkey sentence pieces: (declaration, key).
pub fn passkey(rng: &mut Pcg64) -> (String, String) {
    let key: String = (0..5).map(|_| char::from(b'0' + rng.below(10) as u8)).collect();
    (format!("the passkey is {key} . "), key)
}

/// Filler prose of roughly `n_chars` characters.
pub fn filler(rng: &mut Pcg64, n_chars: usize) -> String {
    let mut s = String::new();
    while s.len() < n_chars {
        s.push_str(&prose(rng));
    }
    s.truncate(n_chars);
    // avoid cutting mid-word confusing the model more than needed
    if let Some(i) = s.rfind(' ') {
        s.truncate(i + 1);
    }
    s
}

/// Mixed corpus text (for perplexity), ~`n_chars` characters.
pub fn mixed_text(rng: &mut Pcg64, n_chars: usize) -> String {
    let mut s = String::new();
    while s.len() < n_chars {
        match rng.below(5) {
            0 | 1 => s.push_str(&prose(rng)),
            2 => {
                let (decl, key, val) = fact(rng);
                s.push_str(&decl);
                s.push_str(&format!("recall {key} -> {val} . "));
            }
            3 => {
                let (body, ans) = arith_chain(rng, 4);
                s.push_str(&body);
                s.push_str(&ans);
                s.push_str(" . ");
            }
            _ => {
                let (def, arg) = code_def(rng);
                s.push_str(&def);
                s.push_str(&arg);
                s.push_str(") ; ");
            }
        }
    }
    s.truncate(n_chars);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = mixed_text(&mut Pcg64::new(1), 500);
        let b = mixed_text(&mut Pcg64::new(1), 500);
        assert_eq!(a, b);
        assert_ne!(a, mixed_text(&mut Pcg64::new(2), 500));
    }

    #[test]
    fn arith_chain_is_consistent() {
        for seed in 0..20 {
            let (body, ans) = arith_chain(&mut Pcg64::new(seed), 5);
            // re-derive the answer by parsing the chain
            let mut x: i64 = 0;
            for tok in body.split(';') {
                let tok = tok.trim();
                if let Some(v) = tok.strip_prefix("start ") {
                    x = v.trim().parse().unwrap();
                } else if tok.starts_with("add") || tok.starts_with("sub") {
                    let y: i64 = tok.split('=').nth(1).unwrap().trim().parse().unwrap();
                    x = y;
                }
            }
            assert_eq!(x.to_string(), ans, "{body}");
        }
    }

    #[test]
    fn passkey_embedded_in_declaration() {
        let (decl, key) = passkey(&mut Pcg64::new(3));
        assert!(decl.contains(&key));
        assert_eq!(key.len(), 5);
    }

    #[test]
    fn filler_is_ascii_printable() {
        let f = filler(&mut Pcg64::new(4), 300);
        assert!(f.bytes().all(|b| (32..127).contains(&b)));
        assert!(f.len() <= 300);
    }
}
