//! KIVI-style quantization baseline (Zirui Liu et al., 2023): historical
//! tokens are stored at low bit-width (asymmetric per-vector uint
//! quantization), while a small dense residual window of recent tokens
//! stays in full precision.  Unlike SWAN this has a hard compression
//! ceiling (the bit-width) and must dequantize on read.

use crate::kvcache::CachePolicy;
use crate::tensor::ops::{dot, softmax_inplace};

/// Per-vector asymmetric uint-b quantization: q = round((x - min) / step).
struct QuantVec {
    codes: Vec<u8>,
    min: f32,
    step: f32,
}

impl QuantVec {
    fn quantize(x: &[f32], bits: u8) -> QuantVec {
        let levels = ((1u32 << bits) - 1) as f32;
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let step = if hi > lo { (hi - lo) / levels } else { 1.0 };
        let codes = x
            .iter()
            .map(|&v| (((v - lo) / step).round() as i64).clamp(0, levels as i64) as u8)
            .collect();
        QuantVec { codes, min: lo, step }
    }

    fn dequantize_into(&self, out: &mut [f32]) {
        for (o, &c) in out.iter_mut().zip(&self.codes) {
            *o = self.min + c as f32 * self.step;
        }
    }

    fn bytes(&self, bits: u8) -> usize {
        // packed codes + two f16 scale params
        (self.codes.len() * bits as usize).div_ceil(8) + 4
    }
}

pub struct KiviCache {
    d: usize,
    bits: u8,
    residual: usize,
    hist_k: Vec<QuantVec>,
    hist_v: Vec<QuantVec>,
    res_k: Vec<f32>,
    res_v: Vec<f32>,
    res_len: usize,
    seen: usize,
    scratch: Vec<f32>,
}

impl KiviCache {
    pub fn new(d: usize, bits: u8, residual: usize) -> KiviCache {
        assert!(bits >= 1 && bits <= 8);
        KiviCache {
            d,
            bits,
            residual,
            hist_k: Vec::new(),
            hist_v: Vec::new(),
            res_k: Vec::new(),
            res_v: Vec::new(),
            res_len: 0,
            seen: 0,
            scratch: vec![0.0; d],
        }
    }
}

impl CachePolicy for KiviCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        self.res_k.extend_from_slice(k_hat);
        self.res_v.extend_from_slice(v_hat);
        self.res_len += 1;
        self.seen += 1;
        while self.res_len > self.residual {
            let k_old: Vec<f32> = self.res_k.drain(..self.d).collect();
            let v_old: Vec<f32> = self.res_v.drain(..self.d).collect();
            self.res_len -= 1;
            self.hist_k.push(QuantVec::quantize(&k_old, self.bits));
            self.hist_v.push(QuantVec::quantize(&v_old, self.bits));
        }
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        let d = self.d;
        let scale = 1.0 / (d as f32).sqrt();
        let nh = self.hist_k.len();
        let nr = self.res_len;
        let mut scores = Vec::with_capacity(nh + nr + 1);
        // explicit decompression step — the overhead SWAN eliminates
        for qk in &self.hist_k {
            qk.dequantize_into(&mut self.scratch);
            scores.push(dot(&self.scratch, q_hat) * scale);
        }
        for t in 0..nr {
            scores.push(dot(&self.res_k[t * d..(t + 1) * d], q_hat) * scale);
        }
        scores.push(dot(k_cur, q_hat) * scale);
        softmax_inplace(&mut scores);
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, qv) in self.hist_v.iter().enumerate() {
            qv.dequantize_into(&mut self.scratch);
            let w = scores[i];
            for (o, x) in out.iter_mut().zip(&self.scratch) {
                *o += w * x;
            }
        }
        for t in 0..nr {
            let w = scores[nh + t];
            for (o, x) in out.iter_mut().zip(&self.res_v[t * d..(t + 1) * d]) {
                *o += w * x;
            }
        }
        for (o, x) in out.iter_mut().zip(v_cur) {
            *o += scores[nh + nr] * x;
        }
    }

    fn storage_bytes(&self) -> usize {
        let hist: usize = self
            .hist_k
            .iter()
            .chain(self.hist_v.iter())
            .map(|q| q.bytes(self.bits))
            .sum();
        hist + 2 * self.res_len * self.d * 2
    }

    fn retained_tokens(&self) -> usize {
        self.hist_k.len() + self.res_len
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        format!("kivi{} r={}", self.bits, self.residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::test_support::run_policy;
    use crate::util::Pcg64;

    #[test]
    fn within_residual_is_exact() {
        let mut p = KiviCache::new(16, 2, 64);
        let (out, want) = run_policy(&mut p, 16, 20, 0);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn int8_is_close_to_dense() {
        let mut p = KiviCache::new(32, 8, 4);
        let (out, want) = run_policy(&mut p, 32, 50, 1);
        let err: f32 = out.iter().zip(&want).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt();
        let norm: f32 = want.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(err / norm < 0.05, "rel err {}", err / norm);
    }

    #[test]
    fn lower_bits_use_less_memory_more_error() {
        let d = 32;
        let run = |bits| {
            let mut p = KiviCache::new(d, bits, 4);
            let (out, want) = run_policy(&mut p, d, 60, 2);
            let err: f32 =
                out.iter().zip(&want).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt();
            (p.storage_bytes(), err)
        };
        let (m8, e8) = run(8);
        let (m2, e2) = run(2);
        assert!(m2 < m8);
        assert!(e2 > e8);
    }

    #[test]
    fn quantvec_roundtrip_error_bounded() {
        let mut r = Pcg64::new(3);
        let x = r.normal_vec(64);
        let q = QuantVec::quantize(&x, 8);
        let mut y = vec![0.0; 64];
        q.dequantize_into(&mut y);
        let span = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
            - x.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= span / 255.0 * 0.5 + 1e-6);
        }
    }

    #[test]
    fn retains_all_tokens_like_swan() {
        let mut p = KiviCache::new(8, 4, 2);
        let mut r = Pcg64::new(4);
        for _ in 0..30 {
            p.append(&r.normal_vec(8), &r.normal_vec(8));
        }
        assert_eq!(p.retained_tokens(), 30);
    }
}
