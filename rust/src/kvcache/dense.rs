//! Uncompressed dense cache — the quality upper bound and the memory
//! baseline every ratio in the figures is relative to.

use crate::kvcache::CachePolicy;
use crate::swan::attention::{dense_attention, dense_attention_scratch};
use crate::swan::batch::AttentionScratch;

pub struct DenseCache {
    d: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    seen: usize,
}

impl DenseCache {
    pub fn new(d: usize) -> DenseCache {
        DenseCache { d, k: Vec::new(), v: Vec::new(), seen: 0 }
    }
}

impl CachePolicy for DenseCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        debug_assert_eq!(k_hat.len(), self.d);
        self.k.extend_from_slice(k_hat);
        self.v.extend_from_slice(v_hat);
        self.seen += 1;
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        dense_attention(q_hat, &self.k, &self.v, k_cur, v_cur, self.d, out);
    }

    fn attend_with(
        &mut self,
        q_hat: &[f32],
        k_cur: &[f32],
        v_cur: &[f32],
        scratch: &mut AttentionScratch,
        out: &mut [f32],
    ) {
        dense_attention_scratch(
            q_hat,
            &self.k,
            &self.v,
            k_cur,
            v_cur,
            self.d,
            &mut scratch.scores,
            out,
        );
    }

    fn storage_bytes(&self) -> usize {
        2 * self.seen * self.d * 2 // k+v, f16 serving convention
    }

    fn retained_tokens(&self) -> usize {
        self.seen
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        "dense".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::test_support::run_policy;

    #[test]
    fn dense_is_exact() {
        let mut p = DenseCache::new(24);
        let (out, want) = run_policy(&mut p, 24, 20, 0);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn memory_grows_linearly() {
        let mut p = DenseCache::new(16);
        p.append(&vec![0.0; 16], &vec![0.0; 16]);
        let one = p.storage_bytes();
        p.append(&vec![0.0; 16], &vec![0.0; 16]);
        assert_eq!(p.storage_bytes(), 2 * one);
    }
}
