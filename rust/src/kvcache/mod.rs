//! Pluggable KV-cache compression policies.
//!
//! A [`CachePolicy`] instance manages one (layer, kv-head) cache of one
//! sequence, in the *rotated* space (every policy receives the same k̂/v̂
//! streams, so comparisons isolate the cache strategy itself).  SWAN is the
//! paper's method; the others are the baselines its related-work section
//! compares against:
//!
//! * [`dense::DenseCache`]        — uncompressed upper bound
//! * [`swan_policy::SwanCache`]   — hybrid winnowed cache (16/8-bit)
//! * [`h2o::H2OCache`]            — heavy-hitter token eviction (H2O)
//! * [`streaming::StreamingCache`]— attention sinks + recency window
//!   (StreamingLLM)
//! * [`kivi::KiviCache`]          — low-bit quantization with a dense
//!   residual window (KIVI-style)

pub mod dense;
pub mod h2o;
pub mod kivi;
pub mod streaming;
pub mod swan_policy;

pub use dense::DenseCache;
pub use h2o::H2OCache;
pub use kivi::KiviCache;
pub use streaming::StreamingCache;
pub use swan_policy::SwanCache;

use crate::sparse::StorageMode;
use crate::swan::batch::AttentionScratch;
use crate::swan::hybrid_cache::SwanParams;

/// One (layer, kv-head) cache of one sequence.
///
/// `attend` computes softmax(q̂·K/√d)·V over everything the policy has
/// retained **plus** the current token's (k̂_cur, v̂_cur), and may update
/// internal statistics (H2O tracks cumulative attention mass).
pub trait CachePolicy: Send {
    /// Append one token's rotated key/value to the cache.
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]);

    /// Attention for one query over the retained cache + current token.
    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]);

    /// [`CachePolicy::attend`] through a caller-provided
    /// [`AttentionScratch`] (the batched decode path hands every task its
    /// worker's scratch).  Policies whose kernel accepts an external score
    /// buffer override this to run allocation-free; the default ignores
    /// the scratch and must stay result-identical to `attend`.
    fn attend_with(
        &mut self,
        q_hat: &[f32],
        k_cur: &[f32],
        v_cur: &[f32],
        scratch: &mut AttentionScratch,
        out: &mut [f32],
    ) {
        let _ = scratch;
        self.attend(q_hat, k_cur, v_cur, out);
    }

    /// Bulk-load an exact prefill history (flat [n, d] arrays, oldest
    /// first).  `mass` optionally carries the cumulative attention each
    /// position received during prefill — H2O seeds its heavy-hitter
    /// statistics from it; other policies ignore it.
    fn load_history(&mut self, k_flat: &[f32], v_flat: &[f32], d: usize, mass: Option<&[f32]>) {
        let _ = mass;
        let n = if d == 0 { 0 } else { k_flat.len() / d };
        for t in 0..n {
            self.append(&k_flat[t * d..(t + 1) * d], &v_flat[t * d..(t + 1) * d]);
        }
    }

    /// Bytes of the stored representation under serving accounting.
    fn storage_bytes(&self) -> usize;

    /// Tokens currently represented (retained) in the cache.
    fn retained_tokens(&self) -> usize;

    /// Tokens ever appended.
    fn seen_tokens(&self) -> usize;

    /// Downcast hook to the pool-backed cache — the prefix subsystem
    /// attaches/extracts shared blocks through it.  `None` for every
    /// non-paged policy.
    fn as_paged(&mut self) -> Option<&mut crate::pool::PagedSwanCache> {
        None
    }

    fn label(&self) -> String;
}

/// Which policy to instantiate (CLI / experiment configuration).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PolicyKind {
    Dense,
    /// SWAN with retention ratio, buffer tokens, storage mode.
    Swan { k_active: usize, buffer: usize, mode: StorageMode },
    /// SWAN with asymmetric key/value retention (Table 2).
    SwanAsym { k_keys: usize, k_vals: usize, buffer: usize, mode: StorageMode },
    /// H2O with a token budget (heavy hitters + recent).
    H2O { budget: usize, recent: usize },
    /// StreamingLLM with sink + window token counts.
    Streaming { sinks: usize, window: usize },
    /// KIVI-style quantization: bits per value, dense residual window.
    Kivi { bits: u8, residual: usize },
}

impl PolicyKind {
    pub fn build(self, d_h: usize) -> Box<dyn CachePolicy> {
        match self {
            PolicyKind::Dense => Box::new(DenseCache::new(d_h)),
            PolicyKind::Swan { k_active, buffer, mode } => Box::new(SwanCache::new(
                d_h,
                SwanParams::new(k_active, buffer, mode),
            )),
            PolicyKind::SwanAsym { k_keys, k_vals, buffer, mode } => {
                let mut p = SwanParams::new(k_keys, buffer, mode);
                p.k_active_vals = k_vals;
                Box::new(SwanCache::new(d_h, p))
            }
            PolicyKind::H2O { budget, recent } => Box::new(H2OCache::new(d_h, budget, recent)),
            PolicyKind::Streaming { sinks, window } => {
                Box::new(StreamingCache::new(d_h, sinks, window))
            }
            PolicyKind::Kivi { bits, residual } => Box::new(KiviCache::new(d_h, bits, residual)),
        }
    }

    pub fn label(&self) -> String {
        match self {
            PolicyKind::Dense => "dense".into(),
            PolicyKind::Swan { k_active, buffer, mode } => {
                format!("swan-{} k={k_active} bt={buffer}", mode.label())
            }
            PolicyKind::SwanAsym { k_keys, k_vals, buffer, .. } => {
                format!("swan-asym k_k={k_keys} k_v={k_vals} bt={buffer}")
            }
            PolicyKind::H2O { budget, recent } => format!("h2o b={budget} r={recent}"),
            PolicyKind::Streaming { sinks, window } => {
                format!("streaming s={sinks} w={window}")
            }
            PolicyKind::Kivi { bits, residual } => format!("kivi{bits} r={residual}"),
        }
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::util::Pcg64;

    /// Drive a policy through `n` random tokens, then attend with a random
    /// query; returns (output, dense reference output).
    pub fn run_policy(policy: &mut dyn CachePolicy, d: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut r = Pcg64::new(seed);
        let mut kflat = Vec::new();
        let mut vflat = Vec::new();
        for _ in 0..n {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            policy.append(&k, &v);
            kflat.extend_from_slice(&k);
            vflat.extend_from_slice(&v);
        }
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut out = vec![0.0; d];
        policy.attend(&q, &kc, &vc, &mut out);
        let mut dense = vec![0.0; d];
        crate::swan::attention::dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut dense);
        (out, dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_labels_unique() {
        let kinds = [
            PolicyKind::Dense,
            PolicyKind::Swan { k_active: 16, buffer: 64, mode: StorageMode::F16 },
            PolicyKind::Swan { k_active: 16, buffer: 64, mode: StorageMode::F8 },
            PolicyKind::H2O { budget: 64, recent: 16 },
            PolicyKind::Streaming { sinks: 4, window: 60 },
            PolicyKind::Kivi { bits: 4, residual: 32 },
        ];
        let labels: Vec<String> = kinds.iter().map(|k| k.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }

    #[test]
    fn build_constructs_every_kind() {
        for kind in [
            PolicyKind::Dense,
            PolicyKind::Swan { k_active: 8, buffer: 2, mode: StorageMode::F16 },
            PolicyKind::SwanAsym { k_keys: 8, k_vals: 4, buffer: 2, mode: StorageMode::F8 },
            PolicyKind::H2O { budget: 8, recent: 2 },
            PolicyKind::Streaming { sinks: 2, window: 6 },
            PolicyKind::Kivi { bits: 8, residual: 4 },
        ] {
            let mut p = kind.build(16);
            let (out, _) = test_support::run_policy(p.as_mut(), 16, 12, 1);
            assert!(out.iter().all(|x| x.is_finite()), "{}", kind.label());
            assert_eq!(p.seen_tokens(), 12);
        }
    }
}
