//! SWAN as a [`CachePolicy`] — a thin adapter over
//! [`crate::swan::HybridCache`] + the decompression-free attention kernel.

use crate::kvcache::CachePolicy;
use crate::swan::attention::swan_attention;
use crate::swan::batch::AttentionScratch;
use crate::swan::hybrid_cache::{HybridCache, SwanParams};

pub struct SwanCache {
    cache: HybridCache,
    seen: usize,
}

impl SwanCache {
    pub fn new(d_h: usize, params: SwanParams) -> SwanCache {
        SwanCache { cache: HybridCache::new(d_h, params), seen: 0 }
    }

    /// Runtime compression tuning (the paper's operational flexibility).
    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.cache.set_k_active(k_keys, k_vals);
    }

    pub fn inner(&self) -> &HybridCache {
        &self.cache
    }
}

impl CachePolicy for SwanCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        self.cache.append(k_hat, v_hat);
        self.seen += 1;
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        swan_attention(q_hat, &self.cache, k_cur, v_cur, out);
    }

    fn attend_with(
        &mut self,
        q_hat: &[f32],
        k_cur: &[f32],
        v_cur: &[f32],
        scratch: &mut AttentionScratch,
        out: &mut [f32],
    ) {
        self.cache.attend(q_hat, k_cur, v_cur, &mut scratch.scores, out);
    }

    /// Bulk path: winnow the head of the history straight into the sparse
    /// stores and copy only the tail into the ring
    /// ([`HybridCache::load_prefill`]) — bit-identical to the default
    /// per-token appends, without paying the eviction path n - buffer
    /// times.
    fn load_history(&mut self, k_flat: &[f32], v_flat: &[f32], d: usize, _mass: Option<&[f32]>) {
        if d == 0 {
            return;
        }
        self.cache.load_prefill(k_flat, v_flat);
        self.seen += k_flat.len() / d;
    }

    fn storage_bytes(&self) -> usize {
        self.cache.storage_bytes()
    }

    fn retained_tokens(&self) -> usize {
        self.cache.len()
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        format!(
            "swan-{} k={}/{} bt={}",
            self.cache.params.mode.label(),
            self.cache.params.k_active_keys,
            self.cache.params.k_active_vals,
            self.cache.params.buffer
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::test_support::run_policy;
    use crate::sparse::StorageMode;

    #[test]
    fn full_retention_matches_dense() {
        let d = 16;
        let mut p = SwanCache::new(d, SwanParams::new(d, 4, StorageMode::F32));
        let (out, want) = run_policy(&mut p, d, 15, 3);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn keeps_every_token() {
        // unlike eviction baselines, SWAN retains (partial) info for all
        let d = 16;
        let mut p = SwanCache::new(d, SwanParams::new(4, 2, StorageMode::F16));
        let (_, _) = run_policy(&mut p, d, 40, 4);
        assert_eq!(p.retained_tokens(), 40);
    }

    #[test]
    fn memory_below_dense_at_low_k() {
        let d = 64;
        let mut p = SwanCache::new(d, SwanParams::new(16, 8, StorageMode::F16));
        let mut dense = crate::kvcache::DenseCache::new(d);
        run_policy(&mut p, d, 100, 5);
        run_policy(&mut dense, d, 100, 5);
        assert!(p.storage_bytes() < dense.storage_bytes());
    }

    #[test]
    fn bulk_load_history_matches_per_token_appends() {
        let d = 16;
        let mut r = crate::util::Pcg64::new(11);
        let n = 23;
        let kflat = r.normal_vec(n * d);
        let vflat = r.normal_vec(n * d);
        let mut bulk = SwanCache::new(d, SwanParams::new(6, 4, StorageMode::F16));
        let mut serial = SwanCache::new(d, SwanParams::new(6, 4, StorageMode::F16));
        bulk.load_history(&kflat, &vflat, d, None);
        for t in 0..n {
            serial.append(&kflat[t * d..(t + 1) * d], &vflat[t * d..(t + 1) * d]);
        }
        assert_eq!(bulk.seen_tokens(), serial.seen_tokens());
        assert_eq!(bulk.retained_tokens(), serial.retained_tokens());
        assert_eq!(bulk.storage_bytes(), serial.storage_bytes());
        // attention over both caches must be bit-identical
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        bulk.attend(&q, &kc, &vc, &mut a);
        serial.attend(&q, &kc, &vc, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn approximation_bounded_at_half_retention() {
        // sanity: at k=d/2 the attention output should stay close to dense
        let d = 64;
        let mut p = SwanCache::new(d, SwanParams::new(32, 8, StorageMode::F16));
        let (out, want) = run_policy(&mut p, d, 60, 6);
        let err: f32 = out.iter().zip(&want).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt();
        let norm: f32 = want.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(err / norm < 0.5, "rel err {}", err / norm);
    }
}
