//! StreamingLLM baseline (Xiao et al., 2024): keep the first `sinks`
//! tokens (attention sinks) plus a sliding window of the most recent
//! `window` tokens; everything in between is discarded.

use crate::kvcache::CachePolicy;
use crate::tensor::ops::{dot, softmax_inplace};

pub struct StreamingCache {
    d: usize,
    sinks: usize,
    window: usize,
    sink_k: Vec<f32>,
    sink_v: Vec<f32>,
    sink_len: usize,
    win_k: Vec<f32>,
    win_v: Vec<f32>,
    win_len: usize,
    seen: usize,
}

impl StreamingCache {
    pub fn new(d: usize, sinks: usize, window: usize) -> StreamingCache {
        StreamingCache {
            d,
            sinks,
            window: window.max(1),
            sink_k: Vec::new(),
            sink_v: Vec::new(),
            sink_len: 0,
            win_k: Vec::new(),
            win_v: Vec::new(),
            win_len: 0,
            seen: 0,
        }
    }
}

impl CachePolicy for StreamingCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        if self.sink_len < self.sinks {
            self.sink_k.extend_from_slice(k_hat);
            self.sink_v.extend_from_slice(v_hat);
            self.sink_len += 1;
        } else {
            self.win_k.extend_from_slice(k_hat);
            self.win_v.extend_from_slice(v_hat);
            self.win_len += 1;
            if self.win_len > self.window {
                self.win_k.drain(..self.d);
                self.win_v.drain(..self.d);
                self.win_len -= 1;
            }
        }
        self.seen += 1;
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        let d = self.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n = self.sink_len + self.win_len;
        let mut scores = Vec::with_capacity(n + 1);
        for t in 0..self.sink_len {
            scores.push(dot(&self.sink_k[t * d..(t + 1) * d], q_hat) * scale);
        }
        for t in 0..self.win_len {
            scores.push(dot(&self.win_k[t * d..(t + 1) * d], q_hat) * scale);
        }
        scores.push(dot(k_cur, q_hat) * scale);
        softmax_inplace(&mut scores);
        out.iter_mut().for_each(|o| *o = 0.0);
        for t in 0..self.sink_len {
            let w = scores[t];
            for (o, x) in out.iter_mut().zip(&self.sink_v[t * d..(t + 1) * d]) {
                *o += w * x;
            }
        }
        for t in 0..self.win_len {
            let w = scores[self.sink_len + t];
            for (o, x) in out.iter_mut().zip(&self.win_v[t * d..(t + 1) * d]) {
                *o += w * x;
            }
        }
        for (o, x) in out.iter_mut().zip(v_cur) {
            *o += scores[n] * x;
        }
    }

    fn storage_bytes(&self) -> usize {
        2 * (self.sink_len + self.win_len) * self.d * 2
    }

    fn retained_tokens(&self) -> usize {
        self.sink_len + self.win_len
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        format!("streaming s={} w={}", self.sinks, self.window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::test_support::run_policy;
    use crate::util::Pcg64;

    #[test]
    fn within_capacity_is_exact() {
        let mut p = StreamingCache::new(16, 4, 60);
        let (out, want) = run_policy(&mut p, 16, 20, 0);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn middle_tokens_are_dropped() {
        let d = 8;
        let mut p = StreamingCache::new(d, 2, 3);
        let mut r = Pcg64::new(1);
        for i in 0..10 {
            let mut k = r.normal_vec(d);
            k[0] = 100.0 + i as f32;
            p.append(&k, &r.normal_vec(d));
        }
        assert_eq!(p.retained_tokens(), 5);
        // sinks = tokens 0,1; window = 7,8,9
        let mut tags = Vec::new();
        for t in 0..p.sink_len {
            tags.push(p.sink_k[t * d] - 100.0);
        }
        for t in 0..p.win_len {
            tags.push(p.win_k[t * d] - 100.0);
        }
        assert_eq!(tags, vec![0.0, 1.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn capacity_is_bounded() {
        let mut p = StreamingCache::new(4, 2, 8);
        let mut r = Pcg64::new(2);
        for _ in 0..100 {
            p.append(&r.normal_vec(4), &r.normal_vec(4));
        }
        assert_eq!(p.retained_tokens(), 10);
        assert_eq!(p.storage_bytes(), 2 * 10 * 4 * 2);
    }
}
