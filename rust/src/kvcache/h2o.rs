//! H2O-style heavy-hitter eviction baseline (Zhang et al., 2023).
//!
//! Keeps a fixed token budget: the `recent` most recent tokens always stay;
//! beyond that, the tokens with the highest *cumulative attention mass*
//! (observed across past `attend` calls) survive and the lightest hitter is
//! evicted.  Evicted tokens are gone forever — the irreversible information
//! loss the paper contrasts SWAN against.

use crate::kvcache::CachePolicy;
use crate::tensor::ops::{dot, softmax_inplace};

struct Entry {
    k: Vec<f32>,
    v: Vec<f32>,
    /// cumulative attention mass this token has received
    mass: f32,
    /// arrival index (for the recency window)
    arrival: usize,
}

pub struct H2OCache {
    d: usize,
    budget: usize,
    recent: usize,
    entries: Vec<Entry>,
    seen: usize,
}

impl H2OCache {
    pub fn new(d: usize, budget: usize, recent: usize) -> H2OCache {
        assert!(recent <= budget, "recency window must fit in budget");
        H2OCache { d, budget: budget.max(1), recent, entries: Vec::new(), seen: 0 }
    }

    fn evict_if_needed(&mut self) {
        while self.entries.len() > self.budget {
            // candidates: everything outside the recency window
            let cutoff = self.seen.saturating_sub(self.recent);
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| e.arrival < cutoff)
                .min_by(|(_, a), (_, b)| a.mass.partial_cmp(&b.mass).unwrap())
                .map(|(i, _)| i)
                // all inside the window (tiny budget): drop the oldest
                .unwrap_or(0);
            self.entries.remove(victim);
        }
    }
}

impl CachePolicy for H2OCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        self.entries.push(Entry {
            // lint: allow(hot_alloc, "H2O is a baseline comparator that stores owned rows by design; not the SWAN serving path")
            k: k_hat.to_vec(),
            // lint: allow(hot_alloc, "see k above — baseline stores owned rows")
            v: v_hat.to_vec(),
            mass: 0.0,
            arrival: self.seen,
        });
        self.seen += 1;
        self.evict_if_needed();
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        let d = self.d;
        let scale = 1.0 / (d as f32).sqrt();
        let n = self.entries.len();
        let mut scores: Vec<f32> = self
            .entries
            .iter()
            .map(|e| dot(&e.k, q_hat) * scale)
            .collect();
        scores.push(dot(k_cur, q_hat) * scale);
        softmax_inplace(&mut scores);
        out.iter_mut().for_each(|o| *o = 0.0);
        for (i, e) in self.entries.iter_mut().enumerate() {
            let w = scores[i];
            e.mass += w; // heavy-hitter statistic
            for (o, x) in out.iter_mut().zip(&e.v) {
                *o += w * x;
            }
        }
        for (o, x) in out.iter_mut().zip(v_cur) {
            *o += scores[n] * x;
        }
    }

    fn load_history(&mut self, k_flat: &[f32], v_flat: &[f32], d: usize, mass: Option<&[f32]>) {
        let n = if d == 0 { 0 } else { k_flat.len() / d };
        for t in 0..n {
            self.entries.push(Entry {
                k: k_flat[t * d..(t + 1) * d].to_vec(),
                v: v_flat[t * d..(t + 1) * d].to_vec(),
                // seed heavy-hitter stats from the prefill attention mass
                mass: mass.map(|m| m[t]).unwrap_or(0.0),
                arrival: self.seen,
            });
            self.seen += 1;
            self.evict_if_needed();
        }
    }

    fn storage_bytes(&self) -> usize {
        // k+v f16 + 4-byte mass counter per retained token
        self.entries.len() * (2 * self.d * 2 + 4)
    }

    fn retained_tokens(&self) -> usize {
        self.entries.len()
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        format!("h2o b={} r={}", self.budget, self.recent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::test_support::run_policy;
    use crate::util::Pcg64;

    #[test]
    fn within_budget_is_exact() {
        let mut p = H2OCache::new(16, 64, 8);
        let (out, want) = run_policy(&mut p, 16, 20, 0);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn budget_is_enforced() {
        let mut p = H2OCache::new(8, 10, 4);
        let mut r = Pcg64::new(1);
        for _ in 0..50 {
            let k = r.normal_vec(8);
            let v = r.normal_vec(8);
            p.append(&k, &v);
            // interleave attends so masses accumulate
            let q = r.normal_vec(8);
            let mut out = vec![0.0; 8];
            let kc = r.normal_vec(8);
            let vc = r.normal_vec(8);
            p.attend(&q, &kc, &vc, &mut out);
        }
        assert_eq!(p.retained_tokens(), 10);
        assert_eq!(p.seen_tokens(), 50);
    }

    #[test]
    fn recent_tokens_survive() {
        let mut p = H2OCache::new(8, 6, 4);
        let mut r = Pcg64::new(2);
        for i in 0..30 {
            let mut k = r.normal_vec(8);
            k[0] = i as f32; // tag
            p.append(&k, &r.normal_vec(8));
        }
        // the 4 most recent tags must be present
        let tags: Vec<f32> = p.entries.iter().map(|e| e.k[0]).collect();
        for want in 26..30 {
            assert!(tags.contains(&(want as f32)), "missing {want} in {tags:?}");
        }
    }

    #[test]
    fn heavy_hitters_survive() {
        // one key aligned with every future query accumulates mass and must
        // outlive orthogonal keys
        let d = 8;
        let mut p = H2OCache::new(d, 5, 1);
        let mut hot = vec![0.0; d];
        hot[0] = 5.0;
        p.append(&hot, &vec![1.0; d]);
        let mut r = Pcg64::new(3);
        for _ in 0..40 {
            let mut k = r.normal_vec(d);
            k[0] = 0.0; // orthogonal to the hot direction
            p.append(&k, &r.normal_vec(d));
            let mut q = vec![0.0; d];
            q[0] = 3.0; // queries keep hitting the hot key
            let mut out = vec![0.0; d];
            let kc = vec![0.0; d];
            let vc = vec![0.0; d];
            p.attend(&q, &kc, &vc, &mut out);
        }
        assert!(p.entries.iter().any(|e| e.k[0] == 5.0), "heavy hitter evicted");
    }
}
