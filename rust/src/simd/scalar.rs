//! Portable scalar kernels — the reference implementations every other
//! path is property-tested against.
//!
//! These are the unrolled loops that previously lived inline in
//! `tensor::ops` and `sparse::store`, moved here verbatim so the scalar
//! path of the dispatch layer is bit-identical to the pre-dispatch
//! behaviour (goldens and determinism tests carry over unchanged).

/// Dot product, manually unrolled 4-wide.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// y[n] = x[m] @ a[m,n] (row-major `a`), with the zero-row skip.
pub fn vecmat(x: &[f32], a: &[f32], m: usize, n: usize, y: &mut [f32]) {
    y.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..m {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &a[i * n..(i + 1) * n];
        for (yj, aij) in y.iter_mut().zip(row) {
            *yj += xi * aij;
        }
    }
}

/// out += w * row.
#[inline]
pub fn axpy(w: f32, row: &[f32], out: &mut [f32]) {
    for (o, x) in out.iter_mut().zip(row) {
        *o += w * x;
    }
}

/// Maximum element (`NEG_INFINITY` when empty).
#[inline]
pub fn max_fold(x: &[f32]) -> f32 {
    x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
}

/// exp/sum/scale phase of softmax; `m` is the (finite) maximum.
pub fn softmax_with_max(x: &mut [f32], m: f32) {
    let mut z = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    x.iter_mut().for_each(|v| *v *= inv);
}

/// RMSNorm: out = x * rsqrt(mean(x^2) + eps) * w.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = dot(x, x) / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * r * wi;
    }
}

/// CSR scores with fused running max: `out.push(row · q * scale)` per row,
/// returning the max pushed score.  Contiguous walk; the inner gather uses
/// unchecked indexing (indices are validated at insertion: every
/// `idx < d_h <= q.len()`) with 2-way unrolling to hide gather latency.
pub fn csr_scores_max_into(
    vals: &[f32],
    idx: &[u16],
    offsets: &[u32],
    scale: f32,
    q: &[f32],
    out: &mut Vec<f32>,
) -> f32 {
    let rows = offsets.len() - 1;
    out.reserve(rows);
    let mut m = f32::NEG_INFINITY;
    for r in 0..rows {
        let lo = offsets[r] as usize;
        let hi = offsets[r + 1] as usize;
        let vals = &vals[lo..hi];
        let idx = &idx[lo..hi];
        let n = vals.len();
        let mut s0 = 0.0f32;
        let mut s1 = 0.0f32;
        let pairs = n / 2;
        // SAFETY: idx entries are < d_h (checked at push), q.len() >= d_h
        // (debug-asserted by callers), and j bounds follow from `pairs`.
        unsafe {
            for p in 0..pairs {
                let j = 2 * p;
                s0 += vals.get_unchecked(j) * q.get_unchecked(*idx.get_unchecked(j) as usize);
                s1 += vals.get_unchecked(j + 1)
                    * q.get_unchecked(*idx.get_unchecked(j + 1) as usize);
            }
            if n % 2 == 1 {
                s0 += vals.get_unchecked(n - 1)
                    * q.get_unchecked(*idx.get_unchecked(n - 1) as usize);
            }
        }
        let s = (s0 + s1) * scale;
        m = m.max(s);
        out.push(s);
    }
    m
}

/// Weighted scatter-add of all rows: `out[idx[r,j]] += w[r] * vals[r,j]`.
pub fn csr_axpy_all(vals: &[f32], idx: &[u16], offsets: &[u32], w: &[f32], out: &mut [f32]) {
    let rows = offsets.len() - 1;
    for r in 0..rows {
        let lo = offsets[r] as usize;
        let hi = offsets[r + 1] as usize;
        let wr = w[r];
        // SAFETY: idx entries < d_h <= out.len() (validated at push).
        unsafe {
            for j in lo..hi {
                let i = *idx.get_unchecked(j) as usize;
                *out.get_unchecked_mut(i) += wr * vals.get_unchecked(j);
            }
        }
    }
}
