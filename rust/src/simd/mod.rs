//! Runtime-dispatched SIMD kernel layer.
//!
//! The decompression-free CSR walk and the dense primitives behind it are
//! the serving hot path (no decompression step means the kernel *is* the
//! request latency).  This module provides every hot primitive in two
//! implementations:
//!
//! * [`scalar`] — the portable reference (the unrolled loops that used to
//!   live inline in `tensor::ops` / `sparse::store`), and
//! * [`avx2`] — 8-lane AVX2+FMA paths (`vfmadd` dots, `vgatherdps` CSR
//!   score gathers), compiled on x86_64 and selected only when the CPU
//!   reports the features at runtime.
//!
//! Selection happens **once**: [`active`] detects the best path on first
//! use (honouring the `SWAN_KERNELS` env var), and the CLI's `--kernels
//! auto|scalar|avx2` flag pins it at startup via [`init_from_name`].  All
//! downstream layers — `tensor::ops`, `SparseStore`, the attention
//! kernels, batch decode, shard engines — go through the same dispatch,
//! so a single switch flips the whole stack.
//!
//! # Numerics contract
//!
//! Kernel paths may differ in floating-point *accumulation order* (8-lane
//! trees vs 2/4-way unrolls), so cross-path results agree to tight
//! tolerance, not bit-for-bit — `tests/prop_invariants.rs` locks the
//! tolerance down for every primitive.  Within one path, results are
//! deterministic: the serial≡parallel guarantees of `swan::batch` and the
//! prefill fan-out are unaffected because every worker dispatches to the
//! same active kernel.  `softmax` is the exception that stays bit-exact
//! across paths: `max` is order-insensitive and the exp/sum loop is
//! shared, so only provably-identical element-wise ops differ.

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

use std::sync::atomic::{AtomicU8, Ordering};

/// Which implementation a [`Kernels`] instance dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable unrolled loops (every host).
    Scalar,
    /// AVX2 + FMA, 8 x f32 lanes (x86_64 hosts that report the features).
    Avx2,
}

/// A selected kernel implementation.  The inner kind is private: `Avx2`
/// instances can only be obtained through the feature-checked
/// constructors, which is what makes the `unsafe` target-feature calls in
/// the dispatch methods sound.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Kernels(KernelKind);

/// Dispatch to the scalar or (feature-checked) AVX2 implementation.  On
/// non-x86_64 builds the Avx2 arm falls back to scalar; such an instance
/// cannot be constructed there, the arm just keeps the match total.
macro_rules! dispatch {
    ($kind:expr, $scalar:expr, $avx2:expr) => {
        match $kind {
            KernelKind::Scalar => $scalar,
            #[cfg(target_arch = "x86_64")]
            KernelKind::Avx2 => $avx2,
            #[cfg(not(target_arch = "x86_64"))]
            KernelKind::Avx2 => $scalar,
        }
    };
}

impl Kernels {
    /// The portable reference path (always available).
    pub const fn scalar() -> Kernels {
        Kernels(KernelKind::Scalar)
    }

    /// The AVX2+FMA path, if this host supports it.
    pub fn avx2() -> Option<Kernels> {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return Some(Kernels(KernelKind::Avx2));
            }
        }
        None
    }

    /// Every path this host can run (scalar first).
    pub fn available() -> Vec<Kernels> {
        let mut v = vec![Kernels::scalar()];
        if let Some(k) = Kernels::avx2() {
            v.push(k);
        }
        v
    }

    /// The best path the hardware supports, ignoring overrides.
    fn hw_best() -> Kernels {
        Kernels::avx2().unwrap_or(Kernels::scalar())
    }

    /// Best path for this host, honouring a `SWAN_KERNELS` override
    /// (`scalar`, `avx2` or `auto`).  Unlike `--kernels`, an env override
    /// cannot abort startup, so an unsupported `avx2` or a typo'd value
    /// falls back to hardware detection — with a warning, never silently.
    pub fn detect() -> Kernels {
        match std::env::var("SWAN_KERNELS").as_deref() {
            Ok("scalar") => Kernels::scalar(),
            Ok("avx2") => Kernels::avx2().unwrap_or_else(|| {
                log::warn!("SWAN_KERNELS=avx2 but this host lacks AVX2+FMA; using scalar");
                Kernels::scalar()
            }),
            Ok("auto") | Ok("") | Err(_) => Kernels::hw_best(),
            Ok(other) => {
                log::warn!("SWAN_KERNELS='{other}' not recognised (auto|scalar|avx2); auto-detecting");
                Kernels::hw_best()
            }
        }
    }

    /// Parse a `--kernels` value.  `auto` resolves through
    /// [`Kernels::detect`] (so a `SWAN_KERNELS` env override survives the
    /// CLI's and `Engine::new`'s default-`auto` re-pin); `avx2` errors on
    /// hosts without the features (rather than silently degrading, so a
    /// pinned production config fails loudly).
    pub fn from_name(name: &str) -> anyhow::Result<Kernels> {
        match name {
            "scalar" => Ok(Kernels::scalar()),
            "avx2" => Kernels::avx2().ok_or_else(|| {
                anyhow::anyhow!("avx2 kernels requested but this host lacks AVX2+FMA")
            }),
            "auto" | "" => Ok(Kernels::detect()),
            other => anyhow::bail!("--kernels must be auto, scalar or avx2, got '{other}'"),
        }
    }

    pub fn kind(&self) -> KernelKind {
        self.0
    }

    pub fn label(&self) -> &'static str {
        match self.0 {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
        }
    }

    /// Preferred f32 lane width: the multiple [`crate::sparse::SparseStore`]
    /// rows are padded to so the CSR gather loop runs with no scalar tail.
    pub fn lanes(&self) -> usize {
        match self.0 {
            KernelKind::Scalar => 1,
            KernelKind::Avx2 => 8,
        }
    }

    // ------------------------------------------------------------------
    // dense primitives
    // ------------------------------------------------------------------

    /// Dot product.
    #[inline]
    pub fn dot(&self, a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        dispatch!(self.0, scalar::dot(a, b), unsafe { avx2::dot(a, b) })
    }

    /// y[n] = x[m] @ a[m,n] (row-major `a`).
    #[inline]
    pub fn vecmat(&self, x: &[f32], a: &[f32], m: usize, n: usize, y: &mut [f32]) {
        debug_assert_eq!(a.len(), m * n);
        debug_assert_eq!(x.len(), m);
        debug_assert_eq!(y.len(), n);
        dispatch!(self.0, scalar::vecmat(x, a, m, n, y), unsafe {
            avx2::vecmat(x, a, m, n, y)
        })
    }

    /// out += w * row.
    #[inline]
    pub fn axpy(&self, w: f32, row: &[f32], out: &mut [f32]) {
        debug_assert_eq!(row.len(), out.len());
        dispatch!(self.0, scalar::axpy(w, row, out), unsafe { avx2::axpy(w, row, out) })
    }

    /// Maximum element (`NEG_INFINITY` for an empty slice).
    #[inline]
    pub fn max_fold(&self, x: &[f32]) -> f32 {
        dispatch!(self.0, scalar::max_fold(x), unsafe { avx2::max_fold(x) })
    }

    /// In-place numerically-stable softmax.
    #[inline]
    pub fn softmax_inplace(&self, x: &mut [f32]) {
        let m = self.max_fold(x);
        self.softmax_inplace_with_max(x, m);
    }

    /// Softmax when the caller already knows `max(x)` — the fused
    /// scores+running-max CSR walk feeds this so the softmax drops its
    /// max pass.  `m` MUST equal the true maximum (the `-inf`-masked
    /// uniform fallback is keyed off it).
    #[inline]
    pub fn softmax_inplace_with_max(&self, x: &mut [f32], m: f32) {
        if !m.is_finite() {
            // all -inf (or empty): define as uniform to avoid NaN —
            // callers mask at least one live slot in practice
            let u = 1.0 / x.len() as f32;
            x.iter_mut().for_each(|v| *v = u);
            return;
        }
        dispatch!(self.0, scalar::softmax_with_max(x, m), unsafe {
            avx2::softmax_with_max(x, m)
        })
    }

    /// RMSNorm: out = x * rsqrt(mean(x^2) + eps) * w.
    #[inline]
    pub fn rmsnorm(&self, x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
        debug_assert_eq!(x.len(), w.len());
        debug_assert_eq!(x.len(), out.len());
        dispatch!(self.0, scalar::rmsnorm(x, w, eps, out), unsafe {
            avx2::rmsnorm(x, w, eps, out)
        })
    }

    // ------------------------------------------------------------------
    // sparse CSR walks (the decompression-free hot path)
    // ------------------------------------------------------------------
    //
    // Layout contract (shared with `SparseStore`): `offsets.len() == rows
    // + 1`, row r spans `vals[offsets[r]..offsets[r+1]]` in lock-step with
    // `idx`, and every index satisfies `idx[j] < q.len()` (resp.
    // `out.len()`) — validated at insertion, which is what makes the
    // unchecked gathers sound.  Zero-padded sentinel entries (value 0.0,
    // index 0) contribute nothing to either walk.

    /// Scores for all rows: `out.push(sum_j vals[r,j] * q[idx[r,j]] * scale)`.
    #[inline]
    pub fn csr_scores_into(
        &self,
        vals: &[f32],
        idx: &[u16],
        offsets: &[u32],
        scale: f32,
        q: &[f32],
        out: &mut Vec<f32>,
    ) {
        self.csr_scores_max_into(vals, idx, offsets, scale, q, out);
    }

    /// Fused scores + running max: as [`Kernels::csr_scores_into`], also
    /// returning the maximum pushed score (`NEG_INFINITY` when there are
    /// no rows) so the downstream softmax can skip its max pass.
    #[inline]
    pub fn csr_scores_max_into(
        &self,
        vals: &[f32],
        idx: &[u16],
        offsets: &[u32],
        scale: f32,
        q: &[f32],
        out: &mut Vec<f32>,
    ) -> f32 {
        dispatch!(
            self.0,
            scalar::csr_scores_max_into(vals, idx, offsets, scale, q, out),
            unsafe { avx2::csr_scores_max_into(vals, idx, offsets, scale, q, out) }
        )
    }

    /// Weighted scatter-add of all rows: `out[idx[r,j]] += w[r] * vals[r,j]`.
    #[inline]
    pub fn csr_axpy_all(
        &self,
        vals: &[f32],
        idx: &[u16],
        offsets: &[u32],
        w: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(w.len(), offsets.len() - 1);
        dispatch!(self.0, scalar::csr_axpy_all(vals, idx, offsets, w, out), unsafe {
            avx2::csr_axpy_all(vals, idx, offsets, w, out)
        })
    }
}

// ----------------------------------------------------------------------
// process-wide selection
// ----------------------------------------------------------------------

const CODE_UNSET: u8 = 0;
const CODE_SCALAR: u8 = 1;
const CODE_AVX2: u8 = 2;

static ACTIVE: AtomicU8 = AtomicU8::new(CODE_UNSET);

/// The process-wide active kernel set.  First use runs [`Kernels::detect`]
/// and caches the result; [`set_active`] / [`init_from_name`] override it
/// (the CLI does this once at startup).
#[inline]
pub fn active() -> Kernels {
    match ACTIVE.load(Ordering::Relaxed) {
        CODE_SCALAR => Kernels(KernelKind::Scalar),
        CODE_AVX2 => Kernels(KernelKind::Avx2),
        _ => {
            let k = Kernels::detect();
            set_active(k);
            k
        }
    }
}

/// Pin the process-wide kernel set.  Safe at any time (an atomic swap);
/// in-flight attention calls finish on the path they started with.
pub fn set_active(k: Kernels) {
    let code = match k.kind() {
        KernelKind::Scalar => CODE_SCALAR,
        KernelKind::Avx2 => CODE_AVX2,
    };
    ACTIVE.store(code, Ordering::Relaxed);
}

/// Parse a `--kernels` value and pin the process-wide selection to it.
pub fn init_from_name(name: &str) -> anyhow::Result<Kernels> {
    let k = Kernels::from_name(name)?;
    set_active(k);
    Ok(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol * (1.0 + b.abs())
    }

    #[test]
    fn scalar_always_available_and_labelled() {
        let ks = Kernels::available();
        assert_eq!(ks[0], Kernels::scalar());
        assert_eq!(ks[0].label(), "scalar");
        assert_eq!(ks[0].lanes(), 1);
        for k in &ks[1..] {
            assert_eq!(k.label(), "avx2");
            assert_eq!(k.lanes(), 8);
        }
    }

    #[test]
    fn from_name_parses_and_rejects() {
        assert_eq!(Kernels::from_name("scalar").unwrap(), Kernels::scalar());
        assert!(Kernels::from_name("auto").is_ok());
        assert!(Kernels::from_name("neon").is_err());
        match Kernels::avx2() {
            Some(k) => assert_eq!(Kernels::from_name("avx2").unwrap(), k),
            None => assert!(Kernels::from_name("avx2").is_err()),
        }
    }

    /// The global selection resolves to something this host can run.
    /// (Flipping it is covered in `tests/prop_invariants.rs`, a separate
    /// process — lib tests run concurrently and some assert exact
    /// equality between two dispatched calls, so none may flip the
    /// global mid-run.)
    #[test]
    fn active_resolves_to_an_available_path() {
        let k = active();
        assert!(Kernels::available().contains(&k));
        set_active(k); // idempotent re-pin
        assert_eq!(active(), k);
    }

    /// Every available path agrees with scalar on every primitive (the
    /// exhaustive sweep lives in tests/prop_invariants.rs; this is the
    /// in-module smoke check).
    #[test]
    fn paths_agree_on_dense_primitives() {
        let mut r = Pcg64::new(41);
        let sc = Kernels::scalar();
        for k in Kernels::available() {
            for n in [1usize, 7, 8, 9, 16, 33, 100] {
                let a = r.normal_vec(n);
                let b = r.normal_vec(n);
                assert!(close(k.dot(&a, &b), sc.dot(&a, &b), 1e-5), "dot n={n} {}", k.label());

                let mut x1 = a.clone();
                let mut x2 = a.clone();
                k.softmax_inplace(&mut x1);
                sc.softmax_inplace(&mut x2);
                // softmax is bit-exact across paths (shared exp/sum loop)
                assert_eq!(x1, x2, "softmax n={n} {}", k.label());

                let w = r.normal_vec(n);
                let mut o1 = vec![0.0; n];
                let mut o2 = vec![0.0; n];
                k.rmsnorm(&a, &w, 1e-5, &mut o1);
                sc.rmsnorm(&a, &w, 1e-5, &mut o2);
                for (p, q) in o1.iter().zip(&o2) {
                    assert!(close(*p, *q, 1e-5), "rmsnorm n={n} {}", k.label());
                }

                let mut y1 = b.clone();
                let mut y2 = b.clone();
                k.axpy(0.3, &a, &mut y1);
                sc.axpy(0.3, &a, &mut y2);
                for (p, q) in y1.iter().zip(&y2) {
                    assert!(close(*p, *q, 1e-5), "axpy n={n} {}", k.label());
                }
            }
            let (m, n) = (13, 19);
            let x = r.normal_vec(m);
            let a = r.normal_vec(m * n);
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            k.vecmat(&x, &a, m, n, &mut y1);
            sc.vecmat(&x, &a, m, n, &mut y2);
            for (p, q) in y1.iter().zip(&y2) {
                assert!(close(*p, *q, 1e-4), "vecmat {}", k.label());
            }
        }
    }

    #[test]
    fn max_fold_handles_empty_and_neg_inf() {
        for k in Kernels::available() {
            assert_eq!(k.max_fold(&[]), f32::NEG_INFINITY);
            assert_eq!(k.max_fold(&[f32::NEG_INFINITY; 11]), f32::NEG_INFINITY);
            let mut v = vec![f32::NEG_INFINITY; 10];
            v[7] = 2.5;
            assert_eq!(k.max_fold(&v), 2.5);
            let mut x = v.clone();
            k.softmax_inplace(&mut x);
            assert_eq!(x[7], 1.0);
            assert_eq!(x[0], 0.0);
        }
    }
}
