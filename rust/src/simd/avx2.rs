//! AVX2 + FMA kernels (x86_64).
//!
//! Every function is `#[target_feature]`-gated and therefore `unsafe fn`:
//! the dispatch layer ([`crate::simd::Kernels`]) only constructs the Avx2
//! kind after `is_x86_feature_detected!("avx2") && ("fma")`, which is the
//! soundness argument for every call site.
//!
//! Numerics: FMA contraction (`dot`, `axpy`) and 8-lane accumulation
//! trees mean reductions differ from the scalar path in rounding only
//! (property-tested tolerance in `tests/prop_invariants.rs`).  Ops whose
//! per-element arithmetic matches scalar exactly (softmax's scale phase,
//! rmsnorm's final multiply, the CSR scatter-add) stay bit-identical
//! given the same inputs.  `max` is order-insensitive, so `max_fold` is
//! exact (inputs here are finite or `-inf`, never NaN — `vmaxps` NaN
//! semantics don't apply).

#![allow(clippy::missing_safety_doc)] // one safety contract, stated at module level

use std::arch::x86_64::*;

/// Horizontal sum of 8 lanes.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}

/// Horizontal max of 8 lanes.
#[inline]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn hmax(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let m = _mm_max_ps(lo, hi);
    let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
    let m = _mm_max_ss(m, _mm_shuffle_ps::<1>(m, m));
    _mm_cvtss_f32(m)
}

/// Dot product: two 8-lane FMA accumulators (16 floats/iter).
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(pa.add(i + 8)),
            _mm256_loadu_ps(pb.add(i + 8)),
            acc1,
        );
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(pa.add(i)), _mm256_loadu_ps(pb.add(i)), acc0);
        i += 8;
    }
    let mut s = hsum(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += *pa.add(i) * *pb.add(i);
        i += 1;
    }
    s
}

/// out += w * row (8-lane FMA; per-element arithmetic identical to scalar).
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn axpy(w: f32, row: &[f32], out: &mut [f32]) {
    let n = row.len();
    let vw = _mm256_set1_ps(w);
    let pr = row.as_ptr();
    let po = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let o = _mm256_loadu_ps(po.add(i));
        _mm256_storeu_ps(po.add(i), _mm256_fmadd_ps(vw, _mm256_loadu_ps(pr.add(i)), o));
        i += 8;
    }
    while i < n {
        *po.add(i) += w * *pr.add(i);
        i += 1;
    }
}

/// y[n] = x[m] @ a[m,n]: zero y, then one 8-lane axpy per non-zero x row.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn vecmat(x: &[f32], a: &[f32], m: usize, n: usize, y: &mut [f32]) {
    y.iter_mut().for_each(|v| *v = 0.0);
    for i in 0..m {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        axpy(xi, &a[i * n..(i + 1) * n], y);
    }
}

/// Maximum element (`NEG_INFINITY` when empty).
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn max_fold(x: &[f32]) -> f32 {
    let n = x.len();
    let p = x.as_ptr();
    let mut m = f32::NEG_INFINITY;
    let mut i = 0usize;
    if n >= 8 {
        let mut vm = _mm256_loadu_ps(p);
        i = 8;
        while i + 8 <= n {
            vm = _mm256_max_ps(vm, _mm256_loadu_ps(p.add(i)));
            i += 8;
        }
        m = hmax(vm);
    }
    while i < n {
        m = m.max(*p.add(i));
        i += 1;
    }
    m
}

/// exp/sum/scale phase of softmax; `m` is the (finite) maximum.  The
/// exp+sum loop is scalar (shared arithmetic with the scalar path keeps
/// softmax bit-exact across kernels); only the final scale is 8-lane.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn softmax_with_max(x: &mut [f32], m: f32) {
    let mut z = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    let n = x.len();
    let p = x.as_mut_ptr();
    let vi = _mm256_set1_ps(inv);
    let mut i = 0usize;
    while i + 8 <= n {
        _mm256_storeu_ps(p.add(i), _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), vi));
        i += 8;
    }
    while i < n {
        *p.add(i) *= inv;
        i += 1;
    }
}

/// RMSNorm: out = (x * r) * w with the mean-square via the AVX2 dot.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = dot(x, x) / x.len() as f32;
    let r = 1.0 / (ms + eps).sqrt();
    let n = x.len();
    let vr = _mm256_set1_ps(r);
    let px = x.as_ptr();
    let pw = w.as_ptr();
    let po = out.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= n {
        let xr = _mm256_mul_ps(_mm256_loadu_ps(px.add(i)), vr);
        _mm256_storeu_ps(po.add(i), _mm256_mul_ps(xr, _mm256_loadu_ps(pw.add(i))));
        i += 8;
    }
    while i < n {
        *po.add(i) = *px.add(i) * r * *pw.add(i);
        i += 1;
    }
}

/// Fused CSR scores + running max.  The inner loop is the vectorized
/// gather walk: 8 u16 indices widen to i32 (`vpmovzxwd`), gather 8 query
/// lanes (`vgatherdps`), FMA against the stored values.  Lane-padded rows
/// (multiples of 8) run with no scalar tail — that layout is what
/// `SparseStore::with_lanes(8)` provides.
///
/// Safety (beyond target features): every `idx[j] < q.len()` — validated
/// by `SparseStore` at insertion time.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn csr_scores_max_into(
    vals: &[f32],
    idx: &[u16],
    offsets: &[u32],
    scale: f32,
    q: &[f32],
    out: &mut Vec<f32>,
) -> f32 {
    let rows = offsets.len() - 1;
    out.reserve(rows);
    let qp = q.as_ptr();
    let mut m = f32::NEG_INFINITY;
    for r in 0..rows {
        let lo = *offsets.get_unchecked(r) as usize;
        let hi = *offsets.get_unchecked(r + 1) as usize;
        let n = hi - lo;
        let vp = vals.as_ptr().add(lo);
        let ip = idx.as_ptr().add(lo);
        let mut acc = _mm256_setzero_ps();
        let mut j = 0usize;
        while j + 8 <= n {
            let raw = _mm_loadu_si128(ip.add(j) as *const __m128i);
            let idx32 = _mm256_cvtepu16_epi32(raw);
            let gathered = _mm256_i32gather_ps::<4>(qp, idx32);
            acc = _mm256_fmadd_ps(_mm256_loadu_ps(vp.add(j)), gathered, acc);
            j += 8;
        }
        let mut s = hsum(acc);
        while j < n {
            s += *vp.add(j) * *qp.add(*ip.add(j) as usize);
            j += 1;
        }
        let s = s * scale;
        m = m.max(s);
        out.push(s);
    }
    m
}

/// Weighted scatter-add of all rows.  AVX2 has no scatter instruction, so
/// the products are formed 8 lanes at a time and committed with scalar
/// read-modify-writes (bit-identical to the scalar walk: same per-element
/// multiply, same in-row commit order).
///
/// Safety (beyond target features): every `idx[j] < out.len()`.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn csr_axpy_all(vals: &[f32], idx: &[u16], offsets: &[u32], w: &[f32], out: &mut [f32]) {
    let rows = offsets.len() - 1;
    let mut buf = [0.0f32; 8];
    for r in 0..rows {
        let lo = *offsets.get_unchecked(r) as usize;
        let hi = *offsets.get_unchecked(r + 1) as usize;
        let n = hi - lo;
        let wr = *w.get_unchecked(r);
        let vw = _mm256_set1_ps(wr);
        let vp = vals.as_ptr().add(lo);
        let ip = idx.as_ptr().add(lo);
        let mut j = 0usize;
        while j + 8 <= n {
            _mm256_storeu_ps(buf.as_mut_ptr(), _mm256_mul_ps(vw, _mm256_loadu_ps(vp.add(j))));
            for (l, &p) in buf.iter().enumerate() {
                let i = *ip.add(j + l) as usize;
                *out.get_unchecked_mut(i) += p;
            }
            j += 8;
        }
        while j < n {
            let i = *ip.add(j) as usize;
            *out.get_unchecked_mut(i) += wr * *vp.add(j);
            j += 1;
        }
    }
}
