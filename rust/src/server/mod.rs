//! TCP front-end: a line-oriented protocol over the serving engine.
//!
//! Protocol (one command per line):
//!   GEN <max_new_tokens> <prompt text...>   -> "OK <id> <text>" + stats line
//!   SET k_active <n>                        -> "OK"
//!   STATS                                   -> metrics snapshot, "." line
//!   PING                                    -> "PONG"
//!   QUIT                                    -> closes the connection
//!
//! The engine runs on a dedicated thread; connections are handled by a
//! small thread pool and communicate via channels (tokio is unavailable
//! offline — std threads keep the request path dependency-free).

pub mod client;
pub mod proto;
pub mod tcp;

pub use tcp::serve;
