//! TCP front-end: a line-oriented protocol over the shard router.
//!
//! Protocol (one command per line):
//!   GEN <max_new_tokens> <prompt text...>   -> "OK <id> <text>" + stats line
//!   SET k_active <n>                        -> "OK" (fleet-wide: every shard)
//!   SET balance <policy>                    -> "OK" (swap placement live)
//!   STATS                                   -> fleet + per-shard view, "." line
//!   PING                                    -> "PONG"
//!   QUIT                                    -> closes the connection
//! Malformed lines answer `ERR <code> <message>` and keep the connection.
//!
//! Each shard's engine runs on its own thread behind
//! [`crate::shard::Router`]; connection threads place `GEN` through the
//! balance policy and fan admin commands out to every shard (tokio is
//! unavailable offline — std threads keep the request path
//! dependency-free).

pub mod client;
pub mod proto;
pub mod tcp;

pub use tcp::serve;
