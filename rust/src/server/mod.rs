//! TCP front-end: a line-oriented protocol over the shard router.
//!
//! Protocol v2 (one command per line):
//!   GEN <max_new> <prompt...>               -> "OK <id> <text>" + STAT line (legacy spelling)
//!   GEN key=value... <prompt...>            -> typed params: max_new= temp= top_p= rep=
//!                                              seed= stop= k= (per-request compression
//!                                              override) stream= — with stream=1 the reply
//!                                              is "TOK <id> <text>" per token, then OK+STAT
//!   CANCEL <id>                             -> "OK"; the generation retires within one
//!                                              decode iteration (partial output, cancelled=1)
//!   SET k_active <n>                        -> "OK" (fleet-wide: every shard)
//!   SET balance <policy>                    -> "OK" (swap placement live)
//!   STATS                                   -> fleet + per-shard view, "." line
//!   PING                                    -> "PONG"
//!   QUIT                                    -> closes the connection
//! Malformed lines answer `ERR <code> <message>` and keep the connection.
//! A clamped `max_new` is surfaced as `clamped=<cap>` on the OK line and
//! `requested=<n>` on the STAT line; client disconnects cancel the
//! connection's in-flight generations.
//!
//! Each shard's engine runs on its own thread behind
//! [`crate::shard::Router`]; connection threads place `GEN` through the
//! balance policy and fan admin commands out to every shard (tokio is
//! unavailable offline — std threads keep the request path
//! dependency-free).

pub mod client;
pub mod proto;
pub mod tcp;

pub use tcp::serve;
