//! Blocking client for the TCP protocol (used by examples, benches and
//! integration tests; doubles as the reference protocol-v2
//! implementation: keyword `GEN` via [`crate::server::proto::encode_gen`],
//! `TOK` streaming lines, `CANCEL`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::Context;

use crate::api::GenParams;
use crate::server::proto::encode_gen;

/// Parsed per-request stats from the server's STAT line.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub tokens: usize,
    pub tps: f64,
    pub mem_saving_pct: f64,
    /// `Some(n)`: the server clamped `max_new`; `n` is what was
    /// originally requested (`requested=` on the STAT line).
    pub requested: Option<usize>,
    /// The generation was cancelled (`cancelled=1` on the STAT line);
    /// the text is the partial output.
    pub cancelled: bool,
}

/// One finished generation as the server reported it.
#[derive(Clone, Debug, Default)]
pub struct Gen {
    pub id: u64,
    pub text: String,
    pub stats: GenStats,
    /// `Some(cap)` when the server clamped `max_new` to `cap`
    /// (`clamped=<cap>` on the OK line).
    pub clamped_to: Option<usize>,
}

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    fn line(&mut self) -> anyhow::Result<String> {
        let mut s = String::new();
        self.reader.read_line(&mut s)?;
        anyhow::ensure!(!s.is_empty(), "server closed the connection");
        Ok(s.trim_end().to_string())
    }

    pub fn ping(&mut self) -> anyhow::Result<()> {
        writeln!(self.writer, "PING")?;
        let l = self.line()?;
        anyhow::ensure!(l == "PONG", "unexpected reply '{l}'");
        Ok(())
    }

    pub fn set_k_active(&mut self, k: usize) -> anyhow::Result<()> {
        writeln!(self.writer, "SET k_active {k}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    /// Swap the router's placement policy live.
    pub fn set_balance(&mut self, policy: &str) -> anyhow::Result<()> {
        writeln!(self.writer, "SET balance {policy}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    /// Scale the fleet to `n` placeable shards live (`SET shards <n>`).
    pub fn set_shards(&mut self, n: usize) -> anyhow::Result<()> {
        writeln!(self.writer, "SET shards {n}")?;
        let l = self.line()?;
        anyhow::ensure!(l == format!("OK shards={n}"), "unexpected reply '{l}'");
        Ok(())
    }

    /// Toggle cross-request prefix caching fleet-wide (`SET prefix
    /// on|off`); returns how many members applied the toggle (engine
    /// shards and dense-baseline groups cannot host a tree and don't
    /// count).
    pub fn set_prefix(&mut self, on: bool) -> anyhow::Result<usize> {
        let v = if on { "on" } else { "off" };
        writeln!(self.writer, "SET prefix {v}")?;
        let l = self.line()?;
        let want = format!("OK prefix={v} applied=");
        anyhow::ensure!(l.starts_with(&want), "unexpected reply '{l}'");
        let applied = l[want.len()..]
            .split('/')
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("malformed reply '{l}'"))?;
        Ok(applied)
    }

    /// Drain shard `id`: placement stops immediately, in-flight work
    /// finishes (or migrates after the server's drain timeout), then the
    /// shard retires (`DRAIN <id>`).
    pub fn drain(&mut self, id: usize) -> anyhow::Result<()> {
        writeln!(self.writer, "DRAIN {id}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    /// Cancel a generation by id; the pending `GEN` still answers (with
    /// its partial output and `cancelled=1`).
    pub fn cancel(&mut self, id: u64) -> anyhow::Result<()> {
        writeln!(self.writer, "CANCEL {id}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    pub fn stats(&mut self) -> anyhow::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut out = String::new();
        loop {
            let l = self.line()?;
            if l == "." {
                return Ok(out);
            }
            out.push_str(&l);
            out.push('\n');
        }
    }

    /// Fetch the Prometheus text exposition (`METRICS`); the server
    /// terminates the block with a `# EOF` comment line, which is not
    /// included in the returned text.
    pub fn metrics(&mut self) -> anyhow::Result<String> {
        writeln!(self.writer, "METRICS")?;
        let mut out = String::new();
        loop {
            let l = self.line()?;
            if l == "# EOF" {
                return Ok(out);
            }
            out.push_str(&l);
            out.push('\n');
        }
    }

    /// Fetch a retained request trace as JSONL (`TRACE <id>`);
    /// `Ok(None)` when the server no longer holds the id.
    pub fn trace(&mut self, id: u64) -> anyhow::Result<Option<String>> {
        writeln!(self.writer, "TRACE {id}")?;
        let mut l = self.line()?;
        if l.starts_with("ERR ") {
            return Ok(None);
        }
        let mut out = String::new();
        loop {
            if l == "." {
                return Ok(Some(out));
            }
            out.push_str(&l);
            out.push('\n');
            l = self.line()?;
        }
    }

    /// Legacy-spelled generation; returns (text, stats).
    pub fn generate(&mut self, prompt: &str, max_new: usize) -> anyhow::Result<(String, GenStats)> {
        anyhow::ensure!(!prompt.contains('\n'), "prompt must be single-line");
        writeln!(self.writer, "GEN {max_new} {prompt}")?;
        let g = self.read_generation(|_, _| {})?;
        Ok((g.text, g.stats))
    }

    /// Keyword-spelled generation with typed [`GenParams`].  For
    /// streaming params, prefer [`Client::generate_stream`] (this method
    /// silently drains the `TOK` lines).
    pub fn generate_with(&mut self, prompt: &str, params: &GenParams) -> anyhow::Result<Gen> {
        self.generate_stream(prompt, params, |_, _| {})
    }

    /// Keyword-spelled generation invoking `on_token(id, text)` per
    /// streamed token (the first call reveals the request id, so a
    /// caller can `CANCEL` from another connection mid-stream).
    pub fn generate_stream(
        &mut self,
        prompt: &str,
        params: &GenParams,
        on_token: impl FnMut(u64, &str),
    ) -> anyhow::Result<Gen> {
        anyhow::ensure!(!prompt.contains('\n'), "prompt must be single-line");
        let line = encode_gen(params, prompt);
        writeln!(self.writer, "{line}")?;
        self.read_generation(on_token)
    }

    /// Consume one generation's replies: any number of `TOK` lines, the
    /// `OK` line, then the STAT line.
    fn read_generation(&mut self, mut on_token: impl FnMut(u64, &str)) -> anyhow::Result<Gen> {
        let ok = loop {
            let l = self.line()?;
            if let Some(rest) = l.strip_prefix("TOK ") {
                let (id, text) = rest.split_once(' ').unwrap_or((rest, ""));
                on_token(id.parse().unwrap_or(0), text);
                continue;
            }
            break l;
        };
        let rest = ok
            .strip_prefix("OK ")
            .ok_or_else(|| anyhow::anyhow!("generation failed: {ok}"))?;
        let (id_str, mut rest) = rest.split_once(' ').unwrap_or((rest, ""));
        let id = id_str.parse().unwrap_or(0);
        let mut clamped_to = None;
        if let Some(tail) = rest.strip_prefix("clamped=") {
            let (n, t) = tail.split_once(' ').unwrap_or((tail, ""));
            clamped_to = n.parse().ok();
            rest = t;
        }
        let text = rest.to_string();
        let stat_line = self.line()?;
        let stats = parse_stat_line(&stat_line).unwrap_or_default();
        Ok(Gen { id, text, stats, clamped_to })
    }

    pub fn quit(mut self) {
        let _ = writeln!(self.writer, "QUIT");
    }
}

fn parse_stat_line(line: &str) -> Option<GenStats> {
    let rest = line.strip_prefix("STAT ")?;
    let mut s = GenStats::default();
    for kv in rest.split_whitespace() {
        let (k, v) = kv.split_once('=')?;
        let v = v.trim_end_matches('%');
        match k {
            "prefill_ms" => s.prefill_ms = v.parse().ok()?,
            "decode_ms" => s.decode_ms = v.parse().ok()?,
            "tokens" => s.tokens = v.parse().ok()?,
            "tps" => s.tps = v.parse().ok()?,
            "mem_saving" => s.mem_saving_pct = v.parse().ok()?,
            "requested" => s.requested = v.parse().ok(),
            "cancelled" => s.cancelled = v == "1",
            _ => {}
        }
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_line_parses() {
        let s = parse_stat_line(
            "STAT prefill_ms=12.50 decode_ms=30.10 tokens=16 tps=531.2 mem_saving=42.3%",
        )
        .unwrap();
        assert_eq!(s.tokens, 16);
        assert!((s.prefill_ms - 12.5).abs() < 1e-9);
        assert!((s.mem_saving_pct - 42.3).abs() < 1e-9);
        assert_eq!(s.requested, None);
        assert!(!s.cancelled);
    }

    #[test]
    fn stat_line_parses_clamp_and_cancel_markers() {
        let s = parse_stat_line(
            "STAT prefill_ms=1.00 decode_ms=2.00 tokens=4 tps=9.0 mem_saving=10.0% \
             requested=9000 cancelled=1",
        )
        .unwrap();
        assert_eq!(s.requested, Some(9000));
        assert!(s.cancelled);
    }

    #[test]
    fn garbage_stat_line_is_none() {
        assert!(parse_stat_line("nonsense").is_none());
    }
}
