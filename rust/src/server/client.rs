//! Blocking client for the TCP protocol (used by examples, benches and
//! integration tests; doubles as the reference protocol implementation).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::Context;

/// Parsed per-request stats from the server's STAT line.
#[derive(Clone, Debug, Default)]
pub struct GenStats {
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub tokens: usize,
    pub tps: f64,
    pub mem_saving_pct: f64,
}

pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    fn line(&mut self) -> anyhow::Result<String> {
        let mut s = String::new();
        self.reader.read_line(&mut s)?;
        anyhow::ensure!(!s.is_empty(), "server closed the connection");
        Ok(s.trim_end().to_string())
    }

    pub fn ping(&mut self) -> anyhow::Result<()> {
        writeln!(self.writer, "PING")?;
        let l = self.line()?;
        anyhow::ensure!(l == "PONG", "unexpected reply '{l}'");
        Ok(())
    }

    pub fn set_k_active(&mut self, k: usize) -> anyhow::Result<()> {
        writeln!(self.writer, "SET k_active {k}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    /// Swap the router's placement policy live.
    pub fn set_balance(&mut self, policy: &str) -> anyhow::Result<()> {
        writeln!(self.writer, "SET balance {policy}")?;
        let l = self.line()?;
        anyhow::ensure!(l == "OK", "unexpected reply '{l}'");
        Ok(())
    }

    pub fn stats(&mut self) -> anyhow::Result<String> {
        writeln!(self.writer, "STATS")?;
        let mut out = String::new();
        loop {
            let l = self.line()?;
            if l == "." {
                return Ok(out);
            }
            out.push_str(&l);
            out.push('\n');
        }
    }

    /// Generate; returns (text, stats).
    pub fn generate(&mut self, prompt: &str, max_new: usize) -> anyhow::Result<(String, GenStats)> {
        anyhow::ensure!(!prompt.contains('\n'), "prompt must be single-line");
        writeln!(self.writer, "GEN {max_new} {prompt}")?;
        let l = self.line()?;
        let rest = l
            .strip_prefix("OK ")
            .ok_or_else(|| anyhow::anyhow!("generation failed: {l}"))?;
        let text = rest.split_once(' ').map(|(_, t)| t.to_string()).unwrap_or_default();
        let stat_line = self.line()?;
        let stats = parse_stat_line(&stat_line).unwrap_or_default();
        Ok((text, stats))
    }

    pub fn quit(mut self) {
        let _ = writeln!(self.writer, "QUIT");
    }
}

fn parse_stat_line(line: &str) -> Option<GenStats> {
    let rest = line.strip_prefix("STAT ")?;
    let mut s = GenStats::default();
    for kv in rest.split_whitespace() {
        let (k, v) = kv.split_once('=')?;
        let v = v.trim_end_matches('%');
        match k {
            "prefill_ms" => s.prefill_ms = v.parse().ok()?,
            "decode_ms" => s.decode_ms = v.parse().ok()?,
            "tokens" => s.tokens = v.parse().ok()?,
            "tps" => s.tps = v.parse().ok()?,
            "mem_saving" => s.mem_saving_pct = v.parse().ok()?,
            _ => {}
        }
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stat_line_parses() {
        let s = parse_stat_line(
            "STAT prefill_ms=12.50 decode_ms=30.10 tokens=16 tps=531.2 mem_saving=42.3%",
        )
        .unwrap();
        assert_eq!(s.tokens, 16);
        assert!((s.prefill_ms - 12.5).abs() < 1e-9);
        assert!((s.mem_saving_pct - 42.3).abs() < 1e-9);
    }

    #[test]
    fn garbage_stat_line_is_none() {
        assert!(parse_stat_line("nonsense").is_none());
    }
}
