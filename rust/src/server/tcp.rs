//! TCP serving loop over the shard router (protocol v2).
//!
//! [`crate::shard::Router`] owns `cfg.shards` engines, each on its own
//! thread; connection threads translate protocol lines into router calls.
//! `GEN` is *placed* on one shard by the configured balance policy, while
//! `SET k_active` and `STATS` fan out to every shard (broadcast + gather)
//! — one wire command retunes or inspects the whole fleet.
//!
//! Each `GEN` is pumped by its own reply thread: the connection's reader
//! loop keeps consuming lines while a generation runs, so `CANCEL <id>`
//! works mid-stream on the same connection and — crucially — a client
//! disconnect is *observed* (the reader hits EOF/error) instead of
//! leaving the connection thread parked on a reply channel while the
//! abandoned sequence decodes to completion.  On disconnect every
//! in-flight generation of the connection is cancelled, freeing its
//! decode slot within one iteration.  Streaming requests (`stream=1`)
//! get `TOK <id> <text>` per token before the final `OK` line; replies
//! are written line-atomically under a shared writer lock.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use crate::api::{CancelToken, Event, GenHandle};
use crate::config::ServeConfig;
use crate::coordinator::request::{Request, Response};
use crate::server::proto::{parse_line, Command};
use crate::shard::balance::policy_from_name;
use crate::shard::{Router, ShardLostError};
use crate::util::sync::lock_recover;

/// In-flight generations of one connection: id → cancel token.  Entries
/// are removed by the pump thread at terminal events; anything left when
/// the reader loop exits belongs to an abandoned request and is
/// cancelled.
type Inflight = Arc<Mutex<HashMap<u64, CancelToken>>>;

/// Render the final reply for one finished generation: the `OK` line
/// (with the `clamped=<cap>` marker when the server clamped `max_new`)
/// plus the STAT line.
fn write_done(
    writer: &Mutex<TcpStream>,
    resp: &Response,
    max_new_cap: usize,
) -> std::io::Result<()> {
    let mut w = lock_recover(writer);
    if resp.stats.clamped_from.is_some() {
        writeln!(w, "OK {} clamped={} {}", resp.id, max_new_cap, resp.text)?;
    } else {
        writeln!(w, "OK {} {}", resp.id, resp.text)?;
    }
    let mut stat = format!(
        "STAT prefill_ms={:.2} decode_ms={:.2} tokens={} tps={:.1} mem_saving={:.1}%",
        resp.stats.prefill_time.as_secs_f64() * 1e3,
        resp.stats.decode_time.as_secs_f64() * 1e3,
        resp.stats.decode_steps,
        resp.stats.decode_tps(),
        resp.stats.memory_saving() * 100.0
    );
    // SLO fields: TTFT (queue + prefill) and the inter-token gap stats.
    // Appended after the historical fields so line-prefix matchers hold.
    stat.push_str(&format!(
        " ttft_ms={:.2} itl_mean_ms={:.2} itl_max_ms={:.2}",
        resp.stats.ttft_ns as f64 / 1e6,
        resp.stats.itl_mean_ns() as f64 / 1e6,
        resp.stats.itl_max_ns as f64 / 1e6,
    ));
    if let Some(requested) = resp.stats.clamped_from {
        stat.push_str(&format!(" requested={requested}"));
    }
    if let Some(requested) = resp.stats.truncated_prompt_from {
        stat.push_str(&format!(" requested_prompt={requested}"));
    }
    if resp.stats.cancelled {
        stat.push_str(" cancelled=1");
    }
    writeln!(w, "{stat}")
}

/// Pump one generation's events to the connection: `TOK` lines for
/// streamed tokens, then the final `OK`/`ERR`.  Runs on its own thread so
/// the reader loop stays responsive (CANCEL, disconnect detection).  A
/// write failure means the client is gone — cancel the generation so it
/// stops burning a decode slot.
fn pump_generation(
    handle: GenHandle,
    writer: Arc<Mutex<TcpStream>>,
    inflight: Inflight,
    max_new_cap: usize,
) {
    let id = handle.id();
    loop {
        let ev = match handle.recv() {
            Ok(ev) => ev,
            Err(_) => {
                let _ = writeln!(lock_recover(&writer), "ERR unavailable shard gone");
                break;
            }
        };
        let write_res = match &ev {
            Event::Token { id, text, .. } => {
                writeln!(lock_recover(&writer), "TOK {id} {text}")
            }
            Event::Done(resp) => write_done(&writer, resp, max_new_cap),
            Event::Error { message, .. } => {
                // a recovery that found no healthy shard is a fleet
                // condition, not a generation bug — distinct ERR code
                match message.strip_prefix("shard_lost: ") {
                    Some(rest) => writeln!(lock_recover(&writer), "ERR shard_lost {rest}"),
                    None => writeln!(lock_recover(&writer), "ERR generation {message}"),
                }
            }
        };
        let terminal = !matches!(ev, Event::Token { .. });
        if write_res.is_err() {
            // broken pipe: nobody is reading — stop the sequence
            handle.cancel();
            break;
        }
        if terminal {
            break;
        }
    }
    lock_recover(&inflight).remove(&id);
}

fn handle_conn(stream: TcpStream, router: Arc<Router>, max_new_cap: usize) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    // per-connection wire counters, in the router's server registry so
    // the METRICS exposition carries them next to the shard series
    let obs = router.server_registry();
    let wire_lines = obs.counter("swan_wire_lines_total", &[]);
    let proto_errors = obs.counter("swan_wire_errors_total", &[("kind", "proto")]);
    obs.counter("swan_connections_total", &[]).inc();
    let inflight: Inflight = Arc::new(Mutex::new(HashMap::new()));
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        wire_lines.inc();
        match parse_line(&line) {
            Ok(Command::Quit) => break,
            Ok(Command::Ping) => {
                let _ = writeln!(lock_recover(&writer), "PONG");
            }
            Ok(Command::Stats) => {
                let s = router.stats();
                let mut w = lock_recover(&writer);
                let _ = write!(w, "{s}");
                let _ = writeln!(w, ".");
            }
            Ok(Command::Metrics) => {
                // Prometheus text exposition; `# EOF` terminates the
                // response (a comment line, so scrapers parse it away)
                let m = router.metrics_text();
                let mut w = lock_recover(&writer);
                let _ = write!(w, "{m}");
                let _ = writeln!(w, "# EOF");
            }
            Ok(Command::Trace(id)) => match router.trace_jsonl(id) {
                Some(j) => {
                    let mut w = lock_recover(&writer);
                    let _ = write!(w, "{j}");
                    let _ = writeln!(w, ".");
                }
                None => {
                    let _ = writeln!(
                        lock_recover(&writer),
                        "ERR not-found no trace retained for request {id}"
                    );
                }
            },
            Ok(Command::SetKActive(k)) => {
                let reply = match router.set_k_active(k) {
                    Ok(_) => "OK".to_string(),
                    Err(e) => format!("ERR unavailable {e}"),
                };
                let _ = writeln!(lock_recover(&writer), "{reply}");
            }
            Ok(Command::SetBalance(name)) => match policy_from_name(&name) {
                Ok(policy) => {
                    router.set_policy(policy);
                    let _ = writeln!(lock_recover(&writer), "OK");
                }
                Err(e) => {
                    let _ = writeln!(lock_recover(&writer), "ERR bad-args {e}");
                }
            },
            Ok(Command::Gen { params, prompt }) => {
                let req = Request::with_params(0, &prompt, params);
                match router.submit(req) {
                    Ok(handle) => {
                        lock_recover(&inflight).insert(handle.id(), handle.cancel_token());
                        let writer = writer.clone();
                        let inflight = inflight.clone();
                        std::thread::spawn(move || {
                            pump_generation(handle, writer, inflight, max_new_cap)
                        });
                    }
                    Err(e) => {
                        // placement exhaustion is structured: ERR shard_lost
                        let code = if e.downcast_ref::<ShardLostError>().is_some() {
                            "shard_lost"
                        } else {
                            "unavailable"
                        };
                        let _ = writeln!(lock_recover(&writer), "ERR {code} {e}");
                    }
                }
            }
            Ok(Command::SetShards(n)) => {
                let reply = match router.set_shards(n) {
                    Ok(n) => format!("OK shards={n}"),
                    Err(e) => format!("ERR bad-args {e}"),
                };
                let _ = writeln!(lock_recover(&writer), "{reply}");
            }
            Ok(Command::SetPrefix(on)) => {
                // report how many members actually applied the toggle —
                // engine shards and dense-baseline groups cannot host a
                // prefix tree and ack `false`
                let reply = match router.set_prefix(on) {
                    Ok(acks) => {
                        let applied = acks.iter().filter(|(_, ok)| *ok).count();
                        let v = if on { "on" } else { "off" };
                        format!("OK prefix={v} applied={applied}/{}", acks.len())
                    }
                    Err(e) => format!("ERR unavailable {e}"),
                };
                let _ = writeln!(lock_recover(&writer), "{reply}");
            }
            Ok(Command::Drain(id)) => {
                let reply = match router.drain(id) {
                    Ok(()) => "OK".to_string(),
                    Err(e) => format!("ERR bad-args {e}"),
                };
                let _ = writeln!(lock_recover(&writer), "{reply}");
            }
            Ok(Command::Cancel(id)) => {
                // a generation of this connection cancels directly via
                // its token; other ids go through the router broadcast
                // (unknown ids no-op on every shard)
                let local = lock_recover(&inflight).get(&id).cloned();
                let ok = match local {
                    Some(tok) => {
                        tok.cancel();
                        Ok(())
                    }
                    None => router.cancel(id),
                };
                let reply = match ok {
                    Ok(()) => "OK".to_string(),
                    Err(e) => format!("ERR unavailable {e}"),
                };
                let _ = writeln!(lock_recover(&writer), "{reply}");
            }
            Err(e) => {
                // structured reply; the connection stays open
                proto_errors.inc();
                let _ = writeln!(lock_recover(&writer), "ERR {} {e}", e.code());
            }
        }
    }
    // reader gone (QUIT, EOF or socket error): whatever is still
    // in-flight belongs to a client that will never read the reply —
    // cancel it so abandoned requests stop burning decode slots
    for tok in lock_recover(&inflight).values() {
        tok.cancel();
    }
    log::info!("connection {peer} closed");
}

/// Serve until the process is killed.  Binds `cfg.bind`.
pub fn serve(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<()> {
    serve_with_ready(artifacts_dir, cfg, |_| {})
}

/// Like [`serve`], invoking `on_ready(local_addr)` once listening (used by
/// tests to learn the ephemeral port).
pub fn serve_with_ready(
    artifacts_dir: &std::path::Path,
    cfg: ServeConfig,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let router = Arc::new(Router::launch(artifacts_dir, cfg.clone())?);
    serve_router(router, &cfg, on_ready)
}

/// Serve an already-built router (chaos/e2e tests drive artifact-free
/// synthetic fleets over real TCP through this; `swan serve` goes through
/// [`serve_with_ready`], which launches the fleet from artifacts first).
pub fn serve_router(
    router: Arc<Router>,
    cfg: &ServeConfig,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let max_new_cap = cfg.max_new_hard_cap();
    let listener = TcpListener::bind(&cfg.bind)?;
    let addr = listener.local_addr()?;
    let topology = if cfg.pipeline > 1 {
        format!("{} groups x {} stages (layer-sharded)", router.n_shards(), cfg.pipeline)
    } else {
        format!("shards={}", router.n_shards())
    };
    println!(
        "swan serving {} on {addr} ({topology} balance={} k_active={} buffer={} mode={} workers/shard={})",
        cfg.model,
        router.policy_name(),
        cfg.k_active,
        cfg.buffer,
        cfg.mode.label(),
        cfg.decode_workers,
    );
    on_ready(addr);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let router = router.clone();
                std::thread::spawn(move || handle_conn(s, router, max_new_cap));
            }
            Err(e) => log::warn!("accept: {e}"),
        }
    }
    Ok(())
}
