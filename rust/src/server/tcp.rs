//! TCP serving loop over the shard router.
//!
//! [`crate::shard::Router`] owns `cfg.shards` engines, each on its own
//! thread; connection threads translate protocol lines into router calls.
//! `GEN` is *placed* on one shard by the configured balance policy, while
//! `SET k_active` and `STATS` fan out to every shard (broadcast + gather)
//! — one wire command retunes or inspects the whole fleet.  Generation is
//! synchronous per connection (each shard still interleaves decode across
//! its sequences — iteration-level batching happens inside the engine).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::config::ServeConfig;
use crate::coordinator::request::Request;
use crate::server::proto::{parse_line, Command};
use crate::shard::balance::policy_from_name;
use crate::shard::Router;

fn handle_conn(stream: TcpStream, router: Arc<Router>, max_new_cap: usize) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(Command::Quit) => break,
            Ok(Command::Ping) => {
                let _ = writeln!(writer, "PONG");
            }
            Ok(Command::Stats) => {
                let _ = write!(writer, "{}", router.stats());
                let _ = writeln!(writer, ".");
            }
            Ok(Command::SetKActive(k)) => match router.set_k_active(k) {
                Ok(_) => {
                    let _ = writeln!(writer, "OK");
                }
                Err(e) => {
                    let _ = writeln!(writer, "ERR unavailable {e}");
                }
            },
            Ok(Command::SetBalance(name)) => match policy_from_name(&name) {
                Ok(policy) => {
                    router.set_policy(policy);
                    let _ = writeln!(writer, "OK");
                }
                Err(e) => {
                    let _ = writeln!(writer, "ERR bad-args {e}");
                }
            },
            Ok(Command::Gen { max_new, prompt }) => {
                let req = Request::from_text(0, &prompt, max_new.min(max_new_cap));
                let reply = match router.submit(req) {
                    Ok(rx) => rx.recv(),
                    Err(e) => {
                        let _ = writeln!(writer, "ERR unavailable {e}");
                        continue;
                    }
                };
                match reply {
                    Ok(Ok(resp)) => {
                        let _ = writeln!(writer, "OK {} {}", resp.id, resp.text);
                        let _ = writeln!(
                            writer,
                            "STAT prefill_ms={:.2} decode_ms={:.2} tokens={} tps={:.1} mem_saving={:.1}%",
                            resp.stats.prefill_time.as_secs_f64() * 1e3,
                            resp.stats.decode_time.as_secs_f64() * 1e3,
                            resp.stats.decode_steps,
                            resp.stats.decode_tps(),
                            resp.stats.memory_saving() * 100.0
                        );
                    }
                    Ok(Err(e)) => {
                        let _ = writeln!(writer, "ERR generation {e}");
                    }
                    Err(_) => {
                        let _ = writeln!(writer, "ERR unavailable shard gone");
                        break;
                    }
                }
            }
            Err(e) => {
                // structured reply; the connection stays open
                let _ = writeln!(writer, "ERR {} {e}", e.code());
            }
        }
    }
    log::info!("connection {peer} closed");
}

/// Serve until the process is killed.  Binds `cfg.bind`.
pub fn serve(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<()> {
    serve_with_ready(artifacts_dir, cfg, |_| {})
}

/// Like [`serve`], invoking `on_ready(local_addr)` once listening (used by
/// tests to learn the ephemeral port).
pub fn serve_with_ready(
    artifacts_dir: &std::path::Path,
    cfg: ServeConfig,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let max_new_cap = cfg.max_new_tokens.max(1) * 8;
    let router = Arc::new(Router::launch(artifacts_dir, cfg.clone())?);

    let listener = TcpListener::bind(&cfg.bind)?;
    let addr = listener.local_addr()?;
    let topology = if cfg.pipeline > 1 {
        format!("{} groups x {} stages (layer-sharded)", router.n_shards(), cfg.pipeline)
    } else {
        format!("shards={}", router.n_shards())
    };
    println!(
        "swan serving {} on {addr} ({topology} balance={} k_active={} buffer={} mode={} workers/shard={})",
        cfg.model,
        router.policy_name(),
        cfg.k_active,
        cfg.buffer,
        cfg.mode.label(),
        cfg.decode_workers,
    );
    on_ready(addr);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let router = router.clone();
                std::thread::spawn(move || handle_conn(s, router, max_new_cap));
            }
            Err(e) => log::warn!("accept: {e}"),
        }
    }
    Ok(())
}
