//! TCP serving loop.
//!
//! One engine thread owns the [`Engine`]; connection threads translate
//! protocol lines into engine commands over channels.  Generation is
//! synchronous per connection (the engine still interleaves decode across
//! concurrent connections — iteration-level batching happens inside
//! `Engine::step`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::config::ServeConfig;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Request, Response};
use crate::server::proto::{parse_line, Command};

enum EngineCmd {
    Gen { req: Request, reply: mpsc::Sender<anyhow::Result<Response>> },
    SetK(usize),
    Stats(mpsc::Sender<String>),
    Shutdown,
}

/// Engine thread: pulls commands, steps the engine, routes completions.
fn engine_thread(mut engine: Engine, rx: mpsc::Receiver<EngineCmd>) {
    let mut waiters: std::collections::HashMap<u64, mpsc::Sender<anyhow::Result<Response>>> =
        std::collections::HashMap::new();
    loop {
        // drain commands (non-blocking when busy, blocking when idle)
        loop {
            let cmd = if engine.has_work() {
                match rx.try_recv() {
                    Ok(c) => c,
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            } else {
                match rx.recv() {
                    Ok(c) => c,
                    Err(_) => return,
                }
            };
            match cmd {
                EngineCmd::Gen { req, reply } => {
                    let id = engine.submit(req);
                    waiters.insert(id, reply);
                }
                EngineCmd::SetK(k) => engine.set_k_active(k),
                EngineCmd::Stats(tx) => {
                    let mut s = engine.metrics.snapshot();
                    s.push_str(&format!("k_active: {}\n", engine.current_k_active()));
                    s.push_str(&format!("queue: {} active: {}\n",
                        0, // queue length folded into metrics
                        engine.live_cache_bytes()));
                    let _ = tx.send(s);
                }
                EngineCmd::Shutdown => return,
            }
        }
        if let Err(e) = engine.step() {
            log::error!("engine step failed: {e:#}");
        }
        while let Some(resp) = engine.pop_finished() {
            if let Some(tx) = waiters.remove(&resp.id) {
                let _ = tx.send(Ok(resp));
            }
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Arc<Mutex<mpsc::Sender<EngineCmd>>>, max_new_cap: usize) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&line) {
            Ok(Command::Quit) => break,
            Ok(Command::Ping) => {
                let _ = writeln!(writer, "PONG");
            }
            Ok(Command::Stats) => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.lock().unwrap().send(EngineCmd::Stats(rtx));
                if let Ok(s) = rrx.recv() {
                    let _ = write!(writer, "{s}");
                }
                let _ = writeln!(writer, ".");
            }
            Ok(Command::SetKActive(k)) => {
                let _ = tx.lock().unwrap().send(EngineCmd::SetK(k));
                let _ = writeln!(writer, "OK");
            }
            Ok(Command::Gen { max_new, prompt }) => {
                let (rtx, rrx) = mpsc::channel();
                let req = Request::from_text(0, &prompt, max_new.min(max_new_cap));
                let _ = tx.lock().unwrap().send(EngineCmd::Gen { req, reply: rtx });
                match rrx.recv() {
                    Ok(Ok(resp)) => {
                        let _ = writeln!(writer, "OK {} {}", resp.id, resp.text);
                        let _ = writeln!(
                            writer,
                            "STAT prefill_ms={:.2} decode_ms={:.2} tokens={} tps={:.1} mem_saving={:.1}%",
                            resp.stats.prefill_time.as_secs_f64() * 1e3,
                            resp.stats.decode_time.as_secs_f64() * 1e3,
                            resp.stats.decode_steps,
                            resp.stats.decode_tps(),
                            resp.stats.memory_saving() * 100.0
                        );
                    }
                    Ok(Err(e)) => {
                        let _ = writeln!(writer, "ERR {e}");
                    }
                    Err(_) => {
                        let _ = writeln!(writer, "ERR engine gone");
                        break;
                    }
                }
            }
            Err(e) => {
                let _ = writeln!(writer, "ERR {e}");
            }
        }
    }
    log::info!("connection {peer} closed");
}

/// Serve until the process is killed.  Binds `cfg.bind`.
pub fn serve(artifacts_dir: &std::path::Path, cfg: ServeConfig) -> anyhow::Result<()> {
    serve_with_ready(artifacts_dir, cfg, |_| {})
}

/// Like [`serve`], invoking `on_ready(local_addr)` once listening (used by
/// tests to learn the ephemeral port).
pub fn serve_with_ready(
    artifacts_dir: &std::path::Path,
    cfg: ServeConfig,
    on_ready: impl FnOnce(std::net::SocketAddr),
) -> anyhow::Result<()> {
    let max_new_cap = cfg.max_new_tokens.max(1) * 8;
    let engine = Engine::new(artifacts_dir, cfg.clone())?;
    engine.warmup()?;
    let (tx, rx) = mpsc::channel();
    let tx = Arc::new(Mutex::new(tx));
    std::thread::spawn(move || engine_thread(engine, rx));

    let listener = TcpListener::bind(&cfg.bind)?;
    let addr = listener.local_addr()?;
    println!("swan serving {} on {addr} (k_active={} buffer={} mode={})",
        cfg.model, cfg.k_active, cfg.buffer, cfg.mode.label());
    on_ready(addr);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = tx.clone();
                std::thread::spawn(move || handle_conn(s, tx, max_new_cap));
            }
            Err(e) => log::warn!("accept: {e}"),
        }
    }
    // unreachable: incoming() iterates forever; keep the sender alive
    drop(tx);
    let _ = EngineCmd::Shutdown;
    Ok(())
}
