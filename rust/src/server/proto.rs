//! Wire protocol parsing for the TCP front-end.

/// A parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// GEN <max_new> <prompt...>
    Gen { max_new: usize, prompt: String },
    /// SET k_active <n>
    SetKActive(usize),
    Stats,
    Ping,
    Quit,
}

/// Parse one protocol line.
pub fn parse_line(line: &str) -> Result<Command, String> {
    let line = line.trim_end_matches(['\r', '\n']);
    let mut parts = line.splitn(2, ' ');
    let verb = parts.next().unwrap_or("").to_ascii_uppercase();
    let rest = parts.next().unwrap_or("");
    match verb.as_str() {
        "GEN" => {
            let mut p = rest.splitn(2, ' ');
            let max_new: usize = p
                .next()
                .unwrap_or("")
                .parse()
                .map_err(|_| "GEN: expected '<max_new_tokens> <prompt>'".to_string())?;
            let prompt = p.next().unwrap_or("").to_string();
            if prompt.is_empty() {
                return Err("GEN: empty prompt".into());
            }
            Ok(Command::Gen { max_new, prompt })
        }
        "SET" => {
            let mut p = rest.split_whitespace();
            match (p.next(), p.next()) {
                (Some("k_active"), Some(n)) => n
                    .parse()
                    .map(Command::SetKActive)
                    .map_err(|_| "SET k_active: bad number".to_string()),
                _ => Err("SET: expected 'k_active <n>'".into()),
            }
        }
        "STATS" => Ok(Command::Stats),
        "PING" => Ok(Command::Ping),
        "QUIT" => Ok(Command::Quit),
        other => Err(format!("unknown command '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_gen() {
        assert_eq!(
            parse_line("GEN 32 the passkey is\n").unwrap(),
            Command::Gen { max_new: 32, prompt: "the passkey is".into() }
        );
    }

    #[test]
    fn parses_set_and_misc() {
        assert_eq!(parse_line("SET k_active 16").unwrap(), Command::SetKActive(16));
        assert_eq!(parse_line("stats").unwrap(), Command::Stats);
        assert_eq!(parse_line("PING").unwrap(), Command::Ping);
        assert_eq!(parse_line("QUIT\r\n").unwrap(), Command::Quit);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GEN").is_err());
        assert!(parse_line("GEN x y").is_err());
        assert!(parse_line("GEN 5 ").is_err());
        assert!(parse_line("SET foo 3").is_err());
        assert!(parse_line("NOPE").is_err());
    }
}
