//! Wire protocol parsing for the TCP front-end (protocol v2).
//!
//! `GEN` comes in two spellings, both supported forever:
//!
//! * **legacy** — `GEN <max_new> <prompt…>`: the first token is a bare
//!   number.  Parses to exactly the request it always did (default
//!   [`GenParams`] with that `max_new`).
//! * **keyword** — `GEN key=value… [--] <prompt…>`: leading `key=value`
//!   tokens set typed [`GenParams`] fields; the prompt starts at the
//!   first token that is not a recognized `key=value` (so prompts may
//!   freely contain `=`), or explicitly after a standalone `--`
//!   terminator — which is how a prompt whose *first word* happens to
//!   look like a recognized parameter (`k=2 plus k=3 …`) is sent
//!   unambiguously ([`encode_gen`] emits the `--` automatically).
//!   Keys: `max_new`, `temp`, `top_p`, `rep`, `seed`, `stop`, `k`
//!   (per-request compression override) and `stream`
//!   (`1`/`0`/`true`/`false`).  A *recognized* key with an unparsable
//!   value is a `bad-args` error rather than silently becoming prompt
//!   text.
//!
//! Streaming generations answer `TOK <id> <text>` per token before the
//! final `OK <id> …` line, and `CANCEL <id>` retires a running request.
//!
//! Malformed lines parse to a structured [`ProtoError`] (stable machine
//! code + human message) rather than a bare string; the connection loop
//! answers `ERR <code> <message>` and keeps the connection open, so a
//! client typo never costs the session.
//!
//! The command set is topology-agnostic: in pipeline mode (`--pipeline`)
//! `GEN` is placed on a pipeline *group*, `SET k_active` retunes every
//! stage of every group, and `STATS` blocks additionally carry one
//! `stage i: layers a..b … queued=…` line per stage (queue depth is the
//! pipeline-bubble indicator).

use crate::api::GenParams;

/// A parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// `GEN <max_new> <prompt…>` or `GEN key=value… <prompt…>`.
    Gen { params: GenParams, prompt: String },
    /// `CANCEL <id>` — retire a queued or mid-decode generation.
    Cancel(u64),
    /// SET k_active <n> — fleet-wide live compression retune.
    SetKActive(usize),
    /// SET balance <policy> — swap the router's placement policy live.
    SetBalance(String),
    /// `SET shards <n>` — elastic membership: scale the fleet to `n`
    /// placeable shards live (scale-up launches supervised members,
    /// scale-down drains the youngest; KV budget rebalances either way).
    SetShards(usize),
    /// `SET prefix on|off` — fleet-wide cross-request prefix caching
    /// toggle; `off` flushes every group's tree and releases the
    /// pinned blocks.
    SetPrefix(bool),
    /// `DRAIN <id>` — stop placing on shard `id`, let its in-flight
    /// work finish (or migrate after the drain timeout), then retire it.
    Drain(usize),
    Stats,
    /// `METRICS` — Prometheus text exposition of the fleet registries,
    /// terminated by a `# EOF` line.
    Metrics,
    /// `TRACE <id>` — one request's lifecycle timeline as JSONL,
    /// terminated by a lone `.` line (`ERR not-found …` if unknown).
    Trace(u64),
    Ping,
    Quit,
}

/// A structured protocol error: `code()` is the stable machine-readable
/// token on the `ERR` reply line, `Display` the human explanation.
#[derive(Clone, Debug, PartialEq)]
pub enum ProtoError {
    /// The line was empty (no verb).
    Empty,
    /// The verb is not part of the protocol.
    UnknownCommand(String),
    /// The verb is known but its arguments don't parse.
    BadArgs { verb: &'static str, expected: &'static str, got: String },
}

impl ProtoError {
    pub fn code(&self) -> &'static str {
        match self {
            ProtoError::Empty => "empty",
            ProtoError::UnknownCommand(_) => "unknown-command",
            ProtoError::BadArgs { .. } => "bad-args",
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Empty => write!(f, "empty command line"),
            ProtoError::UnknownCommand(verb) => write!(f, "unknown command '{verb}'"),
            ProtoError::BadArgs { verb, expected, got } => {
                write!(f, "{verb}: expected {expected}, got '{got}'")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

/// The recognized keyword-GEN keys (the prompt starts at the first
/// token that is not one of these followed by `=`).
pub const GEN_KEYS: &[&str] =
    &["max_new", "temp", "top_p", "rep", "seed", "stop", "k", "stream"];

fn bad_gen(expected: &'static str, got: &str) -> ProtoError {
    ProtoError::BadArgs { verb: "GEN", expected, got: got.to_string() }
}

/// Apply one recognized `key=value` to the params; `Ok(false)` when the
/// key is not recognized (i.e. the token belongs to the prompt).
fn apply_gen_kv(params: &mut GenParams, key: &str, val: &str) -> Result<bool, ProtoError> {
    if !GEN_KEYS.contains(&key) {
        return Ok(false);
    }
    match key {
        "max_new" => {
            params.max_new =
                val.parse().map_err(|_| bad_gen("max_new=<tokens>", val))?;
        }
        "temp" => {
            params.temperature =
                val.parse().map_err(|_| bad_gen("temp=<float>", val))?;
        }
        "top_p" => {
            params.top_p = val.parse().map_err(|_| bad_gen("top_p=<float>", val))?;
        }
        "rep" => {
            params.repetition_penalty =
                val.parse().map_err(|_| bad_gen("rep=<float>", val))?;
        }
        "seed" => {
            params.seed =
                Some(val.parse().map_err(|_| bad_gen("seed=<u64>", val))?);
        }
        "stop" => {
            params.stop =
                Some(val.parse().map_err(|_| bad_gen("stop=<token id>", val))?);
        }
        "k" => {
            params.k_active =
                Some(val.parse().map_err(|_| bad_gen("k=<level>", val))?);
        }
        "stream" => {
            params.stream = match val {
                "1" | "true" => true,
                "0" | "false" => false,
                _ => return Err(bad_gen("stream=0|1", val)),
            };
        }
        _ => unreachable!("key checked against GEN_KEYS"),
    }
    Ok(true)
}

/// Parse the argument tail of a `GEN` line (everything after the verb).
fn parse_gen(rest: &str) -> Result<Command, ProtoError> {
    let first = rest.split(' ').next().unwrap_or("");
    // legacy spelling: a bare leading number is max_new
    if let Ok(max_new) = first.parse::<usize>() {
        let prompt = rest.split_once(' ').map(|(_, p)| p).unwrap_or("");
        if prompt.is_empty() {
            return Err(bad_gen("a non-empty prompt after <max_new_tokens>", rest));
        }
        return Ok(Command::Gen { params: GenParams::new(max_new), prompt: prompt.to_string() });
    }
    // keyword spelling: consume leading key=value tokens, the remainder
    // (internal spacing preserved) is the prompt; a standalone `--`
    // ends the parameters explicitly
    let mut params = GenParams::default();
    let mut any = false;
    let mut cur = rest;
    loop {
        let (word, tail) = cur.split_once(' ').unwrap_or((cur, ""));
        if word == "--" {
            any = true;
            cur = tail;
            break;
        }
        let Some((key, val)) = word.split_once('=') else { break };
        if !apply_gen_kv(&mut params, key, val)? {
            break;
        }
        any = true;
        cur = tail;
    }
    if !any {
        return Err(bad_gen(
            "'<max_new_tokens> <prompt>' or 'key=value… [--] <prompt>'",
            rest,
        ));
    }
    if cur.is_empty() {
        return Err(bad_gen("a non-empty prompt after the parameters", rest));
    }
    Ok(Command::Gen { params, prompt: cur.to_string() })
}

/// Whether `word` would be consumed as a parameter (or `--` terminator)
/// by the keyword-GEN parser — i.e. a prompt beginning with it needs an
/// explicit `--` so the boundary stays unambiguous.
fn consumed_as_param(word: &str) -> bool {
    if word == "--" {
        return true;
    }
    matches!(word.split_once('='), Some((key, _)) if GEN_KEYS.contains(&key))
}

/// Encode a `GEN` line for `(params, prompt)` — the inverse of
/// [`parse_line`] (the reference client writes requests through this, and
/// the round-trip is property-tested, including prompts whose first word
/// looks like a parameter: those get an explicit `--` terminator).
/// Default-valued fields are omitted; an all-default request still emits
/// `max_new=` so the line stays unambiguous.
pub fn encode_gen(params: &GenParams, prompt: &str) -> String {
    let d = GenParams::default();
    let mut out = String::from("GEN");
    let mut push = |s: String| {
        out.push(' ');
        out.push_str(&s);
    };
    push(format!("max_new={}", params.max_new));
    if params.temperature != d.temperature {
        push(format!("temp={}", params.temperature));
    }
    if params.top_p != d.top_p {
        push(format!("top_p={}", params.top_p));
    }
    if params.repetition_penalty != d.repetition_penalty {
        push(format!("rep={}", params.repetition_penalty));
    }
    if let Some(s) = params.seed {
        push(format!("seed={s}"));
    }
    if let Some(s) = params.stop {
        push(format!("stop={s}"));
    }
    if let Some(k) = params.k_active {
        push(format!("k={k}"));
    }
    if params.stream {
        push("stream=1".to_string());
    }
    // a prompt whose first word would itself parse as a parameter needs
    // the explicit terminator, otherwise encode∘parse would not be the
    // identity on it
    let first_word = prompt.split(' ').next().unwrap_or("");
    if consumed_as_param(first_word) {
        push("--".to_string());
    }
    out.push(' ');
    out.push_str(prompt);
    out
}

/// Parse one protocol line.
pub fn parse_line(line: &str) -> Result<Command, ProtoError> {
    let line = line.trim_end_matches(['\r', '\n']);
    let mut parts = line.splitn(2, ' ');
    let verb_raw = parts.next().unwrap_or("");
    let verb = verb_raw.to_ascii_uppercase();
    let rest = parts.next().unwrap_or("");
    match verb.as_str() {
        "" => Err(ProtoError::Empty),
        "GEN" => parse_gen(rest),
        "CANCEL" => {
            let id = rest.trim();
            id.parse().map(Command::Cancel).map_err(|_| ProtoError::BadArgs {
                verb: "CANCEL",
                expected: "a request id",
                got: id.to_string(),
            })
        }
        "SET" => {
            let mut p = rest.split_whitespace();
            match (p.next(), p.next()) {
                (Some("k_active"), Some(n)) => {
                    n.parse().map(Command::SetKActive).map_err(|_| ProtoError::BadArgs {
                        verb: "SET k_active",
                        expected: "a number",
                        got: n.to_string(),
                    })
                }
                (Some("balance"), Some(policy)) => Ok(Command::SetBalance(policy.to_string())),
                (Some("shards"), Some(n)) => {
                    n.parse().map(Command::SetShards).map_err(|_| ProtoError::BadArgs {
                        verb: "SET shards",
                        expected: "a number",
                        got: n.to_string(),
                    })
                }
                (Some("prefix"), Some(v)) => match v {
                    "on" | "1" | "true" => Ok(Command::SetPrefix(true)),
                    "off" | "0" | "false" => Ok(Command::SetPrefix(false)),
                    _ => Err(ProtoError::BadArgs {
                        verb: "SET prefix",
                        expected: "on|off",
                        got: v.to_string(),
                    }),
                },
                _ => Err(ProtoError::BadArgs {
                    verb: "SET",
                    expected: "'k_active <n>', 'balance <policy>', 'shards <n>' or 'prefix on|off'",
                    got: rest.to_string(),
                }),
            }
        }
        "STATS" => Ok(Command::Stats),
        "METRICS" => Ok(Command::Metrics),
        "TRACE" => {
            let id = rest.trim();
            id.parse().map(Command::Trace).map_err(|_| ProtoError::BadArgs {
                verb: "TRACE",
                expected: "a request id",
                got: id.to_string(),
            })
        }
        "DRAIN" => {
            let id = rest.trim();
            id.parse().map(Command::Drain).map_err(|_| ProtoError::BadArgs {
                verb: "DRAIN",
                expected: "a shard id",
                got: id.to_string(),
            })
        }
        "PING" => Ok(Command::Ping),
        "QUIT" => Ok(Command::Quit),
        _ => Err(ProtoError::UnknownCommand(verb_raw.to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_legacy_gen() {
        assert_eq!(
            parse_line("GEN 32 the passkey is\n").unwrap(),
            Command::Gen { params: GenParams::new(32), prompt: "the passkey is".into() }
        );
    }

    #[test]
    fn parses_keyword_gen() {
        let got = parse_line("GEN max_new=64 temp=0.8 top_p=0.9 k=8 stream=1 the prompt").unwrap();
        let want = GenParams::new(64).temperature(0.8).top_p(0.9).k_active(8).stream(true);
        assert_eq!(got, Command::Gen { params: want, prompt: "the prompt".into() });
    }

    #[test]
    fn prompt_starts_at_first_unrecognized_token() {
        // "x=3" is not a recognized key, so it belongs to the prompt
        let got = parse_line("GEN max_new=8 x=3 equals what").unwrap();
        assert_eq!(
            got,
            Command::Gen { params: GenParams::new(8), prompt: "x=3 equals what".into() }
        );
        // internal double spaces in the prompt survive
        let got = parse_line("GEN max_new=8 two  spaces").unwrap();
        assert_eq!(got, Command::Gen { params: GenParams::new(8), prompt: "two  spaces".into() });
    }

    #[test]
    fn terminator_ends_the_parameters_explicitly() {
        // after `--` everything is prompt, even recognized key=value
        assert_eq!(
            parse_line("GEN max_new=8 -- k=2 plus k=3 equals").unwrap(),
            Command::Gen { params: GenParams::new(8), prompt: "k=2 plus k=3 equals".into() }
        );
        // `--` alone enters keyword mode with pure defaults
        assert_eq!(
            parse_line("GEN -- hello there").unwrap(),
            Command::Gen { params: GenParams::default(), prompt: "hello there".into() }
        );
        // the encoder emits the terminator exactly when needed
        let p = GenParams::new(8);
        assert_eq!(encode_gen(&p, "k=2 plus k=3 equals "), "GEN max_new=8 -- k=2 plus k=3 equals ");
        assert_eq!(encode_gen(&p, "-- leading dashes"), "GEN max_new=8 -- -- leading dashes");
        assert_eq!(encode_gen(&p, "plain prompt"), "GEN max_new=8 plain prompt");
        for prompt in ["k=2 plus k=3 equals ", "-- leading dashes", "temp=x is not a param"] {
            assert_eq!(
                parse_line(&encode_gen(&p, prompt)).unwrap(),
                Command::Gen { params: p.clone(), prompt: prompt.into() },
                "{prompt:?} must round-trip"
            );
        }
    }

    #[test]
    fn recognized_key_with_bad_value_is_an_error() {
        assert_eq!(parse_line("GEN max_new=lots hi").unwrap_err().code(), "bad-args");
        assert_eq!(parse_line("GEN max_new=8 stream=maybe hi").unwrap_err().code(), "bad-args");
    }

    #[test]
    fn gen_requires_count_or_keywords_and_a_prompt() {
        assert!(parse_line("GEN").is_err());
        assert!(parse_line("GEN just a prompt").is_err());
        assert!(parse_line("GEN max_new=8").is_err());
        assert!(parse_line("GEN 5 ").is_err());
    }

    #[test]
    fn parses_cancel() {
        assert_eq!(parse_line("CANCEL 17").unwrap(), Command::Cancel(17));
        assert_eq!(parse_line("cancel 17\r\n").unwrap(), Command::Cancel(17));
        assert_eq!(parse_line("CANCEL x").unwrap_err().code(), "bad-args");
    }

    #[test]
    fn encode_gen_round_trips() {
        let p = GenParams::new(48)
            .temperature(0.75)
            .top_p(0.92)
            .repetition_penalty(1.1)
            .seed(7)
            .stop(5)
            .k_active(16)
            .stream(true);
        let line = encode_gen(&p, "hello world");
        assert_eq!(
            parse_line(&line).unwrap(),
            Command::Gen { params: p, prompt: "hello world".into() }
        );
        // defaults collapse to just max_new
        let line = encode_gen(&GenParams::new(8), "hi");
        assert_eq!(line, "GEN max_new=8 hi");
    }

    #[test]
    fn parses_set_and_misc() {
        assert_eq!(parse_line("SET k_active 16").unwrap(), Command::SetKActive(16));
        assert_eq!(
            parse_line("SET balance mem-aware").unwrap(),
            Command::SetBalance("mem-aware".into())
        );
        assert_eq!(parse_line("stats").unwrap(), Command::Stats);
        assert_eq!(parse_line("PING").unwrap(), Command::Ping);
        assert_eq!(parse_line("QUIT\r\n").unwrap(), Command::Quit);
    }

    #[test]
    fn parses_fleet_lifecycle_verbs() {
        assert_eq!(parse_line("SET shards 4").unwrap(), Command::SetShards(4));
        assert_eq!(parse_line("set shards 1\r\n").unwrap(), Command::SetShards(1));
        assert_eq!(parse_line("SET shards many").unwrap_err().code(), "bad-args");
        assert_eq!(parse_line("DRAIN 2").unwrap(), Command::Drain(2));
        assert_eq!(parse_line("drain 0\n").unwrap(), Command::Drain(0));
        assert_eq!(parse_line("DRAIN").unwrap_err().code(), "bad-args");
        assert_eq!(parse_line("DRAIN x").unwrap_err().code(), "bad-args");
        // the SET usage string names every subcommand
        let e = parse_line("SET foo 3").unwrap_err();
        assert!(e.to_string().contains("'shards <n>'"), "{e}");
        assert!(e.to_string().contains("'prefix on|off'"), "{e}");
    }

    #[test]
    fn parses_set_prefix() {
        assert_eq!(parse_line("SET prefix on").unwrap(), Command::SetPrefix(true));
        assert_eq!(parse_line("set prefix 1\r\n").unwrap(), Command::SetPrefix(true));
        assert_eq!(parse_line("SET prefix off").unwrap(), Command::SetPrefix(false));
        assert_eq!(parse_line("SET prefix false").unwrap(), Command::SetPrefix(false));
        assert_eq!(parse_line("SET prefix maybe").unwrap_err().code(), "bad-args");
        assert_eq!(parse_line("SET prefix").unwrap_err().code(), "bad-args");
    }

    #[test]
    fn parses_metrics_and_trace() {
        assert_eq!(parse_line("METRICS").unwrap(), Command::Metrics);
        assert_eq!(parse_line("metrics\r\n").unwrap(), Command::Metrics);
        assert_eq!(parse_line("TRACE 42").unwrap(), Command::Trace(42));
        assert_eq!(parse_line("trace 7\n").unwrap(), Command::Trace(7));
        assert_eq!(parse_line("TRACE").unwrap_err().code(), "bad-args");
        assert_eq!(parse_line("TRACE abc").unwrap_err().code(), "bad-args");
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GEN").is_err());
        assert!(parse_line("GEN x y").is_err());
        assert!(parse_line("GEN 5 ").is_err());
        assert!(parse_line("SET foo 3").is_err());
        assert!(parse_line("NOPE").is_err());
    }

    #[test]
    fn errors_are_structured() {
        assert_eq!(parse_line("").unwrap_err().code(), "empty");
        assert_eq!(parse_line("NOPE 1 2").unwrap_err().code(), "unknown-command");
        // empty rest after SET is a bad-args error, not a verb mismatch
        let e = parse_line("SET").unwrap_err();
        assert_eq!(e.code(), "bad-args");
        assert!(e.to_string().contains("SET: expected"), "{e}");
        // GEN with a count but no prompt names the missing piece
        let e = parse_line("GEN 5 ").unwrap_err();
        assert_eq!(e.code(), "bad-args");
        assert!(e.to_string().contains("non-empty prompt"), "{e}");
        // the number that failed to parse is echoed back
        let e = parse_line("SET k_active lots").unwrap_err();
        assert!(e.to_string().contains("'lots'"), "{e}");
    }
}
