//! Wire protocol parsing for the TCP front-end.
//!
//! Malformed lines parse to a structured [`ProtoError`] (stable machine
//! code + human message) rather than a bare string; the connection loop
//! answers `ERR <code> <message>` and keeps the connection open, so a
//! client typo never costs the session.
//!
//! The command set is topology-agnostic: in pipeline mode (`--pipeline`)
//! `GEN` is placed on a pipeline *group*, `SET k_active` retunes every
//! stage of every group, and `STATS` blocks additionally carry one
//! `stage i: layers a..b … queued=…` line per stage (queue depth is the
//! pipeline-bubble indicator).

/// A parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// GEN <max_new> <prompt...>
    Gen { max_new: usize, prompt: String },
    /// SET k_active <n> — fleet-wide live compression retune.
    SetKActive(usize),
    /// SET balance <policy> — swap the router's placement policy live.
    SetBalance(String),
    Stats,
    Ping,
    Quit,
}

/// A structured protocol error: `code()` is the stable machine-readable
/// token on the `ERR` reply line, `Display` the human explanation.
#[derive(Clone, Debug, PartialEq)]
pub enum ProtoError {
    /// The line was empty (no verb).
    Empty,
    /// The verb is not part of the protocol.
    UnknownCommand(String),
    /// The verb is known but its arguments don't parse.
    BadArgs { verb: &'static str, expected: &'static str, got: String },
}

impl ProtoError {
    pub fn code(&self) -> &'static str {
        match self {
            ProtoError::Empty => "empty",
            ProtoError::UnknownCommand(_) => "unknown-command",
            ProtoError::BadArgs { .. } => "bad-args",
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Empty => write!(f, "empty command line"),
            ProtoError::UnknownCommand(verb) => write!(f, "unknown command '{verb}'"),
            ProtoError::BadArgs { verb, expected, got } => {
                write!(f, "{verb}: expected {expected}, got '{got}'")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

/// Parse one protocol line.
pub fn parse_line(line: &str) -> Result<Command, ProtoError> {
    let line = line.trim_end_matches(['\r', '\n']);
    let mut parts = line.splitn(2, ' ');
    let verb_raw = parts.next().unwrap_or("");
    let verb = verb_raw.to_ascii_uppercase();
    let rest = parts.next().unwrap_or("");
    match verb.as_str() {
        "" => Err(ProtoError::Empty),
        "GEN" => {
            let mut p = rest.splitn(2, ' ');
            let max_new: usize = p.next().unwrap_or("").parse().map_err(|_| {
                ProtoError::BadArgs {
                    verb: "GEN",
                    expected: "'<max_new_tokens> <prompt>'",
                    got: rest.to_string(),
                }
            })?;
            let prompt = p.next().unwrap_or("").to_string();
            if prompt.is_empty() {
                return Err(ProtoError::BadArgs {
                    verb: "GEN",
                    expected: "a non-empty prompt after <max_new_tokens>",
                    got: rest.to_string(),
                });
            }
            Ok(Command::Gen { max_new, prompt })
        }
        "SET" => {
            let mut p = rest.split_whitespace();
            match (p.next(), p.next()) {
                (Some("k_active"), Some(n)) => {
                    n.parse().map(Command::SetKActive).map_err(|_| ProtoError::BadArgs {
                        verb: "SET k_active",
                        expected: "a number",
                        got: n.to_string(),
                    })
                }
                (Some("balance"), Some(policy)) => Ok(Command::SetBalance(policy.to_string())),
                _ => Err(ProtoError::BadArgs {
                    verb: "SET",
                    expected: "'k_active <n>' or 'balance <policy>'",
                    got: rest.to_string(),
                }),
            }
        }
        "STATS" => Ok(Command::Stats),
        "PING" => Ok(Command::Ping),
        "QUIT" => Ok(Command::Quit),
        _ => Err(ProtoError::UnknownCommand(verb_raw.to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_gen() {
        assert_eq!(
            parse_line("GEN 32 the passkey is\n").unwrap(),
            Command::Gen { max_new: 32, prompt: "the passkey is".into() }
        );
    }

    #[test]
    fn parses_set_and_misc() {
        assert_eq!(parse_line("SET k_active 16").unwrap(), Command::SetKActive(16));
        assert_eq!(
            parse_line("SET balance mem-aware").unwrap(),
            Command::SetBalance("mem-aware".into())
        );
        assert_eq!(parse_line("stats").unwrap(), Command::Stats);
        assert_eq!(parse_line("PING").unwrap(), Command::Ping);
        assert_eq!(parse_line("QUIT\r\n").unwrap(), Command::Quit);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GEN").is_err());
        assert!(parse_line("GEN x y").is_err());
        assert!(parse_line("GEN 5 ").is_err());
        assert!(parse_line("SET foo 3").is_err());
        assert!(parse_line("NOPE").is_err());
    }

    #[test]
    fn errors_are_structured() {
        assert_eq!(parse_line("").unwrap_err().code(), "empty");
        assert_eq!(parse_line("NOPE 1 2").unwrap_err().code(), "unknown-command");
        // empty rest after SET is a bad-args error, not a verb mismatch
        let e = parse_line("SET").unwrap_err();
        assert_eq!(e.code(), "bad-args");
        assert!(e.to_string().contains("SET: expected"), "{e}");
        // GEN with a count but no prompt names the missing piece
        let e = parse_line("GEN 5 ").unwrap_err();
        assert_eq!(e.code(), "bad-args");
        assert!(e.to_string().contains("non-empty prompt"), "{e}");
        // the number that failed to parse is echoed back
        let e = parse_line("SET k_active lots").unwrap_err();
        assert!(e.to_string().contains("'lots'"), "{e}");
    }
}
