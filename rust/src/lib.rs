//! SWAN: Sparse Winnowed Attention — decompression-free KV-cache compression.
//!
//! This crate is the Layer-3 serving stack of the SWAN reproduction:
//!
//! * [`sparse`] / [`swan`] — the paper's core contribution: rotated,
//!   magnitude-winnowed sparse KV vectors, the hybrid (sparse + dense-buffer)
//!   cache of Algorithm 1, and attention computed *directly* on the
//!   compressed representation (no decompression step).
//! * [`kvcache`] — pluggable cache-compression policies: SWAN (16/8-bit),
//!   plus the baselines the paper compares against (dense, H2O heavy-hitter
//!   eviction, StreamingLLM sinks, KIVI-style quantization).
//! * [`model`] — a rust-native transformer (MHA/GQA + RoPE) that loads the
//!   JAX-trained `artifacts/weights_*.bin` and is golden-verified against
//!   the python model; used by the experiment harness.
//! * [`runtime`] — PJRT execution of the AOT HLO graphs lowered by
//!   `python/compile/aot.py` (the serving hot path; python never runs at
//!   request time).
//! * [`api`] — the typed request layer every serving path shares:
//!   builder-style [`api::GenParams`] (sampling knobs, a per-request
//!   compression override, streaming), token-event delivery via
//!   [`api::GenHandle`], and cooperative cancellation
//!   ([`api::CancelToken`]).
//! * [`coordinator`] / [`server`] — continuous batcher, prefill/decode
//!   scheduler, admission control and the runtime-tunable compression
//!   controller, plus the TCP front-end (wire protocol v2: keyword
//!   `GEN`, `TOK` streaming lines, `CANCEL`).
//! * [`shard`] — multi-shard serving: N engines on their own threads
//!   behind a request router with pluggable balance policies and
//!   fleet-wide live compression retuning; `--pipeline P` switches the
//!   fleet to layer-sharded pipeline groups (contiguous layer ranges per
//!   stage, batched cross-stage activation handoff) for models whose KV
//!   working set exceeds any single engine's budget.
//! * [`obs`] — dependency-free observability: an atomic counter/gauge
//!   registry with lock-free log2 latency histograms, per-request
//!   lifecycle traces, and the Prometheus text exposition behind the
//!   `METRICS` / `TRACE <id>` wire verbs.
//! * [`simd`] — runtime-dispatched kernel layer (scalar / AVX2+FMA,
//!   selected once at startup) behind every dense primitive and the
//!   sparse CSR walks; `--kernels auto|scalar|avx2` pins the path.
//! * [`eval`] / [`repro`] — the synthetic evaluation suite and one module
//!   per paper table/figure.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

// Lint posture: the numeric kernels deliberately use explicit index loops
// and wide argument lists so the layouts mirror the python/pallas
// reference implementations line for line.  These allows are crate-wide,
// which knowingly weakens CI's `clippy -D warnings` gate for the listed
// classes; once a toolchain-equipped session can run clippy, scope them
// down to the kernel modules that actually need each one.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::module_inception,
    clippy::manual_memcpy,
    clippy::large_enum_variant,
    clippy::type_complexity,
    clippy::ptr_arg
)]

pub mod api;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod kvcache;
pub mod model;
pub mod obs;
pub mod pool;
pub mod prefix;
pub mod repro;
pub mod runtime;
pub mod server;
pub mod shard;
pub mod simd;
pub mod sparse;
pub mod swan;
pub mod tensor;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T, E = anyhow::Error> = std::result::Result<T, E>;

/// Locate the artifacts directory: `$SWAN_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walking up from the current dir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("SWAN_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
