//! In-repo property-testing harness (proptest is unavailable offline).
//!
//! [`prop::check`] runs a property over `n` generated cases with
//! deterministic seeds and, on failure, performs greedy shrinking via the
//! case's [`prop::Shrink`] implementation before panicking with the
//! minimal counterexample.

pub mod prop;
