//! Summary statistics for the in-repo benchmark harness (criterion is not
//! available offline; `rust/benches/*` use this instead).

use std::time::{Duration, Instant};

/// Summary of a sample of measurements (nanoseconds).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Summary {
    pub fn from_ns(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean_ns: mean,
            median_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            min_ns: samples[0],
            max_ns: samples[n - 1],
            std_ns: var.sqrt(),
        }
    }

    /// Human-readable time with unit scaling.
    pub fn fmt_time(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<44} {:>12} {:>12} {:>12}  (n={})",
            Self::fmt_time(self.median_ns),
            Self::fmt_time(self.mean_ns),
            Self::fmt_time(self.p95_ns),
            self.n
        )
    }
}

/// Percentile of an ascending-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Benchmark runner: warms up, then measures `iters` runs of `f`.
/// Returns per-iteration timings.  `f` should include a `black_box` on its
/// result to defeat dead-code elimination.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::from_ns(samples)
}

/// Bench a batch-amortized operation: measures `batch` calls at a time to
/// keep fast ops above the timer resolution.
pub fn bench_batched<F: FnMut()>(warmup: usize, iters: usize, batch: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    Summary::from_ns(samples)
}

/// Wall-clock helper for throughput numbers.
pub fn time_it<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 30.0);
        assert_eq!(percentile(&v, 50.0), 15.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::from_ns(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!(s.mean_ns > s.median_ns);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0u64;
        let s = bench(2, 10, || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 12);
        assert_eq!(s.n, 10);
    }

    #[test]
    fn fmt_units() {
        assert!(Summary::fmt_time(500.0).contains("ns"));
        assert!(Summary::fmt_time(5_000.0).contains("µs"));
        assert!(Summary::fmt_time(5_000_000.0).contains("ms"));
        assert!(Summary::fmt_time(5e9).contains(" s"));
    }
}
