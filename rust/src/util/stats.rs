//! Summary statistics for the in-repo benchmark harness (criterion is not
//! available offline; `rust/benches/*` use this instead).

use std::time::{Duration, Instant};

/// Summary of a sample of measurements (nanoseconds).
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub std_ns: f64,
}

impl Summary {
    pub fn from_ns(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean_ns: mean,
            median_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            min_ns: samples[0],
            max_ns: samples[n - 1],
            std_ns: var.sqrt(),
        }
    }

    /// Human-readable time with unit scaling.
    pub fn fmt_time(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<44} {:>12} {:>12} {:>12}  (n={})",
            Self::fmt_time(self.median_ns),
            Self::fmt_time(self.mean_ns),
            Self::fmt_time(self.p95_ns),
            self.n
        )
    }
}

/// Percentile of an ascending-sorted sample (linear interpolation).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Benchmark runner: warms up, then measures `iters` runs of `f`.
/// Returns per-iteration timings.  `f` should include a `black_box` on its
/// result to defeat dead-code elimination.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::from_ns(samples)
}

/// Bench a batch-amortized operation: measures `batch` calls at a time to
/// keep fast ops above the timer resolution.
pub fn bench_batched<F: FnMut()>(warmup: usize, iters: usize, batch: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    Summary::from_ns(samples)
}

/// Wall-clock helper for throughput numbers.
pub fn time_it<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Accumulating machine-readable bench report (`BENCH_kernels.json` and
/// friends): a flat two-level JSON object `{section: {key: value}}` that
/// independent bench binaries merge into, so the perf trajectory of each
/// kernel path is trackable across PRs.  Existing content at `path` is
/// preserved; same keys overwrite.
pub struct BenchReport {
    path: std::path::PathBuf,
    root: crate::util::json::Json,
}

impl BenchReport {
    /// Open (or create) the report at `path`, merging into any existing
    /// valid JSON object there.
    pub fn open(path: &str) -> BenchReport {
        use crate::util::json::Json;
        let path = std::path::PathBuf::from(path);
        let root = std::fs::read_to_string(&path)
            .ok()
            .and_then(|s| Json::parse(&s).ok())
            .filter(|j| matches!(j, Json::Obj(_)))
            .unwrap_or_else(|| Json::Obj(std::collections::BTreeMap::new()));
        BenchReport { path, root }
    }

    fn section_mut(
        &mut self,
        section: &str,
    ) -> &mut std::collections::BTreeMap<String, crate::util::json::Json> {
        use crate::util::json::Json;
        let root = match &mut self.root {
            Json::Obj(m) => m,
            _ => unreachable!("root is always an object"),
        };
        let entry = root
            .entry(section.to_string())
            .or_insert_with(|| Json::Obj(std::collections::BTreeMap::new()));
        if !matches!(entry, Json::Obj(_)) {
            *entry = Json::Obj(std::collections::BTreeMap::new());
        }
        match entry {
            Json::Obj(m) => m,
            _ => unreachable!(),
        }
    }

    /// Set `section.key` to a numeric value.
    pub fn set(&mut self, section: &str, key: &str, value: f64) {
        self.section_mut(section)
            .insert(key.to_string(), crate::util::json::Json::Num(value));
    }

    /// Set `section.key` to a string value.
    pub fn set_str(&mut self, section: &str, key: &str, value: &str) {
        self.section_mut(section)
            .insert(key.to_string(), crate::util::json::Json::Str(value.to_string()));
    }

    /// Write the report back to its path (compact JSON + newline).
    pub fn save(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, format!("{}\n", self.root))
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 100.0), 30.0);
        assert_eq!(percentile(&v, 50.0), 15.0);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::from_ns(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!(s.mean_ns > s.median_ns);
    }

    #[test]
    fn bench_runs() {
        let mut count = 0u64;
        let s = bench(2, 10, || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 12);
        assert_eq!(s.n, 10);
    }

    #[test]
    fn fmt_units() {
        assert!(Summary::fmt_time(500.0).contains("ns"));
        assert!(Summary::fmt_time(5_000.0).contains("µs"));
        assert!(Summary::fmt_time(5_000_000.0).contains("ms"));
        assert!(Summary::fmt_time(5e9).contains(" s"));
    }

    #[test]
    fn bench_report_merges_sections_across_opens() {
        let path = std::env::temp_dir().join(format!(
            "swan_bench_report_test_{}.json",
            std::process::id()
        ));
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);

        let mut r1 = BenchReport::open(&path_s);
        r1.set("sparse_dot", "scalar_k32_ns", 120.5);
        r1.set_str("sparse_dot", "host", "test");
        r1.save().unwrap();

        // a second bench binary opens the same file and adds its section
        let mut r2 = BenchReport::open(&path_s);
        r2.set("decode_throughput", "scalar_batch4_tps", 1000.0);
        r2.set("sparse_dot", "scalar_k32_ns", 99.0); // overwrite
        r2.save().unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(
            j.get("sparse_dot").and_then(|s| s.get("scalar_k32_ns")).and_then(|v| v.as_f64()),
            Some(99.0)
        );
        assert_eq!(
            j.get("sparse_dot").and_then(|s| s.get("host")).and_then(|v| v.as_str()),
            Some("test")
        );
        assert!(j.get("decode_throughput").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
