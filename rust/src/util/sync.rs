//! Poison-recovering synchronization helpers.
//!
//! Since PR 8 every shard runs supervised: a panic on a shard thread is
//! caught, converted into a [`crate::shard::FleetEvent::ShardDead`] event,
//! and the shard's work is recovered by exact replay.  That contract makes
//! mutex poisoning *pure noise*: the panic that poisoned the lock has
//! already been handled by the supervisor, and the data under the lock is
//! either (a) fleet bookkeeping that the recovery path re-derives (router
//! shard lists, balance policy state, obs series, pool free lists) or
//! (b) per-connection plumbing whose owner is about to observe the failure
//! through its channel anyway.  Propagating the `PoisonError` as a second
//! panic would cascade one shard death into the death of every thread that
//! shares fleet state with it — exactly what the supervisor exists to
//! prevent.
//!
//! These helpers therefore take the other branch: recover the guard via
//! [`std::sync::PoisonError::into_inner`] and carry on.  They generalize
//! the one-off fix PR 8 landed in `ShardHandle::send`, and the
//! `swan-lint` `lock_unwrap` rule (see `rust/lint`) keeps the tree free of
//! new `.lock().unwrap()` sites so the recovery discipline cannot rot.
//!
//! Every call site must still be written so the invariants of the guarded
//! data hold at each `unlock` — recovery is sound only because critical
//! sections in this codebase restore invariants before any early return
//! and never unwind mid-update with the structure torn (the chaos suite
//! exercises exactly this: kills mid-flight, then keeps serving).

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Read-lock `l`, recovering the guard if a previous writer panicked.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Write-lock `l`, recovering the guard if a previous writer panicked.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Block on `cv`, recovering the re-acquired guard if the mutex was
/// poisoned while this thread slept.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    fn poison_mutex(m: &Arc<Mutex<i32>>) {
        let m = Arc::clone(m);
        let _ = std::thread::spawn(move || {
            let _g = m.lock().unwrap();
            panic!("poison it");
        })
        .join();
    }

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7));
        poison_mutex(&m);
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn rwlock_recover_survives_poison() {
        let l = Arc::new(RwLock::new(3));
        {
            let l = Arc::clone(&l);
            let _ = std::thread::spawn(move || {
                let _g = l.write().unwrap();
                panic!("poison it");
            })
            .join();
        }
        assert_eq!(*read_recover(&l), 3);
        *write_recover(&l) = 4;
        assert_eq!(*read_recover(&l), 4);
    }

    #[test]
    fn wait_recover_wakes_after_poisoning_holder() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let waiter = {
            let pair = Arc::clone(&pair);
            std::thread::spawn(move || {
                let (m, cv) = (&pair.0, &pair.1);
                let mut done = lock_recover(m);
                while !*done {
                    done = wait_recover(cv, done);
                }
            })
        };
        // Poison the mutex, then still complete the handshake: set the
        // flag through the recovering lock and wake the waiter.
        {
            let pair = Arc::clone(&pair);
            let _ = std::thread::spawn(move || {
                let _g = pair.0.lock().unwrap();
                panic!("poison it");
            })
            .join();
        }
        *lock_recover(&pair.0) = true;
        pair.1.notify_all();
        waiter.join().unwrap();
    }
}
